from .sharding import (AxisRules, constrain, multi_pod_rules,
                       named_sharding, single_pod_rules, smoke_rules,
                       tree_shardings, use_rules)
from .pipeline import PipelineExecutor, Stage, StageTiming
from .elastic import ElasticController, PlanEvent, frontier_shift
from .ft import (HeartbeatRegistry, ShardAssignment, StragglerDetector,
                 TrainSupervisor)

__all__ = ["AxisRules", "constrain", "multi_pod_rules", "named_sharding",
           "single_pod_rules", "smoke_rules", "tree_shardings", "use_rules",
           "PipelineExecutor", "Stage", "StageTiming", "ElasticController",
           "PlanEvent", "frontier_shift",
           "HeartbeatRegistry", "ShardAssignment",
           "StragglerDetector", "TrainSupervisor"]
