"""Partition-driven pipeline executor.

Executes a Scission :class:`PartitionConfig` — each segment's blocks run as
one jit-compiled stage on its assigned resource, activations crossing
between stages exactly at the chosen cut points.  On a real deployment each
stage lives on a different machine/mesh; here every stage is a separate
XLA executable and the inter-stage handoff goes through host memory
(the same path a WAN hop would take), with the simulated link cost
accounted by the latency model.

This is deliverable (b)'s end-to-end inference driver substrate and the
runtime counterpart of core/partition.py.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import numpy as np

from repro.core.graph import Block, LayerGraph, fuse_block_dag, fuse_blocks
from repro.core.network import NetworkModel
from repro.core.partition import PartitionConfig


@dataclass
class Stage:
    resource: str
    start: int
    end: int
    fn: Callable[[Any], Any]


@dataclass
class StageTiming:
    resource: str
    compute_s: float
    comm_in_s: float
    bytes_in: int


class PipelineExecutor:
    """Compile-once, run-many executor for one (graph, partition)."""

    def __init__(self, graph: LayerGraph, config: PartitionConfig,
                 network: NetworkModel | None = None, source: str = "device"):
        self.graph = graph
        self.config = config
        self.network = network
        self.source = source
        blocks = fuse_blocks(graph)
        self.stages: list[Stage] = []
        for seg in config.segments:
            fns = [blocks[i].make_callable()
                   for i in range(seg.start, seg.end + 1)]

            def stage_fn(x, fns=tuple(fns)):
                for f in fns:
                    x = f(x)
                return x

            self.stages.append(Stage(seg.resource, seg.start, seg.end,
                                     jax.jit(stage_fn)))

    def run(self, x, collect_timing: bool = False):
        """Run input through all stages.  Returns (y, [StageTiming])."""
        timings: list[StageTiming] = []
        prev_loc = self.source
        for st in self.stages:
            nbytes = int(np.asarray(x).nbytes)
            comm = (self.network.comm_time(prev_loc, st.resource, nbytes)
                    if self.network and prev_loc != st.resource else 0.0)
            # host round-trip at the tier boundary (the WAN hop's data path)
            x = np.asarray(x)
            t0 = time.perf_counter()
            y = st.fn(x)
            jax.block_until_ready(y)
            dt = time.perf_counter() - t0
            if collect_timing:
                timings.append(StageTiming(st.resource, dt, comm, nbytes))
            x = y
            prev_loc = st.resource
        return x, timings

    def simulated_latency(self, timings: list[StageTiming],
                          speed_factors: dict[str, float]) -> float:
        """End-to-end latency under the emulated tier speeds + links."""
        total = 0.0
        for t in timings:
            total += t.compute_s * speed_factors.get(t.resource, 1.0)
            total += t.comm_in_s
        return total


@dataclass
class BlockTiming:
    """Per-block measurement of one :class:`DagPipelineExecutor.run`.

    ``comm_in_s`` carries one entry per incoming block edge (entry order;
    block 0's single entry is the source input hop) — zero for edges whose
    endpoints share a resource."""

    block: int
    resource: str
    compute_s: float
    comm_in_s: tuple[float, ...]
    bytes_in: int


class DagPipelineExecutor:
    """Compile-once, run-many executor for one (graph, DAG partition).

    The DAG counterpart of :class:`PipelineExecutor`: the graph is fused
    with :func:`fuse_block_dag` (parallel regions survive as block-level
    branches), each block compiles to its own XLA executable, and execution
    walks blocks in topological order keeping every produced activation
    until its consumers have run.  Blocks on *parallel branches* are
    dispatched without an intervening ``block_until_ready`` — XLA's async
    dispatch overlaps them — and the join block's callable takes one
    argument per incoming branch.  Activations crossing between resources
    take the host round-trip (the WAN hop's data path), one per crossing
    edge, with the link cost accounted by the latency model.

    ``config`` may be a :class:`DagPartitionConfig` (``assignment`` names a
    resource per block) or any chain :class:`PartitionConfig` whose
    segments cover the fused block count — the chain form of the same
    contract.
    """

    def __init__(self, graph: LayerGraph, config: PartitionConfig,
                 network: NetworkModel | None = None, source: str = "device"):
        self.graph = graph
        self.config = config
        self.network = network
        self.source = source
        dag = fuse_block_dag(graph)
        assignment = tuple(getattr(config, "assignment", ()))
        if not assignment:
            assignment = tuple(
                seg.resource for seg in config.segments
                for _ in range(seg.start, seg.end + 1))
        if len(assignment) != len(dag):
            raise ValueError(
                f"partition names {len(assignment)} blocks but the graph "
                f"fuses into {len(dag)} DAG blocks")
        self.dag = dag
        self.assignment = assignment
        self.fns = [jax.jit(b.make_callable()) for b in dag]
        # producing block per entry tensor, in each block's entry order
        owner: dict[int, int] = {}
        for blk in dag:
            for n in blk.node_ids:
                owner[n] = blk.index
        self.entry_blocks: list[list[int]] = []
        for blk in dag:
            ebs = []
            for e in blk.entry_nodes:
                pb = owner[e]
                if dag[pb].node_ids[-1] != e:
                    raise ValueError(
                        f"block {blk.index} consumes node {e}, which is not "
                        f"block {pb}'s output tensor — invalid block DAG")
                ebs.append(pb)
            self.entry_blocks.append(ebs)

    def run(self, x, collect_timing: bool = False):
        """Run input through the block DAG.  Returns (y, [BlockTiming]).

        Without timing collection, blocks are dispatched eagerly (parallel
        branches overlap under async dispatch) and only the final output is
        waited on; with it, each block is timed individually.
        """
        timings: list[BlockTiming] = []
        outs: list[Any] = [None] * len(self.dag)
        for b, blk in enumerate(self.dag):
            comms: list[float] = []
            bytes_in = 0
            if not self.entry_blocks[b]:
                xi = np.asarray(x)
                nbytes = int(xi.nbytes)
                bytes_in = nbytes
                if self.network and self.assignment[b] != self.source:
                    comms.append(self.network.comm_time(
                        self.source, self.assignment[b], nbytes))
                xs = [xi]
            else:
                xs = []
                for pb in self.entry_blocks[b]:
                    xp = outs[pb]
                    if self.assignment[pb] != self.assignment[b]:
                        # host round-trip at the tier boundary
                        xp = np.asarray(xp)
                        nbytes = int(xp.nbytes)
                        bytes_in += nbytes
                        if self.network:
                            comms.append(self.network.comm_time(
                                self.assignment[pb], self.assignment[b],
                                nbytes))
                    xs.append(xp)
            if collect_timing:
                for xv in xs:
                    jax.block_until_ready(xv)
                t0 = time.perf_counter()
                y = self.fns[b](*xs)
                jax.block_until_ready(y)
                timings.append(BlockTiming(
                    b, self.assignment[b], time.perf_counter() - t0,
                    tuple(comms), bytes_in))
            else:
                y = self.fns[b](*xs)
            outs[b] = y
        y = outs[len(self.dag) - 1]
        jax.block_until_ready(y)
        return y, timings

    def simulated_latency(self, timings: list[BlockTiming],
                          speed_factors: dict[str, float]) -> float:
        """Critical-path latency under the emulated tier speeds + links:
        ``finish(b) = max over incoming edges(finish(pred) + link) +
        compute * speed`` — parallel branches overlap, exactly the DAG cost
        model's latency composition."""
        finish: dict[int, float] = {}
        for t in timings:
            arrive = 0.0
            ebs = self.entry_blocks[t.block]
            if not ebs:
                arrive = sum(t.comm_in_s)          # the source input hop
            else:
                ci = iter(t.comm_in_s)
                for pb in ebs:
                    c = next(ci) if self.assignment[pb] != t.resource else 0.0
                    arrive = max(arrive, finish[pb] + c)
            finish[t.block] = arrive + \
                t.compute_s * speed_factors.get(t.resource, 1.0)
        return finish[max(finish)] if finish else 0.0
