"""Partition-driven pipeline executor.

Executes a Scission :class:`PartitionConfig` — each segment's blocks run as
one jit-compiled stage on its assigned resource, activations crossing
between stages exactly at the chosen cut points.  On a real deployment each
stage lives on a different machine/mesh; here every stage is a separate
XLA executable and the inter-stage handoff goes through host memory
(the same path a WAN hop would take), with the simulated link cost
accounted by the latency model.

This is deliverable (b)'s end-to-end inference driver substrate and the
runtime counterpart of core/partition.py.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import numpy as np

from repro.core.graph import Block, LayerGraph, fuse_blocks
from repro.core.network import NetworkModel
from repro.core.partition import PartitionConfig


@dataclass
class Stage:
    resource: str
    start: int
    end: int
    fn: Callable[[Any], Any]


@dataclass
class StageTiming:
    resource: str
    compute_s: float
    comm_in_s: float
    bytes_in: int


class PipelineExecutor:
    """Compile-once, run-many executor for one (graph, partition)."""

    def __init__(self, graph: LayerGraph, config: PartitionConfig,
                 network: NetworkModel | None = None, source: str = "device"):
        self.graph = graph
        self.config = config
        self.network = network
        self.source = source
        blocks = fuse_blocks(graph)
        self.stages: list[Stage] = []
        for seg in config.segments:
            fns = [blocks[i].make_callable()
                   for i in range(seg.start, seg.end + 1)]

            def stage_fn(x, fns=tuple(fns)):
                for f in fns:
                    x = f(x)
                return x

            self.stages.append(Stage(seg.resource, seg.start, seg.end,
                                     jax.jit(stage_fn)))

    def run(self, x, collect_timing: bool = False):
        """Run input through all stages.  Returns (y, [StageTiming])."""
        timings: list[StageTiming] = []
        prev_loc = self.source
        for st in self.stages:
            nbytes = int(np.asarray(x).nbytes)
            comm = (self.network.comm_time(prev_loc, st.resource, nbytes)
                    if self.network and prev_loc != st.resource else 0.0)
            # host round-trip at the tier boundary (the WAN hop's data path)
            x = np.asarray(x)
            t0 = time.perf_counter()
            y = st.fn(x)
            jax.block_until_ready(y)
            dt = time.perf_counter() - t0
            if collect_timing:
                timings.append(StageTiming(st.resource, dt, comm, nbytes))
            x = y
            prev_loc = st.resource
        return x, timings

    def simulated_latency(self, timings: list[StageTiming],
                          speed_factors: dict[str, float]) -> float:
        """End-to-end latency under the emulated tier speeds + links."""
        total = 0.0
        for t in timings:
            total += t.compute_s * speed_factors.get(t.resource, 1.0)
            total += t.comm_in_s
        return total
