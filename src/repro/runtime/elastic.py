"""Elastic re-planning — the paper's 'operational change' scenario, automated.

Scission §II-B(vi): when bandwidth shifts, a resource is drained for
maintenance, or a node fails, the deployment must re-partition quickly.
Because benchmark data is cached per (block, resource), re-planning is a
pure query (<50 ms budget) — no re-benchmarking, no re-compile of
unaffected stages.

:class:`ElasticController` watches a resource-membership view and emits a
new :class:`PartitionConfig` whenever the view or the network model changes.
The same mechanism serves fleet-scale elasticity: scaling the 'cloud' tier
from one pod to two is just a resource swap ('pod_v5e256' -> a 512-chip
aggregate) followed by a re-query.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field, replace as _dc_replace
from typing import Callable

from repro.core.network import NetworkModel
from repro.core.partition import (PartitionConfig, objective_vector,
                                  pareto_frontier, trim_replicas)
from repro.core.planner import Scission
from repro.core.query import Query
from repro.core.resources import Resource


@dataclass
class PlanEvent:
    reason: str
    wall_time: float
    plan_time_s: float
    config: PartitionConfig
    # the whole trade-off surface at plan time (controller frontier mode):
    # the Pareto non-dominated set over (latency, throughput, transfer),
    # so operational changes can report how the surface moved, not just
    # which single winner was picked
    frontier: list[PartitionConfig] | None = None

    # both serving metrics are exposed per event so operators can audit the
    # latency/throughput trade-off across re-plans regardless of which
    # objective drove the query
    @property
    def latency_s(self) -> float:
        return self.config.latency_s

    @property
    def throughput_rps(self) -> float:
        return self.config.throughput_rps

    @property
    def operating_point(self) -> tuple[int, tuple[int, ...]]:
        """(batch size, per-stage replicas) the plan was priced at — lets
        operators audit that re-plans preserved the serving operating
        point."""
        return (self.config.batch_size, self.config.replicas)

    @property
    def frontier_size(self) -> int:
        return len(self.frontier or ())


def frontier_shift(before: list[PartitionConfig] | None,
                   after: list[PartitionConfig] | None) -> dict:
    """How the Pareto surface moved between two plans, as objective-vector
    sets ``(latency_s, bottleneck_s, transfer_bytes)``: points ``added`` to
    the frontier, ``removed`` from it, and ``kept`` unchanged.  Vectors are
    exact-comparable across re-plans because every plan prices from the
    same cached benchmark records — only membership changes."""
    bv = {objective_vector(c) for c in (before or ())}
    av = {objective_vector(c) for c in (after or ())}
    return {"added": sorted(av - bv), "removed": sorted(bv - av),
            "kept": sorted(av & bv)}


class ElasticController:
    """Re-plans on membership/network changes, preserving the active
    operating point: every re-plan reuses the controller's query, so its
    batch size and replica budget (and with them the serving engine's
    admission width) survive resource loss, join, and bandwidth shifts.

    With ``track_frontier=True`` every re-plan extracts the Pareto
    frontier over (latency, throughput, transfer) at the new
    membership/network state, stores it on the :class:`PlanEvent`, and
    derives the plan's config **from the frontier** — the objective-best
    point is on the surface by construction (for any non-negative-weight
    objective, a dominated config never scores strictly better than all
    of its dominators), so a frontier-mode re-plan costs a single solve
    instead of a full ``query()`` followed by a full ``frontier()``.
    Unless ``Query.batch_sizes`` is set explicitly, the re-plan sweep is
    pinned to ``Query.batch_size`` so the active operating point is
    preserved across re-plans exactly like the non-frontier mode (the
    derived config's replicas are trimmed to the minimum achieving its
    bottleneck, which leaves the rate unchanged).  An explicit
    ``Query(batch_sizes=...)`` opts into tracking a wider surface, and
    then the derived config is the objective-best point across that sweep
    — its batch may move when a better operating point appears.

    ``warm_start=True`` (default) re-seeds each frontier-mode re-plan
    with the previous surface's still-valid points
    (:meth:`_warm_start_candidates`): points whose resources survived the
    membership change are re-priced against the current engine and merged
    into the new surface.  At ``frontier_epsilon == 0`` the merge cannot
    change the (already exact) result; with ε > 0 it pins previously
    discovered exact points so a re-plan's approximate surface never
    loses coverage on the unchanged part of the space.

    ``incremental=True`` (default, frontier mode only) additionally keeps
    the solver's final **label arrays** (one :class:`LabelState` per
    swept batch size) between re-plans and hands them back to
    :meth:`QueryEngine.frontier_incremental` on the next membership
    change: a departed resource invalidates only the labels whose paths
    touched it (the DP replays its untouched prefix and re-runs from the
    first affected block), a joined resource generates only the delta
    paths that visit it.  Labels price link costs, so a network change
    drops the kept states and re-plans cold; every unsound-reuse case
    falls back to a cold solve inside the engine, keeping re-plans exact.
    """

    def __init__(self, scission: Scission, model: str,
                 input_bytes: float = 150e3, query: Query | None = None,
                 graph=None, track_frontier: bool = False,
                 warm_start: bool = True, incremental: bool = True):
        self.scission = scission
        self.model = model
        self.input_bytes = input_bytes
        self.query = query or Query(top_n=1)
        self.graph = graph            # for incremental benchmarking on join
        self.track_frontier = track_frontier
        self.warm_start = warm_start
        self.incremental = incremental
        # per-batch final label arrays of the last frontier-mode re-plan;
        # valid across membership changes only (network changes clear it)
        self._label_states: dict = {}
        self.history: list[PlanEvent] = []
        self._listeners: list = []
        self._replan("initial")

    def add_listener(self, fn) -> None:
        """Register a callable invoked with every :class:`PlanEvent` this
        controller produces from now on (e.g. a serving
        :meth:`~repro.serving.router.Router.on_plan`, which swaps its
        operating point live when the plan changes).  Listeners do not see
        plans that predate registration — push ``controller.current``
        yourself if the subscriber needs the standing plan."""
        self._listeners.append(fn)

    @property
    def current(self) -> PartitionConfig:
        return self.history[-1].config

    def _last_frontier(self) -> list[PartitionConfig] | None:
        for ev in reversed(self.history):
            if ev.frontier is not None:
                return ev.frontier
        return None

    def _warm_start_candidates(self, prev: list[PartitionConfig]
                               ) -> list[PartitionConfig]:
        """Previous-frontier points that remain valid under the current
        membership and constraints, re-priced against the current engine
        (bandwidth may have shifted, so costs are recomputed; only the
        *shape* — segments, batch size — is reused)."""
        eng = self.scission.engine(self.model, self.input_bytes)
        cons = self.query.constraints()
        names = {r.name for r in self.scission.resources}
        out: list[PartitionConfig] = []
        for cfg in prev:
            if not set(cfg.resources) <= names:
                continue              # a member resource left the fleet
            try:
                cost = eng._cost_for(
                    _dc_replace(self.query, batch_size=cfg.batch_size))
            except ValueError:
                continue              # batch no longer measurable
            cfg2 = cost.evaluate(cfg.segments)
            if eng._config_satisfies(cfg2, cons, cost):
                out.append(trim_replicas(cfg2))
        return out

    def _replan(self, reason: str) -> PlanEvent:
        t0 = time.perf_counter()
        if self.track_frontier:
            # one solve: the frontier carries the objective-best point, so
            # no separate query() pass is needed.  Pin the sweep to the
            # query's batch size (unless the caller asked for a wider one)
            # so the active operating point survives re-plans.
            fq = self.query if self.query.batch_sizes is not None else \
                _dc_replace(self.query,
                            batch_sizes=(self.query.batch_size,))
            prev = self._last_frontier() if self.warm_start else None
            if self.incremental:
                # labels price link latency/bandwidth, so only membership
                # changes may reuse them — a network change solves cold
                eng = self.scission.engine(self.model, self.input_bytes)
                held = None if reason == "network-change" \
                    else self._label_states
                res, self._label_states = eng.frontier_incremental(fq, held)
                front = res.configs
            else:
                front = self.scission.frontier(self.model, fq,
                                               self.input_bytes).configs
            if prev:
                merged = {(c.segments, c.batch_size, c.replicas): c
                          for c in (*front,
                                    *self._warm_start_candidates(prev))}
                front = pareto_frontier(list(merged.values()))
                front.sort(key=lambda c: (c.latency_s, c.bottleneck_s,
                                          c.transfer_bytes))
            if not front:
                raise ValueError(
                    f"re-plan ({reason}) found no feasible configuration "
                    f"for model {self.model!r} under the current "
                    "membership and constraints")
            score = self.query.objective.score
            config = min(front, key=lambda c: (score(c),
                                               objective_vector(c)))
        else:
            res = self.scission.query(self.model, self.query,
                                      self.input_bytes)
            front = None
            config = res.best
        ev = PlanEvent(reason=reason, wall_time=time.time(),
                       plan_time_s=time.perf_counter() - t0,
                       config=config, frontier=front)
        self.history.append(ev)
        for fn in self._listeners:
            fn(ev)
        return ev

    def last_frontier_shift(self) -> dict | None:
        """Frontier movement between the two most recent frontier-carrying
        plans (None until two such plans exist — requires frontier mode)."""
        evs = [e for e in self.history if e.frontier is not None]
        if len(evs) < 2:
            return None
        return frontier_shift(evs[-2].frontier, evs[-1].frontier)

    # -- operational changes --------------------------------------------------
    def on_resource_lost(self, name: str) -> PlanEvent:
        """Node failure / maintenance drain: drop the resource, re-query.

        The query — and with it the active operating point (batch size and
        replica budget) — is preserved untouched.  A budget entry for the
        lost resource is inert while it is gone (only resources that appear
        in a plan's segments are consulted) and becomes active again if the
        resource rejoins, so a lose/rejoin cycle restores the original
        operating point.
        """
        remaining = [r for r in self.scission.resources if r.name != name]
        self.scission = self.scission.with_resources(remaining)
        return self._replan(f"lost:{name}")

    def on_resource_joined(self, resource: Resource) -> PlanEvent:
        """Elastic scale-up: Scission Step 3 runs incrementally for the new
        resource only (existing records are reused), then a re-query.

        Fails fast — *before* mutating the membership view — when the new
        resource has no benchmark records and no graph is available for
        incremental benchmarking; admitting it would make the very next
        re-plan die inside ``times_matrix``.
        """
        db = self.scission._dbs.get(self.model)
        if self.graph is None and \
                (db is None or resource.name not in db.records):
            raise ValueError(
                f"cannot admit resource {resource.name!r}: model "
                f"{self.model!r} has no benchmark records for it and the "
                "controller was built without graph=..., so incremental "
                "benchmarking is impossible.  Pass graph= at construction "
                "or call Scission.benchmark_resource() before joining.")
        # benchmark BEFORE mutating membership so a provider failure
        # (compile error, OOM on the new resource) leaves the controller
        # in a consistent, re-plannable state
        if self.graph is not None:
            self.scission.benchmark_resource(self.graph, resource)
        self.scission.resources = [*self.scission.resources, resource]
        self.scission._engines.clear()
        return self._replan(f"joined:{resource.name}")

    def on_network_change(self, network: NetworkModel) -> PlanEvent:
        """Bandwidth shift (the drone-leaves-low-coverage case)."""
        old = self.scission
        self.scission = Scission(
            resources=old.resources, network=network, source=old.source,
            provider=old.provider, runs=old.runs)
        # carry cached benchmark DBs — they are network-independent
        for db in old._dbs.values():
            self.scission.load(db)
        return self._replan("network-change")
