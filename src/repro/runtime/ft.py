"""Fault tolerance: checkpoint/restart, straggler mitigation, failure
simulation hooks for the training loop.

Design for 1000+ nodes (DESIGN.md §5):

* **Checkpoint/restart** — CheckpointManager (async, atomic) + the
  deterministic data pipeline (step-indexed) make restart a pure function
  of the last checkpoint step; no iterator state, no host-count coupling.
* **Straggler mitigation** — per-step wall-time watermarking with a robust
  (median + MAD) threshold; hosts flagged as stragglers get their DP shard
  reassigned by rebuilding the device->shard map (on TPU fleets slow hosts
  are usually sick hosts).  The detector is runnable anywhere; the
  reassignment is exercised in simulation in tests.
* **Failure detection** — heartbeat registry with a pluggable clock; a
  missed deadline triggers the elastic re-plan (runtime/elastic.py), which
  is a Scission re-query over cached benchmark data.
"""

from __future__ import annotations

import statistics
import time
from dataclasses import dataclass, field
from typing import Callable


@dataclass
class StepRecord:
    step: int
    host: int
    wall_s: float


class StragglerDetector:
    """Flags hosts whose recent step times exceed median + k·MAD."""

    def __init__(self, window: int = 16, k: float = 6.0):
        self.window = window
        self.k = k
        self._times: dict[int, list[float]] = {}

    def record(self, host: int, wall_s: float) -> None:
        ts = self._times.setdefault(host, [])
        ts.append(wall_s)
        del ts[:-self.window]

    def stragglers(self) -> list[int]:
        if len(self._times) < 2:
            return []
        medians = {h: statistics.median(ts)
                   for h, ts in self._times.items() if ts}
        overall = statistics.median(medians.values())
        mad = statistics.median(
            abs(m - overall) for m in medians.values()) or 1e-6
        return [h for h, m in medians.items()
                if m > overall + self.k * mad]


class HeartbeatRegistry:
    """Deadline-based liveness; `now` injectable for tests."""

    def __init__(self, timeout_s: float = 60.0,
                 now: Callable[[], float] = time.monotonic):
        self.timeout_s = timeout_s
        self.now = now
        self._last: dict[str, float] = {}

    def beat(self, member: str) -> None:
        self._last[member] = self.now()

    def dead(self) -> list[str]:
        t = self.now()
        return [m for m, last in self._last.items()
                if t - last > self.timeout_s]

    def members(self) -> list[str]:
        return sorted(self._last)


@dataclass
class ShardAssignment:
    """host -> list of DP shard indices; rebuilt when membership changes."""

    n_shards: int
    hosts: list[int]
    assignment: dict[int, list[int]] = field(default_factory=dict)

    def __post_init__(self):
        self.rebalance(self.hosts)

    def rebalance(self, hosts: list[int]) -> dict[int, list[int]]:
        hosts = sorted(hosts)
        assert hosts, "no hosts left"
        self.assignment = {h: [] for h in hosts}
        for s in range(self.n_shards):
            self.assignment[hosts[s % len(hosts)]].append(s)
        self.hosts = hosts
        return self.assignment

    def drop_host(self, host: int) -> dict[int, list[int]]:
        return self.rebalance([h for h in self.hosts if h != host])


class TrainSupervisor:
    """Glues detector + heartbeat + checkpointing around a step function.

    Used by launch/train.py; failure injection in tests drives the same
    code paths a real fleet controller would take.
    """

    def __init__(self, ckpt_manager, detector: StragglerDetector | None = None,
                 heartbeat: HeartbeatRegistry | None = None,
                 ckpt_every: int = 100):
        self.ckpt = ckpt_manager
        self.detector = detector or StragglerDetector()
        self.heartbeat = heartbeat or HeartbeatRegistry()
        self.ckpt_every = ckpt_every
        self.events: list[str] = []

    def resume_or_init(self, init_fn: Callable[[], tuple], like=None):
        restored = self.ckpt.restore_latest(like) if like is not None else None
        if restored is None:
            state = init_fn()
            return state, 0
        tree, step, _ = restored
        self.events.append(f"resumed@{step}")
        return tree, step

    def after_step(self, step: int, state, wall_s: float, host: int = 0):
        self.detector.record(host, wall_s)
        self.heartbeat.beat(f"host{host}")
        if self.ckpt_every and step > 0 and step % self.ckpt_every == 0:
            self.ckpt.save(step, state)
            self.events.append(f"ckpt@{step}")
        s = self.detector.stragglers()
        if s:
            self.events.append(f"stragglers@{step}:{s}")
        return s
