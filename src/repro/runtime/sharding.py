"""Logical-axis sharding rules (DP/FSDP/TP/SP/EP) for the model zoo.

Model code annotates arrays with *logical* axes ("act_batch", "act_seq",
"heads", "ff", ...).  A :class:`AxisRules` table maps logical axes to mesh
axes; ``constrain`` applies ``with_sharding_constraint`` when a mesh is
active and is a no-op otherwise (so the same model code runs in single-device
smoke tests and in the 512-chip dry-run).

Default production rules implement:

* DP    — batch over ``data`` (and ``pod`` when present),
* FSDP  — parameter ``embed`` dim over ``data`` (ZeRO-3 style: XLA
          all-gathers weights per layer inside the scan body),
* TP    — ``heads`` / ``ff`` / ``vocab`` / ``experts`` over ``model``,
* SP    — inter-block activation ``act_seq`` over ``model`` (sequence
          parallelism; attention/mixer internally re-gathers),
* EP    — MoE ``experts`` over ``model``.
"""

from __future__ import annotations

import threading
from dataclasses import dataclass, field, replace

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclass(frozen=True)
class AxisRules:
    rules: dict[str, tuple[str, ...] | None] = field(default_factory=dict)

    def spec(self, logical_axes: tuple[str | None, ...]) -> P:
        parts = []
        for ax in logical_axes:
            m = self.rules.get(ax) if ax is not None else None
            if m is None:
                parts.append(None)
            elif len(m) == 1:
                parts.append(m[0])
            else:
                parts.append(tuple(m))
        while parts and parts[-1] is None:
            parts.pop()
        return P(*parts)

    def with_overrides(self, **kv) -> "AxisRules":
        new = dict(self.rules)
        for k, v in kv.items():
            new[k] = v
        return AxisRules(new)


def single_pod_rules() -> AxisRules:
    return AxisRules({
        # activations
        "act_batch": ("data",),
        "act_seq": ("model",),       # SP between blocks
        "act_embed": None,
        "act_heads": ("model",),     # TP inside attention
        "act_ff": ("model",),
        "act_vocab": ("model",),
        "act_experts": ("model",),
        "act_kv_seq": ("model",),    # flash-decoding style KV split
        "act_kv_seq_full": ("data", "model"),  # batch=1 long-context decode
        # parameters
        "embed": ("data",),          # FSDP
        "heads": ("model",),
        "kv_heads": ("model",),
        "ff": ("model",),
        "vocab": ("model",),
        "experts": ("model",),
        "layers": None,
        "head_dim": None,
        "state": None,
        "conv": None,
        "unsharded": None,
    })


def multi_pod_rules() -> AxisRules:
    r = single_pod_rules()
    return r.with_overrides(
        act_batch=("pod", "data"),
        embed=("data",),             # FSDP stays intra-pod (cheap all-gather)
        act_kv_seq_full=("pod", "data", "model"),
    )


def smoke_rules() -> AxisRules:
    """Everything replicated — used on single-device CPU tests."""
    return AxisRules({})


# -- thread-local active rules ------------------------------------------------

class _Ctx(threading.local):
    def __init__(self):
        self.rules: AxisRules | None = None
        self.mesh: Mesh | None = None


_ctx = _Ctx()


class use_rules:
    """Context manager activating (rules, mesh) for ``constrain``."""

    def __init__(self, rules: AxisRules | None, mesh: Mesh | None = None):
        self.rules, self.mesh = rules, mesh

    def __enter__(self):
        self._old = (_ctx.rules, _ctx.mesh)
        _ctx.rules, _ctx.mesh = self.rules, self.mesh
        return self

    def __exit__(self, *exc):
        _ctx.rules, _ctx.mesh = self._old
        return False


def active_rules() -> AxisRules | None:
    return _ctx.rules


def _divisible_spec(mesh: Mesh, spec: P, shape: tuple[int, ...]) -> P:
    """Drop mesh axes from dims they do not divide evenly (e.g. batch=1 in
    long-context decode, 1500-frame encoder sequences): a wrong constraint
    is a trace error, an omitted one just costs a reshard."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    parts = []
    used: set[str] = set()
    for i, p in enumerate(spec):
        if p is None or i >= len(shape):
            parts.append(None)
            continue
        axes = (p,) if isinstance(p, str) else tuple(p)
        if any(a in used for a in axes):   # an axis may shard only one dim
            parts.append(None)
            continue
        total = 1
        for a in axes:
            total *= sizes.get(a, 1)
        if total and shape[i] % total == 0:
            parts.append(p)
            used.update(axes)
        else:
            parts.append(None)
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def constrain(x, *logical_axes: str | None):
    """Apply a sharding constraint expressed in logical axes (no-op when no
    rules are active, e.g. in CPU smoke tests)."""
    rules = _ctx.rules
    if rules is None:
        return x
    spec = rules.spec(logical_axes)
    if _ctx.mesh is not None:
        spec = _divisible_spec(_ctx.mesh, spec, x.shape)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(_ctx.mesh, spec))
    return jax.lax.with_sharding_constraint(x, spec)


def named_sharding(mesh: Mesh, rules: AxisRules,
                   logical_axes: tuple[str | None, ...]) -> NamedSharding:
    return NamedSharding(mesh, rules.spec(logical_axes))


def tree_shardings(mesh: Mesh, rules: AxisRules, axes_tree):
    """Map a pytree of logical-axes tuples to NamedShardings."""
    return jax.tree.map(
        lambda axes: named_sharding(mesh, rules, axes),
        axes_tree, is_leaf=lambda x: isinstance(x, tuple))
