"""VLM / audio modality frontends — STUBS per the assignment.

``input_specs()`` supplies precomputed patch/frame embeddings; these helpers
define their shapes and a deterministic synthetic generator for smoke tests.
The real InternViT / Whisper-conv frontends are out of scope (the backbone
is the assigned architecture); see DESIGN.md §4.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def patch_embed_spec(batch: int, n_tokens: int, d_model: int
                     ) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct((batch, n_tokens, d_model), jnp.bfloat16)


def frame_embed_spec(batch: int, n_frames: int, d_model: int
                     ) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct((batch, n_frames, d_model), jnp.bfloat16)


def synthetic_embeds(key, spec: jax.ShapeDtypeStruct):
    return (jax.random.normal(key, spec.shape, jnp.float32) * 0.02
            ).astype(spec.dtype)
