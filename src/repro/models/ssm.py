"""Mamba-2 (SSD) blocks — TPU-native chunked formulation.

The GPU reference implements the selective scan with a fused warp-level
kernel; the TPU-idiomatic equivalent (per DESIGN.md §2) is the SSD *chunked*
algorithm: the sequence is split into chunks of length ``L``; within a chunk
the recurrence unrolls into dense (L×L) matmuls that map onto the MXU, and
only a small per-chunk state recurrence crosses chunks (lax.scan over
S/L steps).  ``repro.kernels.ssd_scan`` provides the Pallas kernel for the
chunk-local part; this module is the pure-jnp oracle and the dry-run path.

The same ``ssd()`` primitive also powers the xLSTM mLSTM block (mLSTM is an
SSD with forget-gate decays and input-gate injection — see models/xlstm.py).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.runtime.sharding import constrain
from .layers import Pm, rmsnorm, rmsnorm_spec


# ---------------------------------------------------------------------------
# SSD core: y = SSD(x, a, b, c) with per-(position, head) scalar decay
# ---------------------------------------------------------------------------

def ssd(x, log_a, b, c, *, chunk: int | None = None, initial_state=None,
        unroll: bool = False):
    """Chunked state-space duality scan.

    x:      (B, S, H, P)    inputs (already gated / dt-scaled)
    log_a:  (B, S, H)       per-step log decay (<= 0)
    b:      (B, S, Hb, N)   input maps  ("K"); Hb == H, or Hb == 1 for
    c:      (B, S, Hb, N)   head-shared maps (Mamba-2 ngroups=1 — kept
                            un-broadcast so the scan xs stay O(B·S·N))
    returns (y: (B, S, H, P), final_state: (B, H, N, P))

    ``chunk=None`` (the default) resolves to the autotuned ``ssd_scan``
    ``chunk`` winner when a tuned BenchmarkDB has been adopted
    (``kernels/substrate.adopt_tuned_params``), and to 128 otherwise;
    model configs that pin ``ssm_chunk`` keep passing it explicitly.
    """
    if chunk is None:
        from repro.kernels.substrate import serving_param
        chunk = serving_param("ssd_scan", "chunk", 128)
    B, S, H, P = x.shape
    Hb, N = b.shape[-2], b.shape[-1]
    shared = Hb == 1
    L = min(chunk, S)
    assert S % L == 0, (S, L)
    nc = S // L

    xc = x.reshape(B, nc, L, H, P).transpose(1, 0, 2, 3, 4)
    ac = log_a.reshape(B, nc, L, H).astype(jnp.float32).transpose(1, 0, 2, 3)
    bc = b.reshape(B, nc, L, Hb, N).transpose(1, 0, 2, 3, 4)
    cc = c.reshape(B, nc, L, Hb, N).transpose(1, 0, 2, 3, 4)

    if initial_state is None:
        initial_state = jnp.zeros((B, H, N, P), jnp.float32)

    causal = jnp.tril(jnp.ones((L, L), bool))

    # One chunk per scan step: the (L, L, H) decay/score tensors live only
    # inside the step body, bounding peak memory to O(B·L·L·H) instead of
    # O(B·S·L·H) (which blew past HBM at train_4k batch 256 — see
    # EXPERIMENTS.md §Perf).
    def step(state, inputs):
        xu, au, bu, cu = inputs                         # (B,L,H,*) per chunk
        seg = jnp.cumsum(au, axis=1)                    # (B, L, H)
        total = seg[:, -1]                              # (B, H)

        # intra-chunk: D[i,j] = exp(seg_i - seg_j) for j <= i
        diff = seg[:, :, None, :] - seg[:, None, :, :]  # (B, L, L, H)
        decay = jnp.where(causal[None, :, :, None], jnp.exp(diff), 0.0)
        bf = bu.astype(jnp.float32)
        cf = cu.astype(jnp.float32)
        if shared:
            scores = jnp.einsum("bin,bjn->bij", cf[:, :, 0], bf[:, :, 0])
            m = scores[..., None] * decay               # (B, L, L, H)
        else:
            scores = jnp.einsum("bihn,bjhn->bijh", cf, bf)
            m = scores * decay
        y = jnp.einsum("bijh,bjhp->bihp", m.astype(xu.dtype), xu)

        # inter-chunk contribution of the carried state
        if shared:
            y = y + jnp.einsum("bin,bih,bhnp->bihp", cf[:, :, 0],
                               jnp.exp(seg), state).astype(xu.dtype)
        else:
            y = y + jnp.einsum("bihn,bih,bhnp->bihp", cf, jnp.exp(seg),
                               state).astype(xu.dtype)

        # state update
        w = jnp.exp(total[:, None, :] - seg)            # (B, L, H)
        if shared:
            state_c = jnp.einsum("bln,blh,blhp->bhnp", bf[:, :, 0], w,
                                 xu.astype(jnp.float32))
        else:
            state_c = jnp.einsum("blhn,blh,blhp->bhnp", bf, w,
                                 xu.astype(jnp.float32))
        state = state * jnp.exp(total)[:, :, None, None] + state_c
        return state, y

    # checkpoint the chunk body: without it, scan's backward saves the
    # (B,L,L,H) decay/score residuals for EVERY chunk (observed 128 GiB/chip
    # on zamba2 train_4k); with it, each chunk recomputes them in the bwd.
    # ``unroll`` is used by the dry-run costing variants only (XLA cost
    # analysis ignores while-loop trip counts).
    final, ys = jax.lax.scan(jax.checkpoint(step), initial_state,
                             (xc, ac, bc, cc), unroll=nc if unroll else 1)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, S, H, P)
    return y, final


def ssd_decode_step(state, x, log_a, b, c):
    """One-token recurrent update.  x: (B,1,H,P) etc.  Returns (y, state)."""
    a = jnp.exp(log_a[:, 0].astype(jnp.float32))        # (B, H)
    st = state * a[:, :, None, None] + jnp.einsum(
        "bhn,bhp->bhnp", b[:, 0].astype(jnp.float32),
        x[:, 0].astype(jnp.float32))
    y = jnp.einsum("bhn,bhnp->bhp", c[:, 0].astype(jnp.float32), st)
    return y[:, None].astype(x.dtype), st


# ---------------------------------------------------------------------------
# Mamba-2 block
# ---------------------------------------------------------------------------

def mamba2_dims(cfg):
    d_inner = cfg.ssm_expand * cfg.d_model
    nheads = d_inner // cfg.ssm_head_dim
    conv_dim = d_inner + 2 * cfg.ssm_state
    return d_inner, nheads, conv_dim


def mamba2_spec(cfg) -> dict:
    d = cfg.d_model
    d_inner, H, conv_dim = mamba2_dims(cfg)
    N = cfg.ssm_state
    return {
        # fused input projection: [z, x, B, C, dt]
        "w_in": Pm((d, 2 * d_inner + 2 * N + H), ("embed", "ff")),
        "conv_w": Pm((cfg.ssm_conv, conv_dim), ("conv", "ff"), scale=0.5),
        "conv_b": Pm((conv_dim,), ("ff",), init="zeros"),
        "a_log": Pm((H,), ("heads",), init="zeros"),
        "d_skip": Pm((H,), ("heads",), init="ones"),
        "dt_bias": Pm((H,), ("heads",), init="zeros"),
        "norm": rmsnorm_spec(d_inner),
        "w_out": Pm((d_inner, d), ("ff", "embed")),
    }


def _split_in(cfg, h):
    d_inner, H, _ = mamba2_dims(cfg)
    N = cfg.ssm_state
    z, xbc_dt = jnp.split(h, [d_inner], axis=-1)
    xbc, dt = jnp.split(xbc_dt, [d_inner + 2 * N], axis=-1)
    return z, xbc, dt


def _causal_conv(w, bias, x, state=None):
    """Depthwise causal conv1d.  x: (B, S, C); state: (B, K-1, C) or None.
    Returns (y, new_state)."""
    K = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(K))
    new_state = xp[:, -(K - 1):] if K > 1 else None
    return jax.nn.silu(y + bias), new_state


def mamba2(p, cfg, x, *, state=None, conv_state=None, decode=False):
    """x: (B, S, D) -> (y, (ssm_state, conv_state)).

    ``decode=True`` runs the O(1) recurrent update (S == 1 expected);
    otherwise the chunked SSD scan (training / prefill).
    """
    B, S, D = x.shape
    d_inner, H, conv_dim = mamba2_dims(cfg)
    N = cfg.ssm_state
    P = cfg.ssm_head_dim

    h = jnp.einsum("bsd,df->bsf", x, p["w_in"])
    h = constrain(h, "act_batch", None, "act_ff")
    z, xbc, dt = _split_in(cfg, h)
    xbc, new_conv = _causal_conv(p["conv_w"], p["conv_b"], xbc,
                                 state=conv_state)
    xs, bc = jnp.split(xbc, [d_inner], axis=-1)
    b_in, c_out = jnp.split(bc, 2, axis=-1)             # (B,S,N) each

    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))  # (B,S,H)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))        # (H,) negative
    log_a = dt * a                                      # (B,S,H)

    xh = xs.reshape(B, S, H, P) * dt[..., None].astype(xs.dtype)

    if decode:
        if state is None:
            state = jnp.zeros((B, H, N, P), jnp.float32)
        bh = jnp.broadcast_to(b_in[:, :, None, :], (B, S, H, N))
        ch = jnp.broadcast_to(c_out[:, :, None, :], (B, S, H, N))
        y, new_state = ssd_decode_step(state, xh, log_a, bh, ch)
    else:
        # B/C are shared across heads (ngroups=1): pass un-broadcast so the
        # chunk-scan xs stay O(B·S·N), not O(B·S·H·N)
        y, new_state = ssd(xh, log_a, b_in[:, :, None, :],
                           c_out[:, :, None, :], chunk=cfg.ssm_chunk,
                           initial_state=state,
                           unroll=getattr(cfg, "unroll_scans", False))

    y = y + xs.reshape(B, S, H, P) * p["d_skip"].astype(xs.dtype)[:, None]
    y = y.reshape(B, S, d_inner)
    y = rmsnorm(p["norm"], y) * jax.nn.silu(z)
    out = jnp.einsum("bsf,fd->bsd", y, p["w_out"])
    return constrain(out, "act_batch", "act_seq", None), (new_state, new_conv)


def mamba2_state_specs(cfg, batch: int):
    d_inner, H, conv_dim = mamba2_dims(cfg)
    ssm = jax.ShapeDtypeStruct((batch, H, cfg.ssm_state, cfg.ssm_head_dim),
                               jnp.float32)
    conv = jax.ShapeDtypeStruct((batch, cfg.ssm_conv - 1, conv_dim),
                                jnp.bfloat16)
    ssm_axes = ("act_batch", "act_heads", None, None)
    conv_axes = ("act_batch", None, "act_ff")
    return (ssm, ssm_axes), (conv, conv_axes)
