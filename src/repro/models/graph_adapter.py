"""Adapter: DecoderLM -> Scission LayerGraph.

Makes the paper's partitioning a first-class feature for the transformer
zoo: each scan group becomes one graph node (Scission's block), embedding
and unembedding are the terminal nodes, and the residual stream is the
single crossing tensor — so every group boundary is a valid partition
point, exactly like the paper's linear DNNs.

Used by examples/partition_and_serve.py to split a small LM across the
emulated device/edge/cloud tiers and execute it with PipelineExecutor.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.graph import LayerGraph, LayerNode
from repro.models import layers as L
from repro.models.lm import DecoderLM, _norm


def lm_to_graph(model: DecoderLM, params, *, batch: int, seq_len: int
                ) -> LayerGraph:
    cfg = model.cfg
    g = LayerGraph(cfg.name)
    prev = g.input(jax.ShapeDtypeStruct((batch, seq_len), jnp.int32),
                   name="tokens")

    def embed_fn(tokens):
        return model._embed_inputs(params, tokens)

    d = cfg.d_model
    prev = g.add(LayerNode("embed", "embed", apply=embed_fn,
                           flops=0.0,
                           param_bytes=cfg.vocab * d * 2), [prev])

    positions = jnp.arange(seq_len, dtype=jnp.int32)[None, :]
    shared = params.get("shared_block")
    for gi in range(cfg.n_groups):
        pg = jax.tree.map(lambda a, gi=gi: a[gi], params["layers"])

        def group_fn(x, pg=pg):
            y, _, _ = model._apply_group(pg, shared, x, None,
                                         positions=positions,
                                         cache_len=None, mode="train")
            return y

        pbytes = sum(int(jnp.size(a)) * a.dtype.itemsize
                     for a in jax.tree.leaves(pg))
        per_tok_flops = 2.0 * pbytes / 2   # ~2 flops per bf16 param weight
        g.add(LayerNode(f"group{gi}", "block", apply=group_fn,
                        flops=per_tok_flops * batch * seq_len,
                        param_bytes=pbytes), [prev])
        prev = len(g.nodes) - 1

    def head_fn(x):
        normf = _norm(cfg)
        h = normf(params["final_norm"], x[:, -1:])
        return L.unembed(params["embed"], h, softcap=cfg.final_softcap)

    g.add(LayerNode("head", "unembed", apply=head_fn,
                    flops=2.0 * cfg.vocab * d * batch,
                    param_bytes=0), [prev])
    g.trace()
    return g
