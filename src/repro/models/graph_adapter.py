"""Adapters: model zoo -> Scission LayerGraph.

Makes the paper's partitioning a first-class feature for the transformer
zoo: each scan group becomes one graph node (Scission's block), embedding
and unembedding are the terminal nodes, and the residual stream is the
single crossing tensor — so every group boundary is a valid partition
point, exactly like the paper's linear DNNs (:func:`lm_to_graph`).

The DAG adapters emit **genuinely branchy** graphs for the DAG-general
partitioner (``fuse_block_dag`` / ``SPSolver``):

* :func:`encdec_to_graph` — the encoder stack and the target embedding run
  as parallel branches off the token input, meeting at the decoder's
  cross-attention (the natural encoder/decoder placement split);
* :func:`moe_to_graph` — expert *shards* as parallel branches (replicated
  routing, local expert compute), summed at the combine with a residual
  fork→join edge (the expert-parallel deployment shape);
* :func:`xlstm_to_graph` — each recurrent group's residual skip is a
  graph-level fork→join edge, so the skip tensor and the group body can be
  placed independently.

Used by examples/partition_and_serve.py to split a small LM across the
emulated device/edge/cloud tiers and execute it with PipelineExecutor.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.graph import LayerGraph, LayerNode
from repro.models import layers as L
from repro.models.lm import DecoderLM, _norm
from repro.models.xlstm import mlstm, slstm


def lm_to_graph(model: DecoderLM, params, *, batch: int, seq_len: int
                ) -> LayerGraph:
    cfg = model.cfg
    g = LayerGraph(cfg.name)
    prev = g.input(jax.ShapeDtypeStruct((batch, seq_len), jnp.int32),
                   name="tokens")

    def embed_fn(tokens):
        return model._embed_inputs(params, tokens)

    d = cfg.d_model
    prev = g.add(LayerNode("embed", "embed", apply=embed_fn,
                           flops=0.0,
                           param_bytes=cfg.vocab * d * 2), [prev])

    positions = jnp.arange(seq_len, dtype=jnp.int32)[None, :]
    shared = params.get("shared_block")
    for gi in range(cfg.n_groups):
        pg = jax.tree.map(lambda a, gi=gi: a[gi], params["layers"])

        def group_fn(x, pg=pg):
            y, _, _ = model._apply_group(pg, shared, x, None,
                                         positions=positions,
                                         cache_len=None, mode="train")
            return y

        pbytes = sum(int(jnp.size(a)) * a.dtype.itemsize
                     for a in jax.tree.leaves(pg))
        per_tok_flops = 2.0 * pbytes / 2   # ~2 flops per bf16 param weight
        g.add(LayerNode(f"group{gi}", "block", apply=group_fn,
                        flops=per_tok_flops * batch * seq_len,
                        param_bytes=pbytes), [prev])
        prev = len(g.nodes) - 1

    def head_fn(x):
        normf = _norm(cfg)
        h = normf(params["final_norm"], x[:, -1:])
        return L.unembed(params["embed"], h, softcap=cfg.final_softcap)

    g.add(LayerNode("head", "unembed", apply=head_fn,
                    flops=2.0 * cfg.vocab * d * batch,
                    param_bytes=0), [prev])
    g.trace()
    return g


def _tree_bytes(p) -> int:
    return sum(int(jnp.size(a)) * a.dtype.itemsize
               for a in jax.tree.leaves(p))


def encdec_to_graph(model, params, *, batch: int, seq_len: int,
                    enc_splits: int = 2) -> LayerGraph:
    """EncDecLM -> branchy LayerGraph (teacher-forced text-to-text mode:
    the source and target sequences share the input tokens, as in
    denoising / summarisation self-conditioning).

    Structure: the token input forks into the **encoder branch**
    (source embedding, then ``enc_splits`` encoder sub-stacks ending in the
    encoder final norm) and the **target-embedding branch**; both meet at
    the decoder stack, whose cross-attention consumes the encoder memory —
    the two branches are placeable on distinct resources and their
    latencies overlap, which is exactly what the DAG cost model prices.
    """
    cfg = model.cfg
    g = LayerGraph(cfg.name)
    tok = g.input(jax.ShapeDtypeStruct((batch, seq_len), jnp.int32),
                  name="tokens")
    normf = _norm(cfg)
    positions = jnp.arange(seq_len, dtype=jnp.int32)[None, :]

    # -- encoder branch ----------------------------------------------------
    def src_embed_fn(tokens):
        return model._embed_tokens(params, tokens, 0)

    d = cfg.d_model
    prev = g.add(LayerNode("src_embed", "embed", apply=src_embed_fn,
                           flops=0.0, param_bytes=cfg.vocab * d * 2), [tok])

    def enc_body(x, pg):
        h = normf(pg["attn_norm"], x)
        h, _ = L.attention(pg["attn"], h, positions=positions,
                           causal=False, use_rope=False, q_chunk=cfg.q_chunk)
        x = x + h
        h = normf(pg["mlp_norm"], x)
        return x + L.mlp(pg["mlp"], h, activation=cfg.activation)

    n_enc = cfg.encoder_layers
    splits = max(1, min(enc_splits, n_enc))
    bounds = [round(i * n_enc / splits) for i in range(splits + 1)]
    for si in range(splits):
        lo, hi = bounds[si], bounds[si + 1]

        def enc_fn(x, lo=lo, hi=hi, last=(si == splits - 1)):
            for gi in range(lo, hi):
                pg = jax.tree.map(lambda a, gi=gi: a[gi], params["encoder"])
                x = enc_body(x, pg)
            return normf(params["enc_final_norm"], x) if last else x

        pbytes = (hi - lo) * _tree_bytes(
            jax.tree.map(lambda a: a[0], params["encoder"]))
        prev = g.add(LayerNode(f"enc{si}", "block", apply=enc_fn,
                               flops=pbytes * batch * seq_len,
                               param_bytes=pbytes), [prev])
    memory = prev

    # -- target-embedding branch -------------------------------------------
    def tgt_embed_fn(tokens):
        return model._embed_tokens(params, tokens, 0)

    tgt = g.add(LayerNode("tgt_embed", "embed", apply=tgt_embed_fn,
                          flops=0.0, param_bytes=cfg.vocab * d * 2), [tok])

    # -- join: decoder stack (cross-attention reads the encoder memory) ----
    def dec_fn(x, memory):
        y, _ = model._decoder_stack(params, x, memory, None,
                                    positions=positions, cache_len=None,
                                    mode="train")
        return y

    dec_bytes = _tree_bytes(params["decoder"])
    dec = g.add(LayerNode("decoder", "block", apply=dec_fn,
                          flops=dec_bytes * batch * seq_len,
                          param_bytes=dec_bytes), [tgt, memory])

    def head_fn(x):
        h = normf(params["final_norm"], x[:, -1:])
        return L.unembed(params["embed"], h, softcap=cfg.final_softcap)

    g.add(LayerNode("head", "unembed", apply=head_fn,
                    flops=2.0 * cfg.vocab * d * batch, param_bytes=0), [dec])
    g.trace()
    return g


def moe_to_graph(p, *, batch: int, seq_len: int, d_model: int,
                 n_experts: int, top_k: int, n_shards: int = 2,
                 activation: str = "silu", name: str = "moe") -> LayerGraph:
    """One MoE layer as an expert-parallel LayerGraph.

    ``p`` is a :func:`repro.models.moe.moe_spec` param tree.  The input
    activations fork into ``n_shards`` branches; each branch replicates the
    (cheap) routing and computes only its local expert slice's gated
    contribution — the standard expert-parallel decomposition, where each
    shard lives on its own device.  The combine node sums the shard outputs
    and the residual stream, which reaches it over a direct fork→join edge.

    Routing is evaluated densely per shard (every local expert weighted by
    its top-k gate, zero for unrouted tokens): semantically the token-choice
    top-k of :func:`repro.models.moe.moe` without capacity dropping.
    """
    E = p["router"].shape[1]
    shards = [list(range(s, n_experts, n_shards)) for s in range(n_shards)]
    shards = [s for s in shards if s]
    g = LayerGraph(name)
    x0 = g.input(jax.ShapeDtypeStruct((batch, seq_len, d_model),
                                      jnp.bfloat16), name="acts")
    act = {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[activation]

    def gates(x):
        logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                            p["router"].astype(jnp.float32))
        if n_experts < E:
            logits = logits - jnp.where(jnp.arange(E) < n_experts, 0.0, 1e30)
        probs = jax.nn.softmax(logits, axis=-1)
        vals, idx = jax.lax.top_k(probs, top_k)
        vals = vals / jnp.clip(jnp.sum(vals, axis=-1, keepdims=True), 1e-9)
        # dense per-expert gate: (B, S, E)
        dense = jnp.zeros_like(probs)
        for k in range(top_k):
            dense = dense + vals[..., k, None] * \
                jax.nn.one_hot(idx[..., k], E, dtype=jnp.float32)
        return dense

    shard_nodes = []
    expert_bytes = _tree_bytes({k: p[k] for k in ("w_gate", "w_up", "w_down")})
    for si, ids in enumerate(shards):

        def shard_fn(x, ids=tuple(ids)):
            dense = gates(x)
            y = jnp.zeros_like(x, dtype=jnp.float32)
            for e in ids:
                h = act(jnp.einsum("bsd,df->bsf", x, p["w_gate"][e])) * \
                    jnp.einsum("bsd,df->bsf", x, p["w_up"][e])
                ye = jnp.einsum("bsf,fd->bsd", h, p["w_down"][e])
                y = y + dense[..., e, None] * ye.astype(jnp.float32)
            return y.astype(x.dtype)

        pbytes = expert_bytes * len(ids) // E
        shard_nodes.append(g.add(LayerNode(
            f"experts{si}", "moe_shard", apply=shard_fn,
            flops=6.0 * batch * seq_len * d_model *
            p["w_up"].shape[2] * len(ids),
            param_bytes=pbytes), [x0]))

    def combine_fn(*ins):
        *ys, x = ins
        out = x.astype(jnp.float32)
        for y in ys:
            out = out + y.astype(jnp.float32)
        return out.astype(x.dtype)

    join = g.add(LayerNode("combine", "add", apply=combine_fn,
                           flops=float(batch * seq_len * d_model *
                                       (len(shards) + 1)),
                           param_bytes=0), [*shard_nodes, x0])

    g.add(LayerNode("out", "identity", apply=lambda x: x, flops=0.0,
                    param_bytes=0), [join])
    g.trace()
    return g


def xlstm_to_graph(model: DecoderLM, params, *, batch: int, seq_len: int
                   ) -> LayerGraph:
    """DecoderLM with an xLSTM pattern -> LayerGraph whose residual skips
    are graph-level fork→join edges.

    Each ``mlstm`` sub-layer becomes a (core, add) pair: the core node
    computes the normed recurrent update, and the add node sums it with the
    residual stream arriving over a direct edge from the fork — so the
    recurrent body and the skip are independently placeable, and the SP
    decomposition sees one single-branch parallel region per group.
    ``slstm`` sub-layers (residual handled internally) stay chain nodes.
    """
    cfg = model.cfg
    g = LayerGraph(cfg.name)
    prev = g.input(jax.ShapeDtypeStruct((batch, seq_len), jnp.int32),
                   name="tokens")
    normf = _norm(cfg)
    d = cfg.d_model

    def embed_fn(tokens):
        return model._embed_inputs(params, tokens)

    prev = g.add(LayerNode("embed", "embed", apply=embed_fn, flops=0.0,
                           param_bytes=cfg.vocab * d * 2), [prev])

    for gi in range(cfg.n_groups):
        pg = jax.tree.map(lambda a, gi=gi: a[gi], params["layers"])
        for name, kind in zip(model.sub_names, model.kinds):
            sp = pg[name]
            pbytes = _tree_bytes(sp)
            if kind == "mlstm":

                def core_fn(x, sp=sp):
                    h = normf(sp["norm"], x)
                    h, _ = mlstm(sp["core"], cfg, h)
                    return h

                core = g.add(LayerNode(
                    f"g{gi}_{name}", "mlstm", apply=core_fn,
                    flops=pbytes * batch * seq_len, param_bytes=pbytes),
                    [prev])
                prev = g.add(LayerNode(
                    f"g{gi}_{name}_add", "add",
                    apply=lambda h, x: x + h,
                    flops=float(batch * seq_len * d), param_bytes=0),
                    [core, prev])
            elif kind == "slstm":

                def s_fn(x, sp=sp):
                    y, _ = slstm(sp["core"], cfg, x)
                    return y

                prev = g.add(LayerNode(
                    f"g{gi}_{name}", "slstm", apply=s_fn,
                    flops=pbytes * batch * seq_len, param_bytes=pbytes),
                    [prev])
            else:
                raise ValueError(
                    f"xlstm_to_graph supports mlstm/slstm groups, got "
                    f"{kind!r}; use lm_to_graph for mixed patterns")

    def head_fn(x):
        h = normf(params["final_norm"], x[:, -1:])
        return L.unembed(params["embed"], h, softcap=cfg.final_softcap)

    g.add(LayerNode("head", "unembed", apply=head_fn,
                    flops=2.0 * cfg.vocab * d * batch, param_bytes=0),
          [prev])
    g.trace()
    return g
