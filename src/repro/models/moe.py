"""Mixture-of-Experts: top-k token-choice routing with grouped, capacity-based
one-hot dispatch (GShard / MaxText style).

TPU-native formulation: dispatch and combine are dense einsums against a
(group, tokens, E, C) one-hot tensor, so expert compute is plain MXU matmuls
and the expert-sharded dim lowers to an all-to-all — no scatter/gather
kernels.  Tokens are processed in fixed-size *groups* with per-group expert
capacity so the dispatch tensor stays O(g·E·C) regardless of sequence length
(required for the 32k-prefill cells).

Experts are padded to a multiple of the TP axis (e.g. 60 -> 64) so the
expert dim shards evenly; padded experts are masked out of routing.

Covers the two assigned MoE architectures:
* qwen2-moe-a2.7b — 60 routed top-4 + fused shared expert + sigmoid gate;
* granite-moe-3b  — 40 routed top-8, no shared expert.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.runtime.sharding import constrain
from .layers import Pm, mlp, mlp_spec


def pad_experts(n_experts: int, multiple: int = 16) -> int:
    return ((n_experts + multiple - 1) // multiple) * multiple


def moe_spec(d_model: int, d_expert: int, n_experts: int,
             n_shared: int = 0, d_shared: int = 0,
             pad_to: int = 16) -> dict:
    E = pad_experts(n_experts, pad_to)
    spec = {
        "router": Pm((d_model, E), ("embed", "experts")),
        "w_gate": Pm((E, d_model, d_expert), ("experts", "embed", "ff")),
        "w_up": Pm((E, d_model, d_expert), ("experts", "embed", "ff")),
        "w_down": Pm((E, d_expert, d_model), ("experts", "ff", "embed")),
    }
    if n_shared:
        spec["shared"] = mlp_spec(d_model, d_shared, gated=True)
        spec["shared_gate"] = Pm((d_model, 1), ("embed", None), init="zeros")
    return spec


def _capacity(g: int, n_experts: int, top_k: int, factor: float) -> int:
    cap = int(math.ceil(g * top_k / n_experts * factor))
    return max(8, ((cap + 7) // 8) * 8)   # 8-align for the MXU


def moe(p, x, *, top_k: int, n_experts: int, capacity_factor: float = 1.25,
        activation: str = "silu", group_size: int = 512,
        impl: str = "sort"):
    """x: (B, S, D) -> (y, aux_loss).

    ``impl="onehot"`` is the GShard-faithful einsum dispatch (kept as the
    oracle; its (n,g,E,C) combine tensor costs O(T·g·k) HBM and FLOPs).
    ``impl="sort"`` routes with an argsort over expert ids + gather/scatter
    of *indices only*, so every large tensor is O(T·k·D) — the beyond-paper
    optimisation recorded in EXPERIMENTS.md §Perf (same routing semantics:
    token-choice top-k with per-group capacity, overflow dropped).
    """
    if impl == "sort":
        return moe_sort(p, x, top_k=top_k, n_experts=n_experts,
                        capacity_factor=capacity_factor,
                        activation=activation, group_size=group_size)
    B, S, D = x.shape
    E = p["router"].shape[1]             # padded expert count
    T = B * S
    g = min(group_size, T)
    assert T % g == 0, (T, g)
    n = T // g
    xt = x.reshape(n, g, D)
    xt = constrain(xt, "act_batch", None, None)

    logits = jnp.einsum("ngd,de->nge", xt.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    if n_experts < E:                    # mask padded experts out of routing
        logits = logits - jnp.where(jnp.arange(E) < n_experts, 0.0, 1e30)
    probs = jax.nn.softmax(logits, axis=-1)

    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)        # (n, g, k)
    gate_vals = gate_vals / jnp.clip(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    C = _capacity(g, E, top_k, capacity_factor)
    # Position of each routing slot in its expert queue.  Slots are ordered
    # (token-major, then k) within the group.
    onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.float32)  # (n, g, k, E)
    flat = onehot.reshape(n, g * top_k, E)
    pos = jnp.cumsum(flat, axis=1) - flat                    # 0-based queue pos
    pos = pos.reshape(n, g, top_k, E)

    combine = jnp.zeros((n, g, E, C), jnp.float32)
    for k in range(top_k):               # small static loop bounds peak memory
        oh_k = onehot[:, :, k, :]
        pos_k = pos[:, :, k, :]
        keep = (pos_k < C) & (oh_k > 0)
        slot = jax.nn.one_hot(pos_k.astype(jnp.int32), C, dtype=jnp.float32)
        slot = slot * keep[..., None]
        combine = combine + slot * gate_vals[:, :, k, None, None]
    dispatch = (combine > 0).astype(x.dtype)                 # (n, g, E, C)

    # aux load-balancing loss (Switch): E * Σ_e f_e p_e, over real experts
    density = jnp.mean(onehot[..., :n_experts].sum(axis=2), axis=(0, 1))
    p_mean = jnp.mean(probs[..., :n_experts], axis=(0, 1))
    aux = n_experts * jnp.sum(density * p_mean)

    xe = jnp.einsum("ngec,ngd->necd", dispatch, xt)          # (n, E, C, D)
    xe = constrain(xe, None, "act_experts", None, None)
    h = jnp.einsum("necd,edf->necf", xe, p["w_up"])
    gt = jnp.einsum("necd,edf->necf", xe, p["w_gate"])
    act = {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[activation]
    h = act(gt) * h
    ye = jnp.einsum("necf,efd->necd", h, p["w_down"])
    ye = constrain(ye, None, "act_experts", None, None)
    yt = jnp.einsum("ngec,necd->ngd", combine.astype(x.dtype), ye)

    if "shared" in p:
        sg = jax.nn.sigmoid(
            jnp.einsum("ngd,do->ngo", xt.astype(jnp.float32),
                       p["shared_gate"].astype(jnp.float32)))
        ys = mlp(p["shared"], xt, activation=activation)
        yt = yt + (sg * ys.astype(jnp.float32)).astype(yt.dtype)

    y = yt.reshape(B, S, D)
    return constrain(y, "act_batch", "act_seq", None), aux


def moe_sort(p, x, *, top_k: int, n_experts: int,
             capacity_factor: float = 1.25, activation: str = "silu",
             group_size: int = 512):
    """Sort-based dispatch: all O(T·E·C) one-hots replaced by an argsort
    over routing slots plus index gathers.  Identical routing semantics to
    the one-hot path (token-choice top-k, per-group capacity C, overflow
    slots dropped in slot order)."""
    B, S, D = x.shape
    E = p["router"].shape[1]
    T = B * S
    g = min(group_size, T)
    assert T % g == 0, (T, g)
    n = T // g
    xt = x.reshape(n, g, D)

    logits = jnp.einsum("ngd,de->nge", xt.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    if n_experts < E:
        logits = logits - jnp.where(jnp.arange(E) < n_experts, 0.0, 1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)        # (n, g, k)
    gate_vals = gate_vals / jnp.clip(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    C = _capacity(g, E, top_k, capacity_factor)
    gk = g * top_k
    # routing slots in (token-major, k) order — matches the one-hot path
    flat_e = gate_idx.reshape(n, gk)
    order = jnp.argsort(flat_e, axis=1, stable=True)          # (n, gk)
    sorted_e = jnp.take_along_axis(flat_e, order, axis=1)
    # position of each sorted slot within its expert segment
    starts = jax.vmap(lambda se: jnp.searchsorted(se, jnp.arange(E)))(
        sorted_e)                                             # (n, E)
    pos_sorted = jnp.arange(gk)[None, :] - \
        jnp.take_along_axis(starts, sorted_e, axis=1)         # (n, gk)
    keep_sorted = pos_sorted < C
    slot_sorted = sorted_e * C + jnp.clip(pos_sorted, 0, C - 1)

    # token id of each sorted slot; sentinel g for dropped slots
    tok_sorted = order // top_k                               # (n, gk)
    tok_sorted = jnp.where(keep_sorted, tok_sorted, g)

    # expert-slot -> token map via an int32 scatter (tiny: (n, E*C));
    # dropped slots write out-of-bounds and are discarded by mode="drop"
    rows = jnp.broadcast_to(jnp.arange(n)[:, None], (n, gk))
    tok_for_slot = jnp.full((n, E * C), g, jnp.int32)
    safe_slot = jnp.where(keep_sorted, slot_sorted, E * C)
    tok_for_slot = tok_for_slot.at[rows, safe_slot].set(
        tok_sorted.astype(jnp.int32), mode="drop")

    # dispatch: gather token vectors into expert slots (zero row for empty)
    xt_pad = jnp.concatenate([xt, jnp.zeros((n, 1, D), xt.dtype)], axis=1)
    xe = jnp.take_along_axis(xt_pad, tok_for_slot[..., None], axis=1)
    xe = xe.reshape(n, E, C, D)
    xe = constrain(xe, None, "act_experts", None, None)

    h = jnp.einsum("necd,edf->necf", xe, p["w_up"])
    gt = jnp.einsum("necd,edf->necf", xe, p["w_gate"])
    act = {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[activation]
    h = act(gt) * h
    ye = jnp.einsum("necf,efd->necd", h, p["w_down"])
    ye = constrain(ye, None, "act_experts", None, None)

    # combine: each token gathers its k expert slots back
    pos_unsorted = jnp.zeros((n, gk), jnp.int32).at[rows, order].set(
        pos_sorted.astype(jnp.int32))
    keep_unsorted = jnp.take_along_axis(
        keep_sorted, jnp.argsort(order, axis=1), axis=1)
    slot_unsorted = flat_e * C + jnp.clip(pos_unsorted, 0, C - 1)
    ye_flat = ye.reshape(n, E * C, D)
    gathered = jnp.take_along_axis(ye_flat, slot_unsorted[..., None],
                                   axis=1)                    # (n, gk, D)
    w = (gate_vals.reshape(n, gk) *
         keep_unsorted.astype(jnp.float32)).astype(x.dtype)
    yt = jnp.einsum("ngkd,ngk->ngd",
                    gathered.reshape(n, g, top_k, D),
                    w.reshape(n, g, top_k))

    # aux load-balancing loss
    onehot_density = jnp.mean(
        jax.nn.one_hot(gate_idx, E, dtype=jnp.float32)[..., :n_experts]
        .sum(axis=2), axis=(0, 1))
    p_mean = jnp.mean(probs[..., :n_experts], axis=(0, 1))
    aux = n_experts * jnp.sum(onehot_density * p_mean)

    if "shared" in p:
        sg = jax.nn.sigmoid(
            jnp.einsum("ngd,do->ngo", xt.astype(jnp.float32),
                       p["shared_gate"].astype(jnp.float32)))
        ys = mlp(p["shared"], xt, activation=activation)
        yt = yt + (sg * ys.astype(jnp.float32)).astype(yt.dtype)

    y = yt.reshape(B, S, D)
    return constrain(y, "act_batch", "act_seq", None), aux
