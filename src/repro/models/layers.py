"""Shared neural-net layers for the architecture zoo (pure JAX, explicit
pytrees).

Parameters are declared with :class:`Pm` leaf specs carrying shape + logical
sharding axes; ``init_tree`` / ``abstract_tree`` / ``axes_tree`` materialise
them.  All activation tensors pass through ``runtime.sharding.constrain`` so
the same code runs unsharded on CPU and SPMD-sharded on the production mesh.
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.runtime.sharding import constrain


# ---------------------------------------------------------------------------
# Parameter spec trees
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Pm:
    """Parameter leaf: shape + logical axes (+ init)."""

    shape: tuple[int, ...]
    axes: tuple[str | None, ...]
    init: str = "normal"          # normal | zeros | ones
    scale: float | None = None    # None => 1/sqrt(fan_in)

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _is_leaf(x):
    return isinstance(x, Pm)


def abstract_tree(spec, dtype=jnp.bfloat16):
    return jax.tree.map(
        lambda p: jax.ShapeDtypeStruct(p.shape, dtype), spec, is_leaf=_is_leaf)


def axes_tree(spec):
    return jax.tree.map(lambda p: p.axes, spec, is_leaf=_is_leaf)


def init_tree(spec, key, dtype=jnp.bfloat16):
    leaves, treedef = jax.tree.flatten(spec, is_leaf=_is_leaf)
    keys = jax.random.split(key, len(leaves))
    out = []
    for p, k in zip(leaves, keys):
        if p.init == "zeros":
            out.append(jnp.zeros(p.shape, dtype))
        elif p.init == "ones":
            out.append(jnp.ones(p.shape, dtype))
        else:
            fan_in = p.shape[0] if p.shape else 1
            scale = p.scale if p.scale is not None else 1.0 / math.sqrt(max(fan_in, 1))
            out.append((jax.random.normal(k, p.shape, jnp.float32) * scale
                        ).astype(dtype))
    return jax.tree.unflatten(treedef, out)


def stack_spec(spec, n: int):
    """Prepend a 'layers' stacking dim to every leaf (scan-over-layers).

    The fan-in-derived init scale is resolved *before* stacking so the extra
    leading dim does not corrupt it.
    """
    def stack(p: Pm) -> Pm:
        scale = p.scale
        if scale is None and p.init == "normal":
            scale = 1.0 / math.sqrt(max(p.shape[0] if p.shape else 1, 1))
        return Pm((n, *p.shape), ("layers", *p.axes), p.init, scale)

    return jax.tree.map(stack, spec, is_leaf=_is_leaf)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm_spec(d: int) -> dict:
    return {"scale": Pm((d,), ("unsharded",), init="zeros")}  # (1+scale) form


def rmsnorm(p, x, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + p["scale"].astype(jnp.float32))).astype(dt)


def layernorm_spec(d: int) -> dict:
    return {"scale": Pm((d,), ("unsharded",), init="ones"),
            "bias": Pm((d,), ("unsharded",), init="zeros")}


def layernorm(p, x, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)
            + p["bias"].astype(jnp.float32)).astype(dt)


def make_norm(kind: str, d: int):
    if kind == "rmsnorm":
        return rmsnorm_spec(d), rmsnorm
    if kind == "layernorm":
        return layernorm_spec(d), layernorm
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Rotary position embedding
# ---------------------------------------------------------------------------

def rope(x, positions, theta: float = 10000.0):
    """x: (..., S, H, hd) ; positions: (..., S) broadcastable."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(-jnp.arange(0, half, dtype=jnp.float32)
                    * (math.log(theta) / half))
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    cos = cos[..., :, None, :]
    sin = sin[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA, sliding window, logit softcap) — chunked jnp path.
# The Pallas kernels in repro.kernels implement the same math for TPU; the
# jnp path here is the oracle and the dry-run lowering target.
# ---------------------------------------------------------------------------

def attention_spec(d_model: int, n_heads: int, n_kv: int, head_dim: int,
                   qkv_bias: bool = False) -> dict:
    spec = {
        "wq": Pm((d_model, n_heads, head_dim), ("embed", "heads", "head_dim")),
        "wk": Pm((d_model, n_kv, head_dim), ("embed", "kv_heads", "head_dim")),
        "wv": Pm((d_model, n_kv, head_dim), ("embed", "kv_heads", "head_dim")),
        "wo": Pm((n_heads, head_dim, d_model), ("heads", "head_dim", "embed")),
    }
    if qkv_bias:
        spec["bq"] = Pm((n_heads, head_dim), ("heads", "head_dim"), init="zeros")
        spec["bk"] = Pm((n_kv, head_dim), ("kv_heads", "head_dim"), init="zeros")
        spec["bv"] = Pm((n_kv, head_dim), ("kv_heads", "head_dim"), init="zeros")
    return spec


def _softcap(x, cap):
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


def _attn_mask(q_pos, k_pos, *, causal: bool, window: int | None,
               k_len_valid=None):
    """(..., Sq, Sk) boolean mask of allowed attention.

    ``k_len_valid`` may be a scalar or a per-row (B,) vector (ragged decode
    batches in the serving engine)."""
    m = jnp.ones((q_pos.shape[-1], k_pos.shape[-1]), dtype=bool)
    d = q_pos[..., :, None] - k_pos[..., None, :]
    if causal:
        m = m & (d >= 0)
    if window is not None:
        m = m & (d < window)
    if k_len_valid is not None:
        lv = jnp.asarray(k_len_valid)
        if lv.ndim == 1:
            lv = lv[:, None, None]
        m = m & (k_pos[..., None, :] < lv)
    return m


def sdpa(q, k, v, *, q_pos, k_pos, causal=True, window=None, softcap=None,
         k_len_valid=None, q_chunk: int | None = None):
    """Scaled dot-product attention with GQA.

    q: (B, Sq, H, hd) ; k, v: (B, Sk, Hk, hd).  Chunked over Sq so the score
    matrix never exceeds (B, H, q_chunk, Sk) — required for 32k prefill.
    Softmax in fp32.

    ``q_chunk=None`` (the default) resolves to the autotuned
    ``flash_attention`` ``block_q`` winner when a tuned BenchmarkDB has
    been adopted (``kernels/substrate.adopt_tuned_params``) — the serving
    path then chunks at the same granularity the cost model priced — and
    to 512 otherwise.

    GQA is handled by repeating K/V to H heads: the repeated dim then shards
    cleanly over the TP axis, whereas a grouped (Hk, G) einsum forces XLA
    into involuntary resharding (observed: replicated (B,Hk,G,C,Sk) score
    tensors blowing past HBM on starcoder2/internvl2 — EXPERIMENTS.md §Perf).
    """
    if q_chunk is None:
        from repro.kernels.substrate import serving_param
        q_chunk = serving_param("flash_attention", "block_q", 512)
    B, Sq, H, hd = q.shape
    Hk = k.shape[2]
    G = H // Hk
    scale = 1.0 / math.sqrt(hd)

    if G > 1:
        k = jnp.repeat(k, G, axis=2)        # (B, Sk, H, hd)
        v = jnp.repeat(v, G, axis=2)
    kt = k.transpose(0, 2, 3, 1)            # (B, H, hd, Sk)
    vt = v.transpose(0, 2, 1, 3)            # (B, H, Sk, hd)
    # NOTE: no sharding constraint here — decode-mode KV caches may be
    # sequence-sharded (flash-decoding split) while prefill K/V are
    # head-sharded; the cache/input sharding propagates through.

    def one_chunk(qc, qp):
        C = qc.shape[1]
        qh = qc.transpose(0, 2, 1, 3)       # (B, H, C, hd)
        s = jnp.einsum("bhcd,bhds->bhcs", qh.astype(jnp.float32),
                       kt.astype(jnp.float32)) * scale
        s = _softcap(s, softcap)
        m = _attn_mask(qp, k_pos, causal=causal, window=window,
                       k_len_valid=k_len_valid)
        s = jnp.where(m[:, None] if m.ndim == 3 else m, s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhcs,bhsd->bhcd", p, vt.astype(jnp.float32))
        return o.transpose(0, 2, 1, 3).astype(q.dtype)

    if Sq <= q_chunk or Sq % q_chunk != 0:
        return one_chunk(q, q_pos)

    n = Sq // q_chunk
    qs = q.reshape(B, n, q_chunk, H, hd).transpose(1, 0, 2, 3, 4)
    ps = q_pos.reshape(*q_pos.shape[:-1], n, q_chunk)
    ps = jnp.moveaxis(ps, -2, 0)

    def body(_, qp):
        return None, one_chunk(*qp)

    # flash-attention-style recompute: don't let scan's backward save the
    # (B,Hk,G,chunk,Sk) probability residuals of every chunk
    _, outs = jax.lax.scan(jax.checkpoint(body), None, (qs, ps))
    return outs.transpose(1, 0, 2, 3, 4).reshape(B, Sq, H, hd)


def attention(p, x, *, positions, rope_theta=10000.0, causal=True,
              window=None, softcap=None, kv_cache=None, cache_len=None,
              use_rope=True, q_chunk=None, query_pre_attn_scalar=None):
    """Full attention sub-layer: qkv proj -> rope -> sdpa -> out proj.

    ``kv_cache``: None (training/prefill over x itself) or dict with
    "k","v" of shape (B, Smax, Hk, hd) plus ``cache_len`` — decode mode:
    x is the new token(s), cache is updated at ``cache_len``.
    Returns (out, new_cache).
    """
    B, S, D = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if "bq" in p:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    if query_pre_attn_scalar is not None:
        # gemma-style: scale q by 1/sqrt(s) instead of 1/sqrt(hd); fold in the
        # ratio so sdpa's 1/sqrt(hd) combines to 1/sqrt(s).
        hd = q.shape[-1]
        q = q * math.sqrt(hd / query_pre_attn_scalar)
    if use_rope:
        q = rope(q, positions, rope_theta)
        k = rope(k, positions, rope_theta)
    q = constrain(q, "act_batch", None, "act_heads", None)

    if kv_cache is None:
        out = sdpa(q, k, v, q_pos=positions, k_pos=positions, causal=causal,
                   window=window, softcap=softcap, q_chunk=q_chunk)
        new_cache = None
    else:
        clen = jnp.asarray(cache_len)
        if clen.ndim == 1:      # ragged decode: per-row write offsets
            upd = jax.vmap(
                lambda c, new, start: jax.lax.dynamic_update_slice_in_dim(
                    c, new, start, axis=0))
            ck = upd(kv_cache["k"], k.astype(kv_cache["k"].dtype), clen)
            cv = upd(kv_cache["v"], v.astype(kv_cache["v"].dtype), clen)
        else:
            ck = jax.lax.dynamic_update_slice_in_dim(
                kv_cache["k"], k.astype(kv_cache["k"].dtype), cache_len,
                axis=1)
            cv = jax.lax.dynamic_update_slice_in_dim(
                kv_cache["v"], v.astype(kv_cache["v"].dtype), cache_len,
                axis=1)
        Smax = ck.shape[1]
        k_pos = jnp.arange(Smax)
        out = sdpa(q, ck, cv, q_pos=positions, k_pos=k_pos, causal=causal,
                   window=window, softcap=softcap,
                   k_len_valid=cache_len + S, q_chunk=q_chunk)
        new_cache = {"k": ck, "v": cv}

    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    y = constrain(y, "act_batch", "act_seq", None)
    return y, new_cache


def attention_cache_spec(cfg, batch: int, max_len: int,
                         kv_seq_axis: str = "act_kv_seq"):
    """ShapeDtypeStruct + logical axes for one layer's KV cache."""
    shape = (batch, max_len, cfg.n_kv_heads, cfg.head_dim)
    heads_shardable = cfg.n_kv_heads % 16 == 0
    if heads_shardable:
        axes = ("act_batch", None, "act_heads", None)
    else:
        axes = ("act_batch", kv_seq_axis, None, None)
    return jax.ShapeDtypeStruct(shape, jnp.bfloat16), axes


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def mlp_spec(d_model: int, d_ff: int, gated: bool) -> dict:
    spec = {"w_up": Pm((d_model, d_ff), ("embed", "ff")),
            "w_down": Pm((d_ff, d_model), ("ff", "embed"))}
    if gated:
        spec["w_gate"] = Pm((d_model, d_ff), ("embed", "ff"))
    return spec


def mlp(p, x, activation: str = "gelu"):
    act = {"gelu": partial(jax.nn.gelu, approximate=True),
           "silu": jax.nn.silu, "relu": jax.nn.relu}[activation]
    h = jnp.einsum("bsd,df->bsf", x, p["w_up"])
    if "w_gate" in p:
        g = jnp.einsum("bsd,df->bsf", x, p["w_gate"])
        h = act(g) * h
    else:
        h = act(h)
    h = constrain(h, "act_batch", None, "act_ff")
    y = jnp.einsum("bsf,fd->bsd", h, p["w_down"])
    return constrain(y, "act_batch", "act_seq", None)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def embed_spec(vocab: int, d_model: int) -> dict:
    # 1/sqrt(d) keeps tied-unembedding logits O(1) after the final norm.
    # The embed dim is deliberately NOT FSDP-sharded: a ('model','data')
    # table makes the unembed backward all-gather the full (B,c,V) logit
    # cotangent on every chip (XLA must reshard the table grad to 'data' on
    # d while 'data' is busy on the contraction) — observed +29 GiB/chip.
    # Vocab over TP alone keeps the table at vocab/16 per chip.
    return {"table": Pm((vocab, d_model), ("vocab", "unsharded"),
                        scale=1.0 / math.sqrt(d_model))}


def embed(p, tokens, scale_by_dim: bool = False):
    # identity constraint matching the table's own sharding: free in the
    # forward, but it pins the COTANGENT sharding in the backward — without
    # it the gather-bwd scatter materialises the full (V, D) fp32 table
    # gradient replicated on every chip (observed ~17 GiB on gemma2 train)
    t = constrain(p["table"], "act_vocab", None)
    x = jnp.take(t, tokens, axis=0)
    if scale_by_dim:
        x = x * math.sqrt(p["table"].shape[1])
    return constrain(x, "act_batch", "act_seq", None)


def unembed(p, x, softcap: float | None = None):
    logits = jnp.einsum("bsd,vd->bsv", x, p["table"]).astype(jnp.float32)
    logits = _softcap(logits, softcap)
    # TP layout: batch over data, vocab over model (seq stays unsharded —
    # it is already chunked by the loss and the vocab dim carries the TP).
    return constrain(logits, "act_batch", None, "act_vocab")


# ---------------------------------------------------------------------------
# Loss (chunked over sequence so (B,S,V) logits never fully materialise)
# ---------------------------------------------------------------------------

def cross_entropy_loss(embed_params, x, labels, *, softcap=None,
                       seq_chunk: int | None = None):
    """x: (B, S, D) final hidden; labels: (B, S) int32; returns mean nll.

    ``seq_chunk`` bounds the materialised logits to (B, chunk, V).

    The gold-label logit is computed as ⟨x, table[label]⟩ — NOT via
    ``take_along_axis`` on the logits: indexing the vocab-sharded logits
    makes XLA all-gather them to every chip (observed 7.3 GiB/chip × several
    copies on internvl2 train — EXPERIMENTS.md §Perf).  The logsumexp runs
    on the vocab-sharded logits (partial reductions + small all-reduce).
    """
    B, S, D = x.shape

    def chunk_loss(xc, yc):
        logits = unembed(embed_params, xc, softcap=softcap)
        logz = jax.nn.logsumexp(logits, axis=-1)
        # gold logit via a vocab-iota mask: the masked sum reduces the
        # *sharded* vocab axis locally + a tiny all-reduce, whereas
        # take_along_axis would all-gather the full logits to every chip
        mask = yc[..., None] == jax.lax.broadcasted_iota(
            jnp.int32, logits.shape, 2)
        gold = jnp.sum(jnp.where(mask, logits, 0.0), axis=-1)
        valid = yc >= 0                     # label -1 = ignore (VLM prefix)
        return jnp.sum(jnp.where(valid, logz - gold, 0.0)), \
            jnp.sum(valid.astype(jnp.float32))

    # gather the sequence dim once in bf16 (the chunked loss consumes
    # seq-contiguous blocks; leaving the SP sharding in place makes XLA
    # keep fp32 full-sequence cotangent copies around the reshape)
    x = constrain(x, "act_batch", None, None)
    if seq_chunk is not None and S % seq_chunk != 0:
        # largest divisor of S not exceeding the requested chunk
        seq_chunk = next(c for c in range(min(seq_chunk, S), 0, -1)
                         if S % c == 0)
    if seq_chunk is None or S <= seq_chunk:
        total, count = chunk_loss(x, labels)
    else:
        n = S // seq_chunk
        xs = x.reshape(B, n, seq_chunk, D).transpose(1, 0, 2, 3)
        ys = labels.reshape(B, n, seq_chunk).transpose(1, 0, 2)

        def body(acc, xy):
            t, c = chunk_loss(*xy)
            return (acc[0] + t, acc[1] + c), None

        # recompute (B, chunk, V) logits per chunk in the backward
        (total, count), _ = jax.lax.scan(
            jax.checkpoint(body), (jnp.float32(0.0), jnp.float32(0.0)),
            (xs, ys))
    return total / jnp.maximum(count, 1.0)
