"""Generic decoder-only LM over per-layer sub-layer patterns.

One class covers the dense, MoE, SSM, hybrid and VLM-backbone architectures:
the config's ``pattern`` lists each layer's sub-layer kinds, the whole stack
runs as a ``lax.scan`` over *groups* (one pattern repetition) with stacked
parameters — so the lowered HLO contains a single group body regardless of
depth, which keeps 512-way SPMD compiles tractable.  zamba2-style shared
blocks live outside the scanned stack and are applied once per group.

Execution modes:
* ``forward``      — full-sequence training path (remat per group),
* ``prefill``      — full sequence, builds decode caches,
* ``decode_step``  — one token against the caches (serve_step).
"""

from __future__ import annotations

import math
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.runtime.sharding import constrain
from . import layers as L
from .layers import Pm
from .moe import moe, moe_spec
from .ssm import mamba2, mamba2_spec, mamba2_state_specs, mamba2_dims
from .xlstm import (mlstm, mlstm_spec, mlstm_state_specs, slstm, slstm_spec,
                    slstm_state_specs)


# ---------------------------------------------------------------------------
# Per-sub-layer specs and application
# ---------------------------------------------------------------------------

def _sub_spec(cfg, kind: str) -> dict:
    norm_spec, _ = L.make_norm(cfg.norm, cfg.d_model)
    if kind in ("attn", "attn_local"):
        s = {"norm": norm_spec,
             "attn": L.attention_spec(cfg.d_model, cfg.n_heads,
                                      cfg.n_kv_heads, cfg.head_dim,
                                      qkv_bias=cfg.qkv_bias)}
        if cfg.post_block_norm:
            s["post_norm"] = norm_spec
        return s
    if kind == "mlp":
        s = {"norm": norm_spec,
             "mlp": L.mlp_spec(cfg.d_model, cfg.d_ff, gated=cfg.mlp_gated)}
        if cfg.post_block_norm:
            s["post_norm"] = norm_spec
        return s
    if kind == "moe":
        return {"norm": norm_spec,
                "moe": moe_spec(cfg.d_model, cfg.d_ff, cfg.moe_experts,
                                n_shared=1 if cfg.moe_shared_dff else 0,
                                d_shared=cfg.moe_shared_dff)}
    if kind == "mamba2":
        return {"norm": norm_spec, "core": mamba2_spec(cfg)}
    if kind == "mlstm":
        return {"norm": norm_spec, "core": mlstm_spec(cfg)}
    if kind == "slstm":
        return {"core": slstm_spec(cfg)}
    raise ValueError(kind)


def _norm(cfg):
    return L.rmsnorm if cfg.norm == "rmsnorm" else L.layernorm


def apply_sublayer(cfg, kind, p, x, *, positions, cache, cache_len, mode):
    """Returns (x, new_cache, aux)."""
    normf = _norm(cfg)
    aux = jnp.float32(0.0)

    if kind in ("attn", "attn_local"):
        h = normf(p["norm"], x)
        window = cfg.window if kind == "attn_local" else None
        h, new_cache = L.attention(
            p["attn"], h, positions=positions, rope_theta=cfg.rope_theta,
            causal=True, window=window, softcap=cfg.attn_softcap,
            kv_cache=cache, cache_len=cache_len, use_rope=cfg.use_rope,
            q_chunk=cfg.q_chunk,
            query_pre_attn_scalar=cfg.query_pre_attn_scalar)
        if cfg.post_block_norm:
            h = normf(p["post_norm"], h)
        return x + h, new_cache, aux

    if kind == "mlp":
        h = normf(p["norm"], x)
        h = L.mlp(p["mlp"], h, activation=cfg.activation)
        if cfg.post_block_norm:
            h = normf(p["post_norm"], h)
        return x + h, None, aux

    if kind == "moe":
        h = normf(p["norm"], x)
        h, aux = moe(p["moe"], h, top_k=cfg.moe_top_k,
                     n_experts=cfg.moe_experts,
                     capacity_factor=cfg.moe_capacity_factor,
                     activation=cfg.activation,
                     group_size=cfg.moe_group_size,
                     impl=cfg.moe_impl)
        return x + h, None, aux

    if kind == "mamba2":
        h = normf(p["norm"], x)
        st = cache or {}
        h, (ssm_st, conv_st) = mamba2(p["core"], cfg, h,
                                      state=st.get("ssm"),
                                      conv_state=st.get("conv"),
                                      decode=(mode == "decode"))
        new_cache = None if mode == "train" else \
            {"ssm": ssm_st, "conv": conv_st}
        return x + h, new_cache, aux

    if kind == "mlstm":
        h = normf(p["norm"], x)
        st = cache or {}
        h, (mat, conv_st) = mlstm(p["core"], cfg, h, state=st.get("mat"),
                                  conv_state=st.get("conv"),
                                  decode=(mode == "decode"))
        new_cache = None if mode == "train" else \
            {"mat": mat, "conv": conv_st}
        return x + h, new_cache, aux

    if kind == "slstm":
        st = cache.get("s") if cache else None
        x, new_st = slstm(p["core"], cfg, x, state=st,
                          decode=(mode == "decode"))
        new_cache = None if mode == "train" else {"s": new_st}
        return x, new_cache, aux

    raise ValueError(kind)


def _sub_cache_spec(cfg, kind: str, batch: int, max_len: int):
    """(ShapeDtypeStruct, logical-axes) pytree for one sub-layer's cache."""
    if kind in ("attn", "attn_local"):
        sds, axes = L.attention_cache_spec(cfg, batch, max_len)
        return {"k": (sds, axes), "v": (sds, axes)}
    if kind == "mamba2":
        (s, sa), (c, ca) = mamba2_state_specs(cfg, batch)
        return {"ssm": (s, sa), "conv": (c, ca)}
    if kind == "mlstm":
        (m, ma), (c, ca) = mlstm_state_specs(cfg, batch)
        return {"mat": (m, ma), "conv": (c, ca)}
    if kind == "slstm":
        return {"s": tuple(slstm_state_specs(cfg, batch))}
    return None


# ---------------------------------------------------------------------------
# The model
# ---------------------------------------------------------------------------

class DecoderLM:
    def __init__(self, cfg):
        self.cfg = cfg
        self.kinds = list(cfg.group_kinds)
        self.sub_names = [f"s{i}_{k}" for i, k in enumerate(self.kinds)]

    # -- parameter trees ----------------------------------------------------
    def group_spec(self) -> dict:
        return {n: _sub_spec(self.cfg, k)
                for n, k in zip(self.sub_names, self.kinds)}

    def spec(self) -> dict:
        cfg = self.cfg
        norm_spec, _ = L.make_norm(cfg.norm, cfg.d_model)
        spec = {
            "embed": L.embed_spec(cfg.vocab, cfg.d_model),
            "final_norm": norm_spec,
            "layers": L.stack_spec(self.group_spec(), cfg.n_groups),
        }
        if cfg.shared_attn_period:
            spec["shared_block"] = {
                "norm1": norm_spec,
                "attn": L.attention_spec(cfg.d_model, cfg.n_heads,
                                         cfg.n_kv_heads, cfg.head_dim),
                "norm2": norm_spec,
                "mlp": L.mlp_spec(cfg.d_model, cfg.d_ff, gated=cfg.mlp_gated),
            }
        return spec

    def init(self, key, dtype=jnp.bfloat16):
        return L.init_tree(self.spec(), key, dtype)

    def abstract_params(self, dtype=jnp.bfloat16):
        return L.abstract_tree(self.spec(), dtype)

    def param_axes(self):
        return L.axes_tree(self.spec())

    # -- caches ---------------------------------------------------------------
    def cache_spec(self, batch: int, max_len: int) -> dict:
        """Stacked (G, ...) decode-cache specs: {(name): {leaf: (sds, axes)}}."""
        cfg = self.cfg
        out = {}
        for n, k in zip(self.sub_names, self.kinds):
            sub = _sub_cache_spec(cfg, k, batch, max_len)
            if sub is None:
                continue
            out[n] = jax.tree.map(
                lambda t: (jax.ShapeDtypeStruct((cfg.n_groups, *t[0].shape),
                                                t[0].dtype),
                           ("layers", *t[1])),
                sub, is_leaf=lambda t: isinstance(t, tuple) and len(t) == 2
                and hasattr(t[0], "shape"))
        if cfg.shared_attn_period:
            sds, axes = L.attention_cache_spec(cfg, batch, max_len)
            out["shared_attn"] = {
                "k": (jax.ShapeDtypeStruct((cfg.n_groups, *sds.shape),
                                           sds.dtype), ("layers", *axes)),
                "v": (jax.ShapeDtypeStruct((cfg.n_groups, *sds.shape),
                                           sds.dtype), ("layers", *axes)),
            }
        return out

    def init_cache(self, batch: int, max_len: int):
        spec = self.cache_spec(batch, max_len)
        return jax.tree.map(
            lambda t: jnp.zeros(t[0].shape, t[0].dtype), spec,
            is_leaf=lambda t: isinstance(t, tuple) and len(t) == 2)

    # -- stack ---------------------------------------------------------------
    def _apply_group(self, params_g, shared, x, cache_g, *, positions,
                     cache_len, mode):
        aux = jnp.float32(0.0)
        new_cache = {}
        for n, k in zip(self.sub_names, self.kinds):
            c = cache_g.get(n) if cache_g else None
            x, nc, a = apply_sublayer(self.cfg, k, params_g[n], x,
                                      positions=positions, cache=c,
                                      cache_len=cache_len, mode=mode)
            aux = aux + a
            if nc is not None:
                new_cache[n] = nc
        if shared is not None:
            normf = _norm(self.cfg)
            h = normf(shared["norm1"], x)
            c = cache_g.get("shared_attn") if cache_g else None
            h, nc = L.attention(shared["attn"], h, positions=positions,
                                rope_theta=self.cfg.rope_theta, causal=True,
                                kv_cache=c, cache_len=cache_len,
                                q_chunk=self.cfg.q_chunk)
            x = x + h
            h = normf(shared["norm2"], x)
            x = x + L.mlp(shared["mlp"], h, activation=self.cfg.activation)
            if nc is not None:
                new_cache["shared_attn"] = nc
        return x, new_cache, aux

    def _stack(self, params, x, caches, *, positions, cache_len, mode):
        cfg = self.cfg
        shared = params.get("shared_block")

        def body_fn(x, params_g, cache_g):
            return self._apply_group(params_g, shared, x, cache_g,
                                     positions=positions,
                                     cache_len=cache_len, mode=mode)

        if cfg.remat and mode == "train":
            body_fn = jax.checkpoint(body_fn)

        if not cfg.scan_layers:
            # unrolled stack — used by the dry-run costing variants (XLA
            # cost analysis counts a while body once, so scanned layers are
            # invisible to it; an unrolled 2-vs-3-group pair recovers the
            # true per-group cost slope)
            aux = jnp.float32(0.0)
            new_caches = caches
            for gi in range(cfg.n_groups):
                pg = jax.tree.map(lambda a: a[gi], params["layers"])
                cg = (None if caches is None else
                      jax.tree.map(lambda c: c[gi], new_caches))
                x, ncg, a = body_fn(x, pg, cg)
                aux = aux + a
                if caches is not None:
                    new_caches = jax.tree.map(
                        lambda c, nv: c.at[gi].set(nv.astype(c.dtype)),
                        new_caches, ncg)
            return x, new_caches, aux

        if caches is None:
            def body(carry, pg):
                x, aux = carry
                x, _, a = body_fn(x, pg, None)
                return (x, aux + a), None

            (x, aux), _ = jax.lax.scan(body, (x, jnp.float32(0.0)),
                                       params["layers"])
            return x, None, aux

        # Caches ride in the CARRY (updated in place with dynamic slices),
        # not as scan xs/ys — xs+ys would hold the old and new cache
        # simultaneously, doubling decode HBM (observed +7 GiB on
        # gemma-7b decode_32k; EXPERIMENTS.md §Perf).
        def body(carry, xs):
            x, aux, caches = carry
            pg, g = xs
            cg = jax.tree.map(
                lambda c: jax.lax.dynamic_index_in_dim(c, g, 0,
                                                       keepdims=False),
                caches)
            x, ncg, a = body_fn(x, pg, cg)
            caches = jax.tree.map(
                lambda c, n: jax.lax.dynamic_update_index_in_dim(
                    c, n.astype(c.dtype), g, 0), caches, ncg)
            return (x, aux + a, caches), None

        (x, aux, new_caches), _ = jax.lax.scan(
            body, (x, jnp.float32(0.0), caches),
            (params["layers"], jnp.arange(cfg.n_groups, dtype=jnp.int32)))
        return x, new_caches, aux

    # -- entry points ---------------------------------------------------------
    def _embed_inputs(self, params, tokens, patch_embeds=None):
        x = L.embed(params["embed"], tokens,
                    scale_by_dim=self.cfg.embed_scale)
        if patch_embeds is not None:
            x = jnp.concatenate([patch_embeds.astype(x.dtype), x], axis=1)
        return constrain(x, "act_batch", "act_seq", None)

    def forward(self, params, tokens, patch_embeds=None):
        """Training/eval full-sequence pass -> final hidden states."""
        x = self._embed_inputs(params, tokens, patch_embeds)
        S = x.shape[1]
        positions = jnp.arange(S, dtype=jnp.int32)[None, :]
        x, _, aux = self._stack(params, x, None, positions=positions,
                                cache_len=None, mode="train")
        normf = _norm(self.cfg)
        return normf(params["final_norm"], x), aux

    def loss(self, params, batch):
        """batch: {"tokens": (B,S), "labels": (B,S) [, "patch_embeds"]}"""
        hidden, aux = self.forward(params, batch["tokens"],
                                   batch.get("patch_embeds"))
        labels = batch["labels"]
        n_img = self.cfg.n_img_tokens if "patch_embeds" in batch else 0
        if n_img:
            # keep the full (sharded) sequence; image positions carry
            # label -1 and are masked inside the loss — slicing the
            # seq-sharded hidden would force a reshard of every cotangent
            pad = jnp.full((labels.shape[0], n_img), -1, labels.dtype)
            labels = jnp.concatenate([pad, labels], axis=1)
        nll = L.cross_entropy_loss(params["embed"], hidden, labels,
                                   softcap=self.cfg.final_softcap,
                                   seq_chunk=self.cfg.loss_seq_chunk)
        return nll + 0.01 * aux, {"nll": nll, "aux": aux}

    def prefill(self, params, tokens, cache, patch_embeds=None):
        """Fill caches with the prompt; returns (last_logits, caches)."""
        x = self._embed_inputs(params, tokens, patch_embeds)
        S = x.shape[1]
        positions = jnp.arange(S, dtype=jnp.int32)[None, :]
        x, caches, _ = self._stack(params, x, cache, positions=positions,
                                   cache_len=jnp.int32(0), mode="prefill")
        normf = _norm(self.cfg)
        hidden = normf(params["final_norm"], x[:, -1:])
        logits = L.unembed(params["embed"], hidden,
                           softcap=self.cfg.final_softcap)
        return logits, caches

    def decode_step(self, params, token, cache, cache_len):
        """token: (B, 1) int32; cache_len: filled length — scalar for
        uniform decode (fleet cells) or (B,) for ragged serving batches."""
        x = self._embed_inputs(params, token)
        clen = jnp.asarray(cache_len)
        if clen.ndim == 1:
            positions = clen[:, None] + jnp.arange(1, dtype=jnp.int32)[None]
        else:
            positions = (clen + jnp.arange(1, dtype=jnp.int32))[None, :]
        x, caches, _ = self._stack(params, x, cache, positions=positions,
                                   cache_len=cache_len, mode="decode")
        normf = _norm(self.cfg)
        hidden = normf(params["final_norm"], x)
        logits = L.unembed(params["embed"], hidden,
                           softcap=self.cfg.final_softcap)
        return logits, caches
