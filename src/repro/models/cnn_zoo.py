"""The paper's 18 Keras CNNs as runnable JAX layer graphs (Table I).

Each constructor builds a :class:`repro.core.graph.LayerGraph` with real
(randomly-initialised) weights and jnp forward functions — Scission
benchmarks *timing and output sizes*, which do not depend on trained
weights, so these graphs reproduce the paper's benchmarking subjects
faithfully: same topology class (linear vs branching), same layer kinds,
same tensor shapes, hence the same partition points and output-data sizes.

NASNetMobile/NASNetLarge and InceptionResNetV2 use structurally faithful
cell-based constructions (correct cell counts, branch widths per the papers)
rather than op-for-op clones; they are tagged ``approx=True`` and the
deviation is noted in EXPERIMENTS.md.
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import LayerGraph, LayerNode

# NHWC everywhere.
_KEY = [jax.random.PRNGKey(1234)]


def _next_key():
    _KEY[0], k = jax.random.split(_KEY[0])
    return k


def _conv_node(name, cin, cout, k=3, stride=1, padding="SAME", groups=1,
               act="relu", bias=True):
    w = (jax.random.normal(_next_key(), (k, k, cin // groups, cout))
         * math.sqrt(2.0 / (k * k * cin))).astype(jnp.float32)
    b = jnp.zeros((cout,), jnp.float32) if bias else None

    def apply(x):
        y = jax.lax.conv_general_dilated(
            x, w, (stride, stride), padding,
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
            feature_group_count=groups)
        if b is not None:
            y = y + b
        if act == "relu":
            y = jax.nn.relu(y)
        elif act == "relu6":
            y = jnp.clip(y, 0, 6)
        return y

    def flops_fn(ins, out):
        # 2 * k*k * (cin/groups) * spatial_out * cout * batch
        return 2.0 * k * k * (cin // groups) * int(np.prod(out.shape))

    return LayerNode(name=name, kind="conv", apply=apply,
                     flops_fn=flops_fn,
                     param_bytes=int(np.prod(w.shape)) * 4
                     + (cout * 4 if bias else 0))


def _dw_conv_node(name, c, k=3, stride=1, act="relu6"):
    return _conv_node(name, c, c, k=k, stride=stride, groups=c, act=act)


def _pool_node(name, k=2, stride=2, kind="max", padding="VALID"):
    def apply(x):
        if kind == "max":
            return jax.lax.reduce_window(
                x, -jnp.inf, jax.lax.max, (1, k, k, 1),
                (1, stride, stride, 1), padding)
        return jax.lax.reduce_window(
            x, 0.0, jax.lax.add, (1, k, k, 1), (1, stride, stride, 1),
            padding) / (k * k)

    return LayerNode(name=name, kind="pool", apply=apply)


def _gap_node(name="gap"):
    return LayerNode(name=name, kind="pool",
                     apply=lambda x: jnp.mean(x, axis=(1, 2)))


def _dense_node(name, cin, cout, act=None):
    w = (jax.random.normal(_next_key(), (cin, cout))
         * math.sqrt(2.0 / cin)).astype(jnp.float32)
    b = jnp.zeros((cout,), jnp.float32)

    def apply(x):
        y = x @ w + b
        if act == "relu":
            y = jax.nn.relu(y)
        if act == "softmax":
            y = jax.nn.softmax(y, axis=-1)
        return y

    return LayerNode(name=name, kind="dense", apply=apply,
                     flops_fn=lambda ins, out: 2.0 * cin * cout
                     * (int(np.prod(out.shape)) // cout),
                     param_bytes=(cin + 1) * cout * 4)


def _flatten_node(name="flatten"):
    return LayerNode(name=name, kind="reshape",
                     apply=lambda x: x.reshape(x.shape[0], -1))


def _add_node(name="add"):
    return LayerNode(name=name, kind="merge", apply=lambda *xs: sum(xs))


def _concat_node(name="concat"):
    return LayerNode(name=name, kind="merge",
                     apply=lambda *xs: jnp.concatenate(xs, axis=-1))


def _input(g: LayerGraph, res: int):
    return g.input(jax.ShapeDtypeStruct((1, res, res, 3), jnp.float32))


# ---------------------------------------------------------------------------
# VGG (linear)
# ---------------------------------------------------------------------------

def _vgg(name: str, cfg: list) -> LayerGraph:
    g = LayerGraph(name)
    prev = _input(g, 224)
    cin = 3
    bi = 0
    for item in cfg:
        if item == "M":
            prev = g.add(_pool_node(f"pool{bi}"), [prev])
            bi += 1
        else:
            prev = g.add(_conv_node(f"conv{bi}", cin, item), [prev])
            cin = item
            bi += 1
    prev = g.add(_flatten_node(), [prev])
    prev = g.add(_dense_node("fc1", cin * 7 * 7, 4096, act="relu"), [prev])
    prev = g.add(_dense_node("fc2", 4096, 4096, act="relu"), [prev])
    prev = g.add(_dense_node("pred", 4096, 1000, act="softmax"), [prev])
    g.trace()
    return g


def vgg16() -> LayerGraph:
    return _vgg("VGG16", [64, 64, "M", 128, 128, "M", 256, 256, 256, "M",
                          512, 512, 512, "M", 512, 512, 512, "M"])


def vgg19() -> LayerGraph:
    return _vgg("VGG19", [64, 64, "M", 128, 128, "M", 256, 256, 256, 256,
                          "M", 512, 512, 512, 512, "M", 512, 512, 512, 512,
                          "M"])


# ---------------------------------------------------------------------------
# ResNet v1 / v2 (branching)
# ---------------------------------------------------------------------------

def _resnet(name: str, blocks_per_stage: list[int], v2: bool = False
            ) -> LayerGraph:
    g = LayerGraph(name)
    prev = _input(g, 224)
    prev = g.add(_conv_node("stem_conv", 3, 64, k=7, stride=2), [prev])
    prev = g.add(_pool_node("stem_pool", k=3, stride=2, padding="SAME"),
                 [prev])
    cin = 64
    widths = [64, 128, 256, 512]
    for si, (n_blocks, w) in enumerate(zip(blocks_per_stage, widths)):
        for bi in range(n_blocks):
            stride = 2 if (bi == 0 and si > 0) else 1
            cout = w * 4
            tag = f"s{si}b{bi}"
            # main path: 1x1 -> 3x3 -> 1x1
            a = g.add(_conv_node(f"{tag}_c1", cin, w, k=1, stride=stride),
                      [prev])
            b = g.add(_conv_node(f"{tag}_c2", w, w, k=3), [a])
            c = g.add(_conv_node(f"{tag}_c3", w, cout, k=1, act=None), [b])
            if cin != cout or stride != 1:
                sc = g.add(_conv_node(f"{tag}_sc", cin, cout, k=1,
                                      stride=stride, act=None), [prev])
            else:
                sc = prev
            prev = g.add(_add_node(f"{tag}_add"), [c, sc])
            cin = cout
    prev = g.add(_gap_node(), [prev])
    prev = g.add(_dense_node("pred", cin, 1000, act="softmax"), [prev])
    g.trace()
    return g


def resnet50():
    return _resnet("ResNet50", [3, 4, 6, 3])


def resnet101():
    return _resnet("ResNet101", [3, 4, 23, 3])


def resnet152():
    return _resnet("ResNet152", [3, 8, 36, 3])


def resnet50v2():
    return _resnet("ResNet50V2", [3, 4, 6, 3], v2=True)


def resnet101v2():
    return _resnet("ResNet101V2", [3, 4, 23, 3], v2=True)


def resnet152v2():
    return _resnet("ResNet152V2", [3, 8, 36, 3], v2=True)


# ---------------------------------------------------------------------------
# MobileNet v1 (linear) / v2 (branching)
# ---------------------------------------------------------------------------

def mobilenet() -> LayerGraph:
    g = LayerGraph("MobileNet")
    prev = _input(g, 224)
    prev = g.add(_conv_node("stem", 3, 32, stride=2, act="relu6"), [prev])
    cin = 32
    plan = [(64, 1), (128, 2), (128, 1), (256, 2), (256, 1), (512, 2),
            *[(512, 1)] * 5, (1024, 2), (1024, 1)]
    for i, (cout, s) in enumerate(plan):
        prev = g.add(_dw_conv_node(f"dw{i}", cin, stride=s), [prev])
        prev = g.add(_conv_node(f"pw{i}", cin, cout, k=1, act="relu6"),
                     [prev])
        cin = cout
    prev = g.add(_gap_node(), [prev])
    prev = g.add(_dense_node("pred", cin, 1000, act="softmax"), [prev])
    g.trace()
    return g


def mobilenetv2() -> LayerGraph:
    g = LayerGraph("MobileNetV2")
    prev = _input(g, 224)
    prev = g.add(_conv_node("stem", 3, 32, stride=2, act="relu6"), [prev])
    cin = 32
    # (expansion, out, n, stride)
    plan = [(1, 16, 1, 1), (6, 24, 2, 2), (6, 32, 3, 2), (6, 64, 4, 2),
            (6, 96, 3, 1), (6, 160, 3, 2), (6, 320, 1, 1)]
    idx = 0
    for t, c, n, s in plan:
        for bi in range(n):
            stride = s if bi == 0 else 1
            tag = f"b{idx}"
            mid = cin * t
            a = prev
            if t != 1:
                a = g.add(_conv_node(f"{tag}_exp", cin, mid, k=1,
                                     act="relu6"), [a])
            a = g.add(_dw_conv_node(f"{tag}_dw", mid, stride=stride), [a])
            a = g.add(_conv_node(f"{tag}_proj", mid, c, k=1, act=None), [a])
            if stride == 1 and cin == c:
                prev = g.add(_add_node(f"{tag}_add"), [a, prev])
            else:
                prev = a
            cin = c
            idx += 1
    prev = g.add(_conv_node("head", cin, 1280, k=1, act="relu6"), [prev])
    prev = g.add(_gap_node(), [prev])
    prev = g.add(_dense_node("pred", 1280, 1000, act="softmax"), [prev])
    g.trace()
    return g


# ---------------------------------------------------------------------------
# DenseNet (branching: dense blocks fuse)
# ---------------------------------------------------------------------------

def _densenet(name: str, blocks: list[int], growth: int = 32) -> LayerGraph:
    g = LayerGraph(name)
    prev = _input(g, 224)
    prev = g.add(_conv_node("stem", 3, 64, k=7, stride=2), [prev])
    prev = g.add(_pool_node("stem_pool", k=3, stride=2, padding="SAME"),
                 [prev])
    cin = 64
    for si, n in enumerate(blocks):
        for bi in range(n):
            tag = f"d{si}b{bi}"
            a = g.add(_conv_node(f"{tag}_bn1", cin, 4 * growth, k=1), [prev])
            a = g.add(_conv_node(f"{tag}_conv", 4 * growth, growth, k=3),
                      [a])
            prev = g.add(_concat_node(f"{tag}_cat"), [prev, a])
            cin += growth
        if si < len(blocks) - 1:
            cin //= 2
            prev = g.add(_conv_node(f"t{si}_conv", cin * 2, cin, k=1),
                         [prev])
            prev = g.add(_pool_node(f"t{si}_pool", kind="avg"), [prev])
    prev = g.add(_gap_node(), [prev])
    prev = g.add(_dense_node("pred", cin, 1000, act="softmax"), [prev])
    g.trace()
    return g


def densenet121():
    return _densenet("DenseNet121", [6, 12, 24, 16])


def densenet169():
    return _densenet("DenseNet169", [6, 12, 32, 32])


def densenet201():
    return _densenet("DenseNet201", [6, 12, 48, 32])


# ---------------------------------------------------------------------------
# Inception V3 (branching)
# ---------------------------------------------------------------------------

def _inception_block(g, prev, cin, tag, widths):
    """4 parallel towers concatenated (simplified InceptionV3 cell)."""
    w1, w5, w3, wp = widths
    t1 = g.add(_conv_node(f"{tag}_1x1", cin, w1, k=1), [prev])
    t5a = g.add(_conv_node(f"{tag}_5r", cin, w5 // 2, k=1), [prev])
    t5 = g.add(_conv_node(f"{tag}_5x5", w5 // 2, w5, k=5), [t5a])
    t3a = g.add(_conv_node(f"{tag}_3r", cin, w3 // 2, k=1), [prev])
    t3b = g.add(_conv_node(f"{tag}_3x3a", w3 // 2, w3, k=3), [t3a])
    t3 = g.add(_conv_node(f"{tag}_3x3b", w3, w3, k=3), [t3b])
    tp1 = g.add(_pool_node(f"{tag}_pool", k=3, stride=1, kind="avg",
                           padding="SAME"), [prev])
    tp = g.add(_conv_node(f"{tag}_poolproj", cin, wp, k=1), [tp1])
    out = g.add(_concat_node(f"{tag}_cat"), [t1, t5, t3, tp])
    return out, w1 + w5 + w3 + wp


def inceptionv3() -> LayerGraph:
    g = LayerGraph("InceptionV3")
    prev = _input(g, 299)
    prev = g.add(_conv_node("stem1", 3, 32, stride=2, padding="VALID"),
                 [prev])
    prev = g.add(_conv_node("stem2", 32, 64, k=3), [prev])
    prev = g.add(_pool_node("stem_pool", k=3, stride=2), [prev])
    prev = g.add(_conv_node("stem3", 64, 80, k=1), [prev])
    prev = g.add(_conv_node("stem4", 80, 192, k=3, stride=2), [prev])
    cin = 192
    for i, widths in enumerate([(64, 64, 96, 32), (64, 64, 96, 64),
                                (64, 64, 96, 64)]):
        prev, cin = _inception_block(g, prev, cin, f"mix{i}", widths)
    prev = g.add(_pool_node("red0", k=3, stride=2), [prev])
    for i, widths in enumerate([(192, 128, 128, 192)] * 4):
        prev, cin = _inception_block(g, prev, cin, f"mid{i}", widths)
    prev = g.add(_pool_node("red1", k=3, stride=2), [prev])
    for i, widths in enumerate([(320, 192, 192, 192)] * 2):
        prev, cin = _inception_block(g, prev, cin, f"top{i}", widths)
    prev = g.add(_gap_node(), [prev])
    prev = g.add(_dense_node("pred", cin, 1000, act="softmax"), [prev])
    g.trace()
    return g


# ---------------------------------------------------------------------------
# Xception (branching, depthwise separable + residuals)
# ---------------------------------------------------------------------------

def xception() -> LayerGraph:
    g = LayerGraph("Xception")
    prev = _input(g, 299)
    prev = g.add(_conv_node("stem1", 3, 32, stride=2), [prev])
    prev = g.add(_conv_node("stem2", 32, 64), [prev])
    cin = 64
    for i, cout in enumerate([128, 256, 728]):
        tag = f"entry{i}"
        a = g.add(_dw_conv_node(f"{tag}_dw1", cin), [prev])
        a = g.add(_conv_node(f"{tag}_pw1", cin, cout, k=1), [a])
        a = g.add(_dw_conv_node(f"{tag}_dw2", cout), [a])
        a = g.add(_conv_node(f"{tag}_pw2", cout, cout, k=1, act=None), [a])
        a = g.add(_pool_node(f"{tag}_pool", k=3, stride=2, padding="SAME"),
                  [a])
        sc = g.add(_conv_node(f"{tag}_sc", cin, cout, k=1, stride=2,
                              act=None), [prev])
        prev = g.add(_add_node(f"{tag}_add"), [a, sc])
        cin = cout
    for i in range(8):
        tag = f"mid{i}"
        a = g.add(_dw_conv_node(f"{tag}_dw1", cin), [prev])
        a = g.add(_conv_node(f"{tag}_pw1", cin, cin, k=1), [a])
        a = g.add(_dw_conv_node(f"{tag}_dw2", cin), [a])
        a = g.add(_conv_node(f"{tag}_pw2", cin, cin, k=1, act=None), [a])
        prev = g.add(_add_node(f"{tag}_add"), [a, prev])
    prev = g.add(_conv_node("exit1", cin, 1024, k=1), [prev])
    prev = g.add(_gap_node(), [prev])
    prev = g.add(_dense_node("pred", 1024, 1000, act="softmax"), [prev])
    g.trace()
    return g


# ---------------------------------------------------------------------------
# InceptionResNetV2 / NASNet — structurally faithful approximations
# ---------------------------------------------------------------------------

def inception_resnet_v2() -> LayerGraph:
    """approx=True: correct cell counts (5×A, 10×B, 5×C) and widths."""
    g = LayerGraph("InceptionResNetV2")
    prev = _input(g, 299)
    prev = g.add(_conv_node("stem1", 3, 32, stride=2, padding="VALID"),
                 [prev])
    prev = g.add(_conv_node("stem2", 32, 64, k=3), [prev])
    prev = g.add(_pool_node("stem_pool", k=3, stride=2), [prev])
    prev = g.add(_conv_node("stem3", 64, 192, k=3, stride=2), [prev])
    prev = g.add(_conv_node("stem4", 192, 320, k=1), [prev])
    cin = 320
    for phase, (n, width) in enumerate([(5, 320), (10, 1088), (5, 2080)]):
        if phase > 0:
            prev = g.add(_conv_node(f"red{phase}", cin, width, k=3,
                                    stride=2), [prev])
            cin = width
        for i in range(n):
            tag = f"irb{phase}_{i}"
            a = g.add(_conv_node(f"{tag}_b1", cin, 32, k=1), [prev])
            b1 = g.add(_conv_node(f"{tag}_b2a", cin, 32, k=1), [prev])
            b = g.add(_conv_node(f"{tag}_b2b", 32, 48, k=3), [b1])
            cat = g.add(_concat_node(f"{tag}_cat"), [a, b])
            proj = g.add(_conv_node(f"{tag}_proj", 80, cin, k=1, act=None),
                         [cat])
            prev = g.add(_add_node(f"{tag}_add"), [proj, prev])
    prev = g.add(_gap_node(), [prev])
    prev = g.add(_dense_node("pred", cin, 1000, act="softmax"), [prev])
    g.trace()
    return g


def _nasnet(name: str, n_cells: int, width: int, res: int = 224
            ) -> LayerGraph:
    """approx=True: NASNet normal cells as 5-branch concat cells; the real
    cell wiring is messier but the partition-point structure (only 4 valid
    cuts — between reduction stages) matches Table I."""
    g = LayerGraph(name)
    prev = _input(g, res)
    prev = g.add(_conv_node("stem", 3, width, k=3, stride=2), [prev])
    cin = width
    per_stage = n_cells // 3
    for stage in range(3):
        if stage > 0:
            prev = g.add(_conv_node(f"red{stage}", cin, cin * 2, k=3,
                                    stride=2), [prev])
            cin *= 2
        # cells within a stage cross-link (use both of the previous two
        # outputs), so cuts inside a stage are invalid, like real NASNet
        prev2 = prev
        for ci in range(per_stage):
            tag = f"s{stage}c{ci}"
            b1 = g.add(_dw_conv_node(f"{tag}_dw3", cin), [prev])
            b1 = g.add(_conv_node(f"{tag}_pw1", cin, cin // 2, k=1), [b1])
            b2 = g.add(_dw_conv_node(f"{tag}_dw5", cin, k=5), [prev2])
            b2 = g.add(_conv_node(f"{tag}_pw2", cin, cin // 2, k=1), [b2])
            cat = g.add(_concat_node(f"{tag}_cat"), [b1, b2])
            new = g.add(_conv_node(f"{tag}_fit", cin, cin, k=1), [cat])
            prev2, prev = prev, new
        # close the stage: merge the dangling prev2 so the stage boundary
        # becomes a valid cut
        if per_stage > 0:
            prev = g.add(_add_node(f"s{stage}_merge"), [prev, prev2])
    prev = g.add(_gap_node(), [prev])
    prev = g.add(_dense_node("pred", cin, 1000, act="softmax"), [prev])
    g.trace()
    return g


def nasnet_mobile():
    return _nasnet("NASNetMobile", 12, 44)


def nasnet_large():
    return _nasnet("NASNetLarge", 18, 168, res=331)


# ---------------------------------------------------------------------------

ZOO: dict[str, callable] = {
    "Xception": xception,
    "VGG16": vgg16,
    "VGG19": vgg19,
    "ResNet50": resnet50,
    "ResNet101": resnet101,
    "ResNet152": resnet152,
    "ResNet50V2": resnet50v2,
    "ResNet101V2": resnet101v2,
    "ResNet152V2": resnet152v2,
    "InceptionV3": inceptionv3,
    "InceptionResNetV2": inception_resnet_v2,
    "MobileNet": mobilenet,
    "MobileNetV2": mobilenetv2,
    "DenseNet121": densenet121,
    "DenseNet169": densenet169,
    "DenseNet201": densenet201,
    "NASNetMobile": nasnet_mobile,
    "NASNetLarge": nasnet_large,
}

APPROX = {"InceptionResNetV2", "NASNetMobile", "NASNetLarge"}

# Table I linear/branching classification
LINEAR = {"VGG16", "VGG19", "MobileNet"}


def build(name: str) -> LayerGraph:
    return ZOO[name]()
