from .lm import DecoderLM
from .encdec import EncDecLM
from .registry import build_model, config_names, get_config, register

__all__ = ["DecoderLM", "EncDecLM", "build_model", "config_names",
           "get_config", "register"]
