"""Whisper-style encoder-decoder backbone.

The conv/mel frontend is a STUB per the assignment: ``input_specs`` supplies
precomputed frame embeddings (B, enc_len, d_model), i.e. the output the two
strided conv1d layers would produce.  Everything after that — sinusoidal
positions, bidirectional encoder, causal decoder with cross-attention, tied
unembedding — is implemented and partitioned for real.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.runtime.sharding import constrain
from . import layers as L


def sinusoidal(S: int, D: int, offset=0) -> jnp.ndarray:
    """(S, D) table, or (B, S, D) when ``offset`` is a per-row vector."""
    off = jnp.asarray(offset, jnp.float32)
    pos = jnp.arange(S, dtype=jnp.float32)
    pos = (off[:, None] + pos[None, :] if off.ndim == 1
           else pos + off)[..., None]
    half = D // 2
    freq = jnp.exp(-math.log(10000.0) * jnp.arange(half, dtype=jnp.float32)
                   / max(half - 1, 1))
    ang = pos * freq
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


class EncDecLM:
    """Protocol-compatible with DecoderLM (loss / prefill / decode_step)."""

    def __init__(self, cfg):
        assert cfg.is_encdec
        self.cfg = cfg

    # -- specs ----------------------------------------------------------------
    def _enc_group_spec(self):
        cfg = self.cfg
        norm_spec, _ = L.make_norm(cfg.norm, cfg.d_model)
        return {
            "attn_norm": norm_spec,
            "attn": L.attention_spec(cfg.d_model, cfg.n_heads,
                                     cfg.n_kv_heads, cfg.head_dim,
                                     qkv_bias=cfg.qkv_bias),
            "mlp_norm": norm_spec,
            "mlp": L.mlp_spec(cfg.d_model, cfg.d_ff, gated=cfg.mlp_gated),
        }

    def _dec_group_spec(self):
        cfg = self.cfg
        norm_spec, _ = L.make_norm(cfg.norm, cfg.d_model)
        return {
            "self_norm": norm_spec,
            "self_attn": L.attention_spec(cfg.d_model, cfg.n_heads,
                                          cfg.n_kv_heads, cfg.head_dim,
                                          qkv_bias=cfg.qkv_bias),
            "cross_norm": norm_spec,
            "cross_attn": L.attention_spec(cfg.d_model, cfg.n_heads,
                                           cfg.n_kv_heads, cfg.head_dim,
                                           qkv_bias=cfg.qkv_bias),
            "mlp_norm": norm_spec,
            "mlp": L.mlp_spec(cfg.d_model, cfg.d_ff, gated=cfg.mlp_gated),
        }

    def spec(self):
        cfg = self.cfg
        norm_spec, _ = L.make_norm(cfg.norm, cfg.d_model)
        return {
            "embed": L.embed_spec(cfg.vocab, cfg.d_model),
            "encoder": L.stack_spec(self._enc_group_spec(),
                                    cfg.encoder_layers),
            "enc_final_norm": norm_spec,
            "decoder": L.stack_spec(self._dec_group_spec(), cfg.n_layers),
            "final_norm": norm_spec,
        }

    def init(self, key, dtype=jnp.bfloat16):
        return L.init_tree(self.spec(), key, dtype)

    def abstract_params(self, dtype=jnp.bfloat16):
        return L.abstract_tree(self.spec(), dtype)

    def param_axes(self):
        return L.axes_tree(self.spec())

    # -- encoder ----------------------------------------------------------------
    def encode(self, params, frames):
        cfg = self.cfg
        normf = L.rmsnorm if cfg.norm == "rmsnorm" else L.layernorm
        S = frames.shape[1]
        x = frames.astype(jnp.bfloat16) + \
            sinusoidal(S, cfg.d_model).astype(jnp.bfloat16)[None]
        x = constrain(x, "act_batch", "act_seq", None)
        positions = jnp.arange(S, dtype=jnp.int32)[None, :]

        def body_fn(x, pg):
            h = normf(pg["attn_norm"], x)
            h, _ = L.attention(pg["attn"], h, positions=positions,
                               causal=False, use_rope=False,
                               q_chunk=cfg.q_chunk)
            x = x + h
            h = normf(pg["mlp_norm"], x)
            x = x + L.mlp(pg["mlp"], h, activation=cfg.activation)
            return x

        if cfg.remat:
            body_fn = jax.checkpoint(body_fn)

        if not cfg.scan_layers:     # unrolled costing variant (see lm.py)
            for gi in range(cfg.encoder_layers):
                x = body_fn(x, jax.tree.map(lambda a, gi=gi: a[gi],
                                            params["encoder"]))
            return normf(params["enc_final_norm"], x)

        def body(x, pg):
            return body_fn(x, pg), None

        x, _ = jax.lax.scan(body, x, params["encoder"])
        return normf(params["enc_final_norm"], x)

    # -- decoder ----------------------------------------------------------------
    def _cross_attend(self, pg, h, memory=None, mem_kv=None):
        """Cross-attention: q from h, k/v from encoder memory (or its
        precomputed projection during decode)."""
        cfg = self.cfg
        B, S, D = h.shape
        q = jnp.einsum("bsd,dhk->bshk", h, pg["cross_attn"]["wq"])
        if "bq" in pg["cross_attn"]:
            q = q + pg["cross_attn"]["bq"]
        if mem_kv is None:
            k = jnp.einsum("btd,dhk->bthk", memory, pg["cross_attn"]["wk"])
            v = jnp.einsum("btd,dhk->bthk", memory, pg["cross_attn"]["wv"])
            if "bk" in pg["cross_attn"]:
                k = k + pg["cross_attn"]["bk"]
                v = v + pg["cross_attn"]["bv"]
        else:
            k, v = mem_kv["xk"], mem_kv["xv"]
        T = k.shape[1]
        qpos = jnp.zeros((1, S), jnp.int32)
        kpos = jnp.zeros((T,), jnp.int32)
        out = L.sdpa(q, k, v, q_pos=qpos, k_pos=kpos, causal=False,
                     q_chunk=cfg.q_chunk)
        y = jnp.einsum("bshk,hkd->bsd", out, pg["cross_attn"]["wo"])
        return constrain(y, "act_batch", "act_seq", None), {"xk": k, "xv": v}

    def _decoder_stack(self, params, x, memory, caches, *, positions,
                       cache_len, mode):
        cfg = self.cfg
        normf = L.rmsnorm if cfg.norm == "rmsnorm" else L.layernorm

        def body_fn(x, pg, cg):
            h = normf(pg["self_norm"], x)
            h, kv = L.attention(pg["self_attn"], h, positions=positions,
                                causal=True, use_rope=False,
                                kv_cache=cg.get("self") if cg else None,
                                cache_len=cache_len, q_chunk=cfg.q_chunk)
            x = x + h
            h = normf(pg["cross_norm"], x)
            h, mem_kv = self._cross_attend(
                pg, h, memory=memory,
                mem_kv=cg.get("cross") if (cg and mode == "decode") else None)
            x = x + h
            h = normf(pg["mlp_norm"], x)
            x = x + L.mlp(pg["mlp"], h, activation=cfg.activation)
            ncg = None
            if kv is not None:
                ncg = {"self": kv, "cross": jax.tree.map(
                    lambda a: a.astype(jnp.bfloat16), mem_kv)}
            return x, ncg

        if cfg.remat and mode == "train":
            body_fn = jax.checkpoint(body_fn)

        if not cfg.scan_layers:     # unrolled costing variant (see lm.py)
            new_caches = caches
            for gi in range(cfg.n_layers):
                pg = jax.tree.map(lambda a, gi=gi: a[gi], params["decoder"])
                cg = (None if caches is None else
                      jax.tree.map(lambda c, gi=gi: c[gi], new_caches))
                x, ncg = body_fn(x, pg, cg)
                if caches is not None:
                    new_caches = jax.tree.map(
                        lambda c, nv, gi=gi: c.at[gi].set(
                            nv.astype(c.dtype)), new_caches, ncg)
            return x, new_caches

        if caches is None:
            def body(x, pg):
                y, _ = body_fn(x, pg, None)
                return y, None

            x, _ = jax.lax.scan(body, x, params["decoder"])
            return x, None

        # cache-as-carry (see DecoderLM._stack): avoids double-buffering
        def body(carry, xs):
            x, caches = carry
            pg, g = xs
            cg = jax.tree.map(
                lambda c: jax.lax.dynamic_index_in_dim(c, g, 0,
                                                       keepdims=False),
                caches)
            x, ncg = body_fn(x, pg, cg)
            caches = jax.tree.map(
                lambda c, n: jax.lax.dynamic_update_index_in_dim(
                    c, n.astype(c.dtype), g, 0), caches, ncg)
            return (x, caches), None

        (x, new_caches), _ = jax.lax.scan(
            body, (x, caches),
            (params["decoder"],
             jnp.arange(self.cfg.n_layers, dtype=jnp.int32)))
        return x, new_caches

    # -- entry points -------------------------------------------------------
    def _embed_tokens(self, params, tokens, offset):
        cfg = self.cfg
        x = L.embed(params["embed"], tokens)
        S = tokens.shape[1]
        pe = sinusoidal(S, cfg.d_model, offset=offset).astype(x.dtype)
        x = x + (pe if pe.ndim == 3 else pe[None])
        return constrain(x, "act_batch", "act_seq", None)

    def loss(self, params, batch):
        memory = self.encode(params, batch["frames"])
        x = self._embed_tokens(params, batch["tokens"], 0)
        S = x.shape[1]
        positions = jnp.arange(S, dtype=jnp.int32)[None, :]
        x, _ = self._decoder_stack(params, x, memory, None,
                                   positions=positions, cache_len=None,
                                   mode="train")
        normf = L.rmsnorm if self.cfg.norm == "rmsnorm" else L.layernorm
        hidden = normf(params["final_norm"], x)
        nll = L.cross_entropy_loss(params["embed"], hidden, batch["labels"],
                                   seq_chunk=self.cfg.loss_seq_chunk)
        return nll, {"nll": nll, "aux": jnp.float32(0.0)}

    def cache_spec(self, batch: int, max_len: int):
        cfg = self.cfg
        sds, axes = L.attention_cache_spec(cfg, batch, max_len)
        xs = jax.ShapeDtypeStruct((batch, cfg.encoder_len, cfg.n_kv_heads,
                                   cfg.head_dim), jnp.bfloat16)
        xaxes = ("act_batch", None, "act_heads", None)
        G = cfg.n_layers

        def stack(t, a):
            return (jax.ShapeDtypeStruct((G, *t.shape), t.dtype),
                    ("layers", *a))

        return {"self": {"k": stack(sds, axes), "v": stack(sds, axes)},
                "cross": {"xk": stack(xs, xaxes), "xv": stack(xs, xaxes)}}

    def init_cache(self, batch: int, max_len: int):
        spec = self.cache_spec(batch, max_len)
        return jax.tree.map(
            lambda t: jnp.zeros(t[0].shape, t[0].dtype), spec,
            is_leaf=lambda t: isinstance(t, tuple) and len(t) == 2)

    def prefill(self, params, tokens, cache, frames=None):
        memory = self.encode(params, frames)
        x = self._embed_tokens(params, tokens, 0)
        S = x.shape[1]
        positions = jnp.arange(S, dtype=jnp.int32)[None, :]
        x, caches = self._decoder_stack(params, x, memory, cache,
                                        positions=positions,
                                        cache_len=jnp.int32(0),
                                        mode="prefill")
        normf = L.rmsnorm if self.cfg.norm == "rmsnorm" else L.layernorm
        hidden = normf(params["final_norm"], x[:, -1:])
        return L.unembed(params["embed"], hidden), caches

    def decode_step(self, params, token, cache, cache_len):
        x = self._embed_tokens(params, token, cache_len)
        clen = jnp.asarray(cache_len)
        if clen.ndim == 1:
            positions = clen[:, None] + jnp.arange(1, dtype=jnp.int32)[None]
        else:
            positions = (clen + jnp.arange(1, dtype=jnp.int32))[None, :]
        x, caches = self._decoder_stack(params, x, None, cache,
                                        positions=positions,
                                        cache_len=cache_len, mode="decode")
        normf = L.rmsnorm if self.cfg.norm == "rmsnorm" else L.layernorm
        hidden = normf(params["final_norm"], x)
        return L.unembed(params["embed"], hidden), caches
