"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory) and sLSTM
(scalar memory with recurrent gating).

TPU adaptation (DESIGN.md §2): the mLSTM recurrence
``C_t = f_t C_{t-1} + i_t v_t k_tᵀ`` is an SSD instance (per-head scalar
decay ``log σ(f)``, input injection ``i``), so training/prefill reuse the
chunked MXU-friendly ``ssd()`` from models/ssm.py instead of a CUDA-style
fused recurrent kernel.  The sLSTM's gate recurrence (R·h_{t-1}) is a true
serial dependency — it runs as a lax.scan over time with block-diagonal
per-head recurrent weights, and its latency-boundedness is visible (by
design) in the roofline tables.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.runtime.sharding import constrain
from .layers import Pm, rmsnorm, rmsnorm_spec
from .ssm import ssd, ssd_decode_step


# ---------------------------------------------------------------------------
# mLSTM block  (proj factor 2, conv + qkv inside the up-projected space)
# ---------------------------------------------------------------------------

def mlstm_dims(cfg):
    d_inner = 2 * cfg.d_model
    H = cfg.n_heads
    hd = d_inner // H
    return d_inner, H, hd


def mlstm_spec(cfg) -> dict:
    d = cfg.d_model
    d_inner, H, hd = mlstm_dims(cfg)
    return {
        "w_up": Pm((d, 2 * d_inner), ("embed", "ff")),       # [x, z]
        "conv_w": Pm((4, d_inner), ("conv", "ff"), scale=0.5),
        "conv_b": Pm((d_inner,), ("ff",), init="zeros"),
        "wq": Pm((d_inner, d_inner), ("embed", "heads")),
        "wk": Pm((d_inner, d_inner), ("embed", "heads")),
        "wv": Pm((d_inner, d_inner), ("embed", "heads")),
        "w_if": Pm((d_inner, 2 * H), ("embed", "heads")),    # input/forget gates
        "b_if": Pm((2 * H,), ("heads",), init="zeros"),
        "norm": rmsnorm_spec(d_inner),
        "w_down": Pm((d_inner, d), ("ff", "embed")),
    }


def _conv1d(w, b, x, state=None):
    K = w.shape[0]
    pad = (jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
           if state is None else state.astype(x.dtype))
    xp = jnp.concatenate([pad, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(K))
    new_state = xp[:, -(K - 1):]
    return jax.nn.silu(y + b), new_state


def mlstm(p, cfg, x, *, state=None, conv_state=None, decode=False):
    """x: (B, S, D) -> (y, (matrix_state, conv_state))."""
    B, S, D = x.shape
    d_inner, H, hd = mlstm_dims(cfg)

    up = jnp.einsum("bsd,df->bsf", x, p["w_up"])
    up = constrain(up, "act_batch", None, "act_ff")
    xi, z = jnp.split(up, 2, axis=-1)
    xc, new_conv = _conv1d(p["conv_w"], p["conv_b"], xi, state=conv_state)

    q = jnp.einsum("bsf,fg->bsg", xc, p["wq"]).reshape(B, S, H, hd)
    k = jnp.einsum("bsf,fg->bsg", xc, p["wk"]).reshape(B, S, H, hd)
    v = jnp.einsum("bsf,fg->bsg", xi, p["wv"]).reshape(B, S, H, hd)
    k = k / math.sqrt(hd)

    gates = jnp.einsum("bsf,fg->bsg", xc, p["w_if"]) + p["b_if"]
    i_gate, f_gate = jnp.split(gates.astype(jnp.float32), 2, axis=-1)
    log_f = jax.nn.log_sigmoid(f_gate)                  # (B,S,H) decay
    i_in = jnp.exp(jax.nn.log_sigmoid(i_gate))          # bounded injection

    xh = v * i_in[..., None].astype(v.dtype)
    if decode:
        if state is None:
            state = jnp.zeros((B, H, hd, hd), jnp.float32)
        y, new_state = ssd_decode_step(state, xh, log_f, k, q)
    else:
        y, new_state = ssd(xh, log_f, k, q, chunk=cfg.ssm_chunk,
                           initial_state=state,
                           unroll=getattr(cfg, "unroll_scans", False))

    y = y.reshape(B, S, d_inner)
    y = rmsnorm(p["norm"], y) * jax.nn.silu(z)
    out = jnp.einsum("bsf,fd->bsd", y, p["w_down"])
    return constrain(out, "act_batch", "act_seq", None), (new_state, new_conv)


def mlstm_state_specs(cfg, batch: int):
    d_inner, H, hd = mlstm_dims(cfg)
    mat = jax.ShapeDtypeStruct((batch, H, hd, hd), jnp.float32)
    conv = jax.ShapeDtypeStruct((batch, 3, d_inner), jnp.bfloat16)
    return (mat, ("act_batch", "act_heads", None, None)), \
        (conv, ("act_batch", None, "act_ff"))


# ---------------------------------------------------------------------------
# sLSTM block  (scalar memory, recurrent gates, post-FFN with pf = 4/3)
# ---------------------------------------------------------------------------

def slstm_spec(cfg) -> dict:
    d = cfg.d_model
    H = cfg.n_heads
    hd = d // H
    d_ff = int(4 * d / 3)
    return {
        "norm_in": rmsnorm_spec(d),
        "w_in": Pm((d, 4 * d), ("embed", "ff")),             # i, f, z, o
        "r": Pm((H, hd, 4 * hd), ("heads", None, None),
                scale=1.0 / math.sqrt(hd)),                  # block-diag recurrent
        "b": Pm((4 * d,), ("ff",), init="zeros"),
        # post FFN (GLU, pf 4/3) — part of the sLSTM block per the paper,
        # hence the block owns both residual connections (self_residual).
        "norm_ff": rmsnorm_spec(d),
        "w_ff_up": Pm((d, 2 * d_ff), ("embed", "ff")),
        "w_ff_down": Pm((d_ff, d), ("ff", "embed")),
    }


def _slstm_cell(p, H, hd, carry, wx_t):
    """One stabilised sLSTM step.  carry: (c, n, h, m) each (B, H, hd)."""
    c, n, h, m = carry
    rh = jnp.einsum("bhd,hdg->bhg", h, p["r"].astype(jnp.float32))
    pre = wx_t + rh                                     # (B, H, 4*hd)
    i_t, f_t, z_t, o_t = jnp.split(pre, 4, axis=-1)
    log_f = jax.nn.log_sigmoid(f_t)
    m_new = jnp.maximum(log_f + m, i_t)                 # stabiliser
    i_s = jnp.exp(i_t - m_new)
    f_s = jnp.exp(log_f + m - m_new)
    c_new = f_s * c + i_s * jnp.tanh(z_t)
    n_new = f_s * n + i_s
    h_new = jax.nn.sigmoid(o_t) * c_new / jnp.maximum(n_new, 1.0)
    return (c_new, n_new, h_new, m_new), h_new


def slstm(p, cfg, x, *, state=None, decode=False):
    """x: (B, S, D) raw residual stream -> (y, state).

    Self-residual block (the sLSTM block owns its two residual connections,
    including the pf=4/3 GLU FFN the xLSTM paper attaches to sLSTM).
    state: (c, n, h, m) each (B, H, hd).
    """
    B, S, D = x.shape
    H = cfg.n_heads
    hd = D // H

    xn = rmsnorm(p["norm_in"], x)
    wx = (jnp.einsum("bsd,dg->bsg", xn, p["w_in"]) + p["b"]).astype(jnp.float32)
    wx = wx.reshape(B, S, H, 4 * hd)
    if state is None:
        zeros = jnp.zeros((B, H, hd), jnp.float32)
        state = (zeros, zeros, zeros, jnp.full((B, H, hd), -1e9, jnp.float32))

    if decode:
        new_state, h = _slstm_cell(p, H, hd, state, wx[:, 0])
        hs = h[:, None]
    else:
        def step(carry, wx_t):
            return _slstm_cell(p, H, hd, carry, wx_t)

        new_state, hs = jax.lax.scan(step, state, wx.transpose(1, 0, 2, 3))
        hs = hs.transpose(1, 0, 2, 3)                   # (B, S, H, hd)

    x = x + constrain(hs.reshape(B, S, D).astype(x.dtype),
                      "act_batch", "act_seq", None)

    # post-FFN (GLU) with its own residual
    y = rmsnorm(p["norm_ff"], x)
    u = jnp.einsum("bsd,df->bsf", y, p["w_ff_up"])
    a, g = jnp.split(u, 2, axis=-1)
    y = jnp.einsum("bsf,fd->bsd", jax.nn.gelu(g) * a, p["w_ff_down"])
    out = x + constrain(y, "act_batch", "act_seq", None)
    return out, new_state


def slstm_state_specs(cfg, batch: int):
    H = cfg.n_heads
    hd = cfg.d_model // H
    s = jax.ShapeDtypeStruct((batch, H, hd), jnp.float32)
    axes = ("act_batch", "act_heads", None)
    return tuple((s, axes) for _ in range(4))
