"""Architecture registry: config name -> (config, model)."""

from __future__ import annotations

from typing import TYPE_CHECKING, Callable

from .encdec import EncDecLM
from .lm import DecoderLM

if TYPE_CHECKING:  # avoid a circular import at runtime (configs import us)
    from repro.configs.base import ModelConfig

_CONFIGS: dict[str, Callable[[], "ModelConfig"]] = {}


def register(name: str):
    def deco(fn):
        _CONFIGS[name] = fn
        return fn
    return deco


def _ensure_loaded():
    # configs register themselves on import
    import repro.configs  # noqa: F401


def config_names() -> list[str]:
    _ensure_loaded()
    return sorted(_CONFIGS)


def get_config(name: str) -> "ModelConfig":
    _ensure_loaded()
    return _CONFIGS[name]()


def build_model(cfg):
    return EncDecLM(cfg) if cfg.is_encdec else DecoderLM(cfg)
