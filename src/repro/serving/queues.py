"""Per-stage bounded queues, prompt buckets, and the KV cache slot pool.

The queueing layer of the request plane: :class:`StageQueue` is the
bounded FIFO every router stage and the engine admission path share (depth
telemetry included, so queue-depth histograms come for free), and
:class:`KVCachePool` is the serving engine's slot-per-sequence cache pool
(moved here from the old monolithic ``serving/engine.py``).

``PROMPT_BUCKETS`` / :func:`bucket_for` implement the padded-prompt-bucket
scheme: admissions that happen in the same engine tick are batched into
**one** prefill call whose sequence length is the smallest bucket covering
the longest prompt in the group, so the number of distinct prefill
compilations is bounded by the bucket count instead of growing with every
distinct prompt length seen.
"""

from __future__ import annotations

from collections import Counter, deque
from typing import Any

import numpy as np

# small fixed set: at most len(PROMPT_BUCKETS) prefill compiles per engine,
# regardless of how many distinct prompt lengths arrive
PROMPT_BUCKETS: tuple[int, ...] = (16, 32, 64, 128, 256, 512, 1024)


def bucket_for(n: int, buckets: tuple[int, ...]) -> int:
    """Smallest bucket >= ``n`` (the exact length when none covers it —
    an escape hatch, not the steady state; callers clip buckets to their
    maximum sequence length up front)."""
    if n <= 0:
        raise ValueError(f"bucket size must be positive, got {n}")
    for b in sorted(buckets):
        if b >= n:
            return b
    return n


class StageQueue:
    """Bounded FIFO with depth telemetry.

    ``push`` returns False (and counts a rejection) when the queue is at
    its limit — the caller sheds or back-pressures; nothing is silently
    dropped.  ``depth_histogram`` counts how often each depth was observed
    at push time, the raw material for the queue-depth histograms on the
    serving metrics.
    """

    def __init__(self, limit: int | None = None):
        if limit is not None and limit < 1:
            raise ValueError(f"queue limit must be >= 1, got {limit}")
        self.limit = limit
        self._q: deque[Any] = deque()
        self.offered = 0
        self.rejected = 0
        self.peak_depth = 0
        self.depth_histogram: Counter[int] = Counter()

    def __len__(self) -> int:
        return len(self._q)

    def __bool__(self) -> bool:
        return bool(self._q)

    @property
    def depth(self) -> int:
        return len(self._q)

    def push(self, item: Any) -> bool:
        self.offered += 1
        self.depth_histogram[len(self._q)] += 1
        if self.limit is not None and len(self._q) >= self.limit:
            self.rejected += 1
            return False
        self._q.append(item)
        self.peak_depth = max(self.peak_depth, len(self._q))
        return True

    def pop(self) -> Any:
        return self._q.popleft()

    def popleft(self) -> Any:
        return self._q.popleft()


class KVCachePool:
    """Fixed-width slot pool over the stacked cache pytree.

    Slot i owns batch row i of every cache leaf.  Freeing a slot just
    recycles the row (lengths are tracked per slot) — sequence-granularity
    paging, the memory-management layer a vLLM-style block table would
    refine further.
    """

    def __init__(self, model, width: int, max_len: int):
        self.width = width
        self.max_len = max_len
        self.cache = model.init_cache(batch=width, max_len=max_len)
        self.lengths = np.zeros(width, np.int32)
        self.free = deque(range(width))
        self.slot_req: dict[int, int] = {}

    def acquire(self, rid: int) -> int | None:
        if not self.free:
            return None
        slot = self.free.popleft()
        self.lengths[slot] = 0
        self.slot_req[slot] = rid
        return slot

    def release(self, slot: int) -> None:
        self.slot_req.pop(slot, None)
        self.lengths[slot] = 0
        self.free.append(slot)
