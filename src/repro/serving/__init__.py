from .engine import (KVCachePool, Request, ServingEngine, ServingStats,
                     simulate_pipeline_throughput)

__all__ = ["KVCachePool", "Request", "ServingEngine", "ServingStats",
           "simulate_pipeline_throughput"]
