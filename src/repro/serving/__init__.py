from .engine import KVCachePool, Request, ServingEngine

__all__ = ["KVCachePool", "Request", "ServingEngine"]
