"""Production serving plane: trace-driven, frontier-placed request plane.

Layered package (split out of the old single-file engine):

* :mod:`repro.serving.requests` — request lifecycle + open-loop arrival
  traces (seeded Poisson / bursty-diurnal generators)
* :mod:`repro.serving.queues` — bounded stage queues, prompt buckets, and
  the KV cache slot pool
* :mod:`repro.serving.router` — trace-driven request router over a
  frontier operating point (admission control, SLO shedding, replica
  load balancing, live operating-point swaps)
* :mod:`repro.serving.metrics` — p50/p99 latency, TTFT, goodput vs SLO,
  queue-depth histograms
* :mod:`repro.serving.sim` — closed-form pipeline throughput simulation
* :mod:`repro.serving.engine` — the continuous-batching model-serving
  engine, rebuilt on the layers above (also the compatibility surface:
  every old ``repro.serving.engine`` import keeps working)
"""

from .engine import (KVCachePool, Request, ServingEngine, ServingStats,
                     simulate_pipeline_throughput)
from .metrics import PlaneReport, mean, percentile
from .queues import PROMPT_BUCKETS, StageQueue, bucket_for
from .requests import (Arrival, arrivals_to_requests, bursty_diurnal_trace,
                       empirical_rate, poisson_trace)
from .router import ExecutorBackend, RoutedRequest, Router, VirtualBackend

__all__ = [
    "Arrival", "ExecutorBackend", "KVCachePool", "PROMPT_BUCKETS",
    "PlaneReport", "Request", "RoutedRequest", "Router", "ServingEngine",
    "ServingStats", "StageQueue", "VirtualBackend", "arrivals_to_requests",
    "bucket_for", "bursty_diurnal_trace", "empirical_rate", "mean",
    "percentile", "poisson_trace", "simulate_pipeline_throughput",
]
