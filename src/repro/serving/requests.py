"""Request lifecycle + open-loop arrival traces.

The request plane distinguishes two request shapes:

* :class:`Request` — the serving engine's unit of work: a concrete prompt
  (token array) flowing through prefill/decode with per-phase timestamps.
* :class:`Arrival` — a trace event: *when* a request arrives and how big
  it is, with no token content.  The router and the throughput benchmarks
  operate on arrivals; :func:`arrivals_to_requests` materializes them into
  engine requests when real tokens are needed.

Traces are **open-loop**: arrival times are drawn up front from a seeded
process and do not depend on service times, so an overloaded system sees
the queue build instead of the load politely waiting — the regime the
paper's "millions of users" story (and any SLO metric) actually lives in.
Two generators are provided:

* :func:`poisson_trace` — homogeneous Poisson (exponential i.i.d. gaps),
  the classic steady-rate workload.
* :func:`bursty_diurnal_trace` — non-homogeneous Poisson via thinning: a
  sinusoidal diurnal envelope between a base and a peak rate, with
  optional periodic burst windows multiplying the instantaneous rate.

Both are deterministic given a seed (numpy ``default_rng``).
"""

from __future__ import annotations

import math
import time
from dataclasses import dataclass, field

import numpy as np


@dataclass
class Request:
    """One serving-engine request and its lifecycle timestamps.

    ``submitted_at`` is stamped at construction (client-side submit);
    ``admitted_at`` when the engine moves it from the admission queue into
    a cache slot (queue wait = ``admitted_at - submitted_at``);
    ``first_token_at`` when the first generated token lands (TTFT);
    ``finished_at`` at completion.  ``deadline_s`` is an optional
    per-request SLO, relative to submission — consumers (router admission
    control, goodput metrics) treat a missing deadline as "no SLO".
    """

    rid: int
    prompt: np.ndarray              # (prompt_len,) int32
    max_new_tokens: int = 16
    submitted_at: float = field(default_factory=time.perf_counter)
    tokens: list[int] = field(default_factory=list)
    done: bool = False
    first_token_at: float | None = None
    finished_at: float | None = None
    admitted_at: float | None = None
    deadline_s: float | None = None

    @property
    def queue_wait_s(self) -> float | None:
        """Time spent in the admission queue (None until admitted)."""
        if self.admitted_at is None:
            return None
        return self.admitted_at - self.submitted_at

    @property
    def latency_s(self) -> float | None:
        if self.finished_at is None:
            return None
        return self.finished_at - self.submitted_at

    @property
    def ttft_s(self) -> float | None:
        if self.first_token_at is None:
            return None
        return self.first_token_at - self.submitted_at


@dataclass(frozen=True)
class Arrival:
    """One open-loop trace event: a request arriving ``t`` seconds after
    the trace start."""

    t: float
    rid: int
    prompt_len: int = 32
    max_new_tokens: int = 16


def _lens(rng: np.random.Generator, n: int, prompt_len) -> np.ndarray:
    if isinstance(prompt_len, tuple):
        lo, hi = prompt_len
        return rng.integers(lo, hi + 1, n)
    return np.full(n, int(prompt_len))


def poisson_trace(rate_rps: float, horizon_s: float, seed: int = 0,
                  prompt_len: int | tuple[int, int] = 32,
                  max_new_tokens: int = 16) -> list[Arrival]:
    """Homogeneous Poisson arrivals at ``rate_rps`` over ``horizon_s``.

    Gaps are i.i.d. exponential with mean ``1/rate_rps``; ``prompt_len``
    may be a fixed int or an inclusive ``(lo, hi)`` range sampled per
    request.  Deterministic given ``seed``.
    """
    if rate_rps <= 0.0:
        raise ValueError(f"rate_rps must be > 0, got {rate_rps}")
    if horizon_s <= 0.0:
        raise ValueError(f"horizon_s must be > 0, got {horizon_s}")
    rng = np.random.default_rng(seed)
    # draw in chunks: E[n] = rate * horizon, oversample to cover the tail
    times: list[float] = []
    t = 0.0
    chunk = max(16, int(rate_rps * horizon_s * 1.25) + 16)
    while t < horizon_s:
        for gap in rng.exponential(1.0 / rate_rps, chunk):
            t += gap
            if t >= horizon_s:
                break
            times.append(t)
    lens = _lens(rng, len(times), prompt_len)
    return [Arrival(t=times[i], rid=i, prompt_len=int(lens[i]),
                    max_new_tokens=max_new_tokens)
            for i in range(len(times))]


def bursty_diurnal_trace(base_rps: float, peak_rps: float, horizon_s: float,
                         period_s: float, seed: int = 0,
                         burst_factor: float = 1.0,
                         burst_every_s: float | None = None,
                         burst_len_s: float = 0.0,
                         prompt_len: int | tuple[int, int] = 32,
                         max_new_tokens: int = 16) -> list[Arrival]:
    """Non-homogeneous Poisson: diurnal sinusoid + periodic bursts.

    The instantaneous rate is::

        rate(t) = base + (peak - base) * sin^2(pi * t / period)
        rate(t) *= burst_factor   while (t mod burst_every) < burst_len

    sampled exactly by thinning (candidates at the max rate, accepted with
    probability ``rate(t) / rate_max``), so the empirical rate tracks the
    envelope without discretization bias.  Deterministic given ``seed``.
    """
    if not 0.0 < base_rps <= peak_rps:
        raise ValueError(
            f"need 0 < base_rps <= peak_rps, got {base_rps}/{peak_rps}")
    if burst_factor < 1.0:
        raise ValueError(f"burst_factor must be >= 1, got {burst_factor}")
    rate_max = peak_rps * burst_factor

    def rate(t: float) -> float:
        r = base_rps + (peak_rps - base_rps) * \
            math.sin(math.pi * t / period_s) ** 2
        if burst_every_s and (t % burst_every_s) < burst_len_s:
            r *= burst_factor
        return r

    rng = np.random.default_rng(seed)
    times: list[float] = []
    t = 0.0
    while True:
        t += rng.exponential(1.0 / rate_max)
        if t >= horizon_s:
            break
        if rng.random() < rate(t) / rate_max:
            times.append(t)
    lens = _lens(rng, len(times), prompt_len)
    return [Arrival(t=times[i], rid=i, prompt_len=int(lens[i]),
                    max_new_tokens=max_new_tokens)
            for i in range(len(times))]


def empirical_rate(trace: list[Arrival]) -> float:
    """Observed arrival rate of a trace (requests per second over the span
    from t=0 to the last arrival; 0 for traces with < 2 arrivals)."""
    if len(trace) < 2:
        return 0.0
    span = trace[-1].t
    return (len(trace) - 1) / span if span > 0 else 0.0


def arrivals_to_requests(trace: list[Arrival], vocab: int,
                         seed: int = 0) -> list[Request]:
    """Materialize trace arrivals into engine :class:`Request`\\ s with
    seeded random prompt tokens (``submitted_at`` carries the *virtual*
    arrival offset, matching the trace's clock, not wall time)."""
    rng = np.random.default_rng(seed)
    return [Request(rid=a.rid,
                    prompt=rng.integers(0, vocab, a.prompt_len),
                    max_new_tokens=a.max_new_tokens,
                    submitted_at=a.t)
            for a in trace]
