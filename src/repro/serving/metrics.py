"""Serving metrics: latency percentiles, TTFT, goodput vs SLO, queue depth.

One vocabulary for both halves of the request plane: the real
:class:`~repro.serving.engine.ServingEngine` reports a
:class:`ServingStats` per run (now including queue-wait percentiles), and
the trace-driven :class:`~repro.serving.router.Router` reports a
:class:`PlaneReport` per served trace.  Percentiles use the nearest-rank
method (``percentile``) so every reported number is an actually-observed
sample, not an interpolation artifact — p99 of 10 samples is the worst
sample, not a blend.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field


def percentile(xs, p: float) -> float:
    """Nearest-rank percentile: the smallest observed value >= ``p``\\ % of
    the sample (0.0 for an empty sample).  ``percentile(xs, 50)`` of an
    odd-length sample is its median element; ``percentile(xs, 100)`` is
    the maximum."""
    if not 0 < p <= 100:
        raise ValueError(f"percentile must be in (0, 100], got {p}")
    xs = sorted(xs)
    if not xs:
        return 0.0
    # nearest-rank: ceil(p/100 * n), 1-indexed; the epsilon keeps exact
    # ranks (p=50 of n=4 -> rank 2) from spilling over via float error
    rank = max(1, math.ceil(p * len(xs) / 100.0 - 1e-9))
    return xs[min(rank, len(xs)) - 1]


def mean(xs) -> float:
    xs = list(xs)
    return sum(xs) / len(xs) if xs else 0.0


@dataclass
class ServingStats:
    """Measured throughput of one :meth:`ServingEngine.run` — the observed
    counterpart of :attr:`PartitionConfig.throughput_rps`.

    ``wall_s`` is the wall-clock of the run itself, so on an **un-warmed**
    engine the first run still includes jit compilation of the
    prefill/decode steps; call :meth:`ServingEngine.warmup` first (or do a
    throwaway run) before comparing against predictions.  Queue wait is
    measured per request from submission to cache-slot admission;
    ``queue_wait_mean_s`` / ``queue_wait_p99_s`` summarize the finished
    requests of the run.
    """

    requests: int = 0
    tokens: int = 0
    wall_s: float = 0.0
    queue_wait_mean_s: float = 0.0
    queue_wait_p99_s: float = 0.0

    @property
    def requests_per_s(self) -> float:
        return self.requests / self.wall_s if self.wall_s > 0 else 0.0

    @property
    def tokens_per_s(self) -> float:
        return self.tokens / self.wall_s if self.wall_s > 0 else 0.0


@dataclass
class PlaneReport:
    """Summary of one served trace (router request plane).

    ``goodput_rps`` counts only completions within the SLO (all
    completions when no SLO is set), measured over the steady-state span
    between the first and last good completion.  ``offered_rps`` is the
    trace's empirical arrival rate; the admission-control story of a run
    is ``arrivals == completed + shed`` (nothing is silently lost).
    ``queue_depth_hist`` maps observed admission-queue depth -> count,
    sampled at every arrival.
    """

    arrivals: int = 0
    completed: int = 0
    shed: int = 0
    shed_reasons: dict[str, int] = field(default_factory=dict)
    duration_s: float = 0.0
    offered_rps: float = 0.0
    goodput_rps: float = 0.0
    latency_p50_s: float = 0.0
    latency_p99_s: float = 0.0
    ttft_p50_s: float = 0.0
    ttft_p99_s: float = 0.0
    queue_wait_mean_s: float = 0.0
    queue_wait_p99_s: float = 0.0
    queue_depth_hist: dict[int, int] = field(default_factory=dict)
    slo_s: float | None = None
    slo_violations: int = 0
    swaps: int = 0

    @property
    def completed_rps(self) -> float:
        return self.completed / self.duration_s if self.duration_s > 0 \
            else 0.0

    def as_dict(self) -> dict:
        """JSON-ready view (benchmark artifacts)."""
        return {
            "arrivals": self.arrivals, "completed": self.completed,
            "shed": self.shed, "shed_reasons": dict(self.shed_reasons),
            "duration_s": round(self.duration_s, 6),
            "offered_rps": round(self.offered_rps, 4),
            "goodput_rps": round(self.goodput_rps, 4),
            "latency_p50_s": round(self.latency_p50_s, 6),
            "latency_p99_s": round(self.latency_p99_s, 6),
            "ttft_p50_s": round(self.ttft_p50_s, 6),
            "ttft_p99_s": round(self.ttft_p99_s, 6),
            "queue_wait_mean_s": round(self.queue_wait_mean_s, 6),
            "queue_wait_p99_s": round(self.queue_wait_p99_s, 6),
            "queue_depth_hist": {str(k): v for k, v in
                                 sorted(self.queue_depth_hist.items())},
            "slo_s": self.slo_s, "slo_violations": self.slo_violations,
            "swaps": self.swaps,
        }
