"""Frontier-placed request router: trace-driven admission, batching,
replica load balancing, and SLO shedding over a Scission operating point.

The router is the open-loop half of the serving story.  A **frontier
operating point** (a :class:`PartitionConfig`, e.g. one returned by
:meth:`QueryEngine.frontier`) fixes everything the request plane needs:

* the **admission width** — requests are formed into batches of
  ``point.batch_size``, the concurrency the cost model priced;
* the **stage pipeline** — input hop (if any), compute segments, comm
  hops, exactly the stage structure ``simulate_pipeline_throughput``
  uses;
* the **replica banks** — a compute stage with ``replicas[k]`` copies
  load-balances batches onto its least-loaded replica;
* the **SLO admission control** — a shadow walk of the pipeline (what
  would a batch dispatched now experience?) estimates a new arrival's
  completion time; arrivals whose estimate blows the SLO are shed at the
  front door, never mid-pipeline.

Time is **virtual**: arrivals carry trace offsets and service times come
from a :class:`Backend` — :class:`VirtualBackend` prices stages straight
from the operating point (so measured goodput is directly comparable to
the cost model's ``throughput_rps`` prediction), while
:class:`ExecutorBackend` measures them from a real
:class:`~repro.runtime.pipeline.PipelineExecutor` over the model graph
(the runtime substrate behind the plane).  Either way the router's
queueing, batching, shedding and drain logic is identical.

Live re-planning: :meth:`Router.set_operating_point` swaps the operating
point mid-trace — in-flight batches drain to completion, then the plane
re-admits at the new width/replicas; nothing in flight is dropped.
:meth:`Router.on_plan` adapts an :class:`ElasticController` re-plan event
(``controller.add_listener(router.on_plan)`` wires controller re-plans
straight into the plane).
"""

from __future__ import annotations

from collections import Counter
from dataclasses import dataclass, field

from repro.core.partition import PartitionConfig

from .metrics import PlaneReport, mean, percentile
from .requests import Arrival, empirical_rate


def stage_layout(point: PartitionConfig) -> list[tuple[str, float, int]]:
    """The pipeline stages of an operating point, in order:
    ``(kind, per-batch service time, replicas)`` with kind one of
    ``"input"`` / ``"compute"`` / ``"hop"``.  Hops are single-server (the
    link is the server) — the same structure
    :func:`~repro.serving.sim.simulate_pipeline_throughput` walks."""
    stages: list[tuple[str, float, int]] = []
    if point.input_comm_s > 0.0:
        stages.append(("input", point.input_comm_s, 1))
    for k, t in enumerate(point.stage_compute_s):
        stages.append(("compute", t, point.replica_count(k)))
        if k < len(point.stage_comm_s):
            stages.append(("hop", point.stage_comm_s[k], 1))
    if not stages:
        # a whole-model placement evaluated without per-stage times: serve
        # it as one stage at the end-to-end latency
        stages.append(("compute", point.latency_s, 1))
    return stages


class VirtualBackend:
    """Stage service times straight from the operating point — the cost
    model's own numbers, so router goodput is directly gated against
    ``point.throughput_rps``."""

    def configure(self, point: PartitionConfig) -> None:
        self._times = [t for _, t, _ in stage_layout(point)]

    def stage_times(self) -> list[float]:
        return self._times


class ExecutorBackend:
    """Stage service times measured from the runtime pipeline executor.

    On :meth:`configure` the backend compiles a
    :class:`~repro.runtime.pipeline.PipelineExecutor` for the operating
    point's placement, runs it ``runs`` times on a ``make_input(batch)``
    input, and serves the median measured per-stage compute times (scaled
    by ``speed_factors``, the tier emulation) with the modeled hop times.
    The router's layout authority stays the operating point — the backend
    only substitutes *measured* service times for predicted ones.
    """

    def __init__(self, graph, make_input, network=None, source: str = "device",
                 speed_factors: dict[str, float] | None = None, runs: int = 3):
        self.graph = graph
        self.make_input = make_input
        self.network = network
        self.source = source
        self.speed_factors = speed_factors or {}
        self.runs = max(1, runs)
        self._times: list[float] = []

    def configure(self, point: PartitionConfig) -> None:
        from repro.runtime.pipeline import PipelineExecutor

        executor = PipelineExecutor(self.graph, point, network=self.network,
                                    source=self.source)
        x = self.make_input(max(1, point.batch_size))
        executor.run(x)                       # compile outside the timings
        samples: list[list] = []
        for _ in range(self.runs):
            _, timings = executor.run(x, collect_timing=True)
            samples.append(timings)
        # median per stage over the runs
        med = [sorted(s[k].compute_s for s in samples)[self.runs // 2]
               for k in range(len(samples[0]))]
        comm = [samples[0][k].comm_in_s for k in range(len(samples[0]))]
        times: list[float] = []
        layout = stage_layout(point)
        k = 0
        for kind, t, _ in layout:
            if kind == "input":
                times.append(comm[0])
            elif kind == "compute":
                sf = self.speed_factors.get(point.segments[k].resource, 1.0)
                times.append(med[k] * sf)
                k += 1
            else:                              # hop into segment k
                times.append(comm[k])
        if len(times) != len(layout):
            raise ValueError(
                f"executor produced {len(times)} stage times for a "
                f"{len(layout)}-stage operating point")
        self._times = times

    def stage_times(self) -> list[float]:
        return self._times


@dataclass
class RoutedRequest:
    """Router-side request record: one trace arrival and its outcome."""

    arrival: Arrival
    admitted_at: float | None = None      # first-stage service start
    first_out_s: float | None = None      # first compute stage done (TTFT)
    finished_s: float | None = None
    shed: bool = False
    shed_reason: str | None = None

    @property
    def latency_s(self) -> float | None:
        if self.finished_s is None:
            return None
        return self.finished_s - self.arrival.t

    @property
    def queue_wait_s(self) -> float | None:
        if self.admitted_at is None:
            return None
        return self.admitted_at - self.arrival.t


class Router:
    """Trace-driven request router over one frontier operating point.

    Feed arrivals in time order with :meth:`offer` (or serve a whole trace
    with :meth:`serve`), then :meth:`flush` and :meth:`report`.  Requests
    are either **completed** or **shed at admission** — the invariant
    ``arrivals == completed + shed`` holds for every run, across any
    number of live operating-point swaps.

    ``queue_limit`` bounds the first-stage queue in *batches*; arrivals
    that would deepen it past the limit are shed (``"queue-full"``).
    ``slo_s`` enables estimate-based admission control: an arrival whose
    shadow-walk completion estimate exceeds the SLO is shed (``"slo"``).
    ``max_wait_s`` bounds how long a partial batch may wait for fill
    before dispatching anyway (default: the time a full batch takes to
    accumulate at the operating point's own service rate).
    """

    def __init__(self, point: PartitionConfig, *, backend=None,
                 slo_s: float | None = None, queue_limit: int | None = 64,
                 max_wait_s: float | None = None):
        self.backend = backend if backend is not None else VirtualBackend()
        self.slo_s = slo_s
        self.queue_limit = queue_limit
        self._max_wait_override = max_wait_s
        self.clock = 0.0
        self.records: list[RoutedRequest] = []
        self.pending: list[RoutedRequest] = []     # forming batch (< width)
        self.swaps: list[tuple[float, float]] = []  # (asked_at, drained_at)
        self.depth_samples: Counter[int] = Counter()
        self._starts: list[list[float]] = []       # per stage: start times
        self._apply_point(point)

    # -- configuration -------------------------------------------------------
    def _apply_point(self, point: PartitionConfig,
                     free_at: float = 0.0) -> None:
        self.point = point
        self.width = max(1, point.batch_size)
        self.backend.configure(point)
        layout = stage_layout(point)
        self._kinds = [k for k, _, _ in layout]
        self._first_compute = self._kinds.index("compute")
        self.free: list[list[float]] = [[free_at] * reps
                                        for _, _, reps in layout]
        self._starts = [[] for _ in layout]
        # a full batch accumulates in width * bottleneck_s at the point's
        # own sustainable rate; waiting much longer than that only adds
        # latency, so it is the default partial-batch dispatch deadline
        self.max_wait_s = self._max_wait_override if \
            self._max_wait_override is not None else \
            max(self.width * point.bottleneck_s, 1e-9)

    def set_operating_point(self, point: PartitionConfig,
                            at: float | None = None) -> float:
        """Live re-plan: drain in-flight batches, then re-admit at the new
        operating point's width/replicas.  Returns the drain-complete time
        (the new point serves nothing earlier).  Pending (not yet
        dispatched) requests survive the swap and dispatch under the new
        point; nothing in flight is dropped."""
        at = self.clock if at is None else max(at, self.clock)
        drained = max([at] + [f for row in self.free for f in row])
        self._apply_point(point, free_at=drained)
        self.swaps.append((at, drained))
        self.clock = at
        return drained

    def on_plan(self, event) -> None:
        """ElasticController listener: a re-plan swaps the router onto the
        event's config at the current virtual clock.  Wire with
        ``controller.add_listener(router.on_plan)``."""
        self.set_operating_point(event.config)

    # -- queue telemetry -----------------------------------------------------
    def _stage_depth(self, s: int, now: float) -> int:
        """Batches queued (assigned, not yet started) at stage ``s``."""
        starts = self._starts[s]
        # prune starts that are already in service/finished
        keep = [t for t in starts if t > now]
        self._starts[s] = keep
        return len(keep)

    # -- admission -----------------------------------------------------------
    def _shadow_finish(self, t: float) -> float:
        """Completion estimate for a batch dispatched at ``t``: walk the
        stages against the current server free times without committing.
        Under saturation this tracks the backlog exactly (it is the same
        arithmetic :meth:`_launch` will apply)."""
        enter = t
        for s, dt in enumerate(self.backend.stage_times()):
            enter = max(enter, min(self.free[s])) + dt
        return enter

    def offer(self, arrival: Arrival) -> RoutedRequest:
        """Process one trace arrival (arrivals must be fed in time
        order)."""
        t = arrival.t
        if t < self.clock - 1e-12:
            raise ValueError(
                f"arrivals must be offered in time order: got t={t} after "
                f"clock={self.clock}")
        self._age_out(t)
        self.clock = max(self.clock, t)
        rec = RoutedRequest(arrival)
        self.records.append(rec)
        depth = self._stage_depth(0, t)
        self.depth_samples[depth * self.width + len(self.pending)] += 1
        if self.queue_limit is not None and depth >= self.queue_limit:
            rec.shed, rec.shed_reason = True, "queue-full"
            return rec
        if self.slo_s is not None and \
                self._shadow_finish(t) - t > self.slo_s:
            rec.shed, rec.shed_reason = True, "slo"
            return rec
        self.pending.append(rec)
        while len(self.pending) >= self.width:
            batch, self.pending = (self.pending[:self.width],
                                   self.pending[self.width:])
            self._launch(batch, at=t)
        return rec

    def _age_out(self, t: float) -> None:
        """Dispatch partial batches whose oldest member has waited past
        ``max_wait_s`` by time ``t`` (they dispatch at their deadline, not
        at ``t`` — the clock advances through the deadline)."""
        while self.pending:
            deadline = self.pending[0].arrival.t + self.max_wait_s
            if deadline > t:
                break
            batch, self.pending = (self.pending[:self.width],
                                   self.pending[self.width:])
            self._launch(batch, at=deadline)

    def flush(self) -> None:
        """Dispatch any remaining partial batches (end of trace)."""
        while self.pending:
            batch, self.pending = (self.pending[:self.width],
                                   self.pending[self.width:])
            self._launch(batch, at=self.clock)

    # -- dispatch ------------------------------------------------------------
    def _launch(self, batch: list[RoutedRequest], at: float) -> None:
        times = self.backend.stage_times()
        enter = at
        for s, dt in enumerate(times):
            # least-loaded replica wins the batch (argmin of free times)
            srv = min(range(len(self.free[s])), key=self.free[s].__getitem__)
            start = max(enter, self.free[s][srv])
            self._starts[s].append(start)
            if s == 0:
                for r in batch:
                    r.admitted_at = start
            finish = start + dt
            self.free[s][srv] = finish
            if s == self._first_compute:
                for r in batch:
                    r.first_out_s = finish
            enter = finish
        for r in batch:
            r.finished_s = enter

    # -- serving -------------------------------------------------------------
    def serve(self, trace: list[Arrival]) -> PlaneReport:
        """Serve a whole trace: offer every arrival, flush, report."""
        for a in trace:
            self.offer(a)
        self.flush()
        return self.report()

    def report(self) -> PlaneReport:
        done = [r for r in self.records if r.finished_s is not None]
        shed = [r for r in self.records if r.shed]
        lats = [r.latency_s for r in done]
        ttfts = [r.first_out_s - r.arrival.t for r in done
                 if r.first_out_s is not None]
        waits = [r.queue_wait_s for r in done if r.admitted_at is not None]
        slo = self.slo_s
        good = done if slo is None else [r for r in done
                                         if r.latency_s <= slo]
        finishes = sorted(r.finished_s for r in good)
        goodput = 0.0
        if len(finishes) >= 2 and finishes[-1] > finishes[0]:
            goodput = (len(finishes) - 1) / (finishes[-1] - finishes[0])
        t_end = max([self.clock] + [r.finished_s for r in done])
        arrivals = [r.arrival for r in self.records]
        return PlaneReport(
            arrivals=len(self.records), completed=len(done), shed=len(shed),
            shed_reasons=dict(Counter(r.shed_reason for r in shed)),
            duration_s=t_end,
            offered_rps=empirical_rate(arrivals),
            goodput_rps=goodput,
            latency_p50_s=percentile(lats, 50),
            latency_p99_s=percentile(lats, 99),
            ttft_p50_s=percentile(ttfts, 50),
            ttft_p99_s=percentile(ttfts, 99),
            queue_wait_mean_s=mean(waits),
            queue_wait_p99_s=percentile(waits, 99),
            queue_depth_hist=dict(self.depth_samples),
            slo_s=slo,
            slo_violations=len(done) - len(good) if slo is not None else 0,
            swaps=len(self.swaps))
