"""Batched serving engine: continuous-batching decode loop over the
prefill/decode step functions, with Scission-placed stages.

The engine owns:
* a :class:`KVCachePool` (slot-per-sequence paging at sequence granularity),
* a request queue with admission up to the batch width,
* the jitted prefill/decode steps (one compile per padded prompt bucket).

On a cloud-edge deployment the *placement* of the two phases comes from the
Scission query engine (e.g. prefill on the pod, decode on the regional
slice, or the paper's device/edge/cloud split for CNNs); here the engine
runs single-host but the phase boundary and cache handoff are the same.
"""

from __future__ import annotations

import math
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.partition import PartitionConfig
from repro.launch.steps import make_decode_step, make_prefill_step


@dataclass
class ServingStats:
    """Measured throughput of one :meth:`ServingEngine.run` — the observed
    counterpart of :attr:`PartitionConfig.throughput_rps`.

    ``wall_s`` is the full wall-clock of the run, so the *first* run on an
    engine includes jit compilation of the prefill/decode steps; compare
    against predictions only on a warmed engine (or after a throwaway run).
    """

    requests: int = 0
    tokens: int = 0
    wall_s: float = 0.0

    @property
    def requests_per_s(self) -> float:
        return self.requests / self.wall_s if self.wall_s > 0 else 0.0

    @property
    def tokens_per_s(self) -> float:
        return self.tokens / self.wall_s if self.wall_s > 0 else 0.0


def simulate_pipeline_throughput(config: PartitionConfig,
                                 n_requests: int = 128) -> float:
    """Steady-state request rate of a partition under pipelined serving.

    Discrete-event simulation with the classic pipeline recurrence — the
    unit in flight is one *batch* of ``config.batch_size`` requests, and a
    compute stage with ``replicas[k]`` copies round-robins batches over its
    servers: batch ``i`` enters stage ``s`` when the previous stage has
    produced it and server ``i % replicas`` has finished batch
    ``i - replicas``:

        finish[i][s] = max(finish[i][s-1], finish[i-replicas_s][s])
                       + stage_time[s]

    Stages are the input hop (if any), then compute segments interleaved
    with inter-stage comm hops; hops are single-server (the link is the
    server).  The measured request rate (batch rate × batch size) converges
    to the cost model's ``1 / bottleneck_s`` prediction;
    benchmarks/bench_partitions.py uses this to validate predicted vs.
    simulated throughput.

    Raises ``ValueError`` for ``n_requests < 2``, a config with no
    pipeline stages — there is no steady state to measure, and the old
    ``inf`` return silently poisoned predicted-vs-simulated comparisons —
    or a ``replicas`` entry below 1 (a zero-replica stage serves nothing;
    the old code would round-robin over an empty server list).
    """
    if n_requests < 2:
        raise ValueError(
            f"need at least 2 requests to measure a steady-state rate, "
            f"got n_requests={n_requests}")
    if any(r < 1 for r in config.replicas):
        raise ValueError(
            f"every replicas entry must be >= 1, got {config.replicas}")
    batch = max(1, config.batch_size)
    stages: list[tuple[float, int]] = []       # (per-batch time, replicas)
    if config.input_comm_s > 0.0:
        stages.append((config.input_comm_s, 1))
    for k, t in enumerate(config.stage_compute_s):
        stages.append((t, config.replica_count(k)))
        if k < len(config.stage_comm_s):
            stages.append((config.stage_comm_s[k], 1))
    if not stages:
        raise ValueError(
            "config has no pipeline stages (no stage_compute_s/input hop); "
            "evaluate it through CostModel.evaluate before simulating")
    # enough batches that every replica set wraps around several times —
    # fewer and the measured span can be zero (all in-flight batches finish
    # simultaneously on distinct servers, no steady state yet).  The joint
    # pattern of a replicated pipeline repeats with period lcm(replicas) in
    # batch index, so the run must also cover whole joint periods.
    max_reps = max(reps for _, reps in stages)
    period = math.lcm(*(reps for _, reps in stages))
    warm = 2 * max_reps               # fill-up: every set wraps >= twice
    n_batches = max(4 * max_reps, 2 * (warm + period + 1),
                    -(-n_requests // batch))
    finish = [[0.0] * reps for _, reps in stages]
    done: list[float] = []
    for i in range(n_batches):
        prev = 0.0
        for s, (dt, reps) in enumerate(stages):
            srv = i % reps
            finish[s][srv] = max(prev, finish[s][srv]) + dt
            prev = finish[s][srv]
        done.append(prev)
    # measure the steady-state rate over (roughly) the second half, but:
    # start only after every replica set has wrapped at least twice, and
    # measure a whole number of joint periods — finish times within a wrap
    # are bursty, so a window that cuts a period mid-wrap biases the rate
    lo = max(len(done) // 2, warm + 1)
    whole = (len(done) - lo) // period * period
    start = len(done) - whole
    span = done[-1] - done[start - 1]
    if span <= 0.0:
        raise ValueError(
            "steady-state span is zero (every stage time is zero?) — "
            "cannot measure a finite pipeline rate")
    return whole / span * batch


@dataclass
class Request:
    rid: int
    prompt: np.ndarray              # (prompt_len,) int32
    max_new_tokens: int = 16
    submitted_at: float = field(default_factory=time.perf_counter)
    tokens: list[int] = field(default_factory=list)
    done: bool = False
    first_token_at: float | None = None
    finished_at: float | None = None


class KVCachePool:
    """Fixed-width slot pool over the stacked cache pytree.

    Slot i owns batch row i of every cache leaf.  Freeing a slot just
    recycles the row (lengths are tracked per slot) — sequence-granularity
    paging, the memory-management layer a vLLM-style block table would
    refine further.
    """

    def __init__(self, model, width: int, max_len: int):
        self.width = width
        self.max_len = max_len
        self.cache = model.init_cache(batch=width, max_len=max_len)
        self.lengths = np.zeros(width, np.int32)
        self.free = deque(range(width))
        self.slot_req: dict[int, int] = {}

    def acquire(self, rid: int) -> int | None:
        if not self.free:
            return None
        slot = self.free.popleft()
        self.lengths[slot] = 0
        self.slot_req[slot] = rid
        return slot

    def release(self, slot: int) -> None:
        self.slot_req.pop(slot, None)
        self.lengths[slot] = 0
        self.free.append(slot)


class ServingEngine:
    """Continuous-batching engine, optionally driven by a Scission
    operating point: constructing with ``config=`` (a
    :class:`PartitionConfig`, e.g. a frontier point) sets the admission
    width to the operating point's batch size, so the engine admits exactly
    the concurrency the cost model priced.  An explicit ``width`` always
    wins."""

    def __init__(self, model, params, *, width: int | None = None,
                 max_len: int = 256, eos_id: int | None = None,
                 config: PartitionConfig | None = None):
        if width is None:
            width = config.batch_size if config is not None else 4
        if width < 1:
            raise ValueError(f"admission width must be >= 1, got {width}")
        self.model = model
        self.cfg = model.cfg
        self.params = params
        self.config = config
        self.width = width
        self.max_len = max_len
        self.eos_id = eos_id
        self.pool = KVCachePool(model, width, max_len)
        self._prefill = jax.jit(make_prefill_step(model, None, None))
        self._decode = jax.jit(make_decode_step(model, None, None))
        self.queue: deque[Request] = deque()
        self.active: dict[int, Request] = {}       # slot -> request
        self._next_tok = np.zeros((width, 1), np.int32)
        self.stats = ServingStats()

    # -- client API -----------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def run(self, max_steps: int = 10_000) -> list[Request]:
        finished: list[Request] = []
        steps = 0
        t0 = time.perf_counter()
        while (self.queue or self.active) and steps < max_steps:
            self._admit()
            if self.active:
                self._decode_step(finished)
            steps += 1
        self.stats = ServingStats(
            requests=len(finished),
            tokens=sum(len(r.tokens) for r in finished),
            wall_s=time.perf_counter() - t0)
        return finished

    @property
    def measured_throughput_rps(self) -> float:
        """Request throughput observed on the last :meth:`run`."""
        return self.stats.requests_per_s

    # -- internals --------------------------------------------------------------
    def _admit(self) -> None:
        while self.queue and self.pool.free:
            req = self.queue.popleft()
            slot = self.pool.acquire(req.rid)
            # prefill one sequence into its slot (single-row batch; padded
            # prompt buckets would batch these — kept simple here)
            prompt = jnp.asarray(req.prompt, jnp.int32)[None, :]
            single = self.model.init_cache(batch=1,
                                           max_len=self.max_len)
            logits, single = self._prefill(self.params, single,
                                           {"tokens": prompt})
            tok = int(jnp.argmax(logits[0, -1]))
            req.tokens.append(tok)
            req.first_token_at = time.perf_counter()
            self._write_slot(single, slot)
            self.pool.lengths[slot] = len(req.prompt)
            self._next_tok[slot, 0] = tok
            self.active[slot] = req

    def _write_slot(self, single_cache, slot: int) -> None:
        def write(dst, src):
            # batch dim position differs per leaf kind; all our cache leaves
            # carry batch at axis 1 (after the layer-stack axis) except
            # scalar-state tuples where it is axis 1 as well.
            return dst.at[:, slot:slot + 1].set(src)

        self.pool.cache = jax.tree.map(write, self.pool.cache, single_cache)

    def _decode_step(self, finished: list[Request]) -> None:
        # ragged continuous batching: per-slot cache lengths drive per-row
        # positions, write offsets and attention masks
        cache_len = jnp.asarray(self.pool.lengths, jnp.int32)
        tok = jnp.asarray(self._next_tok)
        next_tok, logits, self.pool.cache = self._decode(
            self.params, self.pool.cache, tok, cache_len)
        nxt = np.asarray(next_tok)
        for slot, req in list(self.active.items()):
            t = int(nxt[slot, 0])
            req.tokens.append(t)
            self.pool.lengths[slot] += 1
            limit = (len(req.tokens) >= req.max_new_tokens
                     or (self.eos_id is not None and t == self.eos_id)
                     or self.pool.lengths[slot] >= self.max_len - 1)
            if limit:
                req.done = True
                req.finished_at = time.perf_counter()
                finished.append(req)
                del self.active[slot]
                self.pool.release(slot)
            else:
                self._next_tok[slot, 0] = t
