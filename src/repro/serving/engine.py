"""Batched serving engine: continuous-batching decode loop over the
prefill/decode step functions, with Scission-placed stages.

This module is the compatibility surface of the ``repro.serving`` package
(the old monolithic engine split into layers, the same way
``core/partition.py`` became ``core/lattice/``): :class:`Request` lives in
:mod:`repro.serving.requests`, :class:`KVCachePool` and the prompt-bucket
machinery in :mod:`repro.serving.queues`, :class:`ServingStats` in
:mod:`repro.serving.metrics`, and :func:`simulate_pipeline_throughput` in
:mod:`repro.serving.sim` — all re-exported here, so
``from repro.serving.engine import ServingEngine, ServingStats,
simulate_pipeline_throughput`` keeps working unchanged.

The engine owns:
* a :class:`KVCachePool` (slot-per-sequence paging at sequence granularity),
* a request queue with admission up to the batch width,
* the jitted prefill/decode steps — same-tick admissions share **one**
  prefill over a padded prompt bucket (compiles bounded by the fixed
  bucket set), instead of one jit call + fresh batch-1 cache per request.

On a cloud-edge deployment the *placement* of the two phases comes from the
Scission query engine (e.g. prefill on the pod, decode on the regional
slice, or the paper's device/edge/cloud split for CNNs); here the engine
runs single-host but the phase boundary and cache handoff are the same.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.partition import PartitionConfig
from repro.launch.steps import make_decode_step, make_prefill_step

from .metrics import ServingStats, mean, percentile
from .queues import KVCachePool, PROMPT_BUCKETS, bucket_for
from .requests import Request
from .sim import simulate_pipeline_throughput

__all__ = ["KVCachePool", "Request", "ServingEngine", "ServingStats",
           "simulate_pipeline_throughput"]

# sub-layer kinds whose cache is a recurrent state rather than per-position
# K/V: a padded prefill would fold the padding into the state irreversibly,
# so bucketed admission auto-disables for models containing any of these
# (attention caches are safe: positions beyond a row's length are never
# visible — the per-row cache_len masks them, and each position is
# overwritten by the real token before cache_len reaches it)
RECURRENT_KINDS = frozenset({"mamba2", "mlstm", "slstm"})


class ServingEngine:
    """Continuous-batching engine, optionally driven by a Scission
    operating point: constructing with ``config=`` (a
    :class:`PartitionConfig`, e.g. a frontier point) sets the admission
    width to the operating point's batch size, so the engine admits exactly
    the concurrency the cost model priced.  An explicit ``width`` always
    wins.

    ``prompt_buckets`` controls admission batching: ``"auto"`` (default)
    batches same-tick admissions into one padded-prompt-bucket prefill for
    attention-cache models and falls back to exact per-request prefill for
    recurrent-state models (see :data:`RECURRENT_KINDS`); an explicit
    tuple forces those buckets; ``None`` forces the exact path.
    """

    def __init__(self, model, params, *, width: int | None = None,
                 max_len: int = 256, eos_id: int | None = None,
                 config: PartitionConfig | None = None,
                 prompt_buckets: tuple[int, ...] | str | None = "auto"):
        if width is None:
            width = config.batch_size if config is not None else 4
        if width < 1:
            raise ValueError(f"admission width must be >= 1, got {width}")
        self.model = model
        self.cfg = model.cfg
        self.params = params
        self.config = config
        self.width = width
        self.max_len = max_len
        self.eos_id = eos_id
        self.pool = KVCachePool(model, width, max_len)
        self._prefill = jax.jit(make_prefill_step(model, None, None))
        self._decode = jax.jit(make_decode_step(model, None, None))
        if prompt_buckets == "auto":
            kinds = set(getattr(self.cfg, "group_kinds", ()) or ())
            prompt_buckets = None if kinds & RECURRENT_KINDS \
                else PROMPT_BUCKETS
        if prompt_buckets is not None:
            # clip to the cache length; always keep one bucket that covers
            # the longest admissible prompt
            prompt_buckets = tuple(sorted(
                {b for b in prompt_buckets if b < max_len} | {max_len}))
        self.prompt_buckets = prompt_buckets
        # zeros scratch cache for the batched bucket prefill (prefill is
        # functional, so one allocation serves every admission tick)
        self._scratch = None
        self.queue: list[Request] = []
        self.active: dict[int, Request] = {}       # slot -> request
        self._next_tok = np.zeros((width, 1), np.int32)
        self.stats = ServingStats()

    # -- client API -----------------------------------------------------------
    def submit(self, req: Request) -> None:
        if len(req.prompt) > self.max_len - 1:
            raise ValueError(
                f"prompt of request {req.rid} is {len(req.prompt)} tokens; "
                f"the engine's cache holds max_len={self.max_len} (prompt "
                "must leave room for at least one generated token)")
        self.queue.append(req)

    def warmup(self) -> "ServingEngine":
        """Pre-compile the decode step and the prefill bucket(s) the queued
        requests will need (the smallest bucket when the queue is empty),
        so the next :meth:`run`'s :class:`ServingStats` measure serving,
        not jit compilation.  Idempotent; results are discarded — no
        engine state changes."""
        dec = self._decode(self.params, self.pool.cache,
                           jnp.asarray(self._next_tok),
                           jnp.asarray(self.pool.lengths, jnp.int32))
        jax.block_until_ready(dec[0])
        if self.prompt_buckets is None:
            # exact-path compiles key on prompt length; warm each distinct
            # length present in the queue
            lens = sorted({len(r.prompt) for r in self.queue
                           if len(r.prompt) > 1})
            for L in lens:
                single = self.model.init_cache(batch=1, max_len=self.max_len)
                out = self._prefill(self.params, single,
                                    {"tokens": jnp.zeros((1, L), jnp.int32)})
                jax.block_until_ready(out[0])
            return self
        if self.queue:
            buckets = sorted({bucket_for(max(len(r.prompt) - 1, 1),
                                         self.prompt_buckets)
                              for r in self.queue if len(r.prompt) > 1})
        else:
            buckets = [min(self.prompt_buckets)]
        for b in buckets:
            out = self._prefill(self.params, self._scratch_cache(),
                                {"tokens": jnp.zeros((self.width, b),
                                                     jnp.int32)})
            jax.block_until_ready(out[0])
        return self

    def run(self, max_steps: int = 10_000) -> list[Request]:
        finished: list[Request] = []
        steps = 0
        t0 = time.perf_counter()
        while (self.queue or self.active) and steps < max_steps:
            self._admit()
            if self.active:
                self._decode_step(finished)
            steps += 1
        waits = [r.queue_wait_s for r in finished
                 if r.queue_wait_s is not None]
        self.stats = ServingStats(
            requests=len(finished),
            tokens=sum(len(r.tokens) for r in finished),
            wall_s=time.perf_counter() - t0,
            queue_wait_mean_s=mean(waits),
            queue_wait_p99_s=percentile(waits, 99))
        return finished

    @property
    def measured_throughput_rps(self) -> float:
        """Request throughput observed on the last :meth:`run`."""
        return self.stats.requests_per_s

    # -- internals --------------------------------------------------------------
    def _scratch_cache(self):
        if self._scratch is None:
            self._scratch = self.model.init_cache(batch=self.width,
                                                  max_len=self.max_len)
        return self._scratch

    def _admit(self) -> None:
        batch: list[tuple[Request, int]] = []
        while self.queue and self.pool.free:
            req = self.queue.pop(0)
            slot = self.pool.acquire(req.rid)
            batch.append((req, slot))
        if not batch:
            return
        if self.prompt_buckets is None:
            for req, slot in batch:
                self._admit_exact(req, slot)
            return
        self._admit_bucketed(batch)

    def _admit_exact(self, req: Request, slot: int) -> None:
        """Legacy per-request prefill (recurrent-state models): one jit
        call per distinct prompt length, fresh batch-1 cache, the first
        token taken from the prefill logits."""
        prompt = jnp.asarray(req.prompt, jnp.int32)[None, :]
        single = self.model.init_cache(batch=1, max_len=self.max_len)
        logits, single = self._prefill(self.params, single,
                                       {"tokens": prompt})
        tok = int(jnp.argmax(logits[0, -1]))
        req.tokens.append(tok)
        req.admitted_at = time.perf_counter()
        req.first_token_at = req.admitted_at
        self._write_slot(single, slot)
        self.pool.lengths[slot] = len(req.prompt)
        self._next_tok[slot, 0] = tok
        self.active[slot] = req

    def _admit_bucketed(self, batch: list[tuple[Request, int]]) -> None:
        """One prefill for every same-tick admission: prompts minus their
        last token are right-padded into the smallest covering bucket
        (fixed batch width, so compiles are bounded by the bucket count),
        the resulting cache rows are scattered into the admitted slots,
        and the *last* prompt token becomes each slot's first decode input
        — the next decode step then produces the first generated token
        from logits identical to an exact prefill's last position (causal
        attention never sees the right padding, and the per-row cache_len
        masks the padded cache positions until real tokens overwrite
        them)."""
        now = time.perf_counter()
        pre = max(len(req.prompt) - 1 for req, _ in batch)
        if pre > 0:
            bucket = bucket_for(pre, self.prompt_buckets)
            toks = np.zeros((self.width, bucket), np.int32)
            for j, (req, _) in enumerate(batch):
                toks[j, :len(req.prompt) - 1] = req.prompt[:-1]
            _, cache = self._prefill(self.params, self._scratch_cache(),
                                     {"tokens": jnp.asarray(toks)})
            self._scatter_rows(cache, rows=list(range(len(batch))),
                               slots=[slot for _, slot in batch])
        for req, slot in batch:
            req.admitted_at = now
            self.pool.lengths[slot] = len(req.prompt) - 1
            self._next_tok[slot, 0] = int(req.prompt[-1])
            self.active[slot] = req

    def _scatter_rows(self, src_cache, rows: list[int],
                      slots: list[int]) -> None:
        """Copy batch rows ``rows`` of a width-batch cache into pool slots
        ``slots`` (batch lives at axis 1 of every cache leaf, after the
        layer-stack axis)."""
        rows_ix = jnp.asarray(rows)
        slots_ix = jnp.asarray(slots)

        def write(dst, src):
            return dst.at[:, slots_ix].set(src[:, rows_ix])

        self.pool.cache = jax.tree.map(write, self.pool.cache, src_cache)

    def _write_slot(self, single_cache, slot: int) -> None:
        def write(dst, src):
            # batch dim position differs per leaf kind; all our cache leaves
            # carry batch at axis 1 (after the layer-stack axis) except
            # scalar-state tuples where it is axis 1 as well.
            return dst.at[:, slot:slot + 1].set(src)

        self.pool.cache = jax.tree.map(write, self.pool.cache, single_cache)

    def _decode_step(self, finished: list[Request]) -> None:
        # ragged continuous batching: per-slot cache lengths drive per-row
        # positions, write offsets and attention masks
        cache_len = jnp.asarray(self.pool.lengths, jnp.int32)
        tok = jnp.asarray(self._next_tok)
        next_tok, logits, self.pool.cache = self._decode(
            self.params, self.pool.cache, tok, cache_len)
        nxt = np.asarray(next_tok)
        now = time.perf_counter()
        for slot, req in list(self.active.items()):
            t = int(nxt[slot, 0])
            req.tokens.append(t)
            if req.first_token_at is None:
                req.first_token_at = now
            self.pool.lengths[slot] += 1
            limit = (len(req.tokens) >= req.max_new_tokens
                     or (self.eos_id is not None and t == self.eos_id)
                     or self.pool.lengths[slot] >= self.max_len - 1)
            if limit:
                req.done = True
                req.finished_at = now
                finished.append(req)
                del self.active[slot]
                self.pool.release(slot)
            else:
                self._next_tok[slot, 0] = t
