"""Closed-form pipeline throughput simulation (predicted-rate validation).

:func:`simulate_pipeline_throughput` moved verbatim from the old
monolithic ``serving/engine.py`` — it is the closed-loop, saturation-fed
counterpart of the open-loop trace-driven :class:`~repro.serving.router.
Router`: it answers "what rate *can* this operating point sustain", while
the router answers "what does this operating point do under *this*
arrival process".  ``benchmarks/bench_partitions.py`` gates the cost
model's ``throughput_rps`` predictions against it.
"""

from __future__ import annotations

import math

from repro.core.partition import PartitionConfig


def simulate_pipeline_throughput(config: PartitionConfig,
                                 n_requests: int = 128) -> float:
    """Steady-state request rate of a partition under pipelined serving.

    Discrete-event simulation with the classic pipeline recurrence — the
    unit in flight is one *batch* of ``config.batch_size`` requests, and a
    compute stage with ``replicas[k]`` copies round-robins batches over its
    servers: batch ``i`` enters stage ``s`` when the previous stage has
    produced it and server ``i % replicas`` has finished batch
    ``i - replicas``:

        finish[i][s] = max(finish[i][s-1], finish[i-replicas_s][s])
                       + stage_time[s]

    Stages are the input hop (if any), then compute segments interleaved
    with inter-stage comm hops; hops are single-server (the link is the
    server).  The measured request rate (batch rate × batch size) converges
    to the cost model's ``1 / bottleneck_s`` prediction;
    benchmarks/bench_partitions.py uses this to validate predicted vs.
    simulated throughput.

    Raises ``ValueError`` for ``n_requests < 2``, a config with no
    pipeline stages — there is no steady state to measure, and the old
    ``inf`` return silently poisoned predicted-vs-simulated comparisons —
    or a ``replicas`` entry below 1 (a zero-replica stage serves nothing;
    the old code would round-robin over an empty server list).
    """
    if n_requests < 2:
        raise ValueError(
            f"need at least 2 requests to measure a steady-state rate, "
            f"got n_requests={n_requests}")
    if any(r < 1 for r in config.replicas):
        raise ValueError(
            f"every replicas entry must be >= 1, got {config.replicas}")
    batch = max(1, config.batch_size)
    stages: list[tuple[float, int]] = []       # (per-batch time, replicas)
    if config.input_comm_s > 0.0:
        stages.append((config.input_comm_s, 1))
    for k, t in enumerate(config.stage_compute_s):
        stages.append((t, config.replica_count(k)))
        if k < len(config.stage_comm_s):
            stages.append((config.stage_comm_s[k], 1))
    if not stages:
        raise ValueError(
            "config has no pipeline stages (no stage_compute_s/input hop); "
            "evaluate it through CostModel.evaluate before simulating")
    # enough batches that every replica set wraps around several times —
    # fewer and the measured span can be zero (all in-flight batches finish
    # simultaneously on distinct servers, no steady state yet).  The joint
    # pattern of a replicated pipeline repeats with period lcm(replicas) in
    # batch index, so the run must also cover whole joint periods.
    max_reps = max(reps for _, reps in stages)
    period = math.lcm(*(reps for _, reps in stages))
    warm = 2 * max_reps               # fill-up: every set wraps >= twice
    n_batches = max(4 * max_reps, 2 * (warm + period + 1),
                    -(-n_requests // batch))
    finish = [[0.0] * reps for _, reps in stages]
    done: list[float] = []
    for i in range(n_batches):
        prev = 0.0
        for s, (dt, reps) in enumerate(stages):
            srv = i % reps
            finish[s][srv] = max(prev, finish[s][srv]) + dt
            prev = finish[s][srv]
        done.append(prev)
    # measure the steady-state rate over (roughly) the second half, but:
    # start only after every replica set has wrapped at least twice, and
    # measure a whole number of joint periods — finish times within a wrap
    # are bursty, so a window that cuts a period mid-wrap biases the rate
    lo = max(len(done) // 2, warm + 1)
    whole = (len(done) - lo) // period * period
    start = len(done) - whole
    span = done[-1] - done[start - 1]
    if span <= 0.0:
        raise ValueError(
            "steady-state span is zero (every stage time is zero?) — "
            "cannot measure a finite pipeline rate")
    return whole / span * batch
