"""Partition configuration generation and ranking (Scission §II-C Steps 4-5).

Two engines over the same cost model:

* :func:`enumerate_partitions` — the paper's **exhaustive** enumeration of
  every native and distributed configuration over every ordered resource
  pipeline.  Kept as the validation oracle and for rich post-hoc queries.
* :class:`PartitionLattice` — a **beyond-paper** Viterbi lattice over
  (block, resource) states.  Exact under the paper's additive cost model
  (assumptions 1 and 2 in §III-A), O(B·R²·2^R) with must-use masks, and
  supports k-best (top-N) extraction.  This is what lets the same decision
  procedure scale from the paper's 3-tier testbed to a 1000+-node fleet,
  and what keeps re-planning (elastic runtime) inside the paper's 50 ms
  query budget.

Cost model (paper's two assumptions, validated in tests/test_bench.py):

    latency(config) = comm(source -> r_1, input_bytes)
                    + Σ_segments Σ_blocks time(r_i, b)
                    + Σ_cuts     comm(r_i -> r_{i+1}, out_bytes[cut])
"""

from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from typing import Callable, Iterable, Sequence

import numpy as np

from .bench import BenchmarkDB
from .network import NetworkModel
from .resources import Resource


@dataclass(frozen=True)
class Segment:
    resource: str
    start: int          # first block index (inclusive)
    end: int            # last block index (inclusive)


@dataclass
class PartitionConfig:
    """One ranked configuration (a row of the paper's Table IV)."""

    model: str
    segments: tuple[Segment, ...]
    latency_s: float
    compute_s: dict[str, float]
    comm_s: float
    transfer_bytes: float           # total inter-resource bytes (incl. input)
    input_comm_s: float = 0.0

    @property
    def resources(self) -> tuple[str, ...]:
        return tuple(s.resource for s in self.segments)

    @property
    def is_native(self) -> bool:
        return len(self.segments) == 1

    def describe(self) -> str:
        parts = [f"{s.resource}: {s.start}-{s.end}" if s.start != s.end
                 else f"{s.resource}: {s.start}" for s in self.segments]
        return (f"[{self.model}] " + " | ".join(parts)
                + f"  latency={self.latency_s * 1e3:.1f}ms"
                + f" transfer={self.transfer_bytes / 1e6:.3f}MB")


@dataclass
class CostModel:
    """Precomputed vectorised costs for one (model, resource set, network)."""

    db: BenchmarkDB
    resources: list[Resource]
    network: NetworkModel
    source: str                      # where the input data originates
    input_bytes: float

    times: np.ndarray = field(init=False)        # (R, B)
    cum: np.ndarray = field(init=False)          # (R, B+1) prefix sums
    out_bytes: np.ndarray = field(init=False)    # (B,)

    def __post_init__(self):
        names = [r.name for r in self.resources]
        self.times = self.db.times_matrix(names)
        self.cum = np.concatenate(
            [np.zeros((len(names), 1)), np.cumsum(self.times, axis=1)], axis=1)
        self.out_bytes = self.db.out_bytes_vector()
        self._idx = {n: i for i, n in enumerate(names)}

    @property
    def n_blocks(self) -> int:
        return self.db.n_blocks

    def segment_time(self, resource: str, start: int, end: int) -> float:
        i = self._idx[resource]
        return float(self.cum[i, end + 1] - self.cum[i, start])

    def comm(self, src: str, dst: str, nbytes: float) -> float:
        return self.network.comm_time(src, dst, nbytes)

    def evaluate(self, segments: Sequence[Segment],
                 objective: "Objective | None" = None) -> PartitionConfig:
        compute = {}
        comm = 0.0
        xfer = 0.0
        first = segments[0].resource
        input_comm = 0.0
        if first != self.source:
            input_comm = self.comm(self.source, first, self.input_bytes)
            xfer += self.input_bytes
        for k, seg in enumerate(segments):
            compute[seg.resource] = compute.get(seg.resource, 0.0) + \
                self.segment_time(seg.resource, seg.start, seg.end)
            if k + 1 < len(segments):
                nbytes = float(self.out_bytes[seg.end])
                comm += self.comm(seg.resource, segments[k + 1].resource, nbytes)
                xfer += nbytes
        latency = input_comm + sum(compute.values()) + comm
        return PartitionConfig(
            model=self.db.model, segments=tuple(segments), latency_s=latency,
            compute_s=compute, comm_s=comm, transfer_bytes=xfer,
            input_comm_s=input_comm)


@dataclass(frozen=True)
class Objective:
    """Ranking objective: minimise w_latency·latency + w_transfer·transfer.

    The paper's Step 5 default is pure latency; Step 6 allows data-transfer
    and combined objectives.
    """

    w_latency: float = 1.0
    w_transfer_per_mb: float = 0.0

    def score(self, cfg: PartitionConfig) -> float:
        return (self.w_latency * cfg.latency_s
                + self.w_transfer_per_mb * cfg.transfer_bytes / 1e6)


LATENCY = Objective()
TRANSFER = Objective(w_latency=0.0, w_transfer_per_mb=1.0)


# ---------------------------------------------------------------------------
# Exhaustive enumeration (paper-faithful Step 4)
# ---------------------------------------------------------------------------

def ordered_pipelines(resources: list[Resource]) -> list[tuple[str, ...]]:
    """All ordered sub-pipelines: at most one resource per tier, data flows
    device -> edge -> cloud (the paper's native + distributed configs)."""
    tiers: dict[int, list[str]] = {}
    for r in sorted(resources, key=lambda r: r.order):
        tiers.setdefault(r.order, []).append(r.name)
    levels = [tiers[k] for k in sorted(tiers)]
    pipes: list[tuple[str, ...]] = []
    for mask in itertools.product(*[[None, *lvl] for lvl in levels]):
        pipe = tuple(m for m in mask if m is not None)
        if pipe:
            pipes.append(pipe)
    return pipes


def enumerate_partitions(cost: CostModel,
                         pipelines: Iterable[tuple[str, ...]] | None = None,
                         max_configs: int = 2_000_000
                         ) -> list[PartitionConfig]:
    """Every cut combination for every pipeline.  Exact but exponential in
    pipeline length; the lattice below is the scalable path."""
    B = cost.n_blocks
    pipelines = list(pipelines) if pipelines is not None else \
        ordered_pipelines(cost.resources)
    configs: list[PartitionConfig] = []
    n = 0
    for pipe in pipelines:
        k = len(pipe)
        if k > B:
            continue
        for cuts in itertools.combinations(range(1, B), k - 1):
            bounds = [0, *cuts, B]
            segs = [Segment(pipe[i], bounds[i], bounds[i + 1] - 1)
                    for i in range(k)]
            configs.append(cost.evaluate(segs))
            n += 1
            if n > max_configs:
                raise RuntimeError(
                    f"exhaustive enumeration exceeded {max_configs} configs; "
                    "use PartitionLattice")
    return configs


def rank(configs: list[PartitionConfig], objective: Objective = LATENCY,
         top_n: int | None = None) -> list[PartitionConfig]:
    out = sorted(configs, key=objective.score)
    return out[:top_n] if top_n else out


# ---------------------------------------------------------------------------
# DP lattice (beyond-paper exact search + k-best)
# ---------------------------------------------------------------------------

class Constraints:
    """Hard constraints folded into the lattice (Scission Step 6).

    All are exact in the DP except ``max_resource_time`` which is
    path-dependent and enforced by post-filtering k-best paths.
    """

    def __init__(self,
                 must_use: Sequence[str] = (),
                 exclude: Sequence[str] = (),
                 pin: dict[int, str] | None = None,
                 max_link_bytes: dict[tuple[str, str], float] | None = None,
                 max_resource_time: dict[str, float] | None = None,
                 min_blocks_on: dict[str, int] | None = None):
        self.must_use = tuple(must_use)
        self.exclude = frozenset(exclude)
        self.pin = dict(pin or {})
        self.max_link_bytes = dict(max_link_bytes or {})
        self.max_resource_time = dict(max_resource_time or {})
        self.min_blocks_on = dict(min_blocks_on or {})

    def allowed(self, block: int, resource: str) -> bool:
        if resource in self.exclude:
            return False
        pinned = self.pin.get(block)
        return pinned is None or pinned == resource

    def transition_allowed(self, src: str, dst: str, nbytes: float) -> bool:
        limit = self.max_link_bytes.get((src, dst))
        return limit is None or nbytes <= limit

    def path_feasible(self, cfg: PartitionConfig) -> bool:
        for res, tmax in self.max_resource_time.items():
            if cfg.compute_s.get(res, 0.0) > tmax:
                return False
        for res, nmin in self.min_blocks_on.items():
            got = sum(s.end - s.start + 1 for s in cfg.segments
                      if s.resource == res)
            if got < nmin:
                return False
        return True


class PartitionLattice:
    """Viterbi over (block, resource, used-mask) with k-best extraction.

    Transitions: stay on the same resource (free) or hand off to a strictly
    later tier (pay ``comm(out_bytes[block])``).  The used-mask tracks which
    must-use resources have been visited so 'entire pipeline' style
    constraints stay exact.
    """

    def __init__(self, cost: CostModel, constraints: Constraints | None = None,
                 objective: Objective = LATENCY):
        self.cost = cost
        self.cons = constraints or Constraints()
        self.obj = objective
        self.res = [r for r in cost.resources if r.name not in self.cons.exclude]
        self.names = [r.name for r in self.res]
        self.order = {r.name: r.order for r in self.res}
        self.must = [n for n in self.cons.must_use if n in self.names]
        self.must_idx = {n: i for i, n in enumerate(self.must)}
        self.full_mask = (1 << len(self.must)) - 1

    def _mask_with(self, mask: int, resource: str) -> int:
        i = self.must_idx.get(resource)
        return mask | (1 << i) if i is not None else mask

    def _step_cost(self, resource: str, block: int) -> float:
        t = self.cost.segment_time(resource, block, block)
        return self.obj.w_latency * t

    def _comm_cost(self, src: str, dst: str, nbytes: float) -> float:
        return (self.obj.w_latency * self.cost.comm(src, dst, nbytes)
                + self.obj.w_transfer_per_mb * nbytes / 1e6)

    def solve(self, top_n: int = 1) -> list[PartitionConfig]:
        """k-best paths through the lattice; returns up to ``top_n`` feasible
        configs ranked by the objective."""
        B = self.cost.n_blocks
        K = max(top_n * 4, top_n + 4)   # head-room for path-feasibility filter
        # state -> list of (score, path) ; path = tuple of resource per block
        # We keep paths as parent pointers to bound memory: entry =
        # (score, resource, mask, parent_entry)
        Entry = tuple  # (score, tie, resource, mask, parent)
        frontier: dict[tuple[str, int], list[Entry]] = {}
        tie = itertools.count()

        def push(store: dict, key, entry, k=K):
            lst = store.setdefault(key, [])
            lst.append(entry)
            lst.sort(key=lambda e: e[0])
            del lst[k:]

        for r in self.names:
            if not self.cons.allowed(0, r):
                continue
            inp = 0.0
            if r != self.cost.source:
                if not self.cons.transition_allowed(self.cost.source, r,
                                                    self.cost.input_bytes):
                    continue
                inp = self._comm_cost(self.cost.source, r, self.cost.input_bytes)
            score = inp + self._step_cost(r, 0)
            push(frontier, (r, self._mask_with(0, r)),
                 (score, next(tie), r, self._mask_with(0, r), None))

        for b in range(1, B):
            nxt: dict[tuple[str, int], list[Entry]] = {}
            nbytes = float(self.cost.out_bytes[b - 1])
            for (r, mask), entries in frontier.items():
                for e in entries:
                    # stay
                    if self.cons.allowed(b, r):
                        push(nxt, (r, mask),
                             (e[0] + self._step_cost(r, b), next(tie), r, mask, e))
                    # hand off to a later tier
                    for r2 in self.names:
                        if self.order[r2] <= self.order[r] or \
                                not self.cons.allowed(b, r2) or \
                                not self.cons.transition_allowed(r, r2, nbytes):
                            continue
                        m2 = self._mask_with(mask, r2)
                        sc = e[0] + self._comm_cost(r, r2, nbytes) \
                            + self._step_cost(r2, b)
                        push(nxt, (r2, m2), (sc, next(tie), r2, m2, e))
            frontier = nxt

        finals: list[Entry] = []
        for (r, mask), entries in frontier.items():
            if mask != self.full_mask:
                continue
            finals.extend(entries)
        finals.sort(key=lambda e: e[0])

        out: list[PartitionConfig] = []
        seen: set[tuple[Segment, ...]] = set()
        for e in finals:
            segs = self._reconstruct(e)
            if segs in seen:
                continue
            seen.add(segs)
            cfg = self.cost.evaluate(segs)
            if self.cons.path_feasible(cfg):
                out.append(cfg)
            if len(out) >= top_n:
                break
        return out

    @staticmethod
    def _reconstruct(entry) -> tuple[Segment, ...]:
        path: list[str] = []
        e = entry
        while e is not None:
            path.append(e[2])
            e = e[4]
        path.reverse()
        segs: list[Segment] = []
        start = 0
        for i in range(1, len(path) + 1):
            if i == len(path) or path[i] != path[start]:
                segs.append(Segment(path[start], start, i - 1))
                start = i
        return tuple(segs)
