"""Thin re-export shim over :mod:`repro.core.lattice`.

The partitioning engines formerly defined here live in the
``core/lattice/`` package (``chain.py`` holds the cost model and the three
exact chain DPs; ``dag.py`` / ``oracle.py`` / ``sp.py`` add the
DAG-general engine).  Every name importable from ``repro.core.partition``
before the refactor is still importable here, including the private
helpers some tests reach for.
"""

from .lattice.chain import *                                   # noqa: F401,F403
from .lattice.chain import (_LatticeBase, _nondominated_rows,  # noqa: F401
                            _objective_vector)
from .lattice.dag import DagCostModel, DagPartitionConfig      # noqa: F401
from .lattice.oracle import (dag_config_satisfies,             # noqa: F401
                             dag_search_space,
                             enumerate_dag_partitions)
from .lattice.sp import SPSolver                               # noqa: F401
