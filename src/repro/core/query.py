"""Query engine (Scission §II-C Step 6).

Queries run against a cached :class:`BenchmarkDB` — never against live
hardware — which is what keeps the paper's "<50 ms per query" budget.  Two
execution strategies, chosen automatically:

* small search spaces (≤ ``EXHAUSTIVE_LIMIT`` configs): vectorised
  exhaustive enumeration + filter (the paper's own strategy);
* large spaces: the k-best :class:`PartitionLattice` — or, for the
  throughput objective (a max, not a sum), the exact minimax
  :class:`BottleneckLattice`.

Both return identically-shaped ranked :class:`PartitionConfig` lists, so the
paper's experiments and the 1000-node fleet path share one API.  Beyond the
single-objective ``run``, :meth:`QueryEngine.frontier` returns the Pareto
non-dominated set over (latency, throughput, transfer) — the trade-off
surface deployments actually choose between.
"""

from __future__ import annotations

import math
import time
from dataclasses import dataclass, field, replace

from .bench import BenchmarkDB
from .network import NetworkModel
from .partition import (BottleneckLattice, Constraints, CostModel, Objective,
                        ThroughputObjective, LATENCY, TRANSFER, THROUGHPUT,
                        PartitionConfig, PartitionLattice,
                        enumerate_partitions, ordered_pipelines,
                        pareto_frontier, rank)
from .resources import Resource

EXHAUSTIVE_LIMIT = 200_000


def _dedupe(configs: list[PartitionConfig]) -> list[PartitionConfig]:
    seen: set = set()
    out = []
    for cfg in configs:
        if cfg.segments not in seen:
            seen.add(cfg.segments)
            out.append(cfg)
    return out


@dataclass
class Query:
    """A user query (paper Step 6 examples map 1:1 onto these fields)."""

    objective: Objective = LATENCY
    top_n: int = 3
    # constraints
    must_use: tuple[str, ...] = ()
    exclude: tuple[str, ...] = ()
    pin: dict[int, str] = field(default_factory=dict)
    max_link_bytes: dict[tuple[str, str], float] = field(default_factory=dict)
    max_resource_time: dict[str, float] = field(default_factory=dict)
    min_blocks_on: dict[str, int] = field(default_factory=dict)
    pipelines: tuple[tuple[str, ...], ...] | None = None   # restrict pipelines

    def constraints(self) -> Constraints:
        return Constraints(must_use=self.must_use, exclude=self.exclude,
                           pin=self.pin, max_link_bytes=self.max_link_bytes,
                           max_resource_time=self.max_resource_time,
                           min_blocks_on=self.min_blocks_on)


@dataclass
class QueryResult:
    configs: list[PartitionConfig]
    query_time_s: float
    strategy: str

    @property
    def best(self) -> PartitionConfig:
        return self.configs[0]


class QueryEngine:
    """Step 6 over one (model benchmark DB, resource set, network)."""

    def __init__(self, db: BenchmarkDB, resources: list[Resource],
                 network: NetworkModel, source: str, input_bytes: float):
        self.cost = CostModel(db=db, resources=resources, network=network,
                              source=source, input_bytes=input_bytes)
        self.resources = resources
        self._exhaustive_cache: list[PartitionConfig] | None = None
        self._restricted_cache: dict[tuple, list[PartitionConfig]] = {}

    # -- sizing -------------------------------------------------------------
    def _valid_pipelines(self, pipes) -> tuple[tuple[str, ...], ...]:
        """Normalize a ``Query.pipelines`` restriction: keep only pipes made
        of known resources in strictly ascending tier order — the only
        sequences any strategy can produce (data flows device -> edge ->
        cloud).  Applying this in one place keeps the exhaustive-cache,
        restricted-enumeration and lattice branches consistent."""
        order = {r.name: r.order for r in self.resources}
        return tuple(
            p for p in pipes
            if all(n in order for n in p)
            and all(order[a] < order[b] for a, b in zip(p, p[1:])))

    def _search_space(self, query: Query | None = None) -> int:
        """Number of configurations the query actually ranges over — honors
        a ``Query.pipelines`` restriction."""
        B = self.cost.n_blocks
        pipes = ordered_pipelines(self.resources) \
            if query is None or query.pipelines is None \
            else self._valid_pipelines(query.pipelines)
        total = 0
        for pipe in pipes:
            k = len(pipe)
            if k <= B:
                total += math.comb(B - 1, k - 1)
        return total

    # -- execution ----------------------------------------------------------
    def run(self, query: Query | None = None) -> QueryResult:
        query = query or Query()
        t0 = time.perf_counter()
        cons = query.constraints()
        if self._search_space(query) <= EXHAUSTIVE_LIMIT:
            configs = self._run_exhaustive(query, cons)
            strategy = "exhaustive"
        else:
            configs = self._run_lattice(query, cons)
            strategy = "lattice"
        return QueryResult(configs=configs,
                           query_time_s=time.perf_counter() - t0,
                           strategy=strategy)

    def frontier(self, query: Query | None = None) -> QueryResult:
        """Pareto non-dominated set over (latency, throughput, transfer).

        Small spaces: exact — computed from the full (constraint-filtered)
        enumeration.  Large spaces: assembled from k-best lattice solves
        under each base objective and Pareto-filtered (a high-recall
        approximation; every returned config is still non-dominated within
        the candidate pool).  Results are sorted by latency.
        """
        query = query or Query()
        t0 = time.perf_counter()
        cons = query.constraints()
        if self._search_space(query) <= EXHAUSTIVE_LIMIT:
            front = pareto_frontier(self._filtered_exhaustive(query, cons))
            strategy = "exhaustive"
        else:
            width = max(query.top_n, 16)
            cands: list[PartitionConfig] = []
            for obj in (LATENCY, TRANSFER, THROUGHPUT):
                q = replace(query, objective=obj, top_n=width)
                cands.extend(self._run_lattice(q, cons))
            front = pareto_frontier(_dedupe(cands))
            strategy = "lattice"
        front.sort(key=lambda c: (c.latency_s, c.bottleneck_s,
                                  c.transfer_bytes))
        return QueryResult(configs=front,
                           query_time_s=time.perf_counter() - t0,
                           strategy=strategy)

    def _lattice_for(self, cons: Constraints, objective: Objective):
        if isinstance(objective, ThroughputObjective):
            return BottleneckLattice(self.cost, cons)
        return PartitionLattice(self.cost, cons, objective)

    def _run_lattice(self, query: Query,
                     cons: Constraints) -> list[PartitionConfig]:
        if query.pipelines is None:
            return self._lattice_for(cons, query.objective).solve(
                top_n=query.top_n)
        # Restrict the lattice to the requested pipelines: solving with
        # must_use == the pipe and everything else excluded admits exactly
        # that resource sequence (transitions only move to later tiers, so
        # the order is forced), then merge the per-pipe k-best lists.
        all_names = {r.name for r in self.resources}
        merged: list[PartitionConfig] = []
        for pipe in self._valid_pipelines(query.pipelines):
            members = set(pipe)
            if any(m not in members for m in query.must_use):
                continue
            if members & set(query.exclude):
                continue
            pcons = Constraints(
                must_use=pipe,
                exclude=tuple(set(query.exclude) | (all_names - members)),
                pin=query.pin, max_link_bytes=query.max_link_bytes,
                max_resource_time=query.max_resource_time,
                min_blocks_on=query.min_blocks_on)
            merged.extend(self._lattice_for(pcons, query.objective)
                          .solve(top_n=query.top_n))
        return rank(_dedupe(merged), query.objective, query.top_n)

    def _run_exhaustive(self, query: Query,
                        cons: Constraints) -> list[PartitionConfig]:
        return rank(self._filtered_exhaustive(query, cons),
                    query.objective, query.top_n)

    def _filtered_exhaustive(self, query: Query,
                             cons: Constraints) -> list[PartitionConfig]:
        if query.pipelines is not None and \
                self._search_space() > EXHAUSTIVE_LIMIT:
            # only the restricted space is small — enumerate just those
            # pipelines instead of building the full cache (cached per
            # pipeline set so repeated queries stay inside the 50 ms budget)
            pipes = self._valid_pipelines(query.pipelines)
            if pipes not in self._restricted_cache:
                self._restricted_cache[pipes] = enumerate_partitions(
                    self.cost, pipelines=pipes)
            pool = self._restricted_cache[pipes]
        else:
            if self._exhaustive_cache is None:
                self._exhaustive_cache = enumerate_partitions(self.cost)
            pool = self._exhaustive_cache
        out = []
        for cfg in pool:
            if query.pipelines is not None and \
                    cfg.resources not in query.pipelines:
                continue
            if not self._config_satisfies(cfg, cons):
                continue
            out.append(cfg)
        return out

    def _config_satisfies(self, cfg: PartitionConfig,
                          cons: Constraints) -> bool:
        used = set(cfg.resources)
        if any(m not in used for m in cons.must_use):
            return False
        if used & cons.exclude:
            return False
        for blk, res in cons.pin.items():
            ok = any(s.resource == res and s.start <= blk <= s.end
                     for s in cfg.segments)
            if not ok:
                return False
        for i, seg in enumerate(cfg.segments[:-1]):
            nxt = cfg.segments[i + 1]
            nbytes = float(self.cost.out_bytes[seg.end])
            if not cons.transition_allowed(seg.resource, nxt.resource, nbytes):
                return False
        if cfg.segments[0].resource != self.cost.source:
            if not cons.transition_allowed(self.cost.source,
                                           cfg.segments[0].resource,
                                           self.cost.input_bytes):
                return False
        return cons.path_feasible(cfg)
