"""Query engine (Scission §II-C Step 6).

Queries run against a cached :class:`BenchmarkDB` — never against live
hardware — which is what keeps the paper's "<50 ms per query" budget.  Two
execution strategies, chosen automatically:

* small search spaces (≤ ``EXHAUSTIVE_LIMIT`` configs): vectorised
  exhaustive enumeration + filter (the paper's own strategy);
* large spaces: the k-best :class:`PartitionLattice` — or, for the
  throughput objective (a max, not a sum), the exact minimax
  :class:`BottleneckLattice` — and, for :meth:`QueryEngine.frontier`,
  the exact non-dominated-label :class:`ParetoLattice`.

Both return identically-shaped ranked :class:`PartitionConfig` lists, so the
paper's experiments and the 1000-node fleet path share one API.

A query names one **operating point** — a batch size and a per-resource
replica budget — and every cost is priced at that point from the DB's
measured batch profiles.  Beyond the single-objective ``run``,
:meth:`QueryEngine.frontier` sweeps the candidate operating points
(measured batch sizes × replica budget) and returns the Pareto
non-dominated set over (latency, throughput, transfer) — the trade-off
surface deployments actually choose between, from latency-at-batch-1 to
throughput-at-max-batch with replicated stages.
"""

from __future__ import annotations

import math
import time
from dataclasses import dataclass, field, replace

from .bench import BenchmarkDB
from .network import NetworkModel
from .partition import (BottleneckLattice, Constraints, CostModel,
                        DagCostModel, Objective,
                        ThroughputObjective, LATENCY,
                        ParetoLattice, PartitionConfig, PartitionLattice,
                        SPSolver, dag_config_satisfies, dag_search_space,
                        enumerate_dag_partitions, enumerate_partitions,
                        ordered_pipelines, pareto_frontier, rank,
                        trim_replicas)
from .resources import Resource

EXHAUSTIVE_LIMIT = 200_000
# enumerated-partition pools (and cost models) are cached per operating
# point; a frontier sweep touches one per measured batch size, so keep a
# small LRU rather than letting a long-lived engine accrete one ~200k-config
# pool per (batch, replica-budget) key ever queried
CACHE_POINTS = 8


def _cache_get(cache: dict, key):
    """Dict-as-LRU: hit moves the key to the back (most recent)."""
    if key not in cache:
        return None
    val = cache.pop(key)
    cache[key] = val
    return val


def _cache_put(cache: dict, key, val, limit: int = CACHE_POINTS):
    cache.pop(key, None)
    cache[key] = val
    while len(cache) > limit:
        cache.pop(next(iter(cache)))
    return val


def _op_key(cfg: PartitionConfig) -> tuple:
    return (cfg.segments, cfg.batch_size, cfg.replicas)


def _dedupe(configs: list[PartitionConfig]) -> list[PartitionConfig]:
    seen: set = set()
    out = []
    for cfg in configs:
        k = _op_key(cfg)
        if k not in seen:
            seen.add(k)
            out.append(cfg)
    return out


@dataclass
class Query:
    """A user query (paper Step 6 examples map 1:1 onto these fields).

    ``batch_size`` and ``replicas`` (a per-resource replica *budget*:
    resource name -> max copies a stage placed there may use) select the
    operating point ``run`` prices; ``batch_sizes`` optionally restricts
    the operating points ``frontier`` sweeps (default: every batch size
    the DB measured).  ``frontier_epsilon`` is the lattice frontier's
    ε-dominance knob (0.0 == exact; > 0 bounds label-set growth on
    fleet-sized spaces at a bounded relative error).
    """

    objective: Objective = LATENCY
    top_n: int = 3
    # operating point
    batch_size: int = 1
    replicas: dict[str, int] = field(default_factory=dict)
    batch_sizes: tuple[int, ...] | None = None     # frontier sweep override
    frontier_epsilon: float = 0.0                  # ε-dominance (0 == exact)
    # constraints
    must_use: tuple[str, ...] = ()
    exclude: tuple[str, ...] = ()
    pin: dict[int, str] = field(default_factory=dict)
    max_link_bytes: dict[tuple[str, str], float] = field(default_factory=dict)
    max_resource_time: dict[str, float] = field(default_factory=dict)
    min_blocks_on: dict[str, int] = field(default_factory=dict)
    pipelines: tuple[tuple[str, ...], ...] | None = None   # restrict pipelines

    def __post_init__(self):
        # normalize the sequence-valued fields once, so every strategy
        # (enumeration cache, restricted enumeration, lattice) compares
        # against the same shapes — a pipe supplied as a list used to
        # enumerate its configs and then be filtered out one by one
        self.must_use = tuple(self.must_use)
        self.exclude = tuple(self.exclude)
        if self.pipelines is not None:
            self.pipelines = tuple(tuple(p) for p in self.pipelines)
        if self.frontier_epsilon < 0.0:
            raise ValueError(
                f"frontier_epsilon must be >= 0, got {self.frontier_epsilon}")

    def constraints(self) -> Constraints:
        return Constraints(must_use=self.must_use, exclude=self.exclude,
                           pin=self.pin, max_link_bytes=self.max_link_bytes,
                           max_resource_time=self.max_resource_time,
                           min_blocks_on=self.min_blocks_on)


@dataclass
class QueryResult:
    configs: list[PartitionConfig]
    query_time_s: float
    strategy: str
    # ParetoLattice label-set statistics, populated by the lattice frontier
    # strategy: how many vector labels survived per-state dominance pruning
    # across all states, and how many were pruned
    labels_kept: int = 0
    labels_pruned: int = 0
    # scission-lint findings for this query (repro.analysis.plan_lint):
    # structural constraint problems, batch-clamp warnings drained from the
    # DB, and — for an empty result no structural error explains — the
    # exact SCN109 joint-unsatisfiability verdict.  An empty ``configs``
    # therefore always arrives with a machine-checkable explanation.
    diagnostics: list = field(default_factory=list)

    @property
    def best(self) -> PartitionConfig:
        return self.configs[0]


class QueryEngine:
    """Step 6 over one (model benchmark DB, resource set, network)."""

    def __init__(self, db: BenchmarkDB, resources: list[Resource],
                 network: NetworkModel, source: str, input_bytes: float,
                 block_preds: list | None = None, sp_tree=None):
        self.db = db
        self.resources = resources
        self.network = network
        self.source = source
        self.input_bytes = input_bytes
        # DAG mode: block-level edges (BlockDag.preds) + the SP
        # decomposition tree.  A chain-shaped (or absent) block_preds keeps
        # every solve on the untouched chain code paths, bit-identically.
        self.block_preds = [list(p) for p in block_preds] \
            if block_preds is not None else None
        self.sp_tree = sp_tree
        self.is_dag = (self.block_preds is not None and any(
            ps != ([] if i == 0 else [i - 1])
            for i, ps in enumerate(self.block_preds)))
        # cost models and enumeration caches are per operating point
        # (batch size, replica budget) — the batch-1 single-replica model
        # stays constructed eagerly as the legacy `.cost` view
        self._costs: dict[tuple, CostModel] = {}
        self.cost = self._cost_for()
        self._exhaustive_cache: dict[tuple, list[PartitionConfig]] = {}
        self._restricted_cache: dict[tuple, list[PartitionConfig]] = {}

    # -- operating points ----------------------------------------------------
    @staticmethod
    def _point_key(batch_size: int = 1,
                   replicas: dict[str, int] | None = None) -> tuple:
        return (batch_size, tuple(sorted((replicas or {}).items())))

    def _cost_for(self, query: Query | None = None) -> CostModel:
        batch = query.batch_size if query is not None else 1
        reps = dict(query.replicas) if query is not None else {}
        key = self._point_key(batch, reps)
        cost = _cache_get(self._costs, key)
        if cost is None:
            if self.is_dag:
                cost = DagCostModel(
                    db=self.db, resources=self.resources,
                    network=self.network, source=self.source,
                    input_bytes=self.input_bytes, batch_size=batch,
                    replica_budget=reps, block_preds=self.block_preds,
                    tree=self.sp_tree)
            else:
                cost = CostModel(
                    db=self.db, resources=self.resources,
                    network=self.network, source=self.source,
                    input_bytes=self.input_bytes,
                    batch_size=batch, replica_budget=reps)
            cost = _cache_put(self._costs, key, cost)
        return cost

    def _frontier_batches(self, query: Query) -> list[int]:
        """Batch sizes the frontier sweeps: an explicit ``Query.batch_sizes``
        wins; otherwise every batch the DB measured for this engine's
        resources (so a legacy batch-1 DB sweeps exactly the paper's single
        operating point).  Same contract as ``run``: an unmeasurable
        operating point is an error, not a silently-skipped candidate —
        the profile cannot price it without extrapolating."""
        names = [r.name for r in self.resources]
        if query.batch_sizes is None:
            return self.db.measured_batches(names)
        max_batch = self.db.max_batch(names)
        batches = sorted({int(b) for b in query.batch_sizes})
        bad = [b for b in batches if not 1 <= b <= max_batch]
        if bad:
            raise ValueError(
                f"requested batch_sizes {bad} are outside the measured "
                f"range (1..{max_batch}) for model {self.db.model!r}; "
                "re-run benchmark_model(batch_sizes=...) to cover them")
        return batches

    # -- sizing -------------------------------------------------------------
    def _valid_pipelines(self, pipes) -> tuple[tuple[str, ...], ...]:
        """Normalize a ``Query.pipelines`` restriction: keep only pipes made
        of known resources in strictly ascending tier order — the only
        sequences any strategy can produce (data flows device -> edge ->
        cloud).  Applying this in one place keeps the exhaustive-cache,
        restricted-enumeration and lattice branches consistent."""
        order = {r.name: r.order for r in self.resources}
        return tuple(
            tuple(p) for p in pipes
            if all(n in order for n in p)
            and all(order[a] < order[b] for a, b in zip(p, p[1:])))

    def _admissible_pipes(self, query: Query | None = None
                          ) -> tuple[tuple[str, ...], ...]:
        """The pipelines the query can actually draw configs from: the
        valid ordered pipelines (or the query's ``pipelines`` restriction)
        that contain every *demanded* resource — ``must_use``, a
        ``min_blocks_on`` floor >= 1 (presence implied) or a ``pin``
        target — and avoid every excluded one.  Configs from any other
        pipe are rejected by the constraint filter anyway, so restricting
        enumeration (and the counted search space) to these pipes changes
        no result — it only makes the exhaustive strategy's cost, and the
        exhaustive/lattice crossover decision, reflect the constrained
        query actually being answered."""
        pipes = ordered_pipelines(self.resources) \
            if query is None or query.pipelines is None \
            else self._valid_pipelines(query.pipelines)
        if query is None:
            return tuple(pipes)
        need = set(query.must_use) | set(query.pin.values()) | {
            r for r, n in query.min_blocks_on.items() if n >= 1}
        excl = set(query.exclude)
        return tuple(p for p in pipes
                     if need <= set(p) and not (set(p) & excl))

    def _search_space(self, query: Query | None = None) -> int:
        """Number of configurations the query actually ranges over — honors
        a ``Query.pipelines`` restriction and the pipe-level implications
        of the query's constraints (see :meth:`_admissible_pipes`)."""
        if self.is_dag:
            cons = query.constraints() if query is not None else Constraints()
            pipes = None if query is None or query.pipelines is None \
                else self._admissible_pipes(query)
            return self._dag_space(cons, pipes)
        B = self.db.n_blocks
        total = 0
        for pipe in self._admissible_pipes(query):
            k = len(pipe)
            if k <= B:
                total += math.comb(B - 1, k - 1)
        return total

    def _dag_space(self, cons: Constraints,
                   pipes: tuple[tuple[str, ...], ...] | None) -> int:
        """Counted tier-monotone assignment space of a DAG engine, with an
        early cutoff just past the crossover limit."""
        cost = self.cost
        if pipes is None:
            return dag_search_space(cost, cons, limit=EXHAUSTIVE_LIMIT)
        all_names = {r.name for r in self.resources}
        total = 0
        for pipe in pipes:
            pcons = Constraints(
                must_use=pipe,
                exclude=tuple(set(cons.exclude) | (all_names - set(pipe))),
                pin=cons.pin)
            total += dag_search_space(cost, pcons, limit=EXHAUSTIVE_LIMIT)
            if total > EXHAUSTIVE_LIMIT:
                break
        return total

    # -- execution ----------------------------------------------------------
    def run(self, query: Query | None = None) -> QueryResult:
        query = query or Query()
        t0 = time.perf_counter()
        cons = query.constraints()
        cost = self._cost_for(query)
        if self._search_space(query) <= EXHAUSTIVE_LIMIT:
            configs = self._run_exhaustive(query, cons, cost)
            strategy = "exhaustive"
        elif self.is_dag:
            configs = self._run_sp(query, cons, cost)
            strategy = "lattice"
        else:
            configs = self._run_lattice(query, cons, cost)
            strategy = "lattice"
        result = QueryResult(configs=configs,
                             query_time_s=time.perf_counter() - t0,
                             strategy=strategy)
        self._attach_diagnostics(result, query, cons, [cost],
                                 batches=[query.batch_size])
        return result

    def frontier(self, query: Query | None = None,
                 strategy: str | None = None) -> QueryResult:
        """Pareto non-dominated set over (latency, throughput, transfer),
        swept across operating points (measured batch sizes × the query's
        replica budget).

        Both strategies are exact (chosen by search-space size, or forced
        via ``strategy``):

        * ``"exhaustive"`` — non-dominated filter over the full
          (constraint-filtered) enumeration: the paper-faithful path on
          small spaces and the validation oracle the lattice is checked
          against (tests + ``bench_partitions --smoke-frontier``).
        * ``"lattice"`` — :class:`ParetoLattice` per operating point: every
          (block, resource, must-use-mask) state keeps its exact
          non-dominated label set, replacing the three-objective k-best
          union that could silently miss non-dominated operating points.
          ``Query.frontier_epsilon`` > 0 trades a bounded relative error
          for smaller label sets on fleet-sized spaces; label-set
          statistics land on ``QueryResult.labels_kept`` /
          ``labels_pruned``.  Every constraint — including the
          path-dependent ``max_resource_time`` / ``min_blocks_on`` — is
          folded into the DP state, so both strategies return the same
          result set on every constrained query (no post-filtering that
          could under-fill the lattice result).

        Points from every swept operating point compete in one final
        Pareto filter, so the result is the exact global frontier over the
        swept points.  Replica counts of returned points are trimmed to
        the minimum achieving their bottleneck.  Results are sorted by
        latency.
        """
        query = query or Query()
        if strategy not in (None, "exhaustive", "lattice"):
            raise ValueError(f"unknown frontier strategy {strategy!r}")
        t0 = time.perf_counter()
        cons = query.constraints()
        if strategy is None:
            strategy = "exhaustive" \
                if self._search_space(query) <= EXHAUSTIVE_LIMIT else "lattice"
        kept = pruned = 0
        cands: list[PartitionConfig] = []
        batches = self._frontier_batches(query)
        costs: list[CostModel] = []
        for batch in batches:
            q = replace(query, batch_size=batch)
            cost = self._cost_for(q)
            costs.append(cost)
            if strategy == "exhaustive":
                cands.extend(self._filtered_exhaustive(q, cons, cost))
            else:
                configs, k, p = self._lattice_frontier(q, cons, cost)
                cands.extend(configs)
                kept += k
                pruned += p
        front = [trim_replicas(c) for c in pareto_frontier(_dedupe(cands))]
        front.sort(key=lambda c: (c.latency_s, c.bottleneck_s,
                                  c.transfer_bytes))
        result = QueryResult(configs=front,
                             query_time_s=time.perf_counter() - t0,
                             strategy=strategy,
                             labels_kept=kept, labels_pruned=pruned)
        # the frontier ignores top_n, and a timing-dependent error must
        # hold at every swept batch before it explains an empty frontier
        self._attach_diagnostics(result, query, cons, costs,
                                 batches=batches, check_top_n=False)
        return result

    def _attach_diagnostics(self, result: QueryResult, query: Query,
                            cons: Constraints, costs: list[CostModel],
                            batches: list[int],
                            check_top_n: bool = True) -> None:
        """Run the plan linter (repro.analysis) over the just-answered query
        and attach its findings — plus any batch-clamp warnings the pricing
        drained out of the DB.  When the result is empty and no structural
        error explains it, the exact joint-satisfiability sweep (SCN109)
        supplies the explanation.  Runs *after* the solve so the paper's
        <50 ms ``query_time_s`` metric stays a pure solve time.
        """
        from ..analysis.diagnostics import dedupe
        from ..analysis.plan_lint import explain_empty, lint_plan

        diags = lint_plan(query, self.resources, self.network, self.db,
                          source=self.source, batches=batches,
                          check_top_n=check_top_n)
        if hasattr(self.db, "drain_diagnostics"):
            diags.extend(self.db.drain_diagnostics())
        if not result.configs:
            diags.extend(explain_empty(query, cons, costs, prior=diags))
        result.diagnostics = dedupe(diags)

    def _lattice_frontier(self, query: Query, cons: Constraints,
                          cost: CostModel
                          ) -> tuple[list[PartitionConfig], int, int]:
        """One operating point's exact frontier via :class:`ParetoLattice`,
        honoring a ``Query.pipelines`` restriction the same way
        :meth:`_run_lattice` does (per-pipe solves; overlapping pipe
        spaces are fine — the caller Pareto-filters the deduped union).
        Returns (configs, labels_kept, labels_pruned)."""
        eps = query.frontier_epsilon
        if self.is_dag:
            if query.pipelines is None:
                solver = SPSolver(cost, cons, epsilon=eps)
                return (solver.frontier(), solver.labels_kept,
                        solver.labels_pruned)
            merged: list[PartitionConfig] = []
            kept = pruned = 0
            for pcons in self._pipe_constraints(query):
                solver = SPSolver(cost, pcons, epsilon=eps)
                merged.extend(solver.frontier())
                kept += solver.labels_kept
                pruned += solver.labels_pruned
            return merged, kept, pruned
        if query.pipelines is None:
            lattice = ParetoLattice(cost, cons, epsilon=eps)
            return lattice.solve(), lattice.labels_kept, lattice.labels_pruned
        merged = []
        kept = pruned = 0
        for pcons in self._pipe_constraints(query):
            lattice = ParetoLattice(cost, pcons, epsilon=eps)
            merged.extend(lattice.solve())
            kept += lattice.labels_kept
            pruned += lattice.labels_pruned
        return merged, kept, pruned

    def _lattice_for(self, cons: Constraints, objective: Objective,
                     cost: CostModel):
        if isinstance(objective, ThroughputObjective):
            return BottleneckLattice(cost, cons)
        return PartitionLattice(cost, cons, objective)

    def _pipe_constraints(self, query: Query):
        """Per-pipe lattice restrictions for a ``Query.pipelines`` query:
        solving with must_use == the pipe and everything else excluded
        admits exactly that resource sequence (transitions only move to
        later tiers, so the order is forced).  Yields one Constraints per
        admissible pipe — shared by the k-best and frontier lattice paths
        so both honor identical restrictions."""
        all_names = {r.name for r in self.resources}
        # a pipe missing a demanded resource (must_use, or a min_blocks_on
        # floor >= 1, which implies presence) can never yield a feasible
        # config — skip the solve instead of letting the lattice discover
        # the infeasibility
        need = set(query.must_use) | {
            r for r, n in query.min_blocks_on.items() if n >= 1}
        for pipe in self._valid_pipelines(query.pipelines):
            members = set(pipe)
            if any(m not in members for m in need):
                continue
            if members & set(query.exclude):
                continue
            yield Constraints(
                must_use=pipe,
                exclude=tuple(set(query.exclude) | (all_names - members)),
                pin=query.pin, max_link_bytes=query.max_link_bytes,
                max_resource_time=query.max_resource_time,
                min_blocks_on=query.min_blocks_on)

    def _run_lattice(self, query: Query, cons: Constraints,
                     cost: CostModel) -> list[PartitionConfig]:
        if query.pipelines is None:
            return self._lattice_for(cons, query.objective, cost).solve(
                top_n=query.top_n)
        merged: list[PartitionConfig] = []
        for pcons in self._pipe_constraints(query):
            merged.extend(self._lattice_for(pcons, query.objective, cost)
                          .solve(top_n=query.top_n))
        return rank(_dedupe(merged), query.objective, query.top_n)

    def _run_sp(self, query: Query, cons: Constraints,
                cost: CostModel) -> list[PartitionConfig]:
        """Large-space DAG solve via :class:`SPSolver` (the DAG analogue of
        ``_run_lattice``, objective handling included — the solver's label
        vectors carry both the additive and the bottleneck components)."""
        if query.pipelines is None:
            return SPSolver(cost, cons).solve(query.objective,
                                              top_n=query.top_n)
        merged: list[PartitionConfig] = []
        for pcons in self._pipe_constraints(query):
            merged.extend(SPSolver(cost, pcons).solve(query.objective,
                                                      top_n=query.top_n))
        return rank(_dedupe(merged), query.objective, query.top_n)

    def _run_exhaustive(self, query: Query, cons: Constraints,
                        cost: CostModel) -> list[PartitionConfig]:
        return rank(self._filtered_exhaustive(query, cons, cost),
                    query.objective, query.top_n)

    def _filtered_exhaustive(self, query: Query, cons: Constraints,
                             cost: CostModel) -> list[PartitionConfig]:
        if self.is_dag:
            return self._dag_filtered(query, cons, cost)
        point = self._point_key(query.batch_size, query.replicas)
        admissible = self._admissible_pipes(query)
        if self._search_space() > EXHAUSTIVE_LIMIT:
            # only the constrained space is small — enumerate just the
            # admissible pipelines instead of building the full cache
            # (cached per pipeline set so repeated queries stay inside the
            # 50 ms budget)
            ck = (point, admissible)
            pool = _cache_get(self._restricted_cache, ck)
            if pool is None:
                pool = _cache_put(self._restricted_cache, ck,
                                  enumerate_partitions(cost,
                                                       pipelines=admissible))
        else:
            pool = _cache_get(self._exhaustive_cache, point)
            if pool is None:
                pool = _cache_put(self._exhaustive_cache, point,
                                  enumerate_partitions(cost))
        # filter against the *normalized* pipeline set: the enumeration
        # paths normalize through _valid_pipelines, so comparing raw query
        # values (e.g. pipes supplied as lists) would reject every config
        allowed_pipes = None if query.pipelines is None else \
            set(self._valid_pipelines(query.pipelines))
        out = []
        for cfg in pool:
            if allowed_pipes is not None and \
                    cfg.resources not in allowed_pipes:
                continue
            if not self._config_satisfies(cfg, cons, cost):
                continue
            out.append(cfg)
        return out

    def _dag_filtered(self, query: Query, cons: Constraints,
                      cost: CostModel) -> list[PartitionConfig]:
        """Exhaustive DAG pool + constraint filter.  Enumeration applies
        ``exclude``/``pin`` up front (they shrink the recursion), so the
        pool cache is keyed by them alongside the operating point."""
        point = self._point_key(query.batch_size, query.replicas)
        ck = (point, tuple(sorted(query.exclude)),
              tuple(sorted(query.pin.items())))
        pool = _cache_get(self._exhaustive_cache, ck)
        if pool is None:
            pool = _cache_put(self._exhaustive_cache, ck,
                              enumerate_dag_partitions(cost, cons))
        allowed_pipes = None if query.pipelines is None else \
            set(self._valid_pipelines(query.pipelines))
        out = []
        for cfg in pool:
            if allowed_pipes is not None and \
                    tuple(cfg.pipeline) not in allowed_pipes:
                continue
            if not dag_config_satisfies(cost, cfg, cons):
                continue
            out.append(cfg)
        return out

    def _config_satisfies(self, cfg: PartitionConfig, cons: Constraints,
                          cost: CostModel) -> bool:
        used = set(cfg.resources)
        if any(m not in used for m in cons.must_use):
            return False
        if used & cons.exclude:
            return False
        for blk, res in cons.pin.items():
            ok = any(s.resource == res and s.start <= blk <= s.end
                     for s in cfg.segments)
            if not ok:
                return False
        for i, seg in enumerate(cfg.segments[:-1]):
            nxt = cfg.segments[i + 1]
            nbytes = float(cost.out_bytes[seg.end])
            if not cons.transition_allowed(seg.resource, nxt.resource, nbytes):
                return False
        if cfg.segments[0].resource != cost.source:
            if not cons.transition_allowed(cost.source,
                                           cfg.segments[0].resource,
                                           cost.batch_input_bytes):
                return False
        return cons.path_feasible(cfg)
