"""Query engine (Scission §II-C Step 6).

Queries run against a cached :class:`BenchmarkDB` — never against live
hardware — which is what keeps the paper's "<50 ms per query" budget.  Two
execution strategies, chosen automatically:

* small search spaces (≤ ``EXHAUSTIVE_LIMIT`` configs): vectorised
  exhaustive enumeration + filter (the paper's own strategy);
* large spaces: the k-best :class:`PartitionLattice` — or, for the
  throughput objective (a max, not a sum), the exact minimax
  :class:`BottleneckLattice` — and, for :meth:`QueryEngine.frontier`,
  the exact non-dominated-label :class:`ParetoLattice`.

Both return identically-shaped ranked :class:`PartitionConfig` lists, so the
paper's experiments and the 1000-node fleet path share one API.

A query names one **operating point** — a batch size and a per-resource
replica budget — and every cost is priced at that point from the DB's
measured batch profiles.  Beyond the single-objective ``run``,
:meth:`QueryEngine.frontier` sweeps the candidate operating points
(measured batch sizes × replica budget) and returns the Pareto
non-dominated set over (latency, throughput, transfer) — the trade-off
surface deployments actually choose between, from latency-at-batch-1 to
throughput-at-max-batch with replicated stages.
"""

from __future__ import annotations

import math
import time
from dataclasses import dataclass, field, replace

from .bench import BenchmarkDB
from .network import NetworkModel
from .partition import (BottleneckLattice, ChainPlan, Constraints, CostModel,
                        DagCostModel, LabelState, Objective,
                        ThroughputObjective, LATENCY,
                        ParetoLattice, PartitionConfig, PartitionLattice,
                        SPSolver, dag_config_satisfies, dag_search_space,
                        enumerate_dag_partitions, enumerate_partitions,
                        ordered_pipelines, pareto_frontier, rank,
                        trim_replicas)
from .resources import Resource

# auto-dispatch crossover between the paper-faithful exhaustive strategy
# and the vectorised lattices.  Re-measured after the label DPs went
# vectorised (PR 8): a cold lattice solve beats cold enumeration from a
# few hundred configs and a warm one (cached pool) from ~3k, so the old
# 200_000 — which encoded per-label Python DP cost — kept enumeration far
# past its win region.  10_000 keeps paper-testbed-sized spaces (~2.4k)
# on the exhaustive path, which doubles as the validation oracle, and
# dispatches everything larger to the lattices.
EXHAUSTIVE_LIMIT = 10_000
# enumerated-partition pools (and cost models) are cached per operating
# point; a frontier sweep touches one per measured batch size, so keep a
# small LRU rather than letting a long-lived engine accrete one ~200k-config
# pool per (batch, replica-budget) key ever queried
CACHE_POINTS = 8


def _cache_get(cache: dict, key):
    """Dict-as-LRU: hit moves the key to the back (most recent)."""
    if key not in cache:
        return None
    val = cache.pop(key)
    cache[key] = val
    return val


def _cache_put(cache: dict, key, val, limit: int = CACHE_POINTS):
    cache.pop(key, None)
    cache[key] = val
    while len(cache) > limit:
        cache.pop(next(iter(cache)))
    return val


def _op_key(cfg: PartitionConfig) -> tuple:
    return (cfg.segments, cfg.batch_size, cfg.replicas)


def _cons_key(cons: Constraints) -> tuple:
    """Hashable signature of a Constraints — the cache key for everything
    derived from the constraint structure (ChainPlan, warm SP solvers)."""
    return (cons.must_use, tuple(sorted(cons.exclude)),
            tuple(sorted(cons.pin.items())),
            tuple(sorted(cons.max_link_bytes.items())),
            tuple(sorted(cons.max_resource_time.items())),
            tuple(sorted(cons.min_blocks_on.items())))


def _dedupe(configs: list[PartitionConfig]) -> list[PartitionConfig]:
    seen: set = set()
    out = []
    for cfg in configs:
        k = _op_key(cfg)
        if k not in seen:
            seen.add(k)
            out.append(cfg)
    return out


@dataclass
class Query:
    """A user query (paper Step 6 examples map 1:1 onto these fields).

    ``batch_size`` and ``replicas`` (a per-resource replica *budget*:
    resource name -> max copies a stage placed there may use) select the
    operating point ``run`` prices; ``batch_sizes`` optionally restricts
    the operating points ``frontier`` sweeps (default: every batch size
    the DB measured).  ``frontier_epsilon`` is the lattice frontier's
    ε-dominance knob (0.0 == exact; > 0 bounds label-set growth on
    fleet-sized spaces at a bounded relative error).
    """

    objective: Objective = LATENCY
    top_n: int = 3
    # operating point
    batch_size: int = 1
    replicas: dict[str, int] = field(default_factory=dict)
    batch_sizes: tuple[int, ...] | None = None     # frontier sweep override
    frontier_epsilon: float = 0.0                  # ε-dominance (0 == exact)
    # constraints
    must_use: tuple[str, ...] = ()
    exclude: tuple[str, ...] = ()
    pin: dict[int, str] = field(default_factory=dict)
    max_link_bytes: dict[tuple[str, str], float] = field(default_factory=dict)
    max_resource_time: dict[str, float] = field(default_factory=dict)
    min_blocks_on: dict[str, int] = field(default_factory=dict)
    pipelines: tuple[tuple[str, ...], ...] | None = None   # restrict pipelines

    def __post_init__(self):
        # normalize the sequence-valued fields once, so every strategy
        # (enumeration cache, restricted enumeration, lattice) compares
        # against the same shapes — a pipe supplied as a list used to
        # enumerate its configs and then be filtered out one by one
        self.must_use = tuple(self.must_use)
        self.exclude = tuple(self.exclude)
        if self.pipelines is not None:
            self.pipelines = tuple(tuple(p) for p in self.pipelines)
        if self.frontier_epsilon < 0.0:
            raise ValueError(
                f"frontier_epsilon must be >= 0, got {self.frontier_epsilon}")

    def constraints(self) -> Constraints:
        return Constraints(must_use=self.must_use, exclude=self.exclude,
                           pin=self.pin, max_link_bytes=self.max_link_bytes,
                           max_resource_time=self.max_resource_time,
                           min_blocks_on=self.min_blocks_on)


@dataclass
class QueryResult:
    configs: list[PartitionConfig]
    query_time_s: float
    strategy: str
    # label-set statistics, populated by every lattice-strategy path
    # (ParetoLattice frontier, PartitionLattice/BottleneckLattice k-best,
    # SPSolver DAG solves): how many vector labels survived per-state
    # dominance pruning across all states, and how many were pruned
    labels_kept: int = 0
    labels_pruned: int = 0
    # pure solver wall time: the strategy call only, excluding constraint
    # normalisation, cost-model construction/lookup and diagnostics — the
    # number the smoke JSONs compare against the exhaustive oracle
    solve_seconds: float = 0.0
    # scission-lint findings for this query (repro.analysis.plan_lint):
    # structural constraint problems, batch-clamp warnings drained from the
    # DB, and — for an empty result no structural error explains — the
    # exact SCN109 joint-unsatisfiability verdict.  An empty ``configs``
    # therefore always arrives with a machine-checkable explanation.
    diagnostics: list = field(default_factory=list)

    @property
    def best(self) -> PartitionConfig:
        return self.configs[0]


class QueryEngine:
    """Step 6 over one (model benchmark DB, resource set, network)."""

    def __init__(self, db: BenchmarkDB, resources: list[Resource],
                 network: NetworkModel, source: str, input_bytes: float,
                 block_preds: list | None = None, sp_tree=None):
        self.db = db
        self.resources = resources
        self.network = network
        self.source = source
        self.input_bytes = input_bytes
        # DAG mode: block-level edges (BlockDag.preds) + the SP
        # decomposition tree.  A chain-shaped (or absent) block_preds keeps
        # every solve on the untouched chain code paths, bit-identically.
        self.block_preds = [list(p) for p in block_preds] \
            if block_preds is not None else None
        self.sp_tree = sp_tree
        self.is_dag = (self.block_preds is not None and any(
            ps != ([] if i == 0 else [i - 1])
            for i, ps in enumerate(self.block_preds)))
        # cost models and enumeration caches are per operating point
        # (batch size, replica budget) — the batch-1 single-replica model
        # stays constructed eagerly as the legacy `.cost` view
        self._costs: dict[tuple, CostModel] = {}
        self.cost = self._cost_for()
        # cost-model soundness lint (repro.analysis.cost_lint), run once at
        # construction: SCN4xx findings describe premises the exact DPs
        # assume about this DB / network / cost model, so they hold for (and
        # are attached to) every result this engine answers
        from ..analysis.cost_lint import lint_cost
        self._cost_diags = lint_cost(
            db, network=network, resources=[r.name for r in resources],
            cost=self.cost)
        self._exhaustive_cache: dict[tuple, list[PartitionConfig]] = {}
        self._restricted_cache: dict[tuple, list[PartitionConfig]] = {}
        # batch-independent solve structure (ChainPlan) per constraint
        # signature: one plan prices every operating point of a frontier
        # sweep and every elastic re-plan at the same membership
        self._plan_cache: dict[tuple, ChainPlan] = {}
        # warm SPSolver per (constraints, operating point, epsilon): the
        # solver memoises its per-block transition tables and final label
        # sets, so a repeated DAG query re-prices instead of re-solving
        self._sp_cache: dict[tuple, SPSolver] = {}

    # -- operating points ----------------------------------------------------
    @staticmethod
    def _point_key(batch_size: int = 1,
                   replicas: dict[str, int] | None = None) -> tuple:
        return (batch_size, tuple(sorted((replicas or {}).items())))

    def _cost_for(self, query: Query | None = None) -> CostModel:
        batch = query.batch_size if query is not None else 1
        reps = dict(query.replicas) if query is not None else {}
        key = self._point_key(batch, reps)
        cost = _cache_get(self._costs, key)
        if cost is None:
            if self.is_dag:
                cost = DagCostModel(
                    db=self.db, resources=self.resources,
                    network=self.network, source=self.source,
                    input_bytes=self.input_bytes, batch_size=batch,
                    replica_budget=reps, block_preds=self.block_preds,
                    tree=self.sp_tree)
            else:
                cost = CostModel(
                    db=self.db, resources=self.resources,
                    network=self.network, source=self.source,
                    input_bytes=self.input_bytes,
                    batch_size=batch, replica_budget=reps)
            cost = _cache_put(self._costs, key, cost)
        return cost

    def _plan_for(self, cons: Constraints) -> ChainPlan:
        """Batch-independent :class:`ChainPlan` for a constraint signature
        (small LRU).  The plan captures everything a lattice/SP solve needs
        that does not depend on the operating point — resource axis, tier
        transition matrix, link latency/bandwidth/limit matrices, per-block
        ``allowed`` masks — so a frontier sweep solves the structure once
        and re-prices per (batch, replicas), and elastic re-plans at an
        unchanged membership skip the rebuild entirely."""
        key = _cons_key(cons)
        plan = _cache_get(self._plan_cache, key)
        if plan is None:
            plan = _cache_put(self._plan_cache, key,
                              ChainPlan(self.cost, cons))
        return plan

    def _sp_for(self, cons: Constraints, cost: CostModel, query: Query,
                epsilon: float = 0.0) -> SPSolver:
        """Warm :class:`SPSolver` per (constraints, operating point, ε)
        (small LRU).  Reusing the solver keeps its per-block transition
        tables and memoised final label sets across queries, so repeated
        solves at one operating point — e.g. the same query under several
        objectives, or a solve followed by a frontier — skip the DP."""
        key = (_cons_key(cons),
               self._point_key(query.batch_size, query.replicas),
               float(epsilon))
        solver = _cache_get(self._sp_cache, key)
        if solver is None:
            solver = _cache_put(
                self._sp_cache, key,
                SPSolver(cost, cons, epsilon=epsilon,
                         plan=self._plan_for(cons)))
        return solver

    def _frontier_batches(self, query: Query) -> list[int]:
        """Batch sizes the frontier sweeps: an explicit ``Query.batch_sizes``
        wins; otherwise every batch the DB measured for this engine's
        resources (so a legacy batch-1 DB sweeps exactly the paper's single
        operating point).  Same contract as ``run``: an unmeasurable
        operating point is an error, not a silently-skipped candidate —
        the profile cannot price it without extrapolating."""
        names = [r.name for r in self.resources]
        if query.batch_sizes is None:
            return self.db.measured_batches(names)
        max_batch = self.db.max_batch(names)
        batches = sorted({int(b) for b in query.batch_sizes})
        bad = [b for b in batches if not 1 <= b <= max_batch]
        if bad:
            raise ValueError(
                f"requested batch_sizes {bad} are outside the measured "
                f"range (1..{max_batch}) for model {self.db.model!r}; "
                "re-run benchmark_model(batch_sizes=...) to cover them")
        return batches

    # -- sizing -------------------------------------------------------------
    def _valid_pipelines(self, pipes) -> tuple[tuple[str, ...], ...]:
        """Normalize a ``Query.pipelines`` restriction: keep only pipes made
        of known resources in strictly ascending tier order — the only
        sequences any strategy can produce (data flows device -> edge ->
        cloud).  Applying this in one place keeps the exhaustive-cache,
        restricted-enumeration and lattice branches consistent."""
        order = {r.name: r.order for r in self.resources}
        return tuple(
            tuple(p) for p in pipes
            if all(n in order for n in p)
            and all(order[a] < order[b] for a, b in zip(p, p[1:])))

    def _admissible_pipes(self, query: Query | None = None
                          ) -> tuple[tuple[str, ...], ...]:
        """The pipelines the query can actually draw configs from: the
        valid ordered pipelines (or the query's ``pipelines`` restriction)
        that contain every *demanded* resource — ``must_use``, a
        ``min_blocks_on`` floor >= 1 (presence implied) or a ``pin``
        target — and avoid every excluded one.  Configs from any other
        pipe are rejected by the constraint filter anyway, so restricting
        enumeration (and the counted search space) to these pipes changes
        no result — it only makes the exhaustive strategy's cost, and the
        exhaustive/lattice crossover decision, reflect the constrained
        query actually being answered."""
        pipes = ordered_pipelines(self.resources) \
            if query is None or query.pipelines is None \
            else self._valid_pipelines(query.pipelines)
        if query is None:
            return tuple(pipes)
        need = set(query.must_use) | set(query.pin.values()) | {
            r for r, n in query.min_blocks_on.items() if n >= 1}
        excl = set(query.exclude)
        return tuple(p for p in pipes
                     if need <= set(p) and not (set(p) & excl))

    def _search_space(self, query: Query | None = None) -> int:
        """Number of configurations the query actually ranges over — honors
        a ``Query.pipelines`` restriction and the pipe-level implications
        of the query's constraints (see :meth:`_admissible_pipes`)."""
        if self.is_dag:
            cons = query.constraints() if query is not None else Constraints()
            pipes = None if query is None or query.pipelines is None \
                else self._admissible_pipes(query)
            return self._dag_space(cons, pipes)
        B = self.db.n_blocks
        total = 0
        for pipe in self._admissible_pipes(query):
            k = len(pipe)
            if k <= B:
                total += math.comb(B - 1, k - 1)
        return total

    def _dag_space(self, cons: Constraints,
                   pipes: tuple[tuple[str, ...], ...] | None) -> int:
        """Counted tier-monotone assignment space of a DAG engine, with an
        early cutoff just past the crossover limit."""
        cost = self.cost
        if pipes is None:
            return dag_search_space(cost, cons, limit=EXHAUSTIVE_LIMIT)
        all_names = {r.name for r in self.resources}
        total = 0
        for pipe in pipes:
            pcons = Constraints(
                must_use=pipe,
                exclude=tuple(set(cons.exclude) | (all_names - set(pipe))),
                pin=cons.pin)
            total += dag_search_space(cost, pcons, limit=EXHAUSTIVE_LIMIT)
            if total > EXHAUSTIVE_LIMIT:
                break
        return total

    # -- execution ----------------------------------------------------------
    def run(self, query: Query | None = None) -> QueryResult:
        query = query or Query()
        t0 = time.perf_counter()
        cons = query.constraints()
        cost = self._cost_for(query)
        kept = pruned = 0
        exhaustive = self._search_space(query) <= EXHAUSTIVE_LIMIT
        t1 = time.perf_counter()
        if exhaustive:
            configs = self._run_exhaustive(query, cons, cost)
            strategy = "exhaustive"
        elif self.is_dag:
            configs, kept, pruned = self._run_sp(query, cons, cost)
            strategy = "lattice"
        else:
            configs, kept, pruned = self._run_lattice(query, cons, cost)
            strategy = "lattice"
        solve_s = time.perf_counter() - t1
        result = QueryResult(configs=configs,
                             query_time_s=time.perf_counter() - t0,
                             strategy=strategy,
                             labels_kept=kept, labels_pruned=pruned,
                             solve_seconds=solve_s)
        self._attach_diagnostics(result, query, cons, [cost],
                                 batches=[query.batch_size])
        return result

    def frontier(self, query: Query | None = None,
                 strategy: str | None = None) -> QueryResult:
        """Pareto non-dominated set over (latency, throughput, transfer),
        swept across operating points (measured batch sizes × the query's
        replica budget).

        Both strategies are exact (chosen by search-space size, or forced
        via ``strategy``):

        * ``"exhaustive"`` — non-dominated filter over the full
          (constraint-filtered) enumeration: the paper-faithful path on
          small spaces and the validation oracle the lattice is checked
          against (tests + ``bench_partitions --smoke-frontier``).
        * ``"lattice"`` — :class:`ParetoLattice` per operating point: every
          (block, resource, must-use-mask) state keeps its exact
          non-dominated label set, replacing the three-objective k-best
          union that could silently miss non-dominated operating points.
          ``Query.frontier_epsilon`` > 0 trades a bounded relative error
          for smaller label sets on fleet-sized spaces; label-set
          statistics land on ``QueryResult.labels_kept`` /
          ``labels_pruned``.  Every constraint — including the
          path-dependent ``max_resource_time`` / ``min_blocks_on`` — is
          folded into the DP state, so both strategies return the same
          result set on every constrained query (no post-filtering that
          could under-fill the lattice result).

        Points from every swept operating point compete in one final
        Pareto filter, so the result is the exact global frontier over the
        swept points.  Replica counts of returned points are trimmed to
        the minimum achieving their bottleneck.  Results are sorted by
        latency.
        """
        query = query or Query()
        if strategy not in (None, "exhaustive", "lattice"):
            raise ValueError(f"unknown frontier strategy {strategy!r}")
        t0 = time.perf_counter()
        cons = query.constraints()
        if strategy is None:
            strategy = "exhaustive" \
                if self._search_space(query) <= EXHAUSTIVE_LIMIT else "lattice"
        kept = pruned = 0
        cands: list[PartitionConfig] = []
        batches = self._frontier_batches(query)
        costs: list[CostModel] = []
        t1 = time.perf_counter()
        for batch in batches:
            q = replace(query, batch_size=batch)
            cost = self._cost_for(q)
            costs.append(cost)
            if strategy == "exhaustive":
                cands.extend(self._filtered_exhaustive(q, cons, cost))
            else:
                configs, k, p = self._lattice_frontier(q, cons, cost)
                cands.extend(configs)
                kept += k
                pruned += p
        front = [trim_replicas(c) for c in pareto_frontier(_dedupe(cands))]
        front.sort(key=lambda c: (c.latency_s, c.bottleneck_s,
                                  c.transfer_bytes))
        solve_s = time.perf_counter() - t1
        result = QueryResult(configs=front,
                             query_time_s=time.perf_counter() - t0,
                             strategy=strategy,
                             labels_kept=kept, labels_pruned=pruned,
                             solve_seconds=solve_s)
        # the frontier ignores top_n, and a timing-dependent error must
        # hold at every swept batch before it explains an empty frontier
        self._attach_diagnostics(result, query, cons, costs,
                                 batches=batches, check_top_n=False)
        return result

    def frontier_incremental(self, query: Query | None = None,
                             prev_states: dict[int, LabelState] | None = None
                             ) -> tuple[QueryResult, dict[int, LabelState]]:
        """Label-reusing frontier sweep for elastic re-plans.

        Same result contract as :meth:`frontier` under the lattice
        strategy, but every swept operating point keeps its final label
        arrays (:class:`LabelState`, keyed by batch size) and a later call
        at a changed resource membership warm-starts from them: a departed
        resource invalidates only labels whose paths touch it
        (:meth:`ParetoLattice.resume` replays the untouched prefix), a
        joined resource generates only the delta paths that visit it
        (:meth:`ParetoLattice.extend`).  Both fall back to a cold solve
        whenever reuse would be unsound (ε mismatch, changed must-set,
        non-prefix join order, fleets past the bitmask width), so the
        returned frontier is always exactly the cold answer.

        Caller contract: pass ``prev_states`` only across *membership*
        changes — per-(block, resource) costs and the network must be
        unchanged, as labels price both.  On a network/bandwidth change
        pass ``None`` to force cold solves.  DAG and pipeline-restricted
        engines fall back to a plain :meth:`frontier` and return no
        states.
        """
        query = query or Query()
        if self.is_dag or query.pipelines is not None:
            return self.frontier(query), {}
        t0 = time.perf_counter()
        cons = query.constraints()
        prev_states = prev_states or {}
        plan = self._plan_for(cons)
        kept = pruned = 0
        cands: list[PartitionConfig] = []
        states: dict[int, LabelState] = {}
        batches = self._frontier_batches(query)
        costs: list[CostModel] = []
        t1 = time.perf_counter()
        for batch in batches:
            q = replace(query, batch_size=batch)
            cost = self._cost_for(q)
            costs.append(cost)
            lat = ParetoLattice(cost, cons,
                                epsilon=query.frontier_epsilon, plan=plan)
            prev = prev_states.get(batch)
            if prev is None:
                configs = lat.solve(keep_state=True)
            elif all(n in prev.names for n in lat.names):
                configs = lat.resume(prev, keep_state=True)
            else:
                configs = lat.extend(prev, keep_state=True)
            if lat.state is not None:
                states[batch] = lat.state
            cands.extend(configs)
            kept += lat.labels_kept
            pruned += lat.labels_pruned
        front = [trim_replicas(c) for c in pareto_frontier(_dedupe(cands))]
        front.sort(key=lambda c: (c.latency_s, c.bottleneck_s,
                                  c.transfer_bytes))
        solve_s = time.perf_counter() - t1
        result = QueryResult(configs=front,
                             query_time_s=time.perf_counter() - t0,
                             strategy="lattice",
                             labels_kept=kept, labels_pruned=pruned,
                             solve_seconds=solve_s)
        self._attach_diagnostics(result, query, cons, costs,
                                 batches=batches, check_top_n=False)
        return result, states

    def _attach_diagnostics(self, result: QueryResult, query: Query,
                            cons: Constraints, costs: list[CostModel],
                            batches: list[int],
                            check_top_n: bool = True) -> None:
        """Run the plan linter (repro.analysis) over the just-answered query
        and attach its findings — plus any batch-clamp warnings the pricing
        drained out of the DB.  When the result is empty and no structural
        error explains it, the exact joint-satisfiability sweep (SCN109)
        supplies the explanation.  Runs *after* the solve so the paper's
        <50 ms ``query_time_s`` metric stays a pure solve time.
        """
        from ..analysis.diagnostics import dedupe
        from ..analysis.plan_lint import explain_empty, lint_plan

        diags = list(self._cost_diags)
        diags += lint_plan(query, self.resources, self.network, self.db,
                           source=self.source, batches=batches,
                           check_top_n=check_top_n)
        if hasattr(self.db, "drain_diagnostics"):
            diags.extend(self.db.drain_diagnostics())
        if not result.configs:
            diags.extend(explain_empty(query, cons, costs, prior=diags))
        result.diagnostics = dedupe(diags)

    def _lattice_frontier(self, query: Query, cons: Constraints,
                          cost: CostModel
                          ) -> tuple[list[PartitionConfig], int, int]:
        """One operating point's exact frontier via :class:`ParetoLattice`,
        honoring a ``Query.pipelines`` restriction the same way
        :meth:`_run_lattice` does (per-pipe solves; overlapping pipe
        spaces are fine — the caller Pareto-filters the deduped union).
        Returns (configs, labels_kept, labels_pruned)."""
        eps = query.frontier_epsilon
        if self.is_dag:
            if query.pipelines is None:
                solver = self._sp_for(cons, cost, query, epsilon=eps)
                return (solver.frontier(), solver.labels_kept,
                        solver.labels_pruned)
            merged: list[PartitionConfig] = []
            kept = pruned = 0
            for pcons in self._pipe_constraints(query):
                solver = self._sp_for(pcons, cost, query, epsilon=eps)
                merged.extend(solver.frontier())
                kept += solver.labels_kept
                pruned += solver.labels_pruned
            return merged, kept, pruned
        if query.pipelines is None:
            lattice = ParetoLattice(cost, cons, epsilon=eps,
                                    plan=self._plan_for(cons))
            return lattice.solve(), lattice.labels_kept, lattice.labels_pruned
        merged = []
        kept = pruned = 0
        for pcons in self._pipe_constraints(query):
            lattice = ParetoLattice(cost, pcons, epsilon=eps,
                                    plan=self._plan_for(pcons))
            merged.extend(lattice.solve())
            kept += lattice.labels_kept
            pruned += lattice.labels_pruned
        return merged, kept, pruned

    def _lattice_for(self, cons: Constraints, objective: Objective,
                     cost: CostModel):
        plan = self._plan_for(cons)
        if isinstance(objective, ThroughputObjective):
            return BottleneckLattice(cost, cons, plan=plan)
        return PartitionLattice(cost, cons, objective, plan=plan)

    def _pipe_constraints(self, query: Query):
        """Per-pipe lattice restrictions for a ``Query.pipelines`` query:
        solving with must_use == the pipe and everything else excluded
        admits exactly that resource sequence (transitions only move to
        later tiers, so the order is forced).  Yields one Constraints per
        admissible pipe — shared by the k-best and frontier lattice paths
        so both honor identical restrictions."""
        all_names = {r.name for r in self.resources}
        # a pipe missing a demanded resource (must_use, or a min_blocks_on
        # floor >= 1, which implies presence) can never yield a feasible
        # config — skip the solve instead of letting the lattice discover
        # the infeasibility
        need = set(query.must_use) | {
            r for r, n in query.min_blocks_on.items() if n >= 1}
        for pipe in self._valid_pipelines(query.pipelines):
            members = set(pipe)
            if any(m not in members for m in need):
                continue
            if members & set(query.exclude):
                continue
            yield Constraints(
                must_use=pipe,
                exclude=tuple(set(query.exclude) | (all_names - members)),
                pin=query.pin, max_link_bytes=query.max_link_bytes,
                max_resource_time=query.max_resource_time,
                min_blocks_on=query.min_blocks_on)

    def _run_lattice(self, query: Query, cons: Constraints, cost: CostModel
                     ) -> tuple[list[PartitionConfig], int, int]:
        """Returns (configs, labels_kept, labels_pruned)."""
        if query.pipelines is None:
            lat = self._lattice_for(cons, query.objective, cost)
            return (lat.solve(top_n=query.top_n),
                    lat.labels_kept, lat.labels_pruned)
        merged: list[PartitionConfig] = []
        kept = pruned = 0
        for pcons in self._pipe_constraints(query):
            lat = self._lattice_for(pcons, query.objective, cost)
            merged.extend(lat.solve(top_n=query.top_n))
            kept += lat.labels_kept
            pruned += lat.labels_pruned
        return (rank(_dedupe(merged), query.objective, query.top_n),
                kept, pruned)

    def _run_sp(self, query: Query, cons: Constraints, cost: CostModel
                ) -> tuple[list[PartitionConfig], int, int]:
        """Large-space DAG solve via :class:`SPSolver` (the DAG analogue of
        ``_run_lattice``, objective handling included — the solver's label
        vectors carry both the additive and the bottleneck components).
        Returns (configs, labels_kept, labels_pruned)."""
        if query.pipelines is None:
            solver = self._sp_for(cons, cost, query)
            return (solver.solve(query.objective, top_n=query.top_n),
                    solver.labels_kept, solver.labels_pruned)
        merged: list[PartitionConfig] = []
        kept = pruned = 0
        for pcons in self._pipe_constraints(query):
            solver = self._sp_for(pcons, cost, query)
            merged.extend(solver.solve(query.objective, top_n=query.top_n))
            kept += solver.labels_kept
            pruned += solver.labels_pruned
        return (rank(_dedupe(merged), query.objective, query.top_n),
                kept, pruned)

    def _run_exhaustive(self, query: Query, cons: Constraints,
                        cost: CostModel) -> list[PartitionConfig]:
        return rank(self._filtered_exhaustive(query, cons, cost),
                    query.objective, query.top_n)

    def _filtered_exhaustive(self, query: Query, cons: Constraints,
                             cost: CostModel) -> list[PartitionConfig]:
        if self.is_dag:
            return self._dag_filtered(query, cons, cost)
        point = self._point_key(query.batch_size, query.replicas)
        admissible = self._admissible_pipes(query)
        if self._search_space() > EXHAUSTIVE_LIMIT:
            # only the constrained space is small — enumerate just the
            # admissible pipelines instead of building the full cache
            # (cached per pipeline set so repeated queries stay inside the
            # 50 ms budget)
            ck = (point, admissible)
            pool = _cache_get(self._restricted_cache, ck)
            if pool is None:
                pool = _cache_put(self._restricted_cache, ck,
                                  enumerate_partitions(cost,
                                                       pipelines=admissible))
        else:
            pool = _cache_get(self._exhaustive_cache, point)
            if pool is None:
                pool = _cache_put(self._exhaustive_cache, point,
                                  enumerate_partitions(cost))
        # filter against the *normalized* pipeline set: the enumeration
        # paths normalize through _valid_pipelines, so comparing raw query
        # values (e.g. pipes supplied as lists) would reject every config
        allowed_pipes = None if query.pipelines is None else \
            set(self._valid_pipelines(query.pipelines))
        out = []
        for cfg in pool:
            if allowed_pipes is not None and \
                    cfg.resources not in allowed_pipes:
                continue
            if not self._config_satisfies(cfg, cons, cost):
                continue
            out.append(cfg)
        return out

    def _dag_filtered(self, query: Query, cons: Constraints,
                      cost: CostModel) -> list[PartitionConfig]:
        """Exhaustive DAG pool + constraint filter.  Enumeration applies
        ``exclude``/``pin`` up front (they shrink the recursion), so the
        pool cache is keyed by them alongside the operating point."""
        point = self._point_key(query.batch_size, query.replicas)
        ck = (point, tuple(sorted(query.exclude)),
              tuple(sorted(query.pin.items())))
        pool = _cache_get(self._exhaustive_cache, ck)
        if pool is None:
            pool = _cache_put(self._exhaustive_cache, ck,
                              enumerate_dag_partitions(cost, cons))
        allowed_pipes = None if query.pipelines is None else \
            set(self._valid_pipelines(query.pipelines))
        out = []
        for cfg in pool:
            if allowed_pipes is not None and \
                    tuple(cfg.pipeline) not in allowed_pipes:
                continue
            if not dag_config_satisfies(cost, cfg, cons):
                continue
            out.append(cfg)
        return out

    def _config_satisfies(self, cfg: PartitionConfig, cons: Constraints,
                          cost: CostModel) -> bool:
        used = set(cfg.resources)
        if any(m not in used for m in cons.must_use):
            return False
        if used & cons.exclude:
            return False
        for blk, res in cons.pin.items():
            ok = any(s.resource == res and s.start <= blk <= s.end
                     for s in cfg.segments)
            if not ok:
                return False
        for i, seg in enumerate(cfg.segments[:-1]):
            nxt = cfg.segments[i + 1]
            nbytes = float(cost.out_bytes[seg.end])
            if not cons.transition_allowed(seg.resource, nxt.resource, nbytes):
                return False
        if cfg.segments[0].resource != cost.source:
            if not cons.transition_allowed(cost.source,
                                           cfg.segments[0].resource,
                                           cost.batch_input_bytes):
                return False
        return cons.path_feasible(cfg)
