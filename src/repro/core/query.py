"""Query engine (Scission §II-C Step 6).

Queries run against a cached :class:`BenchmarkDB` — never against live
hardware — which is what keeps the paper's "<50 ms per query" budget.  Two
execution strategies, chosen automatically:

* small search spaces (≤ ``EXHAUSTIVE_LIMIT`` configs): vectorised
  exhaustive enumeration + filter (the paper's own strategy);
* large spaces: the k-best :class:`PartitionLattice`.

Both return identically-shaped ranked :class:`PartitionConfig` lists, so the
paper's experiments and the 1000-node fleet path share one API.
"""

from __future__ import annotations

import math
import time
from dataclasses import dataclass, field

from .bench import BenchmarkDB
from .network import NetworkModel
from .partition import (Constraints, CostModel, Objective, LATENCY,
                        PartitionConfig, PartitionLattice,
                        enumerate_partitions, ordered_pipelines, rank)
from .resources import Resource

EXHAUSTIVE_LIMIT = 200_000


@dataclass
class Query:
    """A user query (paper Step 6 examples map 1:1 onto these fields)."""

    objective: Objective = LATENCY
    top_n: int = 3
    # constraints
    must_use: tuple[str, ...] = ()
    exclude: tuple[str, ...] = ()
    pin: dict[int, str] = field(default_factory=dict)
    max_link_bytes: dict[tuple[str, str], float] = field(default_factory=dict)
    max_resource_time: dict[str, float] = field(default_factory=dict)
    min_blocks_on: dict[str, int] = field(default_factory=dict)
    pipelines: tuple[tuple[str, ...], ...] | None = None   # restrict pipelines

    def constraints(self) -> Constraints:
        return Constraints(must_use=self.must_use, exclude=self.exclude,
                           pin=self.pin, max_link_bytes=self.max_link_bytes,
                           max_resource_time=self.max_resource_time,
                           min_blocks_on=self.min_blocks_on)


@dataclass
class QueryResult:
    configs: list[PartitionConfig]
    query_time_s: float
    strategy: str

    @property
    def best(self) -> PartitionConfig:
        return self.configs[0]


class QueryEngine:
    """Step 6 over one (model benchmark DB, resource set, network)."""

    def __init__(self, db: BenchmarkDB, resources: list[Resource],
                 network: NetworkModel, source: str, input_bytes: float):
        self.cost = CostModel(db=db, resources=resources, network=network,
                              source=source, input_bytes=input_bytes)
        self.resources = resources
        self._exhaustive_cache: list[PartitionConfig] | None = None

    # -- sizing -------------------------------------------------------------
    def _search_space(self) -> int:
        B = self.cost.n_blocks
        total = 0
        for pipe in ordered_pipelines(self.resources):
            k = len(pipe)
            if k <= B:
                total += math.comb(B - 1, k - 1)
        return total

    # -- execution ----------------------------------------------------------
    def run(self, query: Query | None = None) -> QueryResult:
        query = query or Query()
        t0 = time.perf_counter()
        cons = query.constraints()
        space = self._search_space()
        if space <= EXHAUSTIVE_LIMIT:
            configs = self._run_exhaustive(query, cons)
            strategy = "exhaustive"
        else:
            lat = PartitionLattice(self.cost, cons, query.objective)
            configs = lat.solve(top_n=query.top_n)
            strategy = "lattice"
        return QueryResult(configs=configs,
                           query_time_s=time.perf_counter() - t0,
                           strategy=strategy)

    def _run_exhaustive(self, query: Query,
                        cons: Constraints) -> list[PartitionConfig]:
        if self._exhaustive_cache is None:
            self._exhaustive_cache = enumerate_partitions(self.cost)
        out = []
        for cfg in self._exhaustive_cache:
            if query.pipelines is not None and \
                    cfg.resources not in query.pipelines:
                continue
            if not self._config_satisfies(cfg, cons):
                continue
            out.append(cfg)
        return rank(out, query.objective, query.top_n)

    def _config_satisfies(self, cfg: PartitionConfig,
                          cons: Constraints) -> bool:
        used = set(cfg.resources)
        if any(m not in used for m in cons.must_use):
            return False
        if used & cons.exclude:
            return False
        for blk, res in cons.pin.items():
            ok = any(s.resource == res and s.start <= blk <= s.end
                     for s in cfg.segments)
            if not ok:
                return False
        for i, seg in enumerate(cfg.segments[:-1]):
            nxt = cfg.segments[i + 1]
            nbytes = float(self.cost.out_bytes[seg.end])
            if not cons.transition_allowed(seg.resource, nxt.resource, nbytes):
                return False
        if cfg.segments[0].resource != self.cost.source:
            if not cons.transition_allowed(self.cost.source,
                                           cfg.segments[0].resource,
                                           self.cost.input_bytes):
                return False
        return cons.path_feasible(cfg)
