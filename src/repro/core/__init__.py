"""Scission core: graph IR, benchmarking, partitioning, querying."""

from .graph import (Block, BlockDag, LayerGraph, LayerNode, SPNode,
                    fuse_block_dag, fuse_blocks, linear_graph, sp_summary)
from .resources import (DeviceModel, Resource, paper_testbed, tpu_testbed,
                        tpu_slice, TPU_V5E, TPU_V5E_PEAK_FLOPS,
                        TPU_V5E_HBM_BW, TPU_V5E_ICI_BW)
from .network import (Link, NetworkModel, THREE_G, FOUR_G, WIRED, EDGE_CLOUD,
                      ICI, DCN, paper_network, tpu_network)
from .bench import (BenchmarkDB, BlockBenchmark, TimingProvider,
                    CompiledCostProvider, AnalyticProvider, benchmark_model,
                    benchmark_batches)
from .partition import (Segment, PartitionConfig, CostModel, Objective,
                        ThroughputObjective, LATENCY, TRANSFER, THROUGHPUT,
                        Constraints, PartitionLattice, BottleneckLattice,
                        ParetoLattice, enumerate_partitions,
                        objective_vector, ordered_pipelines, rank,
                        pareto_frontier, dominates, trim_replicas,
                        DagCostModel, DagPartitionConfig, SPSolver,
                        dag_config_satisfies, dag_search_space,
                        enumerate_dag_partitions)
from .query import Query, QueryEngine, QueryResult
from .planner import Scission

__all__ = [
    "Block", "BlockDag", "LayerGraph", "LayerNode", "SPNode",
    "fuse_block_dag", "fuse_blocks", "linear_graph", "sp_summary",
    "DeviceModel", "Resource", "paper_testbed", "tpu_testbed", "tpu_slice",
    "TPU_V5E", "TPU_V5E_PEAK_FLOPS", "TPU_V5E_HBM_BW", "TPU_V5E_ICI_BW",
    "Link", "NetworkModel", "THREE_G", "FOUR_G", "WIRED", "EDGE_CLOUD",
    "ICI", "DCN", "paper_network", "tpu_network",
    "BenchmarkDB", "BlockBenchmark", "TimingProvider", "CompiledCostProvider",
    "AnalyticProvider", "benchmark_model", "benchmark_batches",
    "Segment", "PartitionConfig", "CostModel", "Objective",
    "ThroughputObjective", "LATENCY", "TRANSFER", "THROUGHPUT",
    "Constraints", "PartitionLattice", "BottleneckLattice", "ParetoLattice",
    "enumerate_partitions", "objective_vector", "ordered_pipelines", "rank",
    "pareto_frontier", "dominates", "trim_replicas",
    "DagCostModel", "DagPartitionConfig", "SPSolver",
    "dag_config_satisfies", "dag_search_space", "enumerate_dag_partitions",
    "Query", "QueryEngine", "QueryResult", "Scission",
]
