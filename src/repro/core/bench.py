"""Benchmarking harness (Scission §II-C Steps 2-3).

Each block is split into a standalone sub-model (with its own input layer)
and benchmarked ``runs`` times on every target resource; the mean execution
time and the output size are recorded in a :class:`BenchmarkDB`.

Three providers implement the paper's "empirical, not estimated" principle
under this container's constraints:

* :class:`TimingProvider` — jit + wall-clock on this host, scaled by the
  resource's ``speed_factor``.  This is the **paper-faithful** path, used for
  the CNN zoo (this host plays the 'Cloud' box; the paper itself emulates
  the other tiers' network conditions the same way).
* :class:`CompiledCostProvider` — ``jit(...).lower().compile().cost_analysis()``
  FLOPs/bytes fed through the resource's roofline DeviceModel.  Used for TPU
  tiers that cannot be timed on this CPU-only host.
* :class:`AnalyticProvider` — the graph's analytic per-layer FLOPs through
  the DeviceModel.  Cheapest; used for very large models and in tests.
"""

from __future__ import annotations

import json
import statistics
import time
from dataclasses import dataclass, asdict, field
from typing import Any, Protocol

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels.substrate import KernelAutotuner, compiled_costs
from .graph import Block, LayerGraph, fuse_blocks
from .resources import Resource


@dataclass
class BlockBenchmark:
    """One (block, resource) measurement — the paper's Step 3 record.

    ``tuned_params`` records the autotuned block sizes (per kernel node)
    the measurement was taken with, so a persisted DB documents exactly
    which kernel configuration its timings describe.
    """

    block: int
    resource: str
    mean_time_s: float
    std_time_s: float
    output_bytes: int
    runs: int
    flops: float = 0.0
    bytes_accessed: float = 0.0
    tuned_params: dict = field(default_factory=dict)


@dataclass
class BenchmarkDB:
    """All measurements for one model: ``times[resource][block]``.

    The query engine (Step 6) operates exclusively on this structure, which
    is what makes queries fast (<50 ms): re-querying never re-benchmarks.
    """

    model: str
    n_blocks: int
    records: dict[str, list[BlockBenchmark]] = field(default_factory=dict)

    def time(self, resource: str, block: int) -> float:
        return self.records[resource][block].mean_time_s

    def output_bytes(self, block: int) -> int:
        some = next(iter(self.records.values()))
        return some[block].output_bytes

    def times_matrix(self, resources: list[str]) -> np.ndarray:
        """(R, B) matrix of mean block times — the vectorised form used by
        the partition enumerator."""
        return np.array([[b.mean_time_s for b in self.records[r]]
                         for r in resources])

    def out_bytes_vector(self) -> np.ndarray:
        return np.array([self.output_bytes(i) for i in range(self.n_blocks)],
                        dtype=np.float64)

    # -- (de)serialisation so benchmarking is a strictly offline step --------
    def to_json(self) -> str:
        return json.dumps({
            "model": self.model,
            "n_blocks": self.n_blocks,
            "records": {r: [asdict(b) for b in bs]
                        for r, bs in self.records.items()},
        })

    @classmethod
    def from_json(cls, s: str) -> "BenchmarkDB":
        d = json.loads(s)
        db = cls(model=d["model"], n_blocks=d["n_blocks"])
        db.records = {r: [BlockBenchmark(**b) for b in bs]
                      for r, bs in d["records"].items()}
        return db


class BenchmarkProvider(Protocol):
    def measure(self, block: Block, resource: Resource, runs: int
                ) -> tuple[float, float, float, float]:
        """Returns (mean_s, std_s, flops, bytes_accessed)."""


def _zeros_like_spec(spec: jax.ShapeDtypeStruct):
    return jnp.zeros(spec.shape, spec.dtype)


class TimingProvider:
    """Wall-clock measurement of the block's jit-compiled sub-model.

    Faithful to the paper: 5 runs, averaged, after one warm-up (compilation)
    run, on real inputs of the block's input shape.

    When constructed with a :class:`KernelAutotuner`, kernel-bearing layers
    are block-size-tuned (per resource) before timing, so the DB records
    tuned rather than default kernel timings.
    """

    def __init__(self, tuner: KernelAutotuner | None = None):
        self.tuner = tuner

    def measure(self, block: Block, resource: Resource, runs: int
                ) -> tuple[float, float, float, float]:
        if self.tuner is not None:
            self.tuner.tune_block(block, resource=resource.name)
        fn = jax.jit(block.make_callable())
        x = _zeros_like_spec(block.in_spec)
        out = fn(x)  # warm-up / compile
        jax.block_until_ready(out)
        samples = []
        for _ in range(runs):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(x))
            samples.append(time.perf_counter() - t0)
        mean = statistics.fmean(samples) * resource.speed_factor
        std = (statistics.pstdev(samples) if len(samples) > 1 else 0.0)
        return mean, std * resource.speed_factor, 0.0, 0.0


class CompiledCostProvider:
    """FLOPs/bytes from the compiled sub-model, through the device roofline.

    Empirical in the paper's sense — the numbers come from the compiled
    artifact of the *actual* block, not from an assumed per-layer-type model.
    ``cost_analysis()`` output is normalized through the kernel substrate
    (dict on some JAX versions, list-of-dicts on others).
    """

    def __init__(self, tuner: KernelAutotuner | None = None):
        self.tuner = tuner

    def measure(self, block: Block, resource: Resource, runs: int
                ) -> tuple[float, float, float, float]:
        if self.tuner is not None:
            self.tuner.tune_block(block, resource=resource.name)
        lowered = jax.jit(block.make_callable()).lower(block.in_spec)
        cost = compiled_costs(lowered.compile())
        flops = cost.get("flops", 0.0)
        nbytes = cost.get("bytes accessed", 0.0)
        t = resource.device.layer_time(flops, nbytes)
        return t, 0.0, flops, nbytes


class AnalyticProvider:
    """Graph-declared FLOPs through the device roofline (no compilation)."""

    def measure(self, block: Block, resource: Resource, runs: int
                ) -> tuple[float, float, float, float]:
        flops = block.flops
        # memory traffic ~ params once + activations in/out
        import math
        in_bytes = int(np.prod(block.in_spec.shape)) * np.dtype(block.in_spec.dtype).itemsize
        nbytes = block.param_bytes + in_bytes + block.output_bytes
        t = resource.device.layer_time(flops, nbytes)
        return t, 0.0, flops, float(nbytes)


def benchmark_model(graph: LayerGraph, resources: list[Resource],
                    provider: BenchmarkProvider | None = None,
                    runs: int = 5,
                    blocks: list[Block] | None = None) -> BenchmarkDB:
    """Steps 2-3: fuse into blocks, benchmark every block on every resource."""
    provider = provider or TimingProvider()
    blocks = blocks if blocks is not None else fuse_blocks(graph)
    db = BenchmarkDB(model=graph.name, n_blocks=len(blocks))
    tuner = getattr(provider, "tuner", None)
    for res in resources:
        recs = []
        for blk in blocks:
            mean, std, flops, nbytes = provider.measure(blk, res, runs)
            tuned = tuner.params_for_block(blk) if tuner is not None else {}
            recs.append(BlockBenchmark(
                block=blk.index, resource=res.name, mean_time_s=mean,
                std_time_s=std, output_bytes=blk.output_bytes, runs=runs,
                flops=flops, bytes_accessed=nbytes, tuned_params=tuned))
        db.records[res.name] = recs
    return db
