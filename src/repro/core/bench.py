"""Benchmarking harness (Scission §II-C Steps 2-3).

Each block is split into a standalone sub-model (with its own input layer)
and benchmarked ``runs`` times on every target resource; the mean execution
time and the output size are recorded in a :class:`BenchmarkDB`.

Measurements are **batch-indexed**: every (block, resource) record carries a
``batch_profile`` mapping batch size to (mean seconds per batch, output
bytes per batch).  One request per stage is just the ``batch == 1`` point;
the partitioner's throughput model reads the profile to price batched and
replicated stages.  Unmeasured batch sizes are answered by log-linear
interpolation between measured points, clamped to the measured range (never
extrapolated).

Three providers implement the paper's "empirical, not estimated" principle
under this container's constraints:

* :class:`TimingProvider` — jit + wall-clock on this host, scaled by the
  resource's ``speed_factor``.  This is the **paper-faithful** path, used for
  the CNN zoo (this host plays the 'Cloud' box; the paper itself emulates
  the other tiers' network conditions the same way).
* :class:`CompiledCostProvider` — ``jit(...).lower().compile().cost_analysis()``
  FLOPs/bytes fed through the resource's roofline DeviceModel.  Used for TPU
  tiers that cannot be timed on this CPU-only host.
* :class:`AnalyticProvider` — the graph's analytic per-layer FLOPs through
  the DeviceModel.  Cheapest; used for very large models and in tests.
"""

from __future__ import annotations

import bisect
import inspect
import json
import math
import statistics
import time
from dataclasses import dataclass, asdict, field
from typing import Any, Protocol

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels.substrate import KernelAutotuner, compiled_costs
from .graph import Block, LayerGraph, fuse_blocks
from .resources import Resource

# JSON schema history:
#   1 — one scalar (mean_time_s, output_bytes) per (block, resource);
#       implicit (no "schema_version" key in the payload).
#   2 — adds ``batch_profile`` {batch: [mean_s, output_bytes]} per record.
# ``from_json`` migrates v1 payloads by promoting the scalars to a batch-1
# profile, so persisted results/ DBs keep loading unchanged.
SCHEMA_VERSION = 2


def _interp_profile(profile: dict[int, tuple[float, float]], batch: int,
                    index: int = 0) -> float:
    """Log-linear interpolation of a batch profile at ``batch``.

    ``index`` selects the profile component (0 = mean seconds, 1 = output
    bytes).  Queries outside the measured range clamp to the nearest
    measured batch — the cost model never extrapolates beyond what was
    benchmarked.  Interpolation is linear in (log batch, log value) space,
    which keeps values positive and preserves monotonicity of the measured
    profile.
    """
    if not profile:
        raise KeyError("empty batch profile")
    if batch in profile:
        return float(profile[batch][index])
    bs = sorted(profile)
    if batch <= bs[0]:
        return float(profile[bs[0]][index])
    if batch >= bs[-1]:
        return float(profile[bs[-1]][index])
    hi = bisect.bisect_left(bs, batch)
    b0, b1 = bs[hi - 1], bs[hi]
    v0 = float(profile[b0][index])
    v1 = float(profile[b1][index])
    u = (math.log(batch) - math.log(b0)) / (math.log(b1) - math.log(b0))
    if v0 > 0.0 and v1 > 0.0:
        return math.exp((1.0 - u) * math.log(v0) + u * math.log(v1))
    return (1.0 - u) * v0 + u * v1        # degenerate zero values


@dataclass
class BlockBenchmark:
    """One (block, resource) measurement — the paper's Step 3 record.

    ``mean_time_s`` / ``output_bytes`` are the batch-1 scalars (the paper's
    one-request-per-stage view); ``batch_profile`` holds the full sweep
    ``{batch_size: (mean_s_per_batch, output_bytes_per_batch)}``.
    ``tuned_params`` records the autotuned block sizes (per kernel node)
    the measurement was taken with, so a persisted DB documents exactly
    which kernel configuration its timings describe.
    """

    block: int
    resource: str
    mean_time_s: float
    std_time_s: float
    output_bytes: int
    runs: int
    flops: float = 0.0
    bytes_accessed: float = 0.0
    tuned_params: dict = field(default_factory=dict)
    batch_profile: dict[int, tuple[float, int]] = field(default_factory=dict)

    def __post_init__(self):
        if not self.batch_profile:
            self.batch_profile = {1: (self.mean_time_s, self.output_bytes)}

    def time_at(self, batch: int) -> float:
        """Mean seconds per batch at ``batch``, interpolated (clamped)."""
        return _interp_profile(self.batch_profile, batch, index=0)

    def output_bytes_at(self, batch: int) -> int:
        """Bytes crossing the cut per batch at ``batch``."""
        if batch in self.batch_profile:
            return int(self.batch_profile[batch][1])
        # activations scale linearly with batch; derive from the smallest
        # measured batch rather than log-interpolating an exactly-linear
        # quantity
        b0 = min(self.batch_profile)
        return int(round(self.batch_profile[b0][1] / b0 * batch))


@dataclass
class BenchmarkDB:
    """All measurements for one model: ``times[resource][block]``.

    The query engine (Step 6) operates exclusively on this structure, which
    is what makes queries fast (<50 ms): re-querying never re-benchmarks.
    """

    model: str
    n_blocks: int
    records: dict[str, list[BlockBenchmark]] = field(default_factory=dict)
    # batch-clamp diagnostics (SCN111) accumulated by time() queries outside
    # the measured profile range, drained by the query engine onto
    # QueryResult.diagnostics; bookkeeping only, not part of the DB's value
    _pending_diags: list = field(default_factory=list, repr=False,
                                 compare=False)
    _noted_clamps: set = field(default_factory=set, repr=False, compare=False)

    def time(self, resource: str, block: int, batch: int = 1) -> float:
        """Mean seconds per batch for ``block`` on ``resource`` at ``batch``.

        Unmeasured batch sizes interpolate log-linearly between measured
        profile points and clamp at the measured extremes — and a clamped
        query is *recorded* (SCN111 warning, drained via
        :meth:`drain_diagnostics`) rather than silently answered with the
        nearest measured batch's time.
        """
        rec = self.records[resource][block]
        if batch == 1:
            return rec.mean_time_s
        lo, hi = min(rec.batch_profile), max(rec.batch_profile)
        if not lo <= batch <= hi:
            self._note_clamp(resource, batch, lo, hi)
        return rec.time_at(batch)

    def _note_clamp(self, resource: str, batch: int, lo: int, hi: int):
        if (resource, batch) in self._noted_clamps:
            return
        self._noted_clamps.add((resource, batch))
        from ..analysis.diagnostics import Diagnostic, WARNING
        self._pending_diags.append(Diagnostic(
            "SCN111", WARNING,
            f"batch size {batch} on {resource!r} is outside the measured "
            f"profile range [{lo}, {hi}]; times were clamped to the "
            f"nearest measured batch", subject=resource,
            hint=f"re-run benchmark_model(batch_sizes=(..., {batch})) to "
                 "measure it"))

    def drain_diagnostics(self) -> list:
        """Hand off (and clear) the accumulated clamp diagnostics — the
        query engine attaches them to the ``QueryResult`` whose pricing
        triggered them."""
        out, self._pending_diags = self._pending_diags, []
        self._noted_clamps.clear()
        return out

    def output_bytes(self, block: int, batch: int = 1) -> int:
        if not self.records:
            raise KeyError(
                f"BenchmarkDB for model {self.model!r} has no records; "
                "run benchmark_model() (Steps 2-3) before querying sizes")
        some = next(iter(self.records.values()))
        if batch == 1:
            return some[block].output_bytes
        return some[block].output_bytes_at(batch)

    def measured_batches(self, resources: list[str] | None = None
                         ) -> list[int]:
        """Sorted batch sizes measured for every (resource, block) record —
        the operating points a frontier sweep can price exactly.

        ``resources`` restricts the intersection to those records: a DB may
        carry stale records for departed resources at fewer batch sizes,
        and they must not mask batches the active testbed did measure.
        """
        common: set[int] | None = None
        for name, recs in self.records.items():
            if resources is not None and name not in resources:
                continue
            for rec in recs:
                bs = set(rec.batch_profile)
                common = bs if common is None else common & bs
        return sorted(common or {1})

    def max_batch(self, resources: list[str] | None = None) -> int:
        batches = self.measured_batches(resources)
        return batches[-1] if batches else 1

    def times_matrix(self, resources: list[str],
                     batch: int = 1) -> np.ndarray:
        """(R, B) matrix of mean per-batch block times — the vectorised form
        used by the partition enumerator."""
        return np.array([[self.time(r, b.block, batch)
                          for b in self.records[r]]
                         for r in resources])

    def out_bytes_vector(self, batch: int = 1) -> np.ndarray:
        return np.array(
            [self.output_bytes(i, batch) for i in range(self.n_blocks)],
            dtype=np.float64)

    # -- (de)serialisation so benchmarking is a strictly offline step --------
    def to_json(self) -> str:
        def rec(b: BlockBenchmark) -> dict:
            d = asdict(b)
            # JSON object keys are strings; values as 2-lists
            d["batch_profile"] = {str(k): [v[0], v[1]]
                                  for k, v in b.batch_profile.items()}
            return d

        return json.dumps({
            "schema_version": SCHEMA_VERSION,
            "model": self.model,
            "n_blocks": self.n_blocks,
            "records": {r: [rec(b) for b in bs]
                        for r, bs in self.records.items()},
        })

    @classmethod
    def from_json(cls, s: str) -> "BenchmarkDB":
        d = json.loads(s)
        version = d.get("schema_version", 1)
        if version > SCHEMA_VERSION:
            raise ValueError(
                f"BenchmarkDB schema_version {version} is newer than this "
                f"code understands ({SCHEMA_VERSION}); upgrade the loader")

        def rec(b: dict) -> BlockBenchmark:
            profile = b.pop("batch_profile", None)
            out = BlockBenchmark(**b)
            if profile:                      # v2 payload
                out.batch_profile = {
                    int(k): (float(v[0]), int(v[1]))
                    for k, v in profile.items()}
            # v1 payloads fall through to __post_init__'s batch-1 profile
            return out

        db = cls(model=d["model"], n_blocks=d["n_blocks"])
        db.records = {r: [rec(dict(b)) for b in bs]
                      for r, bs in d["records"].items()}
        return db


class BenchmarkProvider(Protocol):
    def measure(self, block: Block, resource: Resource, runs: int,
                batch: int = 1) -> tuple[float, float, float, float]:
        """Returns (mean_s, std_s, flops, bytes_accessed) for one batch of
        ``batch`` requests."""


def _batched_input(spec: jax.ShapeDtypeStruct, batch: int):
    """The block's input spec replicated ``batch`` times along axis 0 (every
    graph in this repo traces with a leading batch axis)."""
    if batch == 1:
        return jax.ShapeDtypeStruct(spec.shape, spec.dtype)
    shape = (spec.shape[0] * batch, *spec.shape[1:])
    return jax.ShapeDtypeStruct(shape, spec.dtype)


def _zeros_like_spec(spec: jax.ShapeDtypeStruct):
    return jnp.zeros(spec.shape, spec.dtype)


class TimingProvider:
    """Wall-clock measurement of the block's jit-compiled sub-model.

    Faithful to the paper: 5 runs, averaged, after one warm-up (compilation)
    run, on real inputs of the block's input shape.  Batched measurements
    feed a batch-``b`` input through the same sub-model, so economies of
    scale (dispatch amortisation, vectorisation) are captured empirically.

    When constructed with a :class:`KernelAutotuner`, kernel-bearing layers
    are block-size-tuned (per resource) before timing, so the DB records
    tuned rather than default kernel timings.
    """

    def __init__(self, tuner: KernelAutotuner | None = None):
        self.tuner = tuner

    def measure(self, block: Block, resource: Resource, runs: int,
                batch: int = 1) -> tuple[float, float, float, float]:
        if self.tuner is not None:
            self.tuner.tune_block(block, resource=resource.name)
        fn = jax.jit(block.make_callable())
        # one input per entry tensor — a join block of a branchy graph has
        # several; chain blocks degenerate to the single-input call
        xs = [_zeros_like_spec(_batched_input(s, batch))
              for s in block.in_specs]
        out = fn(*xs)  # warm-up / compile
        jax.block_until_ready(out)
        samples = []
        for _ in range(runs):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(*xs))
            samples.append(time.perf_counter() - t0)
        mean = statistics.fmean(samples) * resource.speed_factor
        std = (statistics.pstdev(samples) if len(samples) > 1 else 0.0)
        return mean, std * resource.speed_factor, 0.0, 0.0


class CompiledCostProvider:
    """FLOPs/bytes from the compiled sub-model, through the device roofline.

    Empirical in the paper's sense — the numbers come from the compiled
    artifact of the *actual* block (compiled at the requested batch size),
    not from an assumed per-layer-type model.  ``cost_analysis()`` output is
    normalized through the kernel substrate (dict on some JAX versions,
    list-of-dicts on others).
    """

    def __init__(self, tuner: KernelAutotuner | None = None):
        self.tuner = tuner

    def measure(self, block: Block, resource: Resource, runs: int,
                batch: int = 1) -> tuple[float, float, float, float]:
        if self.tuner is not None:
            self.tuner.tune_block(block, resource=resource.name)
        specs = [_batched_input(s, batch) for s in block.in_specs]
        lowered = jax.jit(block.make_callable()).lower(*specs)
        cost = compiled_costs(lowered.compile())
        flops = cost.get("flops", 0.0)
        nbytes = cost.get("bytes accessed", 0.0)
        t = resource.device.layer_time(flops, nbytes)
        return t, 0.0, flops, nbytes


class AnalyticProvider:
    """Graph-declared FLOPs through the device roofline (no compilation).

    Batch scaling: FLOPs and activation traffic scale linearly with batch,
    parameters are read once per batch — so per-request time improves with
    batch until the roofline binds (dispatch overhead and parameter reads
    amortise), the analytic analogue of what wall-clock batching measures.
    """

    def measure(self, block: Block, resource: Resource, runs: int,
                batch: int = 1) -> tuple[float, float, float, float]:
        flops = block.flops * batch
        # memory traffic ~ params once + activations in/out per request
        # (every entry tensor of a multi-entry join block is read)
        in_bytes = sum(int(np.prod(s.shape)) * np.dtype(s.dtype).itemsize
                       for s in block.in_specs)
        nbytes = block.param_bytes + (in_bytes + block.output_bytes) * batch
        t = resource.device.layer_time(flops, nbytes)
        return t, 0.0, flops, float(nbytes)


def _accepts_batch(provider: BenchmarkProvider) -> bool:
    try:
        params = inspect.signature(provider.measure).parameters
    except (TypeError, ValueError):
        return True
    return "batch" in params or any(
        p.kind is inspect.Parameter.VAR_KEYWORD for p in params.values())


def _measure(provider: BenchmarkProvider, block: Block, resource: Resource,
             runs: int, batch: int) -> tuple[float, float, float, float]:
    if _accepts_batch(provider):
        return provider.measure(block, resource, runs, batch=batch)
    # pre-batch provider: only the paper's batch-1 point is measurable
    if batch != 1:
        raise TypeError(
            f"provider {type(provider).__name__} does not accept batch= — "
            "it cannot measure a batch-size sweep")
    return provider.measure(block, resource, runs)


def benchmark_model(graph: LayerGraph, resources: list[Resource],
                    provider: BenchmarkProvider | None = None,
                    runs: int = 5,
                    blocks: list[Block] | None = None,
                    batch_sizes: tuple[int, ...] = (1,)) -> BenchmarkDB:
    """Steps 2-3: fuse into blocks, benchmark every block on every resource
    at every requested batch size.

    ``batch_sizes`` always includes 1 (the paper's one-request-per-stage
    point and the scalar view every legacy consumer reads); pass e.g.
    ``(1, 4, 16)`` to record a profile the throughput model can interpolate.
    """
    provider = provider or TimingProvider()
    blocks = blocks if blocks is not None else fuse_blocks(graph)
    batches = sorted({int(b) for b in batch_sizes} | {1})
    if any(b < 1 for b in batches):
        raise ValueError(f"batch sizes must be >= 1, got {batch_sizes}")
    db = BenchmarkDB(model=graph.name, n_blocks=len(blocks))
    tuner = getattr(provider, "tuner", None)
    if tuner is not None and hasattr(tuner, "register_resources"):
        # pick up per-resource VMEM budgets so the sweep statically prunes
        # candidates that cannot fit (repro.analysis.kernel_vmem)
        tuner.register_resources(resources)
    for res in resources:
        recs = []
        for blk in blocks:
            profile: dict[int, tuple[float, int]] = {}
            mean1 = std1 = flops1 = nbytes1 = 0.0
            for b in batches:
                mean, std, flops, nbytes = _measure(provider, blk, res,
                                                    runs, b)
                profile[b] = (mean, blk.output_bytes * b)
                if b == 1:
                    mean1, std1, flops1, nbytes1 = mean, std, flops, nbytes
            tuned = tuner.params_for_block(blk) if tuner is not None else {}
            recs.append(BlockBenchmark(
                block=blk.index, resource=res.name, mean_time_s=mean1,
                std_time_s=std1, output_bytes=blk.output_bytes, runs=runs,
                flops=flops1, bytes_accessed=nbytes1, tuned_params=tuned,
                batch_profile=profile))
        db.records[res.name] = recs
    return db


def benchmark_batches(db: BenchmarkDB, graph: LayerGraph,
                      resources: list[Resource],
                      provider: BenchmarkProvider | None = None,
                      runs: int = 5,
                      batch_sizes: tuple[int, ...] = (),
                      blocks: list[Block] | None = None) -> BenchmarkDB:
    """Incremental Step 3 over *batch sizes*: measure only the batches not
    already in ``db``'s profiles and merge them in place — the batch-axis
    companion of :meth:`Scission.benchmark_resource`'s resource-axis
    incrementality.  Existing measurements (including the batch-1 scalars)
    are never re-timed, so upgrading a cached DB with new operating points
    neither repeats the old sweep nor perturbs its decision geometry.

    Every resource must already have records in ``db`` (benchmark it first).
    """
    provider = provider or TimingProvider()
    batches = sorted({int(b) for b in batch_sizes})
    if any(b < 1 for b in batches):
        raise ValueError(f"batch sizes must be >= 1, got {batch_sizes}")
    blocks = blocks if blocks is not None else fuse_blocks(graph)
    for res in resources:
        recs = db.records.get(res.name)
        if recs is None:
            raise KeyError(
                f"resource {res.name!r} has no records in the DB for model "
                f"{db.model!r}; run benchmark_model for it before adding "
                "batch sizes incrementally")
        for blk, rec in zip(blocks, recs):
            for b in batches:
                if b in rec.batch_profile:
                    continue
                mean, _, _, _ = _measure(provider, blk, res, runs, b)
                rec.batch_profile[b] = (mean, blk.output_bytes * b)
    return db
