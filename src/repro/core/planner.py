"""Scission facade — the six-step methodology end to end (paper Figure 5).

    scission = Scission(resources, network, source="device")
    scission.benchmark(graph)                       # Steps 1-3 (offline)
    result = scission.query(graph.name, Query(...)) # Steps 4-6 (<50 ms)

Benchmark databases persist to disk so Steps 1-3 run once per
(model, resource set) and every later query is an in-memory ranking pass —
this is the property the elastic runtime (runtime/elastic.py) relies on to
re-plan within the paper's query budget when a resource joins or leaves.
"""

from __future__ import annotations

import os
from dataclasses import dataclass, field

from .bench import (BenchmarkDB, BenchmarkProvider, TimingProvider,
                    benchmark_batches, benchmark_model)
from .graph import BlockDag, LayerGraph, fuse_block_dag, fuse_blocks
from .network import NetworkModel
from .partition import PartitionConfig
from .query import Query, QueryEngine, QueryResult
from .resources import Resource


@dataclass
class Scission:
    resources: list[Resource]
    network: NetworkModel
    source: str
    provider: BenchmarkProvider = field(default_factory=TimingProvider)
    runs: int = 5

    def __post_init__(self):
        self._dbs: dict[str, BenchmarkDB] = {}
        self._engines: dict[tuple[str, float], QueryEngine] = {}
        # models benchmarked with dag=True: their BlockDag (block-level
        # edges + SP decomposition tree), handed to every query engine so
        # solves run the DAG-general paths
        self._dags: dict[str, BlockDag] = {}

    # -- Steps 1-3 -----------------------------------------------------------
    def _set_db(self, db: BenchmarkDB) -> None:
        """Install a model DB and drop that model's cached query engines —
        an engine holds a direct reference to the DB it was built from, so
        keeping it would price later queries against stale measurements."""
        self._dbs[db.model] = db
        self._engines = {k: v for k, v in self._engines.items()
                         if k[0] != db.model}

    def _blocks_for(self, graph: LayerGraph):
        """The block structure queries for this model run over: the stored
        BlockDag when the model was benchmarked with ``dag=True`` (indices
        must line up with the DB records), plain chain fusing otherwise."""
        dag = self._dags.get(graph.name)
        return dag if dag is not None else fuse_blocks(graph)

    def benchmark(self, graph: LayerGraph,
                  batch_sizes: tuple[int, ...] = (1,),
                  dag: bool = False) -> BenchmarkDB:
        """Steps 1-3.  ``batch_sizes`` > (1,) records a batch profile per
        (block, resource) so throughput queries can price batched stages.

        ``dag=True`` fuses with :func:`fuse_block_dag` — parallel regions
        of the layer graph survive as block-level branches, and every query
        for this model runs the DAG-general partitioner (SP-decomposition
        DP / DAG-aware exhaustive) instead of the chain engines.  On a
        purely linear graph the two fusings are identical and queries stay
        on the chain paths.
        """
        if dag:
            blocks = fuse_block_dag(graph)
            self._dags[graph.name] = blocks
        else:
            self._dags.pop(graph.name, None)
            blocks = fuse_blocks(graph)
        db = benchmark_model(graph, self.resources, self.provider,
                             runs=self.runs, batch_sizes=batch_sizes,
                             blocks=blocks)
        self._set_db(db)
        return db

    def benchmark_resource(self, graph: LayerGraph, resource,
                           batch_sizes: tuple[int, ...] | None = None
                           ) -> BenchmarkDB:
        """Incremental Step 3 for one newly-joined resource: existing
        records are reused, only the new resource's blocks are measured.

        The newcomer is measured at the same batch sizes as the existing
        DB (or ``batch_sizes`` when given), so batched operating points
        stay answerable after an elastic join.
        """
        db = self._dbs.get(graph.name)
        if batch_sizes is None:
            batch_sizes = tuple(db.measured_batches(
                [r.name for r in self.resources])) if db is not None else (1,)
        new = benchmark_model(graph, [resource], self.provider,
                              runs=self.runs, batch_sizes=batch_sizes,
                              blocks=self._blocks_for(graph))
        if db is None:
            self._set_db(new)
            return new
        db.records[resource.name] = new.records[resource.name]
        self._set_db(db)
        return db

    def benchmark_batches(self, graph: LayerGraph,
                          batch_sizes: tuple[int, ...]) -> BenchmarkDB:
        """Incremental Step 3 over batch sizes: measure only the batches the
        model's DB has not already profiled and merge them in place (the
        batch-axis analogue of :meth:`benchmark_resource`)."""
        db = self._dbs.get(graph.name)
        if db is None:
            return self.benchmark(graph, batch_sizes=batch_sizes)
        benchmark_batches(db, graph, self.resources, self.provider,
                          runs=self.runs, batch_sizes=batch_sizes,
                          blocks=self._blocks_for(graph))
        self._set_db(db)
        return db

    def load(self, db: BenchmarkDB) -> None:
        self._set_db(db)

    def save(self, model: str, path: str) -> None:
        with open(path, "w") as f:
            f.write(self._dbs[model].to_json())

    def restore(self, path: str) -> BenchmarkDB:
        with open(path) as f:
            db = BenchmarkDB.from_json(f.read())
        self._set_db(db)
        return db

    # -- Steps 4-6 -----------------------------------------------------------
    def engine(self, model: str, input_bytes: float) -> QueryEngine:
        key = (model, float(input_bytes))
        if key not in self._engines:
            dag = self._dags.get(model)
            self._engines[key] = QueryEngine(
                self._dbs[model], self.resources, self.network,
                source=self.source, input_bytes=input_bytes,
                block_preds=dag.preds if dag is not None else None,
                sp_tree=dag.tree if dag is not None else None)
        return self._engines[key]

    def query(self, model: str, query: Query | None = None,
              input_bytes: float = 150e3) -> QueryResult:
        """150 KB default input — the paper's standard image size."""
        return self.engine(model, input_bytes).run(query)

    def best(self, model: str, input_bytes: float = 150e3) -> PartitionConfig:
        return self.query(model, Query(top_n=1), input_bytes).best

    def frontier(self, model: str, query: Query | None = None,
                 input_bytes: float = 150e3,
                 strategy: str | None = None) -> QueryResult:
        """Pareto non-dominated set over (latency, throughput, transfer).

        ``strategy`` forces the execution strategy ("exhaustive" keeps the
        validation-oracle enumeration, "lattice" the exact
        :class:`ParetoLattice` path); default picks by search-space size.
        """
        return self.engine(model, input_bytes).frontier(query,
                                                        strategy=strategy)

    # -- operational changes (motivation (vi), elastic runtime hook) ---------
    def with_resources(self, resources: list[Resource]) -> "Scission":
        """Re-plan with a changed resource set (maintenance, failure, join)
        WITHOUT re-benchmarking: the per-(block, resource) records of any
        resource still present are reused.

        A model's DB is kept even when some *new* resource has no records
        yet — dropping it would silently discard all prior benchmarking.
        Querying such a model raises a clear "resource X not benchmarked
        for model Y" error at engine construction (CostModel validates);
        run :meth:`benchmark_resource` for the newcomer first.
        """
        s = Scission(resources=resources, network=self.network,
                     source=self.source, provider=self.provider,
                     runs=self.runs)
        s._dags = dict(self._dags)
        names = {r.name for r in resources}
        for model, db in self._dbs.items():
            kept = {r: recs for r, recs in db.records.items() if r in names}
            if kept:
                ndb = BenchmarkDB(model=db.model, n_blocks=db.n_blocks)
                ndb.records = kept
                s._dbs[model] = ndb
        return s
