"""Resource tiers and device models (Scission Table II + TPU targets).

A :class:`Resource` is one benchmarking/execution target: the paper's
Raspberry Pi device, the two edge boxes, the cloud VM (with and without GPU)
— plus the TPU tiers this framework adds.  Each resource carries a
:class:`DeviceModel` used by the analytic benchmark provider; the timing
provider ignores the model and measures wall-clock on this host (scaled by
``speed_factor`` so the heterogeneous-tier experiments remain meaningful on
a single machine, exactly like the paper's emulated network conditions).
"""

from __future__ import annotations

from dataclasses import dataclass, field


@dataclass(frozen=True)
class DeviceModel:
    """Roofline-style device description.

    ``effective_flops`` is sustained (not peak datasheet) throughput for the
    dominant dtype; ``mem_bw`` is sustained memory bandwidth; ``dispatch_s``
    is the fixed per-layer launch overhead (interpreter + runtime), which the
    paper's per-layer benchmarking implicitly captures and which matters for
    many-layer models like NASNet.
    """

    name: str
    effective_flops: float          # FLOP/s
    mem_bw: float                   # bytes/s
    dispatch_s: float = 20e-6       # per-layer fixed overhead

    def layer_time(self, flops: float, bytes_moved: float) -> float:
        """max(compute, memory) roofline + dispatch."""
        t_compute = flops / self.effective_flops if self.effective_flops else 0.0
        t_memory = bytes_moved / self.mem_bw if self.mem_bw else 0.0
        return max(t_compute, t_memory) + self.dispatch_s


@dataclass(frozen=True)
class Resource:
    """One target in the device/edge/cloud continuum."""

    name: str
    tier: str                       # "device" | "edge" | "cloud"
    device: DeviceModel
    # Multiplier applied to wall-clock times measured on *this* host by the
    # timing provider to emulate the resource (this host plays the role of
    # the paper's 'Cloud' box; slower tiers get factors > 1).
    speed_factor: float = 1.0
    # Tier ordering for pipeline construction: data flows device -> edge -> cloud.
    order: int = field(default=0)
    # Per-core VMEM capacity in bytes (None == unconstrained).  Consumed by
    # the kernel memory analyzer (repro.analysis.kernel_vmem): the autotuner
    # statically prunes block-size candidates whose footprint exceeds it
    # before spending compile/measure time on them.
    vmem_bytes: float | None = None

    def __post_init__(self):
        order = {"device": 0, "edge": 1, "cloud": 2}[self.tier]
        object.__setattr__(self, "order", order)


# ---------------------------------------------------------------------------
# Device models.  CPU numbers are sustained-GEMM estimates for the paper's
# hardware (Table II); they only feed the *analytic* provider — the faithful
# reproduction path measures wall-clock instead.
# ---------------------------------------------------------------------------

# sustained throughput calibrated against reported Pi4 CNN inference times
# (MobileNetV2 ~0.2-0.5 s, ResNet50 ~1-2 s on TF), not the datasheet peak
RPI4 = DeviceModel("rpi4-armv8", effective_flops=1.5e9, mem_bw=1.5e9,
                   dispatch_s=250e-6)
EDGE_BOX_1 = DeviceModel("edge1-2c-4.5ghz", effective_flops=5.5e10, mem_bw=2.0e10,
                         dispatch_s=60e-6)
EDGE_BOX_2 = DeviceModel("edge2-4c-3.7ghz", effective_flops=7.0e10, mem_bw=2.5e10,
                         dispatch_s=60e-6)
CLOUD_VM = DeviceModel("cloud-8c-4.5ghz", effective_flops=1.8e11, mem_bw=4.0e10,
                       dispatch_s=40e-6)
GTX_1070 = DeviceModel("gtx1070", effective_flops=5.0e12, mem_bw=2.56e11,
                       dispatch_s=30e-6)

# TPU v5e — the numbers the roofline analysis is REQUIRED to use.
TPU_V5E_PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
TPU_V5E_HBM_BW = 819e9               # bytes/s per chip
TPU_V5E_ICI_BW = 50e9                # bytes/s per link

TPU_V5E = DeviceModel("tpu-v5e", effective_flops=TPU_V5E_PEAK_FLOPS,
                      mem_bw=TPU_V5E_HBM_BW, dispatch_s=5e-6)


def tpu_slice(chips: int, name: str | None = None) -> DeviceModel:
    """An aggregate device model for a TPU slice of ``chips`` chips (the
    Scission engine treats a whole slice as one resource; intra-slice layout
    is SPMD, decided by runtime/sharding.py, not by the partitioner)."""
    return DeviceModel(name or f"tpu-v5e-{chips}",
                       effective_flops=TPU_V5E_PEAK_FLOPS * chips,
                       mem_bw=TPU_V5E_HBM_BW * chips,
                       dispatch_s=5e-6)


# -- the paper's testbed (Table II) -----------------------------------------

def paper_testbed() -> list[Resource]:
    return [
        Resource("device", "device", RPI4, speed_factor=30.0),
        Resource("edge1", "edge", EDGE_BOX_1, speed_factor=3.3),
        Resource("edge2", "edge", EDGE_BOX_2, speed_factor=2.6),
        Resource("cloud", "cloud", CLOUD_VM, speed_factor=1.0),
        Resource("cloud_gpu", "cloud", GTX_1070, speed_factor=0.03),
    ]


# -- the TPU continuum this framework adds -----------------------------------

def tpu_testbed() -> list[Resource]:
    return [
        Resource("edge_v5e1", "device", tpu_slice(1), speed_factor=1.0),
        Resource("regional_v5e16", "edge", tpu_slice(16), speed_factor=1 / 16),
        Resource("pod_v5e256", "cloud", tpu_slice(256), speed_factor=1 / 256),
    ]


def by_name(resources: list[Resource]) -> dict[str, Resource]:
    return {r.name: r for r in resources}
