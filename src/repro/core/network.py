"""Network link models (Scission §III-A).

The paper's first assumption: ``comm_time = network_latency + bytes /
bandwidth``.  We keep that for every WAN/LAN link and add datacenter links
(ICI within a pod, DCN across pods) for the TPU tiers.  Bandwidth presets
are the paper's emulated conditions.
"""

from __future__ import annotations

from dataclasses import dataclass

Mbps = 1e6 / 8          # bytes/s per megabit-per-second
GBps = 1e9              # bytes/s per gigabyte-per-second


@dataclass(frozen=True)
class Link:
    name: str
    latency_s: float
    bandwidth: float        # bytes / s

    def comm_time(self, nbytes: float) -> float:
        """Paper assumption 1: latency + size/bandwidth."""
        return self.latency_s + nbytes / self.bandwidth


# -- the paper's emulated network conditions ---------------------------------
THREE_G = Link("3g", latency_s=0.067, bandwidth=1.6 * Mbps)
FOUR_G = Link("4g", latency_s=0.055, bandwidth=12.4 * Mbps)
WIRED = Link("wired", latency_s=0.020, bandwidth=20 * Mbps)
EDGE_CLOUD = Link("edge-cloud", latency_s=0.025, bandwidth=50 * Mbps)

# -- datacenter links for the TPU tiers --------------------------------------
ICI = Link("ici", latency_s=1e-6, bandwidth=50 * GBps)       # per link
DCN = Link("dcn", latency_s=10e-6, bandwidth=25 * GBps)      # inter-pod
LOOPBACK = Link("local", latency_s=0.0, bandwidth=float("inf"))


class NetworkModel:
    """Maps ordered resource pairs to links.

    Construction mirrors the paper's experiments: one link class for
    device->edge (3G/4G/wired, the variable under study), one fixed link for
    edge->cloud (25 ms / 50 Mbps), and device->cloud traverses both hops'
    latency but is modelled as the access link (the paper's device-cloud
    numbers use the access-network figures end-to-end).
    """

    def __init__(self, default: Link = EDGE_CLOUD):
        self._links: dict[tuple[str, str], Link] = {}
        self._default = default

    def connect(self, src: str, dst: str, link: Link,
                symmetric: bool = True) -> "NetworkModel":
        self._links[(src, dst)] = link
        if symmetric:
            self._links[(dst, src)] = link
        return self

    def links(self) -> dict[tuple[str, str], Link]:
        """The explicitly-connected directed pairs (a copy).

        Introspection view for the plan linter: ``link()`` silently falls
        back to the default link for any pair not listed here, so a
        ``connect(symmetric=False)`` whose reverse direction a plan relies
        on can be flagged (SCN110) instead of mispriced invisibly.
        """
        return dict(self._links)

    def link(self, src: str, dst: str) -> Link:
        hit = self._links.get((src, dst))
        if hit is not None:
            return hit
        # an explicit (src, src) entry models a real same-box staging cost
        # (e.g. host <-> accelerator); only *implicit* self-links are free
        return LOOPBACK if src == dst else self._default

    def comm_time(self, src: str, dst: str, nbytes: float) -> float:
        return self.link(src, dst).comm_time(nbytes)


def paper_network(access: Link = FOUR_G,
                  device: str = "device",
                  edges: tuple[str, ...] = ("edge1", "edge2"),
                  clouds: tuple[str, ...] = ("cloud", "cloud_gpu")) -> NetworkModel:
    """The paper's testbed wiring: device -> edge over ``access`` (3G / 4G /
    wired, Figure 6-8's variable), edge -> cloud fixed at 25 ms / 50 Mbps,
    device -> cloud over the access link as well."""
    net = NetworkModel()
    for e in edges:
        net.connect(device, e, access)
        for c in clouds:
            net.connect(e, c, EDGE_CLOUD)
    for c in clouds:
        net.connect(device, c, access)
    return net


def tpu_network() -> NetworkModel:
    net = NetworkModel(default=DCN)
    net.connect("edge_v5e1", "regional_v5e16", DCN)
    net.connect("regional_v5e16", "pod_v5e256", DCN)
    net.connect("edge_v5e1", "pod_v5e256", DCN)
    return net
