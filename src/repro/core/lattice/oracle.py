"""DAG-aware exhaustive oracle (the validation ground truth for the SP DP).

Enumerates every **tier-monotone assignment**: block 0 may start on any
resource; along every block edge the consumer either stays on the
producer's resource or hands off to a strictly later tier.  On a chain
this is exactly the set of configurations ``enumerate_partitions``
produces (every ordered sub-pipeline × every cut combination); on a DAG
it additionally allows *parallel branches on distinct same-tier
resources* — two edge boxes each running one branch — which is precisely
the placement freedom DAG partitioning exists to exploit.

``dag_search_space`` counts the same set with an early cutoff, giving the
query engine the number it compares against the exhaustive/lattice
crossover (the chain analogue is the ``math.comb`` pipe sum).
"""

from __future__ import annotations

from typing import Iterable, Sequence

from .chain import Constraints
from .dag import DagCostModel, DagPartitionConfig


def _assignment_universe(cost: DagCostModel,
                         constraints: Constraints | None) -> tuple[list[str], dict[str, int]]:
    cons = constraints or Constraints()
    names = [r.name for r in cost.resources if r.name not in cons.exclude]
    order = {r.name: r.order for r in cost.resources}
    return names, order


def _iter_assignments(preds: Sequence[Sequence[int]], names: list[str],
                      order: dict[str, int], cons: Constraints,
                      limit: int | None = None) -> Iterable[tuple[str, ...]]:
    """Depth-first enumeration of tier-monotone assignments (generator).

    ``allowed`` (exclude via the pre-filtered ``names``, pin per block) is
    applied during enumeration; everything else is filtered downstream so
    the enumeration set matches what the query engine caches.

    The per-block candidate lists and tier orders are hoisted out of the
    DFS: ``allowed``/``order`` answers are path-independent, and this
    generator backs ``dag_search_space`` — which the engine runs on every
    query dispatch — so the inner loop touches only precomputed lists.
    """
    B = len(preds)
    cands = [[(r, order[r]) for r in names if cons.allowed(v, r)]
             for v in range(B)]
    chosen: list[str] = []
    chosen_ord: list[int] = []
    count = 0

    def rec(v: int):
        nonlocal count
        if v == B:
            count += 1
            yield tuple(chosen)
            return
        pv = preds[v]
        for r, o in cands[v]:
            ok = True
            for u in pv:
                if chosen[u] != r and o <= chosen_ord[u]:
                    ok = False
                    break
            if not ok:
                continue
            chosen.append(r)
            chosen_ord.append(o)
            yield from rec(v + 1)
            chosen.pop()
            chosen_ord.pop()
            if limit is not None and count > limit:
                return

    yield from rec(0)


def dag_search_space(cost: DagCostModel, constraints: Constraints | None = None,
                     limit: int = 10_000_000) -> int:
    """Number of tier-monotone assignments the exhaustive strategy would
    enumerate (capped at ``limit + 1`` — a return > ``limit`` means "more
    than the cap", which is all the crossover dispatch needs)."""
    names, order = _assignment_universe(cost, constraints)
    cons = constraints or Constraints()
    n = 0
    for _ in _iter_assignments(cost.block_preds, names, order, cons, limit):
        n += 1
        if n > limit:
            break
    return n


def enumerate_dag_partitions(cost: DagCostModel,
                             constraints: Constraints | None = None,
                             max_configs: int = 2_000_000
                             ) -> list[DagPartitionConfig]:
    """Every tier-monotone assignment, priced.  Exact but exponential —
    the :class:`~repro.core.lattice.sp.SPSolver` is the scalable path."""
    names, order = _assignment_universe(cost, constraints)
    cons = constraints or Constraints()
    configs: list[DagPartitionConfig] = []
    for a in _iter_assignments(cost.block_preds, names, order, cons):
        configs.append(cost.evaluate_assignment(a))
        if len(configs) > max_configs:
            raise RuntimeError(
                f"exhaustive DAG enumeration exceeded {max_configs} configs; "
                "use SPSolver")
    return configs


def dag_config_satisfies(cost: DagCostModel, cfg: DagPartitionConfig,
                         cons: Constraints) -> bool:
    """Whole-config constraint check for DAG assignments — the DAG analogue
    of the engine's chain ``_config_satisfies`` + ``path_feasible``."""
    used = set(cfg.assignment)
    if any(r not in used for r in cons.must_use):
        return False
    if used & cons.exclude:
        return False
    for blk, res in cons.pin.items():
        if blk < len(cfg.assignment) and cfg.assignment[blk] != res:
            return False
    if cfg.assignment and cfg.assignment[0] != cost.source:
        if not cons.transition_allowed(cost.source, cfg.assignment[0],
                                       cost.batch_input_bytes):
            return False
    for u, v in cfg.cut_edges:
        if not cons.transition_allowed(cfg.assignment[u], cfg.assignment[v],
                                       float(cost.out_bytes[u])):
            return False
    for res, tmax in cons.max_resource_time.items():
        if cfg.compute_s.get(res, 0.0) > tmax:
            return False
    for res, nmin in cons.min_blocks_on.items():
        if sum(1 for r in cfg.assignment if r == res) < nmin:
            return False
    return True
