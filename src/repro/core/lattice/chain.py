"""Partition configuration generation and ranking (Scission §II-C Steps 4-5).

Two engines over the same cost model:

* :func:`enumerate_partitions` — the paper's **exhaustive** enumeration of
  every native and distributed configuration over every ordered resource
  pipeline.  Kept as the validation oracle and for rich post-hoc queries.
* :class:`PartitionLattice` — a **beyond-paper** Viterbi lattice over
  (block, resource) states.  Exact under the paper's additive cost model
  (assumptions 1 and 2 in §III-A), O(B·R²·2^R) with must-use masks, and
  supports k-best (top-N) extraction.  This is what lets the same decision
  procedure scale from the paper's 3-tier testbed to a 1000+-node fleet,
  and what keeps re-planning (elastic runtime) inside the paper's 50 ms
  query budget.
* :class:`BottleneckLattice` — the exact min-bottleneck (max-throughput)
  companion DP.  Under steady-state pipelined serving the objective is the
  *max* over stage/hop times, not their sum, so the additive Viterbi
  lattice is not exact; this DP works at segment granularity with minimax
  composition instead.
* :class:`ParetoLattice` — the exact multi-objective companion: a
  label-correcting DP over the same (block, resource, must-use-mask)
  states where each state keeps its full **non-dominated set** of vector
  labels over (latency, bottleneck, transfer) instead of a scalar k-best
  list.  Latency/transfer compose additively and the bottleneck by
  minimax — all monotone — so per-state dominance pruning is exact and
  ``QueryEngine.frontier`` no longer has to approximate the trade-off
  surface from three single-objective k-best solves on fleet-sized
  spaces.  An optional ε-dominance knob bounds label-set growth.

Every Step-6 constraint kind — including the path-dependent
``max_resource_time`` / ``min_blocks_on`` — is folded into each lattice's
DP state (see :class:`Constraints` / :class:`_LatticeBase`), so all three
solvers return the true constrained optimum / frontier with no
post-filtering.

Cost model (paper's two assumptions, validated in tests/test_bench.py):

    latency(config) = comm(source -> r_1, input_bytes)
                    + Σ_segments Σ_blocks time(r_i, b)
                    + Σ_cuts     comm(r_i -> r_{i+1}, out_bytes[cut])

Pipelined-serving model (streamed deployments): requests move through the
pipeline in batches of ``batch_size`` and each compute stage may run on
``replicas[k]`` copies of its resource, so the steady-state rate is limited
by the slowest *effective* stage — a compute segment serves
``replicas[k] * batch`` requests per ``stage_time(batch)``, a communication
hop (including the source->first-resource input hop) serves ``batch``
requests per per-batch transfer time:

    period_k    = stage_time_k(batch) / (replicas_k * batch)   (compute)
    period_j    = hop_time_j(batch)   / batch                  (comm)
    bottleneck  = max_k period_k
    throughput_rps = 1 / bottleneck

With ``batch_size == 1`` and all-ones replicas this reduces to the
one-request-per-stage model (max over raw stage/hop times).  Stage times at
``batch > 1`` come from the benchmark DB's measured batch profiles
(log-linear interpolation between measured points, clamped at the measured
extremes), so batching economies are priced empirically, not assumed.
"""

from __future__ import annotations

import bisect
import itertools
import math
from dataclasses import dataclass, field, replace
from typing import Callable, Iterable, Sequence

import numpy as np

from ..bench import BenchmarkDB
from ..network import NetworkModel
from ..resources import Resource


@dataclass(frozen=True)
class Segment:
    resource: str
    start: int          # first block index (inclusive)
    end: int            # last block index (inclusive)


@dataclass
class PartitionConfig:
    """One ranked configuration (a row of the paper's Table IV).

    A config is an **operating point**: segments plus the batch size the
    per-stage timings were priced at and the per-segment replica counts.
    ``latency_s`` / ``stage_compute_s`` / ``stage_comm_s`` /
    ``transfer_bytes`` are all *per batch* on *one replica* (at
    ``batch_size == 1`` that is exactly the paper's per-request model);
    ``bottleneck_s`` / ``throughput_rps`` are per-request effective values.
    """

    model: str
    segments: tuple[Segment, ...]
    latency_s: float
    compute_s: dict[str, float]
    comm_s: float
    transfer_bytes: float           # total inter-resource bytes (incl. input)
    input_comm_s: float = 0.0
    # per-stage timings for pipelined serving: one compute time per segment,
    # one comm time per hop between consecutive segments
    stage_compute_s: tuple[float, ...] = ()
    stage_comm_s: tuple[float, ...] = ()
    # operating point: batch the stage timings were priced at, and replica
    # count per segment (empty tuple == one replica everywhere)
    batch_size: int = 1
    replicas: tuple[int, ...] = ()

    @property
    def resources(self) -> tuple[str, ...]:
        return tuple(s.resource for s in self.segments)

    @property
    def is_native(self) -> bool:
        return len(self.segments) == 1

    def replica_count(self, k: int) -> int:
        """Replicas serving compute stage ``k`` (1 when unspecified)."""
        return self.replicas[k] if k < len(self.replicas) else 1

    @property
    def stage_periods_s(self) -> tuple[float, ...]:
        """Effective per-request service period of every pipeline stage, in
        pipeline order: input hop (if any), then each compute segment
        followed by its outgoing comm hop.  A compute stage with ``r``
        replicas at batch ``b`` serves ``r*b`` requests per ``stage_time``;
        a hop serves ``b`` requests per per-batch transfer."""
        b = max(1, self.batch_size)
        periods: list[float] = []
        if self.input_comm_s > 0.0:
            periods.append(self.input_comm_s / b)
        for k, t in enumerate(self.stage_compute_s):
            periods.append(t / (self.replica_count(k) * b))
            if k < len(self.stage_comm_s):
                periods.append(self.stage_comm_s[k] / b)
        return tuple(periods)

    @property
    def bottleneck_s(self) -> float:
        """Slowest effective pipeline stage (replica- and batch-adjusted) —
        the steady-state per-request period under pipelined serving."""
        periods = self.stage_periods_s
        return max(periods) if periods else self.latency_s

    @property
    def throughput_rps(self) -> float:
        """Steady-state pipelined request rate = 1 / effective bottleneck."""
        b = self.bottleneck_s
        return 1.0 / b if b > 0.0 else float("inf")

    def describe(self) -> str:
        parts = [f"{s.resource}: {s.start}-{s.end}" if s.start != s.end
                 else f"{s.resource}: {s.start}" for s in self.segments]
        op = ""
        if self.batch_size != 1:
            op += f" batch={self.batch_size}"
        if any(r != 1 for r in self.replicas):
            op += " reps=" + "x".join(str(self.replica_count(k))
                                      for k in range(len(self.segments)))
        return (f"[{self.model}] " + " | ".join(parts)
                + f"  latency={self.latency_s * 1e3:.1f}ms"
                + f" thpt={self.throughput_rps:.1f}rps"
                + f" transfer={self.transfer_bytes / 1e6:.3f}MB" + op)


@dataclass
class CostModel:
    """Precomputed vectorised costs for one (model, resource set, network)
    at one operating point (batch size + per-resource replica budget).

    ``batch_size`` selects the per-batch block times from the DB's measured
    batch profiles (interpolated when unmeasured); ``replica_budget`` maps a
    resource name to the number of copies a stage placed on it may use
    (default 1).  All per-config quantities (latency, stage times, transfer)
    are per batch; the effective per-request stage periods divide by
    ``replicas * batch`` (compute) / ``batch`` (comm).
    """

    db: BenchmarkDB
    resources: list[Resource]
    network: NetworkModel
    source: str                      # where the input data originates
    input_bytes: float               # per request
    batch_size: int = 1
    replica_budget: dict[str, int] = field(default_factory=dict)

    times: np.ndarray = field(init=False)        # (R, B) per-batch seconds
    cum: np.ndarray = field(init=False)          # (R, B+1) prefix sums
    out_bytes: np.ndarray = field(init=False)    # (B,) per-batch bytes

    def __post_init__(self):
        names = [r.name for r in self.resources]
        missing = [n for n in names if n not in self.db.records]
        if missing:
            raise ValueError(
                f"resource(s) {', '.join(sorted(missing))} not benchmarked "
                f"for model {self.db.model!r}; run Scission.benchmark() / "
                "benchmark_resource() for them first")
        if self.batch_size < 1:
            raise ValueError(f"batch_size must be >= 1, got {self.batch_size}")
        max_batch = self.db.max_batch(names)
        if self.batch_size > max_batch:
            # pricing batch b from a profile clamped at max_batch would
            # divide the clamped time by b — linear throughput extrapolation
            # the measurements do not support
            raise ValueError(
                f"batch_size {self.batch_size} exceeds the largest measured "
                f"batch ({max_batch}) for model {self.db.model!r}; "
                "re-run benchmark_model(batch_sizes=...) to cover it")
        bad = {r: n for r, n in self.replica_budget.items() if n < 1}
        if bad:
            raise ValueError(f"replica budget must be >= 1, got {bad}")
        self.times = self.db.times_matrix(names, batch=self.batch_size)
        self.cum = np.concatenate(
            [np.zeros((len(names), 1)), np.cumsum(self.times, axis=1)], axis=1)
        self.out_bytes = self.db.out_bytes_vector(batch=self.batch_size)
        self._idx = {n: i for i, n in enumerate(names)}

    @property
    def n_blocks(self) -> int:
        return self.db.n_blocks

    @property
    def batch_input_bytes(self) -> float:
        """Bytes of input data entering the pipeline per batch."""
        return self.input_bytes * self.batch_size

    def replicas_for(self, resource: str) -> int:
        return max(1, int(self.replica_budget.get(resource, 1)))

    def segment_time(self, resource: str, start: int, end: int) -> float:
        """Per-batch compute time of blocks ``start..end`` on one replica."""
        i = self._idx[resource]
        return float(self.cum[i, end + 1] - self.cum[i, start])

    def comm(self, src: str, dst: str, nbytes: float) -> float:
        return self.network.comm_time(src, dst, nbytes)

    # -- effective per-request periods (the minimax DP's stage costs) --------
    def stage_period(self, resource: str, start: int, end: int) -> float:
        """Per-request service period of a compute stage: ``replicas``
        copies each finish a batch of ``batch_size`` per segment time."""
        return self.segment_time(resource, start, end) / (
            self.replicas_for(resource) * self.batch_size)

    def hop_period(self, src: str, dst: str, nbytes: float) -> float:
        """Per-request service period of a comm hop moving ``nbytes`` (a
        per-batch quantity) between stages."""
        return self.comm(src, dst, nbytes) / self.batch_size

    def evaluate(self, segments: Sequence[Segment],
                 objective: "Objective | None" = None) -> PartitionConfig:
        compute = {}
        comm = 0.0
        xfer = 0.0
        first = segments[0].resource
        input_comm = 0.0
        if first != self.source:
            input_comm = self.comm(self.source, first, self.batch_input_bytes)
            xfer += self.batch_input_bytes
        stage_compute: list[float] = []
        stage_comm: list[float] = []
        for k, seg in enumerate(segments):
            t = self.segment_time(seg.resource, seg.start, seg.end)
            compute[seg.resource] = compute.get(seg.resource, 0.0) + t
            stage_compute.append(t)
            if k + 1 < len(segments):
                nbytes = float(self.out_bytes[seg.end])
                hop = self.comm(seg.resource, segments[k + 1].resource, nbytes)
                stage_comm.append(hop)
                comm += hop
                xfer += nbytes
        latency = input_comm + sum(compute.values()) + comm
        return PartitionConfig(
            model=self.db.model, segments=tuple(segments), latency_s=latency,
            compute_s=compute, comm_s=comm, transfer_bytes=xfer,
            input_comm_s=input_comm,
            stage_compute_s=tuple(stage_compute),
            stage_comm_s=tuple(stage_comm),
            batch_size=self.batch_size,
            replicas=tuple(self.replicas_for(s.resource) for s in segments))


@dataclass(frozen=True)
class Objective:
    """Ranking objective: minimise w_latency·latency + w_transfer·transfer.

    The paper's Step 5 default is pure latency; Step 6 allows data-transfer
    and combined objectives.
    """

    w_latency: float = 1.0
    w_transfer_per_mb: float = 0.0

    def score(self, cfg: PartitionConfig) -> float:
        return (self.w_latency * cfg.latency_s
                + self.w_transfer_per_mb * cfg.transfer_bytes / 1e6)


@dataclass(frozen=True)
class ThroughputObjective(Objective):
    """Maximise steady-state pipelined throughput == minimise the bottleneck
    stage time (max of stage compute and per-hop comm).

    Because the score is a *max* rather than a sum, the additive
    :class:`PartitionLattice` is not exact for this objective — the query
    engine dispatches it to :class:`BottleneckLattice` instead.
    """

    def score(self, cfg: PartitionConfig) -> float:
        return cfg.bottleneck_s


LATENCY = Objective()
TRANSFER = Objective(w_latency=0.0, w_transfer_per_mb=1.0)
THROUGHPUT = ThroughputObjective()


# ---------------------------------------------------------------------------
# Exhaustive enumeration (paper-faithful Step 4)
# ---------------------------------------------------------------------------

def ordered_pipelines(resources: list[Resource]) -> list[tuple[str, ...]]:
    """All ordered sub-pipelines: at most one resource per tier, data flows
    device -> edge -> cloud (the paper's native + distributed configs)."""
    tiers: dict[int, list[str]] = {}
    for r in sorted(resources, key=lambda r: r.order):
        tiers.setdefault(r.order, []).append(r.name)
    levels = [tiers[k] for k in sorted(tiers)]
    pipes: list[tuple[str, ...]] = []
    for mask in itertools.product(*[[None, *lvl] for lvl in levels]):
        pipe = tuple(m for m in mask if m is not None)
        if pipe:
            pipes.append(pipe)
    return pipes


def enumerate_partitions(cost: CostModel,
                         pipelines: Iterable[tuple[str, ...]] | None = None,
                         max_configs: int = 2_000_000
                         ) -> list[PartitionConfig]:
    """Every cut combination for every pipeline.  Exact but exponential in
    pipeline length; the lattice below is the scalable path."""
    B = cost.n_blocks
    pipelines = list(pipelines) if pipelines is not None else \
        ordered_pipelines(cost.resources)
    configs: list[PartitionConfig] = []
    n = 0
    for pipe in pipelines:
        k = len(pipe)
        if k > B:
            continue
        for cuts in itertools.combinations(range(1, B), k - 1):
            bounds = [0, *cuts, B]
            segs = [Segment(pipe[i], bounds[i], bounds[i + 1] - 1)
                    for i in range(k)]
            configs.append(cost.evaluate(segs))
            n += 1
            if n > max_configs:
                raise RuntimeError(
                    f"exhaustive enumeration exceeded {max_configs} configs; "
                    "use PartitionLattice")
    return configs


def rank(configs: list[PartitionConfig], objective: Objective = LATENCY,
         top_n: int | None = None) -> list[PartitionConfig]:
    out = sorted(configs, key=objective.score)
    return out if top_n is None else out[:top_n]


def trim_replicas(cfg: PartitionConfig) -> PartitionConfig:
    """Right-size an operating point: shrink each stage's replica count to
    the minimum that keeps the bottleneck (hence throughput) unchanged.

    A replica budget is an upper bound; a stage that is not the bottleneck
    may hit the same rate with fewer copies.  Frontier results are trimmed
    so operators never over-provision to match a reported operating point.
    """
    if not cfg.replicas or all(r == 1 for r in cfg.replicas):
        return cfg
    b = max(1, cfg.batch_size)
    bneck = cfg.bottleneck_s
    if bneck <= 0.0:
        return cfg
    trimmed = []
    for k, t in enumerate(cfg.stage_compute_s):
        need = max(1, math.ceil(t / (b * bneck) - 1e-12))
        trimmed.append(min(cfg.replica_count(k), need))
    return replace(cfg, replicas=tuple(trimmed))


# ---------------------------------------------------------------------------
# Pareto frontier over (latency, throughput, transfer)
# ---------------------------------------------------------------------------

def objective_vector(cfg: PartitionConfig) -> tuple[float, float, float]:
    """The canonical minimised objective vector of the frontier machinery:
    (latency_s, bottleneck_s, transfer_bytes) — ``bottleneck_s`` stands in
    for -throughput.  Every frontier comparison (Pareto filters, elastic
    ``frontier_shift``, bench equality gates) goes through this one
    definition."""
    return (cfg.latency_s, cfg.bottleneck_s, cfg.transfer_bytes)


_objective_vector = objective_vector        # internal alias


def dominates(a: PartitionConfig, b: PartitionConfig) -> bool:
    """True iff ``a`` is no worse than ``b`` on latency, throughput and
    transfer, and strictly better on at least one."""
    va, vb = _objective_vector(a), _objective_vector(b)
    return all(x <= y for x, y in zip(va, vb)) and va != vb


def pareto_frontier(configs: Sequence[PartitionConfig]
                    ) -> list[PartitionConfig]:
    """Exact non-dominated set over (latency, throughput, transfer).

    Processes candidates in lexicographic objective order so each point only
    needs checking against already-accepted frontier members (any dominator
    of p is itself dominated only by points that dominate p, and sorts
    before p).  Configs with identical objective vectors are all kept —
    they are distinct operating points with equal cost.
    """
    if not configs:
        return []
    order = sorted(range(len(configs)),
                   key=lambda i: _objective_vector(configs[i]))
    front: list[int] = []
    pts = [_objective_vector(c) for c in configs]
    for i in order:
        p = pts[i]
        if any(all(x <= y for x, y in zip(pts[j], p)) and pts[j] != p
               for j in front):
            continue
        front.append(i)
    return [configs[i] for i in front]


# ---------------------------------------------------------------------------
# DP lattice (beyond-paper exact search + k-best)
# ---------------------------------------------------------------------------

class Constraints:
    """Hard constraints on the partitioning search (Scission Step 6).

    **All constraints are exact in every strategy** — the exhaustive
    enumeration filters whole configs, and the lattices fold each kind
    into the DP itself:

    * ``must_use`` — via the used-resource bit mask on the state.
    * ``exclude`` / ``pin`` — via :meth:`allowed` on states.
    * ``max_link_bytes`` — via :meth:`transition_allowed` on hand-offs.
    * ``max_resource_time`` — cap on a resource's total compute time.
      Strict tier ordering means a path visits each resource at most once,
      as one contiguous segment, so the "path-dependent" accumulated time
      is just the open segment's span: the lattices carry the open
      segment's start block in the state key for capped resources and
      prune any extension whose segment time exceeds the cap in-flight.
    * ``min_blocks_on`` — floor on the number of blocks a resource hosts
      (a floor >= 1 also forces the resource to appear, so it joins the
      must-use mask); enforced exactly when the segment closes.

    The two path-dependent kinds used to be enforced by post-filtering
    k-best pools, so a binding constraint could reject every pooled winner
    and return fewer — or zero — results while a feasible optimum existed.
    :meth:`path_feasible` remains as the whole-config reference check used
    by the exhaustive strategy (and as the validation oracle in tests).
    """

    def __init__(self,
                 must_use: Sequence[str] = (),
                 exclude: Sequence[str] = (),
                 pin: dict[int, str] | None = None,
                 max_link_bytes: dict[tuple[str, str], float] | None = None,
                 max_resource_time: dict[str, float] | None = None,
                 min_blocks_on: dict[str, int] | None = None):
        self.must_use = tuple(must_use)
        self.exclude = frozenset(exclude)
        self.pin = dict(pin or {})
        self.max_link_bytes = dict(max_link_bytes or {})
        self.max_resource_time = dict(max_resource_time or {})
        self.min_blocks_on = dict(min_blocks_on or {})

    def allowed(self, block: int, resource: str) -> bool:
        if resource in self.exclude:
            return False
        pinned = self.pin.get(block)
        return pinned is None or pinned == resource

    def transition_allowed(self, src: str, dst: str, nbytes: float) -> bool:
        limit = self.max_link_bytes.get((src, dst))
        return limit is None or nbytes <= limit

    def path_feasible(self, cfg: PartitionConfig) -> bool:
        """Whole-config check of the path-dependent constraints — used by
        the exhaustive strategy's filter and as the lattices' validation
        oracle (the lattices themselves enforce these in the DP state)."""
        for res, tmax in self.max_resource_time.items():
            if cfg.compute_s.get(res, 0.0) > tmax:
                return False
        for res, nmin in self.min_blocks_on.items():
            got = sum(s.end - s.start + 1 for s in cfg.segments
                      if s.resource == res)
            if got < nmin:
                return False
        return True


class _LatticeBase:
    """State shared by every lattice DP: the exclude-filtered resource
    list, tier ordering, the must-use bit mask, and the in-DP form of the
    path-dependent constraints.

    A ``must_use`` entry (or a ``min_blocks_on`` floor >= 1, which demands
    presence) naming a resource that is unknown or excluded is
    **unsatisfiable**: no path can ever visit it, so ``infeasible`` is set
    and every ``solve`` returns ``[]`` — exactly what the exhaustive
    strategy does (it rejects every config), keeping the strategies
    consistent instead of silently dropping the constraint.

    Path-dependent constraints are exact in the DP because transitions
    only move to strictly later tiers: a path visits each resource at most
    once, as one contiguous segment, so a resource's total compute time
    and block count are properties of that single segment.  A lattice that
    works at block granularity carries the open segment's start block in
    its state key — but only for **tracked** resources (those named by
    ``max_resource_time`` / ``min_blocks_on``), so the state space is
    unchanged when the constraints are absent.  ``_seg_ok`` prunes a
    segment that exceeds its compute-time cap the moment it does (the cap
    is monotone in the segment span), and ``_close_ok`` enforces the
    min-block floor when the segment closes.  Both recompute the segment
    time via ``CostModel.segment_time``, the same prefix-sum arithmetic
    ``evaluate`` uses, so the DP and the exhaustive oracle agree bit for
    bit on feasibility.
    """

    def __init__(self, cost: CostModel,
                 constraints: Constraints | None = None):
        self.cost = cost
        self.cons = constraints or Constraints()
        self.res = [r for r in cost.resources
                    if r.name not in self.cons.exclude]
        self.names = [r.name for r in self.res]
        self.order = {r.name: r.order for r in self.res}
        self.tmax = dict(self.cons.max_resource_time)
        # a floor <= 0 is trivially satisfied (path_feasible accepts even
        # an absent resource); a floor >= 1 demands presence
        self.nmin = {n: k for n, k in self.cons.min_blocks_on.items()
                     if k >= 1}
        demanded = list(dict.fromkeys((*self.cons.must_use, *self.nmin)))
        self.must = [n for n in demanded if n in self.names]
        self.must_idx = {n: i for i, n in enumerate(self.must)}
        self.full_mask = (1 << len(self.must)) - 1
        self.infeasible = (
            any(n not in self.names for n in demanded)
            or any(k > cost.n_blocks for k in self.nmin.values()))

    def _bit(self, resource: str) -> int:
        i = self.must_idx.get(resource)
        return 0 if i is None else 1 << i

    def _mask_with(self, mask: int, resource: str) -> int:
        return mask | self._bit(resource)

    def _tracked(self, resource: str) -> bool:
        """True when the open segment's start block must live in the state
        key for ``resource`` (it is compute-time capped or block-floored)."""
        return resource in self.tmax or resource in self.nmin

    def _seg_ok(self, resource: str, start: int, end: int) -> bool:
        """Segment ``start..end`` on ``resource`` within its compute-time
        cap (trivially true for uncapped resources)."""
        t = self.tmax.get(resource)
        return t is None or \
            self.cost.segment_time(resource, start, end) <= t

    def _close_ok(self, resource: str, start: int, end: int) -> bool:
        """Closing segment ``start..end`` on ``resource`` satisfies its
        min-block floor (the time cap was enforced while it grew)."""
        k = self.nmin.get(resource)
        return k is None or end - start + 1 >= k


class PartitionLattice(_LatticeBase):
    """Viterbi over (block, resource, used-mask) with k-best extraction.

    Transitions: stay on the same resource (free) or hand off to a strictly
    later tier (pay ``comm(out_bytes[block])``).  The used-mask tracks which
    must-use resources have been visited so 'entire pipeline' style
    constraints stay exact, and for resources named by the path-dependent
    constraints the state key additionally carries the open segment's start
    block (see ``_LatticeBase``), so ``max_resource_time`` prunes in-flight
    and ``min_blocks_on`` gates segment closes — every constraint is part
    of the DP state and ``solve`` returns the true constrained k-best, with
    no post-filtering.
    """

    def __init__(self, cost: CostModel, constraints: Constraints | None = None,
                 objective: Objective = LATENCY):
        super().__init__(cost, constraints)
        self.obj = objective

    def _step_cost(self, resource: str, block: int) -> float:
        t = self.cost.segment_time(resource, block, block)
        return self.obj.w_latency * t

    def _comm_cost(self, src: str, dst: str, nbytes: float) -> float:
        return (self.obj.w_latency * self.cost.comm(src, dst, nbytes)
                + self.obj.w_transfer_per_mb * nbytes / 1e6)

    @staticmethod
    def _push(store: dict, key, entry, k: int) -> None:
        """Bounded-sorted insertion of ``entry`` into ``store[key]``.

        Entries are (score, tie, ...) tuples with a unique tie counter, so
        tuple comparison never reaches the non-comparable tail; a full
        re-sort per insertion (O(K log K) per relaxed edge) is replaced by
        a rejection test plus one O(K) ``bisect.insort``.
        """
        lst = store.setdefault(key, [])
        if len(lst) >= k:
            if entry[0] >= lst[-1][0]:
                return                   # cannot enter a full list
            del lst[-1]
        bisect.insort(lst, entry)

    def solve(self, top_n: int = 1) -> list[PartitionConfig]:
        """k-best paths through the lattice; returns up to ``top_n`` feasible
        configs ranked by the objective.

        Every constraint lives in the DP state, so this is the exact
        constrained k-best: labels at the same (resource, mask, open-seg
        start) state are interchangeable prefixes for every feasible
        completion, hence ``K == top_n`` per state suffices and distinct
        entries reconstruct distinct configs (a path determines its state).
        """
        if top_n <= 0 or self.infeasible:
            return []
        B = self.cost.n_blocks
        K = top_n
        # state (resource, mask, open-seg start | -1 if untracked) -> k-best
        # entries; paths kept as parent pointers to bound memory: entry =
        # (score, tie, resource, mask, parent_entry)
        Entry = tuple  # (score, tie, resource, mask, parent)
        frontier: dict[tuple[str, int, int], list[Entry]] = {}
        tie = itertools.count()
        push = self._push

        for r in self.names:
            if not self.cons.allowed(0, r) or not self._seg_ok(r, 0, 0):
                continue
            inp = 0.0
            if r != self.cost.source:
                nbytes = self.cost.batch_input_bytes
                if not self.cons.transition_allowed(self.cost.source, r,
                                                    nbytes):
                    continue
                inp = self._comm_cost(self.cost.source, r, nbytes)
            score = inp + self._step_cost(r, 0)
            mask = self._mask_with(0, r)
            push(frontier, (r, mask, 0 if self._tracked(r) else -1),
                 (score, next(tie), r, mask, None), K)

        for b in range(1, B):
            nxt: dict[tuple[str, int, int], list[Entry]] = {}
            nbytes = float(self.cost.out_bytes[b - 1])
            for (r, mask, start), entries in frontier.items():
                # stay: the open segment grows through block b (prune the
                # moment it exceeds its compute-time cap)
                if self.cons.allowed(b, r) and \
                        (start < 0 or self._seg_ok(r, start, b)):
                    step = self._step_cost(r, b)
                    for e in entries:
                        push(nxt, (r, mask, start),
                             (e[0] + step, next(tie), r, mask, e), K)
                # hand off to a later tier: closes [start..b-1] on r, which
                # must meet r's min-block floor
                if start >= 0 and not self._close_ok(r, start, b - 1):
                    continue
                for r2 in self.names:
                    if self.order[r2] <= self.order[r] or \
                            not self.cons.allowed(b, r2) or \
                            not self.cons.transition_allowed(r, r2, nbytes) \
                            or not self._seg_ok(r2, b, b):
                        continue
                    m2 = self._mask_with(mask, r2)
                    s2 = b if self._tracked(r2) else -1
                    hop = self._comm_cost(r, r2, nbytes) \
                        + self._step_cost(r2, b)
                    for e in entries:
                        push(nxt, (r2, m2, s2),
                             (e[0] + hop, next(tie), r2, m2, e), K)
            frontier = nxt

        finals: list[Entry] = []
        for (r, mask, start), entries in frontier.items():
            if mask != self.full_mask:
                continue
            if start >= 0 and not self._close_ok(r, start, B - 1):
                continue
            finals.extend(entries)
        finals.sort(key=lambda e: e[0])

        out: list[PartitionConfig] = []
        seen: set[tuple[Segment, ...]] = set()
        for e in finals:
            segs = self._reconstruct(e)
            if segs in seen:
                continue
            seen.add(segs)
            out.append(self.cost.evaluate(segs))
            if len(out) >= top_n:
                break
        return out

    @staticmethod
    def _reconstruct(entry) -> tuple[Segment, ...]:
        path: list[str] = []
        e = entry
        while e is not None:
            path.append(e[2])
            e = e[4]
        path.reverse()
        segs: list[Segment] = []
        start = 0
        for i in range(1, len(path) + 1):
            if i == len(path) or path[i] != path[start]:
                segs.append(Segment(path[start], start, i - 1))
                start = i
        return tuple(segs)


class BottleneckLattice(_LatticeBase):
    """Exact min-bottleneck (max-throughput) DP — the minimax companion to
    :class:`PartitionLattice`.

    Under pipelined serving the objective is ``max`` over *effective* stage
    periods (replica- and batch-adjusted compute, per-request comm), which
    is not additive, so the Viterbi lattice's sum-composition is not exact.
    This DP works at *segment* granularity:

        f(b, r, need) = k-best achievable bottlenecks over blocks b..B-1
                        when block b starts a new segment on resource r and
                        ``need`` is the set of must-use resources still owed

    with minimax composition ``max(stage_period, hop_period, child)``.  Max
    is monotone in the child value, so k-best per state is exact; replicas
    and batch only rescale each state's local cost (the cost model's
    ``stage_period`` / ``hop_period``), so the DP stays exact at every
    operating point.  Complexity O(B²·R²·K·2^M) for M must-use resources.

    Because this DP works at whole-segment granularity, the path-dependent
    constraints need **no state extension at all**: every transition (and
    every terminal) names its segment's exact extent, so
    ``max_resource_time`` and ``min_blocks_on`` are checked per transition
    (``_seg_ok`` / ``_close_ok``) and infeasible segments never enter the
    lattice — ``solve`` returns the true constrained optimum with no
    post-filtering and no pool widening.

    Ties on the bottleneck value are broken by end-to-end latency across
    the *entire* reconstruction pool (every tied final is reconstructed
    before truncating to ``top_n``).  A tie wider than a single state's
    k-best pool can still be cut *inside* the DP; the solver detects that
    (a state dropped a candidate whose value ties the returned optimum)
    and reconstructs the exact tied surface via :class:`ParetoLattice`
    dispatch — the minimum (bottleneck, latency) point is always on the
    Pareto frontier — so the returned optimum's latency tie-break is exact
    regardless of pool width.
    """

    # introspection state of the last solve (class-level defaults so an
    # early-returning solve — infeasible / top_n <= 0 — reads as no-op)
    _tie_cut = math.inf
    _dispatched = False

    def solve(self, top_n: int = 1) -> list[PartitionConfig]:
        if top_n <= 0 or self.infeasible:
            return []
        B = self.cost.n_blocks
        # K == top_n is exact for the k-best *values*; the +head-room keeps
        # more bottleneck-tied candidates in the pools so the latency
        # tie-break rarely has to fall back to the Pareto dispatch below
        K = max(top_n * 2, top_n + 2)
        self._tie_cut = math.inf       # min value a full pool ever dropped
        names = self.names
        out_bytes = self.cost.out_bytes
        # longest allowed contiguous run starting at each (resource, block)
        run: dict[str, list[int]] = {}
        for r in names:
            ok = [self.cons.allowed(b, r) for b in range(B)]
            ends = [0] * (B + 1)
            for b in range(B - 1, -1, -1):
                ends[b] = ends[b + 1] + 1 if ok[b] else 0
            run[r] = ends[:B]

        # memo[(b, ri, need)] = up to K (value, end, child_key, child_pos),
        # sorted ascending; ``need`` never contains ri's own bit
        memo: dict[tuple[int, int, int], list[tuple]] = {}
        for b in range(B - 1, -1, -1):
            for ri, r in enumerate(names):
                n_run = run[r][b]
                bit_r = self._bit(r)
                # transitions are independent of the must-use mask — hoist
                # the (end, r2) scan out of the need loop.  Constraints on
                # the segment itself (compute-time cap, min-block floor)
                # are exact here: each candidate names its segment extent.
                term = None
                if b + n_run >= B and self._seg_ok(r, b, B - 1) \
                        and self._close_ok(r, b, B - 1):
                    term = self.cost.stage_period(r, b, B - 1)
                trans: list[tuple] = []      # (base, end, rj, clear_bit)
                for end in range(b, min(b + n_run, B - 1)):
                    if not self._seg_ok(r, b, end):
                        break            # segment time is monotone in end
                    if not self._close_ok(r, b, end):
                        continue
                    nbytes = float(out_bytes[end])
                    seg_t = self.cost.stage_period(r, b, end)
                    for rj, r2 in enumerate(names):
                        if self.order[r2] <= self.order[r] or \
                                not self.cons.transition_allowed(
                                    r, r2, nbytes):
                            continue
                        base = max(seg_t, self.cost.hop_period(r, r2, nbytes))
                        trans.append((base, end, rj, ~self._bit(r2)))
                for need in range(self.full_mask + 1):
                    if need & bit_r:
                        continue
                    cands: list[tuple] = []
                    if term is not None and need == 0:
                        cands.append((term, B - 1, None, -1))
                    for base, end, rj, clear in trans:
                        ck = (end + 1, rj, need & clear)
                        child = memo.get(ck)
                        if not child:
                            continue
                        for pos, ce in enumerate(child):
                            cands.append((max(base, ce[0]), end, ck, pos))
                    cands.sort(key=lambda t: t[0])
                    if len(cands) > K:
                        self._tie_cut = min(self._tie_cut, cands[K][0])
                    memo[(b, ri, need)] = cands[:K]

        finals: list[tuple[float, tuple[int, int, int], int]] = []
        for ri, r in enumerate(names):
            key = (0, ri, self.full_mask & ~self._bit(r))
            entries = memo.get(key)
            if not entries:
                continue
            inp = 0.0
            if r != self.cost.source:
                nbytes = self.cost.batch_input_bytes
                if not self.cons.transition_allowed(
                        self.cost.source, r, nbytes):
                    continue
                inp = self.cost.hop_period(self.cost.source, r, nbytes)
            for pos in range(len(entries)):
                finals.append((max(entries[pos][0], inp), key, pos))
        finals.sort(key=lambda t: t[0])

        # ties in bottleneck are common (e.g. the input hop dominates), so
        # truncating the reconstruction pool before the (bottleneck,
        # latency) tie-break could cut a lower-latency config and return a
        # strictly worse one.  Reconstruct until we hold top_n configs AND
        # the next candidate's value exceeds the top_n-th best bottleneck —
        # i.e. collect every bottleneck-tied candidate first.
        out: list[PartitionConfig] = []
        seen: set[tuple[Segment, ...]] = set()
        kth = math.inf                  # top_n-th smallest kept bottleneck
        for val, key, pos in finals:
            if len(out) >= top_n and val > kth * (1 + 1e-12) + 1e-18:
                break
            segs = self._reconstruct(memo, key, pos)
            if segs in seen:
                continue
            seen.add(segs)
            out.append(self.cost.evaluate(segs))
            if len(out) >= top_n:
                kth = sorted(c.bottleneck_s for c in out)[top_n - 1]
        win = min((c.bottleneck_s for c in out), default=math.inf)
        tol = win * (1 + 1e-12) + 1e-18
        n_tied = sum(1 for c in out if c.bottleneck_s <= tol)
        out.sort(key=lambda c: (c.bottleneck_s, c.latency_s))
        out = out[:top_n]

        # a full pool dropped a candidate that could tie the winner AND
        # the winner genuinely ties (if a cut path tied the winner, at
        # least two kept finals tie it too: swapping a dropped entry for a
        # kept sibling only lowers the max-composed value, which cannot go
        # below the global minimum — so a unique winner proves no tie was
        # cut).  Only then is the tied surface possibly wider than the
        # pools: reconstruct it exactly via ParetoLattice (the
        # min-(bottleneck, latency) point is always on the Pareto
        # frontier) and let it lead the ranking.  The double condition
        # keeps this dispatch off the common no-tie path — suffix values
        # exclude the prefix/input-hop floor, so ``_tie_cut`` alone
        # under-estimates wildly and would fire on almost every solve.
        self._dispatched = bool(out and n_tied >= 2
                                and self._tie_cut <= tol)
        if self._dispatched:
            best = self._tied_surface_best(out[0].bottleneck_s)
            if best is not None and best.segments not in seen:
                out = [best, *out]
                out.sort(key=lambda c: (c.bottleneck_s, c.latency_s))
                out = out[:top_n]
        return out

    def _tied_surface_best(self, value: float) -> PartitionConfig | None:
        """Exact min-(bottleneck, latency, transfer) config among those
        whose bottleneck ties ``value``, via the Pareto frontier (which
        always carries that point)."""
        tol = value * (1 + 1e-12) + 1e-18
        tied = [c for c in ParetoLattice(self.cost, self.cons).solve()
                if c.bottleneck_s <= tol]
        if not tied:
            return None
        return min(tied, key=lambda c: (c.bottleneck_s, c.latency_s,
                                        c.transfer_bytes))

    def _reconstruct(self, memo, key, pos) -> tuple[Segment, ...]:
        segs: list[Segment] = []
        start = key[0]
        while True:
            value, end, child_key, child_pos = memo[key][pos]
            segs.append(Segment(self.names[key[1]], start, end))
            if child_key is None:
                return tuple(segs)
            key, pos, start = child_key, child_pos, end + 1


def _nondominated_rows(pts: np.ndarray, eps: float = 0.0) -> np.ndarray:
    """Indices of rows of ``pts`` (every column minimised) surviving
    dominance pruning, ascending.

    Exact-duplicate rows collapse to one representative.  With ``eps == 0``
    the filter is exact: a row is pruned iff some distinct row is <= in
    every column.  With ``eps > 0`` a row is additionally pruned when a
    *kept* row is within a factor (1+eps) in every column (multiplicative
    ε-dominance, applied greedily in lexicographic order so mutually
    ε-close rows keep exactly one representative).
    """
    n = len(pts)
    if n <= 1:
        return np.arange(n)
    uniq, first = np.unique(pts, axis=0, return_index=True)
    if len(uniq) <= 1024:
        # pairwise filter: le[i, j] == row j dominates-or-equals row i;
        # rows are distinct after np.unique, so any hit off the diagonal
        # is strict somewhere
        le = (uniq[None, :, :] <= uniq[:, None, :]).all(-1)
        np.fill_diagonal(le, False)
        alive = ~le.any(axis=1)
        uniq, first = uniq[alive], first[alive]
    if eps > 0.0 or len(uniq) > 1024:
        # sequential sweep in lexicographic order: every exact dominator of
        # a row sorts before it, so checking against kept rows is exact at
        # eps == 0 and the canonical greedy archive at eps > 0 (pre-pruning
        # exact-dominated rows above cannot hurt coverage — any dominator
        # of a pruned row is itself within the ε bound of a kept row)
        scale = 1.0 + eps
        kept = np.empty_like(uniq)
        kcount = 0
        keep_list: list[int] = []
        for u, i in zip(uniq, first):
            if kcount and (kept[:kcount] <= u * scale).all(axis=1).any():
                continue
            kept[kcount] = u
            kcount += 1
            keep_list.append(int(i))
        first = np.asarray(keep_list, dtype=np.intp)
    return np.sort(first)


class ParetoLattice(_LatticeBase):
    """Exact Pareto-frontier extraction over (latency, bottleneck, transfer).

    A label-correcting DP over the same (block, resource, must-use-mask)
    states as :class:`PartitionLattice`, except each state keeps its full
    **non-dominated set** of vector labels

        (latency_so_far, bottleneck_of_closed_stages, transfer_so_far,
         open_segment_time)

    instead of a scalar k-best list.  Latency and transfer compose
    additively, the closed-stage bottleneck by minimax, and the open
    segment's eventual stage period is monotone in its accumulated time —
    all monotone operators — so per-state dominance pruning is exact: no
    genuinely non-dominated operating point can be lost, which the
    three-objective k-best union used by ``QueryEngine.frontier`` before
    this class could not guarantee.  Distinct paths with identical labels
    collapse to one representative, so the result carries one config per
    frontier *vector* (the exhaustive oracle may hold several tied
    configs with equal objectives).

    ``epsilon`` > 0 enables multiplicative ε-dominance pruning to bound
    label-set growth on fleet-sized spaces: a label is also dropped when a
    kept label is within a factor (1+ε) in every component.  Relative
    error composes through the additive/minimax operators, so every
    exact-front point has a returned point within (1+ε)^S of it in every
    objective (S = blocks on the path; far tighter in practice).  The
    default 0.0 is exact.  ``labels_kept`` / ``labels_pruned`` record the
    label-set statistics across all states of the last :meth:`solve`.

    Constraints: ``must_use`` (via the mask), ``exclude``/``pin`` (via
    ``allowed``) and ``max_link_bytes`` (via ``transition_allowed``) are
    exact in the DP, and so are the path-dependent ``max_resource_time`` /
    ``min_blocks_on``: for resources they name, the state key carries the
    open segment's start block (see ``_LatticeBase``), so over-cap
    extensions are pruned the moment they occur and under-floor segment
    closes are rejected — labels within a state remain interchangeable
    prefixes and dominance pruning stays exact.  The split states' label
    sets rejoin in the global non-dominated filter over completed vectors,
    so the returned frontier is the true constrained frontier with no
    post-filtering (the exhaustive strategy remains the validation
    oracle).
    """

    def __init__(self, cost: CostModel,
                 constraints: Constraints | None = None,
                 epsilon: float = 0.0):
        if epsilon < 0.0:
            raise ValueError(f"epsilon must be >= 0, got {epsilon}")
        super().__init__(cost, constraints)
        self.epsilon = float(epsilon)
        self.labels_kept = 0
        self.labels_pruned = 0

    def _div(self, resource: str) -> float:
        """Per-request divisor of a compute stage on ``resource`` — the
        label's open-segment time over this is its eventual stage period."""
        return self.cost.replicas_for(resource) * self.cost.batch_size

    def solve(self) -> list[PartitionConfig]:
        """The exact (ε = 0) non-dominated set of configurations, sorted by
        (latency, bottleneck, transfer)."""
        cost = self.cost
        B = cost.n_blocks
        self.labels_kept = self.labels_pruned = 0
        if self.infeasible:
            return []
        # state (resource, mask, open-seg start | -1 if untracked) ->
        # ((L, 4) label array, parallel [(prev_key, prev_idx)])
        cur: dict[tuple[str, int, int], tuple[np.ndarray, list]] = {}
        for r in self.names:
            if not self.cons.allowed(0, r) or not self._seg_ok(r, 0, 0):
                continue
            lat = bneck = xfer = 0.0
            if r != cost.source:
                nbytes = cost.batch_input_bytes
                if not self.cons.transition_allowed(cost.source, r, nbytes):
                    continue
                lat = cost.comm(cost.source, r, nbytes)
                bneck = cost.hop_period(cost.source, r, nbytes)
                xfer = nbytes
            step = cost.segment_time(r, 0, 0)
            key = (r, self._mask_with(0, r), 0 if self._tracked(r) else -1)
            cur[key] = (
                np.array([[lat + step, bneck, xfer, step]]), [(None, -1)])
        hist = [cur]
        for b in range(1, B):
            nbytes = float(cost.out_bytes[b - 1])
            groups: dict[tuple[str, int, int], list] = {}
            for (r, mask, start), (arr, metas) in cur.items():
                refs = [((r, mask, start), i) for i in range(len(metas))]
                if self.cons.allowed(b, r) and \
                        (start < 0 or self._seg_ok(r, start, b)):
                    # extend the open segment (pruned the moment it would
                    # exceed its compute-time cap)
                    step = cost.segment_time(r, b, b)
                    groups.setdefault((r, mask, start), []).append(
                        (arr + np.array([step, 0.0, 0.0, step]), refs))
                if start >= 0 and not self._close_ok(r, start, b - 1):
                    continue               # closing would violate the floor
                div = self._div(r)
                for r2 in self.names:              # close it and hand off
                    if self.order[r2] <= self.order[r] or \
                            not self.cons.allowed(b, r2) or \
                            not self.cons.transition_allowed(r, r2, nbytes) \
                            or not self._seg_ok(r2, b, b):
                        continue
                    hop = cost.comm(r, r2, nbytes)
                    hop_p = cost.hop_period(r, r2, nbytes)
                    step2 = cost.segment_time(r2, b, b)
                    a2 = np.empty_like(arr)
                    a2[:, 0] = arr[:, 0] + (hop + step2)
                    a2[:, 1] = np.maximum(
                        np.maximum(arr[:, 1], arr[:, 3] / div), hop_p)
                    a2[:, 2] = arr[:, 2] + nbytes
                    a2[:, 3] = step2
                    key2 = (r2, self._mask_with(mask, r2),
                            b if self._tracked(r2) else -1)
                    groups.setdefault(key2, []).append((a2, refs))
            cur = {}
            for key, chunks in groups.items():
                arr = chunks[0][0] if len(chunks) == 1 else \
                    np.concatenate([c[0] for c in chunks])
                metas = [m for c in chunks for m in c[1]]
                keep = _nondominated_rows(arr, self.epsilon)
                self.labels_kept += len(keep)
                self.labels_pruned += len(arr) - len(keep)
                cur[key] = (arr[keep], [metas[i] for i in keep])
            hist.append(cur)

        # close every final open segment and filter the completed vectors
        # (states split by open-seg start rejoin here: the filter is global)
        finals: list[tuple[tuple[str, int, int], int]] = []
        vecs: list[np.ndarray] = []
        for (r, mask, start), (arr, metas) in cur.items():
            if mask != self.full_mask:
                continue
            if start >= 0 and not self._close_ok(r, start, B - 1):
                continue
            vec = np.empty((len(arr), 3))
            vec[:, 0] = arr[:, 0]
            vec[:, 1] = np.maximum(arr[:, 1], arr[:, 3] / self._div(r))
            vec[:, 2] = arr[:, 2]
            for i in range(len(arr)):
                finals.append(((r, mask, start), i))
                vecs.append(vec[i])
        if not finals:
            return []
        keep = _nondominated_rows(np.stack(vecs), 0.0)
        out: list[PartitionConfig] = []
        seen: set[tuple[Segment, ...]] = set()
        for i in keep:
            key, idx = finals[i]
            segs = self._reconstruct(hist, key, idx)
            if segs in seen:
                continue
            seen.add(segs)
            out.append(cost.evaluate(segs))
        # authoritative re-filter on the re-evaluated configs: the DP's
        # label arithmetic accumulates sums incrementally while evaluate()
        # uses prefix-sum differences, and evaluate() is the single source
        # of truth for the objective vectors
        out = pareto_frontier(out)
        out.sort(key=lambda c: (c.latency_s, c.bottleneck_s,
                                c.transfer_bytes))
        return out

    def _reconstruct(self, hist, key, idx) -> tuple[Segment, ...]:
        path: list[str] = []
        for b in range(len(hist) - 1, -1, -1):
            path.append(key[0])
            key, idx = hist[b][key][1][idx]
        path.reverse()
        segs: list[Segment] = []
        start = 0
        for i in range(1, len(path) + 1):
            if i == len(path) or path[i] != path[start]:
                segs.append(Segment(path[start], start, i - 1))
                start = i
        return tuple(segs)
