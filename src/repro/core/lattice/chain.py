"""Partition configuration generation and ranking (Scission §II-C Steps 4-5).

Two engines over the same cost model:

* :func:`enumerate_partitions` — the paper's **exhaustive** enumeration of
  every native and distributed configuration over every ordered resource
  pipeline.  Kept as the validation oracle and for rich post-hoc queries.
* :class:`PartitionLattice` — a **beyond-paper** Viterbi lattice over
  (block, resource) states.  Exact under the paper's additive cost model
  (assumptions 1 and 2 in §III-A), O(B·R²·2^R) with must-use masks, and
  supports k-best (top-N) extraction.  This is what lets the same decision
  procedure scale from the paper's 3-tier testbed to a 1000+-node fleet,
  and what keeps re-planning (elastic runtime) inside the paper's 50 ms
  query budget.
* :class:`BottleneckLattice` — the exact min-bottleneck (max-throughput)
  companion DP.  Under steady-state pipelined serving the objective is the
  *max* over stage/hop times, not their sum, so the additive Viterbi
  lattice is not exact; this DP works at segment granularity with minimax
  composition instead.
* :class:`ParetoLattice` — the exact multi-objective companion: a
  label-correcting DP over the same (block, resource, must-use-mask)
  states where each state keeps its full **non-dominated set** of vector
  labels over (latency, bottleneck, transfer) instead of a scalar k-best
  list.  Latency/transfer compose additively and the bottleneck by
  minimax — all monotone — so per-state dominance pruning is exact and
  ``QueryEngine.frontier`` no longer has to approximate the trade-off
  surface from three single-objective k-best solves on fleet-sized
  spaces.  An optional ε-dominance knob bounds label-set growth.

Every Step-6 constraint kind — including the path-dependent
``max_resource_time`` / ``min_blocks_on`` — is folded into each lattice's
DP state (see :class:`Constraints` / :class:`_LatticeBase`), so all three
solvers return the true constrained optimum / frontier with no
post-filtering.

Cost model (paper's two assumptions, validated in tests/test_bench.py):

    latency(config) = comm(source -> r_1, input_bytes)
                    + Σ_segments Σ_blocks time(r_i, b)
                    + Σ_cuts     comm(r_i -> r_{i+1}, out_bytes[cut])

Pipelined-serving model (streamed deployments): requests move through the
pipeline in batches of ``batch_size`` and each compute stage may run on
``replicas[k]`` copies of its resource, so the steady-state rate is limited
by the slowest *effective* stage — a compute segment serves
``replicas[k] * batch`` requests per ``stage_time(batch)``, a communication
hop (including the source->first-resource input hop) serves ``batch``
requests per per-batch transfer time:

    period_k    = stage_time_k(batch) / (replicas_k * batch)   (compute)
    period_j    = hop_time_j(batch)   / batch                  (comm)
    bottleneck  = max_k period_k
    throughput_rps = 1 / bottleneck

With ``batch_size == 1`` and all-ones replicas this reduces to the
one-request-per-stage model (max over raw stage/hop times).  Stage times at
``batch > 1`` come from the benchmark DB's measured batch profiles
(log-linear interpolation between measured points, clamped at the measured
extremes), so batching economies are priced empirically, not assumed.
"""

from __future__ import annotations

import itertools
import math
from dataclasses import dataclass, field, replace
from typing import Callable, Iterable, Sequence

import numpy as np

from ..bench import BenchmarkDB
from ..network import NetworkModel
from ..resources import Resource
from .labelset import grouped_nondominated, grouped_topk, nondominated_rows


@dataclass(frozen=True)
class Segment:
    resource: str
    start: int          # first block index (inclusive)
    end: int            # last block index (inclusive)


@dataclass
class PartitionConfig:
    """One ranked configuration (a row of the paper's Table IV).

    A config is an **operating point**: segments plus the batch size the
    per-stage timings were priced at and the per-segment replica counts.
    ``latency_s`` / ``stage_compute_s`` / ``stage_comm_s`` /
    ``transfer_bytes`` are all *per batch* on *one replica* (at
    ``batch_size == 1`` that is exactly the paper's per-request model);
    ``bottleneck_s`` / ``throughput_rps`` are per-request effective values.
    """

    model: str
    segments: tuple[Segment, ...]
    latency_s: float
    compute_s: dict[str, float]
    comm_s: float
    transfer_bytes: float           # total inter-resource bytes (incl. input)
    input_comm_s: float = 0.0
    # per-stage timings for pipelined serving: one compute time per segment,
    # one comm time per hop between consecutive segments
    stage_compute_s: tuple[float, ...] = ()
    stage_comm_s: tuple[float, ...] = ()
    # operating point: batch the stage timings were priced at, and replica
    # count per segment (empty tuple == one replica everywhere)
    batch_size: int = 1
    replicas: tuple[int, ...] = ()

    @property
    def resources(self) -> tuple[str, ...]:
        return tuple(s.resource for s in self.segments)

    @property
    def is_native(self) -> bool:
        return len(self.segments) == 1

    def replica_count(self, k: int) -> int:
        """Replicas serving compute stage ``k`` (1 when unspecified)."""
        return self.replicas[k] if k < len(self.replicas) else 1

    @property
    def stage_periods_s(self) -> tuple[float, ...]:
        """Effective per-request service period of every pipeline stage, in
        pipeline order: input hop (if any), then each compute segment
        followed by its outgoing comm hop.  A compute stage with ``r``
        replicas at batch ``b`` serves ``r*b`` requests per ``stage_time``;
        a hop serves ``b`` requests per per-batch transfer."""
        b = max(1, self.batch_size)
        periods: list[float] = []
        if self.input_comm_s > 0.0:
            periods.append(self.input_comm_s / b)
        for k, t in enumerate(self.stage_compute_s):
            periods.append(t / (self.replica_count(k) * b))
            if k < len(self.stage_comm_s):
                periods.append(self.stage_comm_s[k] / b)
        return tuple(periods)

    @property
    def bottleneck_s(self) -> float:
        """Slowest effective pipeline stage (replica- and batch-adjusted) —
        the steady-state per-request period under pipelined serving."""
        periods = self.stage_periods_s
        return max(periods) if periods else self.latency_s

    @property
    def throughput_rps(self) -> float:
        """Steady-state pipelined request rate = 1 / effective bottleneck."""
        b = self.bottleneck_s
        return 1.0 / b if b > 0.0 else float("inf")

    def describe(self) -> str:
        parts = [f"{s.resource}: {s.start}-{s.end}" if s.start != s.end
                 else f"{s.resource}: {s.start}" for s in self.segments]
        op = ""
        if self.batch_size != 1:
            op += f" batch={self.batch_size}"
        if any(r != 1 for r in self.replicas):
            op += " reps=" + "x".join(str(self.replica_count(k))
                                      for k in range(len(self.segments)))
        return (f"[{self.model}] " + " | ".join(parts)
                + f"  latency={self.latency_s * 1e3:.1f}ms"
                + f" thpt={self.throughput_rps:.1f}rps"
                + f" transfer={self.transfer_bytes / 1e6:.3f}MB" + op)


@dataclass
class CostModel:
    """Precomputed vectorised costs for one (model, resource set, network)
    at one operating point (batch size + per-resource replica budget).

    ``batch_size`` selects the per-batch block times from the DB's measured
    batch profiles (interpolated when unmeasured); ``replica_budget`` maps a
    resource name to the number of copies a stage placed on it may use
    (default 1).  All per-config quantities (latency, stage times, transfer)
    are per batch; the effective per-request stage periods divide by
    ``replicas * batch`` (compute) / ``batch`` (comm).
    """

    db: BenchmarkDB
    resources: list[Resource]
    network: NetworkModel
    source: str                      # where the input data originates
    input_bytes: float               # per request
    batch_size: int = 1
    replica_budget: dict[str, int] = field(default_factory=dict)

    times: np.ndarray = field(init=False)        # (R, B) per-batch seconds
    cum: np.ndarray = field(init=False)          # (R, B+1) prefix sums
    out_bytes: np.ndarray = field(init=False)    # (B,) per-batch bytes

    def __post_init__(self):
        names = [r.name for r in self.resources]
        missing = [n for n in names if n not in self.db.records]
        if missing:
            raise ValueError(
                f"resource(s) {', '.join(sorted(missing))} not benchmarked "
                f"for model {self.db.model!r}; run Scission.benchmark() / "
                "benchmark_resource() for them first")
        if self.batch_size < 1:
            raise ValueError(f"batch_size must be >= 1, got {self.batch_size}")
        max_batch = self.db.max_batch(names)
        if self.batch_size > max_batch:
            # pricing batch b from a profile clamped at max_batch would
            # divide the clamped time by b — linear throughput extrapolation
            # the measurements do not support
            raise ValueError(
                f"batch_size {self.batch_size} exceeds the largest measured "
                f"batch ({max_batch}) for model {self.db.model!r}; "
                "re-run benchmark_model(batch_sizes=...) to cover it")
        bad = {r: n for r, n in self.replica_budget.items() if n < 1}
        if bad:
            raise ValueError(f"replica budget must be >= 1, got {bad}")
        self.times = self.db.times_matrix(names, batch=self.batch_size)
        self.cum = np.concatenate(
            [np.zeros((len(names), 1)), np.cumsum(self.times, axis=1)], axis=1)
        self.out_bytes = self.db.out_bytes_vector(batch=self.batch_size)
        self._idx = {n: i for i, n in enumerate(names)}

    @property
    def n_blocks(self) -> int:
        return self.db.n_blocks

    @property
    def batch_input_bytes(self) -> float:
        """Bytes of input data entering the pipeline per batch."""
        return self.input_bytes * self.batch_size

    def replicas_for(self, resource: str) -> int:
        return max(1, int(self.replica_budget.get(resource, 1)))

    def segment_time(self, resource: str, start: int, end: int) -> float:
        """Per-batch compute time of blocks ``start..end`` on one replica."""
        i = self._idx[resource]
        return float(self.cum[i, end + 1] - self.cum[i, start])

    def comm(self, src: str, dst: str, nbytes: float) -> float:
        return self.network.comm_time(src, dst, nbytes)

    # -- effective per-request periods (the minimax DP's stage costs) --------
    def stage_period(self, resource: str, start: int, end: int) -> float:
        """Per-request service period of a compute stage: ``replicas``
        copies each finish a batch of ``batch_size`` per segment time."""
        return self.segment_time(resource, start, end) / (
            self.replicas_for(resource) * self.batch_size)

    def hop_period(self, src: str, dst: str, nbytes: float) -> float:
        """Per-request service period of a comm hop moving ``nbytes`` (a
        per-batch quantity) between stages."""
        return self.comm(src, dst, nbytes) / self.batch_size

    def evaluate(self, segments: Sequence[Segment],
                 objective: "Objective | None" = None) -> PartitionConfig:
        compute = {}
        comm = 0.0
        xfer = 0.0
        first = segments[0].resource
        input_comm = 0.0
        if first != self.source:
            input_comm = self.comm(self.source, first, self.batch_input_bytes)
            xfer += self.batch_input_bytes
        stage_compute: list[float] = []
        stage_comm: list[float] = []
        for k, seg in enumerate(segments):
            t = self.segment_time(seg.resource, seg.start, seg.end)
            compute[seg.resource] = compute.get(seg.resource, 0.0) + t
            stage_compute.append(t)
            if k + 1 < len(segments):
                nbytes = float(self.out_bytes[seg.end])
                hop = self.comm(seg.resource, segments[k + 1].resource, nbytes)
                stage_comm.append(hop)
                comm += hop
                xfer += nbytes
        latency = input_comm + sum(compute.values()) + comm
        return PartitionConfig(
            model=self.db.model, segments=tuple(segments), latency_s=latency,
            compute_s=compute, comm_s=comm, transfer_bytes=xfer,
            input_comm_s=input_comm,
            stage_compute_s=tuple(stage_compute),
            stage_comm_s=tuple(stage_comm),
            batch_size=self.batch_size,
            replicas=tuple(self.replicas_for(s.resource) for s in segments))


@dataclass(frozen=True)
class Objective:
    """Ranking objective: minimise w_latency·latency + w_transfer·transfer.

    The paper's Step 5 default is pure latency; Step 6 allows data-transfer
    and combined objectives.
    """

    w_latency: float = 1.0
    w_transfer_per_mb: float = 0.0

    def score(self, cfg: PartitionConfig) -> float:
        return (self.w_latency * cfg.latency_s
                + self.w_transfer_per_mb * cfg.transfer_bytes / 1e6)


@dataclass(frozen=True)
class ThroughputObjective(Objective):
    """Maximise steady-state pipelined throughput == minimise the bottleneck
    stage time (max of stage compute and per-hop comm).

    Because the score is a *max* rather than a sum, the additive
    :class:`PartitionLattice` is not exact for this objective — the query
    engine dispatches it to :class:`BottleneckLattice` instead.
    """

    def score(self, cfg: PartitionConfig) -> float:
        return cfg.bottleneck_s


LATENCY = Objective()
TRANSFER = Objective(w_latency=0.0, w_transfer_per_mb=1.0)
THROUGHPUT = ThroughputObjective()


# ---------------------------------------------------------------------------
# Exhaustive enumeration (paper-faithful Step 4)
# ---------------------------------------------------------------------------

def ordered_pipelines(resources: list[Resource]) -> list[tuple[str, ...]]:
    """All ordered sub-pipelines: at most one resource per tier, data flows
    device -> edge -> cloud (the paper's native + distributed configs)."""
    tiers: dict[int, list[str]] = {}
    for r in sorted(resources, key=lambda r: r.order):
        tiers.setdefault(r.order, []).append(r.name)
    levels = [tiers[k] for k in sorted(tiers)]
    pipes: list[tuple[str, ...]] = []
    for mask in itertools.product(*[[None, *lvl] for lvl in levels]):
        pipe = tuple(m for m in mask if m is not None)
        if pipe:
            pipes.append(pipe)
    return pipes


def enumerate_partitions(cost: CostModel,
                         pipelines: Iterable[tuple[str, ...]] | None = None,
                         max_configs: int = 2_000_000
                         ) -> list[PartitionConfig]:
    """Every cut combination for every pipeline.  Exact but exponential in
    pipeline length; the lattice below is the scalable path."""
    B = cost.n_blocks
    pipelines = list(pipelines) if pipelines is not None else \
        ordered_pipelines(cost.resources)
    configs: list[PartitionConfig] = []
    n = 0
    for pipe in pipelines:
        k = len(pipe)
        if k > B:
            continue
        for cuts in itertools.combinations(range(1, B), k - 1):
            bounds = [0, *cuts, B]
            segs = [Segment(pipe[i], bounds[i], bounds[i + 1] - 1)
                    for i in range(k)]
            configs.append(cost.evaluate(segs))
            n += 1
            if n > max_configs:
                raise RuntimeError(
                    f"exhaustive enumeration exceeded {max_configs} configs; "
                    "use PartitionLattice")
    return configs


def rank(configs: list[PartitionConfig], objective: Objective = LATENCY,
         top_n: int | None = None) -> list[PartitionConfig]:
    out = sorted(configs, key=objective.score)
    return out if top_n is None else out[:top_n]


def trim_replicas(cfg: PartitionConfig) -> PartitionConfig:
    """Right-size an operating point: shrink each stage's replica count to
    the minimum that keeps the bottleneck (hence throughput) unchanged.

    A replica budget is an upper bound; a stage that is not the bottleneck
    may hit the same rate with fewer copies.  Frontier results are trimmed
    so operators never over-provision to match a reported operating point.
    """
    if not cfg.replicas or all(r == 1 for r in cfg.replicas):
        return cfg
    b = max(1, cfg.batch_size)
    bneck = cfg.bottleneck_s
    if bneck <= 0.0:
        return cfg
    trimmed = []
    for k, t in enumerate(cfg.stage_compute_s):
        need = max(1, math.ceil(t / (b * bneck) - 1e-12))
        trimmed.append(min(cfg.replica_count(k), need))
    return replace(cfg, replicas=tuple(trimmed))


# ---------------------------------------------------------------------------
# Pareto frontier over (latency, throughput, transfer)
# ---------------------------------------------------------------------------

def objective_vector(cfg: PartitionConfig) -> tuple[float, float, float]:
    """The canonical minimised objective vector of the frontier machinery:
    (latency_s, bottleneck_s, transfer_bytes) — ``bottleneck_s`` stands in
    for -throughput.  Every frontier comparison (Pareto filters, elastic
    ``frontier_shift``, bench equality gates) goes through this one
    definition."""
    return (cfg.latency_s, cfg.bottleneck_s, cfg.transfer_bytes)


_objective_vector = objective_vector        # internal alias


def dominates(a: PartitionConfig, b: PartitionConfig) -> bool:
    """True iff ``a`` is no worse than ``b`` on latency, throughput and
    transfer, and strictly better on at least one."""
    va, vb = _objective_vector(a), _objective_vector(b)
    return all(x <= y for x, y in zip(va, vb)) and va != vb


def pareto_frontier(configs: Sequence[PartitionConfig]
                    ) -> list[PartitionConfig]:
    """Exact non-dominated set over (latency, throughput, transfer).

    Processes candidates in lexicographic objective order so each point only
    needs checking against already-accepted frontier members (any dominator
    of p is itself dominated only by points that dominate p, and sorts
    before p).  Configs with identical objective vectors are all kept —
    they are distinct operating points with equal cost.
    """
    if not configs:
        return []
    order = sorted(range(len(configs)),
                   key=lambda i: _objective_vector(configs[i]))
    front: list[int] = []
    pts = [_objective_vector(c) for c in configs]
    for i in order:
        p = pts[i]
        if any(all(x <= y for x, y in zip(pts[j], p)) and pts[j] != p
               for j in front):
            continue
        front.append(i)
    return [configs[i] for i in front]


# ---------------------------------------------------------------------------
# DP lattice (beyond-paper exact search + k-best)
# ---------------------------------------------------------------------------

class Constraints:
    """Hard constraints on the partitioning search (Scission Step 6).

    **All constraints are exact in every strategy** — the exhaustive
    enumeration filters whole configs, and the lattices fold each kind
    into the DP itself:

    * ``must_use`` — via the used-resource bit mask on the state.
    * ``exclude`` / ``pin`` — via :meth:`allowed` on states.
    * ``max_link_bytes`` — via :meth:`transition_allowed` on hand-offs.
    * ``max_resource_time`` — cap on a resource's total compute time.
      Strict tier ordering means a path visits each resource at most once,
      as one contiguous segment, so the "path-dependent" accumulated time
      is just the open segment's span: the lattices carry the open
      segment's start block in the state key for capped resources and
      prune any extension whose segment time exceeds the cap in-flight.
    * ``min_blocks_on`` — floor on the number of blocks a resource hosts
      (a floor >= 1 also forces the resource to appear, so it joins the
      must-use mask); enforced exactly when the segment closes.

    The two path-dependent kinds used to be enforced by post-filtering
    k-best pools, so a binding constraint could reject every pooled winner
    and return fewer — or zero — results while a feasible optimum existed.
    :meth:`path_feasible` remains as the whole-config reference check used
    by the exhaustive strategy (and as the validation oracle in tests).
    """

    def __init__(self,
                 must_use: Sequence[str] = (),
                 exclude: Sequence[str] = (),
                 pin: dict[int, str] | None = None,
                 max_link_bytes: dict[tuple[str, str], float] | None = None,
                 max_resource_time: dict[str, float] | None = None,
                 min_blocks_on: dict[str, int] | None = None):
        self.must_use = tuple(must_use)
        self.exclude = frozenset(exclude)
        self.pin = dict(pin or {})
        self.max_link_bytes = dict(max_link_bytes or {})
        self.max_resource_time = dict(max_resource_time or {})
        self.min_blocks_on = dict(min_blocks_on or {})

    def allowed(self, block: int, resource: str) -> bool:
        if resource in self.exclude:
            return False
        pinned = self.pin.get(block)
        return pinned is None or pinned == resource

    def transition_allowed(self, src: str, dst: str, nbytes: float) -> bool:
        limit = self.max_link_bytes.get((src, dst))
        return limit is None or nbytes <= limit

    def path_feasible(self, cfg: PartitionConfig) -> bool:
        """Whole-config check of the path-dependent constraints — used by
        the exhaustive strategy's filter and as the lattices' validation
        oracle (the lattices themselves enforce these in the DP state)."""
        for res, tmax in self.max_resource_time.items():
            if cfg.compute_s.get(res, 0.0) > tmax:
                return False
        for res, nmin in self.min_blocks_on.items():
            got = sum(s.end - s.start + 1 for s in cfg.segments
                      if s.resource == res)
            if got < nmin:
                return False
        return True


class _LatticeBase:
    """State shared by every lattice DP: the exclude-filtered resource
    list, tier ordering, the must-use bit mask, and the in-DP form of the
    path-dependent constraints.

    A ``must_use`` entry (or a ``min_blocks_on`` floor >= 1, which demands
    presence) naming a resource that is unknown or excluded is
    **unsatisfiable**: no path can ever visit it, so ``infeasible`` is set
    and every ``solve`` returns ``[]`` — exactly what the exhaustive
    strategy does (it rejects every config), keeping the strategies
    consistent instead of silently dropping the constraint.

    Path-dependent constraints are exact in the DP because transitions
    only move to strictly later tiers: a path visits each resource at most
    once, as one contiguous segment, so a resource's total compute time
    and block count are properties of that single segment.  A lattice that
    works at block granularity carries the open segment's start block in
    its state key — but only for **tracked** resources (those named by
    ``max_resource_time`` / ``min_blocks_on``), so the state space is
    unchanged when the constraints are absent.  ``_seg_ok`` prunes a
    segment that exceeds its compute-time cap the moment it does (the cap
    is monotone in the segment span), and ``_close_ok`` enforces the
    min-block floor when the segment closes.  Both recompute the segment
    time via ``CostModel.segment_time``, the same prefix-sum arithmetic
    ``evaluate`` uses, so the DP and the exhaustive oracle agree bit for
    bit on feasibility.
    """

    def __init__(self, cost: CostModel,
                 constraints: Constraints | None = None,
                 plan: "ChainPlan | None" = None):
        self.cost = cost
        self.cons = constraints or Constraints()
        self.res = [r for r in cost.resources
                    if r.name not in self.cons.exclude]
        self.names = [r.name for r in self.res]
        self.order = {r.name: r.order for r in self.res}
        self.tmax = dict(self.cons.max_resource_time)
        # a floor <= 0 is trivially satisfied (path_feasible accepts even
        # an absent resource); a floor >= 1 demands presence
        self.nmin = {n: k for n, k in self.cons.min_blocks_on.items()
                     if k >= 1}
        demanded = list(dict.fromkeys((*self.cons.must_use, *self.nmin)))
        self.must = [n for n in demanded if n in self.names]
        self.must_idx = {n: i for i, n in enumerate(self.must)}
        self.full_mask = (1 << len(self.must)) - 1
        self.infeasible = (
            any(n not in self.names for n in demanded)
            or any(k > cost.n_blocks for k in self.nmin.values()))
        # a caller-supplied ChainPlan (batch-independent solve structure,
        # see ChainPlan) is adopted only when it was built over the same
        # resource axis — the engine keys its plan cache by the constraint
        # signature, so a matching axis implies matching matrices
        if plan is not None and plan.names == self.names:
            self._plan = plan

    def _bit(self, resource: str) -> int:
        i = self.must_idx.get(resource)
        return 0 if i is None else 1 << i

    def _mask_with(self, mask: int, resource: str) -> int:
        return mask | self._bit(resource)

    def _tracked(self, resource: str) -> bool:
        """True when the open segment's start block must live in the state
        key for ``resource`` (it is compute-time capped or block-floored)."""
        return resource in self.tmax or resource in self.nmin

    def _seg_ok(self, resource: str, start: int, end: int) -> bool:
        """Segment ``start..end`` on ``resource`` within its compute-time
        cap (trivially true for uncapped resources)."""
        t = self.tmax.get(resource)
        return t is None or \
            self.cost.segment_time(resource, start, end) <= t

    def _close_ok(self, resource: str, start: int, end: int) -> bool:
        """Closing segment ``start..end`` on ``resource`` satisfies its
        min-block floor (the time cap was enforced while it grew)."""
        k = self.nmin.get(resource)
        return k is None or end - start + 1 >= k

    def _get_plan(self) -> "ChainPlan":
        plan = getattr(self, "_plan", None)
        if plan is None:
            plan = self._plan = ChainPlan(self.cost, base=self)
        return plan


class ChainPlan:
    """Batch-independent structure of a chain-lattice solve.

    Everything a chain DP transition needs that does *not* depend on the
    operating point: the exclude-filtered resource axis, the tier-order
    transition matrix, per-block ``allowed`` masks, link latency /
    bandwidth / byte-limit matrices, and the vectorised forms of the in-DP
    constraints.  One plan is shared across a whole
    ``QueryEngine.frontier()`` operating-point sweep (solve structure
    once, re-price per batch) and across elastic re-plans; per-batch
    numeric tables (block times, output bytes, replica divisors) stay in
    the per-solve ``_tables``.
    """

    def __init__(self, cost: CostModel,
                 constraints: Constraints | None = None,
                 base: _LatticeBase | None = None):
        if base is None:
            base = _LatticeBase(cost, constraints)
        self.cons = base.cons
        self.names = list(base.names)
        self.must = list(base.must)
        self.full_mask = base.full_mask
        self.infeasible = base.infeasible
        R = len(self.names)
        B = cost.n_blocks
        self.R, self.B = R, B
        self.tracked = np.array([base._tracked(n) for n in self.names],
                                dtype=bool)
        self.tmaxv = np.array([base.tmax.get(n, math.inf)
                               for n in self.names])
        self.nminv = np.array([base.nmin.get(n, 0) for n in self.names],
                              dtype=np.int64)
        self.bitv = np.array([base._bit(n) for n in self.names],
                             dtype=np.int64)
        self.allowed = np.array(
            [[self.cons.allowed(b, n) for n in self.names]
             for b in range(B)], dtype=bool)
        ordv = np.array([base.order[n] for n in self.names])
        # [i, j] == a hand-off i -> j moves to a strictly later tier
        self.ok_pair = ordv[None, :] > ordv[:, None]
        lat = np.zeros((R, R))
        bw = np.full((R, R), math.inf)
        for i, a in enumerate(self.names):
            for j, b2 in enumerate(self.names):
                if i == j:
                    continue
                lnk = cost.network.link(a, b2)
                lat[i, j] = lnk.latency_s
                bw[i, j] = lnk.bandwidth
        self.latm, self.bwm = lat, bw
        lim = np.full((R, R), math.inf)
        idx = {n: i for i, n in enumerate(self.names)}
        for (a, b2), v in self.cons.max_link_bytes.items():
            if a in idx and b2 in idx:
                lim[idx[a], idx[b2]] = v
        self.limitm = lim


class PartitionLattice(_LatticeBase):
    """Viterbi over (block, resource, used-mask) with k-best extraction.

    Transitions: stay on the same resource (free) or hand off to a strictly
    later tier (pay ``comm(out_bytes[block])``).  The used-mask tracks which
    must-use resources have been visited so 'entire pipeline' style
    constraints stay exact, and for resources named by the path-dependent
    constraints the state key additionally carries the open segment's start
    block (see ``_LatticeBase``), so ``max_resource_time`` prunes in-flight
    and ``min_blocks_on`` gates segment closes — every constraint is part
    of the DP state and ``solve`` returns the true constrained k-best, with
    no post-filtering.
    """

    labels_kept = 0
    labels_pruned = 0

    def __init__(self, cost: CostModel, constraints: Constraints | None = None,
                 objective: Objective = LATENCY,
                 plan: "ChainPlan | None" = None):
        super().__init__(cost, constraints, plan=plan)
        self.obj = objective

    def _step_cost(self, resource: str, block: int) -> float:
        t = self.cost.segment_time(resource, block, block)
        return self.obj.w_latency * t

    def _comm_cost(self, src: str, dst: str, nbytes: float) -> float:
        return (self.obj.w_latency * self.cost.comm(src, dst, nbytes)
                + self.obj.w_transfer_per_mb * nbytes / 1e6)

    def solve(self, top_n: int = 1) -> list[PartitionConfig]:
        """k-best paths through the lattice; returns up to ``top_n`` feasible
        configs ranked by the objective.

        Every constraint lives in the DP state, so this is the exact
        constrained k-best: labels at the same (resource, mask, open-seg
        start) state are interchangeable prefixes for every feasible
        completion, hence ``K == top_n`` per state suffices and distinct
        entries reconstruct distinct configs (a path determines its state).

        Each block's labels live in flat arrays (score / resource / mask /
        open-seg start / parent row) and the per-state k-best cut is one
        :func:`grouped_topk` call — no per-label Python in the hot loop.
        """
        self.labels_kept = self.labels_pruned = 0
        if top_n <= 0 or self.infeasible:
            return []
        cost = self.cost
        plan = self._get_plan()
        B, R = plan.B, plan.R
        K = top_n
        rsel = [cost._idx[n] for n in plan.names]
        cum = cost.cum[rsel]
        steps = np.ascontiguousarray((cum[:, 1:] - cum[:, :-1]).T)  # (B, R)
        wsteps = self.obj.w_latency * steps
        wtr = self.obj.w_transfer_per_mb

        # block 0 (scalar: one row per feasible start resource)
        rows = []
        for j, r in enumerate(plan.names):
            if not plan.allowed[0, j] or not (steps[0, j] <= plan.tmaxv[j]):
                continue
            inp = 0.0
            if r != cost.source:
                nbytes = cost.batch_input_bytes
                if not plan.cons.transition_allowed(cost.source, r, nbytes):
                    continue
                inp = self._comm_cost(cost.source, r, nbytes)
            rows.append((inp + wsteps[0, j], j))
        score = np.array([x[0] for x in rows])
        rix = np.array([x[1] for x in rows], dtype=np.int64)
        msk = plan.bitv[rix]
        sta = np.where(plan.tracked[rix], 0, -1) if len(rix) else \
            np.zeros(0, dtype=np.int64)
        par = np.full(len(rix), -1, dtype=np.int64)
        blocks = [{"rix": rix, "par": par}]

        for b in range(1, B):
            nbytes = float(cost.out_bytes[b - 1])
            steps_b = steps[b]
            # stay: the open segment grows through block b (pruned the
            # moment it would exceed its compute-time cap)
            ok = plan.allowed[b][rix].copy()
            tr = np.flatnonzero(ok & (sta >= 0))
            if len(tr):
                segt = cum[rix[tr], b + 1] - cum[rix[tr], sta[tr]]
                ok[tr] &= segt <= plan.tmaxv[rix[tr]]
            stay = np.flatnonzero(ok)
            # hand off to a later tier: closes [start..b-1] on r, which
            # must meet r's min-block floor
            close = (sta < 0) | ((b - sta) >= plan.nminv[rix])
            src = np.flatnonzero(close)
            tmask = plan.allowed[b] & (steps_b <= plan.tmaxv)
            pair = plan.ok_pair & (nbytes <= plan.limitm) & tmask[None, :]
            si_l, tj = np.nonzero(pair[rix[src]])
            si = src[si_l]
            hopm = (self.obj.w_latency * (plan.latm + nbytes / plan.bwm)
                    + wtr * nbytes / 1e6) + wsteps[b][None, :]
            c_score = np.concatenate(
                [score[stay] + wsteps[b, rix[stay]],
                 score[si] + hopm[rix[si], tj]])
            c_rix = np.concatenate([rix[stay], tj])
            c_msk = np.concatenate([msk[stay], msk[si] | plan.bitv[tj]])
            c_sta = np.concatenate(
                [sta[stay], np.where(plan.tracked[tj], b, -1)])
            c_par = np.concatenate([stay, si])
            key = ((c_rix * np.int64(plan.full_mask + 1) + c_msk)
                   * np.int64(B + 2) + (c_sta + 1))
            keep = grouped_topk(key, c_score, K)
            self.labels_kept += len(keep)
            self.labels_pruned += len(c_score) - len(keep)
            score, rix, msk, sta, par = (c_score[keep], c_rix[keep],
                                         c_msk[keep], c_sta[keep],
                                         c_par[keep])
            blocks.append({"rix": rix, "par": par})

        fin = (msk == plan.full_mask) & \
            ((sta < 0) | ((B - sta) >= plan.nminv[rix]))
        order = np.argsort(score[np.flatnonzero(fin)], kind="stable")
        finals = np.flatnonzero(fin)[order]
        out: list[PartitionConfig] = []
        seen: set[tuple[Segment, ...]] = set()
        for i in finals:
            segs = _walk_path(blocks, int(i), plan.names)
            if segs in seen:
                continue
            seen.add(segs)
            out.append(cost.evaluate(segs))
            if len(out) >= top_n:
                break
        return out


def _walk_path(blocks: list[dict], i: int,
               names: list[str]) -> tuple[Segment, ...]:
    """Follow parent rows from row ``i`` of the last block back to block 0
    and fold the resource path into contiguous segments."""
    path: list[str] = []
    for b in range(len(blocks) - 1, -1, -1):
        blk = blocks[b]
        path.append(names[blk["rix"][i]])
        i = int(blk["par"][i])
    path.reverse()
    segs: list[Segment] = []
    start = 0
    for k in range(1, len(path) + 1):
        if k == len(path) or path[k] != path[start]:
            segs.append(Segment(path[start], start, k - 1))
            start = k
    return tuple(segs)


class BottleneckLattice(_LatticeBase):
    """Exact min-bottleneck (max-throughput) DP — the minimax companion to
    :class:`PartitionLattice`.

    Under pipelined serving the objective is ``max`` over *effective* stage
    periods (replica- and batch-adjusted compute, per-request comm), which
    is not additive, so the Viterbi lattice's sum-composition is not exact.
    This DP works at *segment* granularity:

        f(b, r, need) = k-best achievable bottlenecks over blocks b..B-1
                        when block b starts a new segment on resource r and
                        ``need`` is the set of must-use resources still owed

    with minimax composition ``max(stage_period, hop_period, child)``.  Max
    is monotone in the child value, so k-best per state is exact; replicas
    and batch only rescale each state's local cost (the cost model's
    ``stage_period`` / ``hop_period``), so the DP stays exact at every
    operating point.  Complexity O(B²·R²·K·2^M) for M must-use resources.

    Because this DP works at whole-segment granularity, the path-dependent
    constraints need **no state extension at all**: every transition (and
    every terminal) names its segment's exact extent, so
    ``max_resource_time`` and ``min_blocks_on`` are checked per transition
    (``_seg_ok`` / ``_close_ok``) and infeasible segments never enter the
    lattice — ``solve`` returns the true constrained optimum with no
    post-filtering and no pool widening.

    Ties on the bottleneck value are broken by end-to-end latency across
    the *entire* reconstruction pool (every tied final is reconstructed
    before truncating to ``top_n``).  A tie wider than a single state's
    k-best pool can still be cut *inside* the DP; the solver detects that
    (a state dropped a candidate whose value ties the returned optimum)
    and reconstructs the exact tied surface via :class:`ParetoLattice`
    dispatch — the minimum (bottleneck, latency) point is always on the
    Pareto frontier — so the returned optimum's latency tie-break is exact
    regardless of pool width.
    """

    # introspection state of the last solve (class-level defaults so an
    # early-returning solve — infeasible / top_n <= 0 — reads as no-op)
    _tie_cut = math.inf
    _dispatched = False
    labels_kept = 0
    labels_pruned = 0

    def solve(self, top_n: int = 1) -> list[PartitionConfig]:
        if top_n <= 0 or self.infeasible:
            return []
        B = self.cost.n_blocks
        # K == top_n is exact for the k-best *values*; the +head-room keeps
        # more bottleneck-tied candidates in the pools so the latency
        # tie-break rarely has to fall back to the Pareto dispatch below
        K = max(top_n * 2, top_n + 2)
        self._tie_cut = math.inf       # min value a full pool ever dropped
        names = self.names
        out_bytes = self.cost.out_bytes
        # longest allowed contiguous run starting at each (resource, block)
        run: dict[str, list[int]] = {}
        for r in names:
            ok = [self.cons.allowed(b, r) for b in range(B)]
            ends = [0] * (B + 1)
            for b in range(B - 1, -1, -1):
                ends[b] = ends[b + 1] + 1 if ok[b] else 0
            run[r] = ends[:B]

        # memo[(b, ri, need)] = up to K (value, end, child_key, child_pos),
        # sorted ascending; ``need`` never contains ri's own bit.  VAL is
        # the same pools as padded value arrays: the candidate merge below
        # gathers every child pool of a state in one fancy index and sorts
        # the max-composed values in one stable argsort — only the <= K
        # surviving entries are materialised as Python tuples.
        memo: dict[tuple[int, int, int], list[tuple]] = {}
        self.labels_kept = self.labels_pruned = 0
        FM = self.full_mask + 1
        VAL = np.full((B, len(names), FM, K), math.inf)
        for b in range(B - 1, -1, -1):
            for ri, r in enumerate(names):
                n_run = run[r][b]
                bit_r = self._bit(r)
                # transitions are independent of the must-use mask — hoist
                # the (end, r2) scan out of the need loop.  Constraints on
                # the segment itself (compute-time cap, min-block floor)
                # are exact here: each candidate names its segment extent.
                term = None
                if b + n_run >= B and self._seg_ok(r, b, B - 1) \
                        and self._close_ok(r, b, B - 1):
                    term = self.cost.stage_period(r, b, B - 1)
                trans: list[tuple] = []      # (base, end, rj, clear_bit)
                for end in range(b, min(b + n_run, B - 1)):
                    if not self._seg_ok(r, b, end):
                        break            # segment time is monotone in end
                    if not self._close_ok(r, b, end):
                        continue
                    nbytes = float(out_bytes[end])
                    seg_t = self.cost.stage_period(r, b, end)
                    for rj, r2 in enumerate(names):
                        if self.order[r2] <= self.order[r] or \
                                not self.cons.transition_allowed(
                                    r, r2, nbytes):
                            continue
                        base = max(seg_t, self.cost.hop_period(r, r2, nbytes))
                        trans.append((base, end, rj, ~self._bit(r2)))
                if trans:
                    basev = np.array([t[0] for t in trans])
                    endv = np.array([t[1] for t in trans], dtype=np.intp)
                    rjv = np.array([t[2] for t in trans], dtype=np.intp)
                    clearv = np.array([t[3] for t in trans], dtype=np.int64)
                for need in range(FM):
                    if need & bit_r:
                        continue
                    has_term = term is not None and need == 0
                    if not trans:
                        ents = [(term, B - 1, None, -1)] if has_term else []
                        memo[(b, ri, need)] = ents
                        if ents:
                            VAL[b, ri, need, 0] = term
                        self.labels_kept += len(ents)
                        continue
                    # candidate values: term first, then trans-major /
                    # child-pos-minor — the exact order the scalar merge
                    # appended them in, so the stable sort breaks value
                    # ties identically; inf padding sorts to the end
                    flat = np.maximum(basev[:, None],
                                      VAL[endv + 1, rjv,
                                          need & clearv]).ravel()
                    off = 0
                    if has_term:
                        flat = np.concatenate([[term], flat])
                        off = 1
                    order = np.argsort(flat, kind="stable")
                    vals = flat[order]
                    n_real = int(np.searchsorted(vals, math.inf))
                    if n_real > K:
                        self._tie_cut = min(self._tie_cut, float(vals[K]))
                    k = min(K, n_real)
                    ents = []
                    for fi in order[:k]:
                        if has_term and fi == 0:
                            ents.append((term, B - 1, None, -1))
                            continue
                        ti, pos = divmod(int(fi) - off, K)
                        ck = (int(endv[ti]) + 1, int(rjv[ti]),
                              need & int(clearv[ti]))
                        ents.append((float(flat[fi]), int(endv[ti]),
                                     ck, pos))
                    memo[(b, ri, need)] = ents
                    VAL[b, ri, need, :k] = vals[:k]
                    self.labels_kept += k
                    self.labels_pruned += n_real - k

        finals: list[tuple[float, tuple[int, int, int], int]] = []
        for ri, r in enumerate(names):
            key = (0, ri, self.full_mask & ~self._bit(r))
            entries = memo.get(key)
            if not entries:
                continue
            inp = 0.0
            if r != self.cost.source:
                nbytes = self.cost.batch_input_bytes
                if not self.cons.transition_allowed(
                        self.cost.source, r, nbytes):
                    continue
                inp = self.cost.hop_period(self.cost.source, r, nbytes)
            for pos in range(len(entries)):
                finals.append((max(entries[pos][0], inp), key, pos))
        finals.sort(key=lambda t: t[0])

        # ties in bottleneck are common (e.g. the input hop dominates), so
        # truncating the reconstruction pool before the (bottleneck,
        # latency) tie-break could cut a lower-latency config and return a
        # strictly worse one.  Reconstruct until we hold top_n configs AND
        # the next candidate's value exceeds the top_n-th best bottleneck —
        # i.e. collect every bottleneck-tied candidate first.
        out: list[PartitionConfig] = []
        seen: set[tuple[Segment, ...]] = set()
        kth = math.inf                  # top_n-th smallest kept bottleneck
        for val, key, pos in finals:
            if len(out) >= top_n and val > kth * (1 + 1e-12) + 1e-18:
                break
            segs = self._reconstruct(memo, key, pos)
            if segs in seen:
                continue
            seen.add(segs)
            out.append(self.cost.evaluate(segs))
            if len(out) >= top_n:
                kth = sorted(c.bottleneck_s for c in out)[top_n - 1]
        win = min((c.bottleneck_s for c in out), default=math.inf)
        tol = win * (1 + 1e-12) + 1e-18
        n_tied = sum(1 for c in out if c.bottleneck_s <= tol)
        out.sort(key=lambda c: (c.bottleneck_s, c.latency_s))
        out = out[:top_n]

        # a full pool dropped a candidate that could tie the winner AND
        # the winner genuinely ties (if a cut path tied the winner, at
        # least two kept finals tie it too: swapping a dropped entry for a
        # kept sibling only lowers the max-composed value, which cannot go
        # below the global minimum — so a unique winner proves no tie was
        # cut).  Only then is the tied surface possibly wider than the
        # pools: reconstruct it exactly via ParetoLattice (the
        # min-(bottleneck, latency) point is always on the Pareto
        # frontier) and let it lead the ranking.  The double condition
        # keeps this dispatch off the common no-tie path — suffix values
        # exclude the prefix/input-hop floor, so ``_tie_cut`` alone
        # under-estimates wildly and would fire on almost every solve.
        self._dispatched = bool(out and n_tied >= 2
                                and self._tie_cut <= tol)
        if self._dispatched:
            best = self._tied_surface_best(out[0].bottleneck_s)
            if best is not None and best.segments not in seen:
                out = [best, *out]
                out.sort(key=lambda c: (c.bottleneck_s, c.latency_s))
                out = out[:top_n]
        return out

    def _tied_surface_best(self, value: float) -> PartitionConfig | None:
        """Exact min-(bottleneck, latency, transfer) config among those
        whose bottleneck ties ``value``, via the Pareto frontier (which
        always carries that point)."""
        tol = value * (1 + 1e-12) + 1e-18
        tied = [c for c in ParetoLattice(self.cost, self.cons).solve()
                if c.bottleneck_s <= tol]
        if not tied:
            return None
        return min(tied, key=lambda c: (c.bottleneck_s, c.latency_s,
                                        c.transfer_bytes))

    def _reconstruct(self, memo, key, pos) -> tuple[Segment, ...]:
        segs: list[Segment] = []
        start = key[0]
        while True:
            value, end, child_key, child_pos = memo[key][pos]
            segs.append(Segment(self.names[key[1]], start, end))
            if child_key is None:
                return tuple(segs)
            key, pos, start = child_key, child_pos, end + 1


# the dominance kernel lives in .labelset (vectorised, with a retained
# scalar reference for the property tests); the historical name is kept —
# the repro.core.partition shim and several tests import it from here
_nondominated_rows = nondominated_rows


class ParetoLattice(_LatticeBase):
    """Exact Pareto-frontier extraction over (latency, bottleneck, transfer).

    A label-correcting DP over the same (block, resource, must-use-mask)
    states as :class:`PartitionLattice`, except each state keeps its full
    **non-dominated set** of vector labels

        (latency_so_far, bottleneck_of_closed_stages, transfer_so_far,
         open_segment_time)

    instead of a scalar k-best list.  Latency and transfer compose
    additively, the closed-stage bottleneck by minimax, and the open
    segment's eventual stage period is monotone in its accumulated time —
    all monotone operators — so per-state dominance pruning is exact: no
    genuinely non-dominated operating point can be lost, which the
    three-objective k-best union used by ``QueryEngine.frontier`` before
    this class could not guarantee.  Distinct paths with identical labels
    collapse to one representative, so the result carries one config per
    frontier *vector* (the exhaustive oracle may hold several tied
    configs with equal objectives).

    ``epsilon`` > 0 enables multiplicative ε-dominance pruning to bound
    label-set growth on fleet-sized spaces: a label is also dropped when a
    kept label is within a factor (1+ε) in every component.  Relative
    error composes through the additive/minimax operators, so every
    exact-front point has a returned point within (1+ε)^S of it in every
    objective (S = blocks on the path; far tighter in practice).  The
    default 0.0 is exact.  ``labels_kept`` / ``labels_pruned`` record the
    label-set statistics across all states of the last :meth:`solve`.

    Constraints: ``must_use`` (via the mask), ``exclude``/``pin`` (via
    ``allowed``) and ``max_link_bytes`` (via ``transition_allowed``) are
    exact in the DP, and so are the path-dependent ``max_resource_time`` /
    ``min_blocks_on``: for resources they name, the state key carries the
    open segment's start block (see ``_LatticeBase``), so over-cap
    extensions are pruned the moment they occur and under-floor segment
    closes are rejected — labels within a state remain interchangeable
    prefixes and dominance pruning stays exact.  The split states' label
    sets rejoin in the global non-dominated filter over completed vectors,
    so the returned frontier is the true constrained frontier with no
    post-filtering (the exhaustive strategy remains the validation
    oracle).
    """

    def __init__(self, cost: CostModel,
                 constraints: Constraints | None = None,
                 epsilon: float = 0.0,
                 plan: ChainPlan | None = None):
        if epsilon < 0.0:
            raise ValueError(f"epsilon must be >= 0, got {epsilon}")
        super().__init__(cost, constraints)
        self.epsilon = float(epsilon)
        self.labels_kept = 0
        self.labels_pruned = 0
        self.state: LabelState | None = None
        if plan is not None and plan.names == self.names:
            self._plan = plan

    def _div(self, resource: str) -> float:
        """Per-request divisor of a compute stage on ``resource`` — the
        label's open-segment time over this is its eventual stage period."""
        return self.cost.replicas_for(resource) * self.cost.batch_size

    # -- per-solve numeric tables (operating-point dependent) --------------
    def _tables(self) -> dict:
        cost, plan = self.cost, self._get_plan()
        rsel = [cost._idx[n] for n in plan.names]
        cum = cost.cum[rsel]
        # per-block times as prefix-sum differences — the exact arithmetic
        # of CostModel.segment_time, so feasibility and label values agree
        # bit for bit with the scalar path and the exhaustive oracle
        steps = np.ascontiguousarray((cum[:, 1:] - cum[:, :-1]).T)  # (B, R)
        div = np.array([cost.replicas_for(n) * cost.batch_size
                        for n in plan.names], dtype=np.float64)
        return {"cum": cum, "steps": steps, "div": div,
                "out": cost.out_bytes}

    def _init_block(self, tbl: dict, only=None) -> dict:
        """Block-0 label rows (``only`` restricts the start resources — the
        join-delta path seeds starts on joined resources alone)."""
        cost, plan = self.cost, self._get_plan()
        rows = []
        for j in (range(plan.R) if only is None else only):
            r = plan.names[j]
            if not plan.allowed[0, j] or \
                    not (tbl["steps"][0, j] <= plan.tmaxv[j]):
                continue
            lat = bneck = xfer = 0.0
            if r != cost.source:
                nbytes = cost.batch_input_bytes
                if not plan.cons.transition_allowed(cost.source, r, nbytes):
                    continue
                lat = cost.comm(cost.source, r, nbytes)
                bneck = cost.hop_period(cost.source, r, nbytes)
                xfer = nbytes
            step = float(tbl["steps"][0, j])
            rows.append((lat + step, bneck, xfer, step, j))
        lab = np.array([x[:4] for x in rows],
                       dtype=np.float64).reshape(-1, 4)
        rix = np.array([x[4] for x in rows], dtype=np.int64)
        return {"lab": lab, "rix": rix, "msk": plan.bitv[rix],
                "sta": np.where(plan.tracked[rix], 0, -1).astype(np.int64),
                "par": np.full(len(rix), -1, dtype=np.int64),
                "used": np.int64(1) << rix}

    def _advance(self, prev: dict, b: int, tbl: dict,
                 delta_from: int = 0, joined=None,
                 protect: dict | None = None) -> dict:
        """One fused extend-then-prune step: all candidate labels of block
        ``b`` from the rows of block ``b - 1``, pruned per state in one
        :func:`grouped_nondominated` call.

        ``delta_from`` / ``joined`` / ``protect`` serve the incremental
        join path: rows of ``prev`` below ``delta_from`` are replayed old
        rows — they do not stay (their extensions are already in
        ``protect``, the old rows of block ``b``) and hand off only into
        ``joined`` resource columns; ``protect`` rows are prepended
        unprunable and only the delta candidates compete against them.
        """
        cost, plan = self.cost, self._get_plan()
        lab, rix, msk, sta, used = (prev["lab"], prev["rix"], prev["msk"],
                                    prev["sta"], prev["used"])
        steps_b = tbl["steps"][b]
        cum = tbl["cum"]
        nbytes = float(tbl["out"][b - 1])
        # stay: the open segment grows through block b (pruned the moment
        # it would exceed its compute-time cap)
        ok = plan.allowed[b][rix].copy()
        if delta_from:
            ok[:delta_from] = False
        tr = np.flatnonzero(ok & (sta >= 0))
        if len(tr):
            segt = cum[rix[tr], b + 1] - cum[rix[tr], sta[tr]]
            ok[tr] &= segt <= plan.tmaxv[rix[tr]]
        stay = np.flatnonzero(ok)
        s_lab = lab[stay].copy()
        sv = steps_b[rix[stay]]
        s_lab[:, 0] += sv
        s_lab[:, 3] += sv
        # hand off: closes [sta..b-1] (min-block floor) and opens block b
        # on a strictly later tier
        close = (sta < 0) | ((b - sta) >= plan.nminv[rix])
        src = np.flatnonzero(close)
        tmask = plan.allowed[b] & (steps_b <= plan.tmaxv)
        pair = plan.ok_pair & (nbytes <= plan.limitm) & tmask[None, :]
        mat = pair[rix[src]]
        if delta_from and joined is not None:
            jm = np.zeros(plan.R, dtype=bool)
            jm[joined] = True
            mat[src < delta_from] &= jm[None, :]
        si_l, tj = np.nonzero(mat)
        si = src[si_l]
        rs = rix[si]
        hopc = plan.latm + nbytes / plan.bwm
        hs = hopc + steps_b[None, :]
        hopp = hopc / cost.batch_size
        h_lab = np.empty((len(si), 4))
        h_lab[:, 0] = lab[si, 0] + hs[rs, tj]
        h_lab[:, 1] = np.maximum(
            np.maximum(lab[si, 1], lab[si, 3] / tbl["div"][rs]),
            hopp[rs, tj])
        h_lab[:, 2] = lab[si, 2] + nbytes
        h_lab[:, 3] = steps_b[tj]
        c_lab = np.concatenate([s_lab, h_lab])
        c_rix = np.concatenate([rix[stay], tj])
        c_msk = np.concatenate([msk[stay], msk[si] | plan.bitv[tj]])
        c_sta = np.concatenate(
            [sta[stay], np.where(plan.tracked[tj], b, -1)]).astype(np.int64)
        c_par = np.concatenate([stay, si])
        c_used = np.concatenate([used[stay],
                                 used[si] | (np.int64(1) << tj)])
        nprot = 0 if protect is None else len(protect["lab"])
        if nprot:
            key = (((np.concatenate([protect["rix"], c_rix])
                     * np.int64(plan.full_mask + 1))
                    + np.concatenate([protect["msk"], c_msk]))
                   * np.int64(plan.B + 2)
                   + (np.concatenate([protect["sta"], c_sta]) + 1))
            keep = grouped_nondominated(
                np.concatenate([protect["lab"], c_lab]), key, self.epsilon)
            keep = keep[keep >= nprot] - nprot   # delta survivors only
        else:
            key = ((c_rix * np.int64(plan.full_mask + 1) + c_msk)
                   * np.int64(plan.B + 2) + (c_sta + 1))
            keep = grouped_nondominated(c_lab, key, self.epsilon)
        self.labels_kept += len(keep)
        self.labels_pruned += len(c_lab) - len(keep)
        blk = {"lab": c_lab[keep], "rix": c_rix[keep], "msk": c_msk[keep],
               "sta": c_sta[keep], "par": c_par[keep], "used": c_used[keep]}
        if nprot:
            blk = _concat_blocks(protect, blk)
        return blk

    def _finish(self, blocks: list[dict],
                tbl: dict) -> list[PartitionConfig]:
        """Close every final open segment, filter the completed vectors
        globally (states split by open-seg start rejoin here), and price
        the surviving paths through ``CostModel.evaluate`` — the single
        source of truth for the objective vectors."""
        cost, plan = self.cost, self._get_plan()
        last = blocks[-1]
        lab, rix, msk, sta = (last["lab"], last["rix"], last["msk"],
                              last["sta"])
        B = plan.B
        fin = (msk == plan.full_mask) & \
            ((sta < 0) | ((B - sta) >= plan.nminv[rix]))
        rows = np.flatnonzero(fin)
        if not len(rows):
            return []
        vec = np.empty((len(rows), 3))
        vec[:, 0] = lab[rows, 0]
        vec[:, 1] = np.maximum(lab[rows, 1],
                               lab[rows, 3] / tbl["div"][rix[rows]])
        vec[:, 2] = lab[rows, 2]
        keep = nondominated_rows(vec, 0.0)
        out: list[PartitionConfig] = []
        seen: set[tuple[Segment, ...]] = set()
        for i in rows[keep]:
            segs = _walk_path(blocks, int(i), plan.names)
            if segs in seen:
                continue
            seen.add(segs)
            out.append(cost.evaluate(segs))
        out = pareto_frontier(out)
        out.sort(key=lambda c: (c.latency_s, c.bottleneck_s,
                                c.transfer_bytes))
        return out

    def solve(self, keep_state: bool = False) -> list[PartitionConfig]:
        """The exact (ε = 0) non-dominated set of configurations, sorted by
        (latency, bottleneck, transfer).

        ``keep_state=True`` additionally retains the per-block label
        arrays on ``self.state`` for incremental elastic re-plans
        (:meth:`resume` / :meth:`extend`)."""
        plan = self._get_plan()
        self.labels_kept = self.labels_pruned = 0
        self.state = None
        if plan.infeasible:
            return []
        tbl = self._tables()
        blocks = [self._init_block(tbl)]
        for b in range(1, plan.B):
            blocks.append(self._advance(blocks[-1], b, tbl))
        out = self._finish(blocks, tbl)
        if keep_state:
            self.state = LabelState(list(plan.names), list(plan.must),
                                    self.epsilon, blocks, out,
                                    plan.R <= _MAX_INC_RESOURCES)
        return out

    # -- incremental elastic re-plans --------------------------------------
    def resume(self, prev: "LabelState",
               keep_state: bool = False) -> list[PartitionConfig]:
        """Warm re-solve after resources *left* the fleet.

        The kept label arrays of ``prev`` are replayed up to (excluding)
        the first block where any kept label's path ever touched a
        departed resource; the DP re-runs only from that frontier.  Exact
        at any ε: state keys name the current resource, so labels on
        surviving resources below that block were generated from — and
        pruned only against — labels on surviving resources, making the
        replayed prefix identical to a cold solve's.  Falls back to a
        cold solve when the state is unusable (different ε / must set /
        non-subset membership).
        """
        plan = self._get_plan()
        if (prev is None or not prev.supports_inc
                or self.epsilon != prev.epsilon
                or list(plan.must) != list(prev.must)
                or any(n not in prev.names for n in plan.names)):
            return self.solve(keep_state=keep_state)
        self.labels_kept = self.labels_pruned = 0
        self.state = None
        if plan.infeasible:
            return []
        pos = {n: i for i, n in enumerate(plan.names)}
        remap = np.array([pos.get(n, -1) for n in prev.names],
                         dtype=np.int64)
        lost = np.flatnonzero(remap < 0)
        lost_bits = np.int64(0)
        for i in lost:
            lost_bits |= np.int64(1) << np.int64(i)
        b0 = None
        for b, blk in enumerate(prev.blocks):
            if np.any(blk["used"] & lost_bits):
                b0 = b
                break
        if b0 == 0:
            return self.solve(keep_state=keep_state)
        tbl = self._tables()
        upto = len(prev.blocks) if b0 is None else b0
        blocks = [_remap_block(prev.blocks[b], remap, len(prev.names))
                  for b in range(upto)]
        if b0 is None:
            out = list(prev.configs)
        else:
            for b in range(b0, plan.B):
                blocks.append(self._advance(blocks[-1], b, tbl))
            out = self._finish(blocks, tbl)
        if keep_state:
            self.state = LabelState(list(plan.names), list(plan.must),
                                    self.epsilon, blocks, out,
                                    plan.R <= _MAX_INC_RESOURCES)
        return out

    def extend(self, prev: "LabelState",
               keep_state: bool = False) -> list[PartitionConfig]:
        """Warm re-solve after resources *joined* the fleet.

        Old kept rows are replayed verbatim as protected rows; only the
        delta — paths that visit a joined resource — is generated (old
        rows hand off into joined columns only, block-0 starts seed on
        joined resources only) and pruned against the protected rows.
        Output-exact at ε == 0: a protected row a delta row dominates
        yields only dominated completions, which the final global filter
        and the authoritative ``pareto_frontier`` re-filter remove; by
        dominance transitivity the delta prune loses nothing.  ε > 0
        falls back cold (the greedy archive is order-dependent).
        """
        plan = self._get_plan()
        if (prev is None or not prev.supports_inc
                or self.epsilon != 0.0 or prev.epsilon != 0.0
                or list(plan.must) != list(prev.must)
                or plan.names[:len(prev.names)] != list(prev.names)
                or plan.R > _MAX_INC_RESOURCES):
            return self.solve(keep_state=keep_state)
        self.labels_kept = self.labels_pruned = 0
        self.state = None
        if plan.infeasible:
            return []
        joined = np.arange(len(prev.names), plan.R)
        tbl = self._tables()
        blocks = [_concat_blocks(prev.blocks[0],
                                 self._init_block(tbl, only=joined))]
        for b in range(1, plan.B):
            blocks.append(self._advance(
                blocks[-1], b, tbl,
                delta_from=len(prev.blocks[b - 1]["lab"]),
                joined=joined, protect=prev.blocks[b]))
        out = self._finish(blocks, tbl)
        if keep_state:
            self.state = LabelState(list(plan.names), list(plan.must),
                                    self.epsilon, blocks, out,
                                    plan.R <= _MAX_INC_RESOURCES)
        return out


# used-resource bitmasks are int64: incremental state needs one bit per
# resource (fleets beyond this fall back to cold solves, which they would
# want anyway — the bigger the fleet, the higher the churn rate)
_MAX_INC_RESOURCES = 62


@dataclass
class LabelState:
    """Final per-block label arrays of one ``ParetoLattice.solve(
    keep_state=True)`` — what incremental elastic re-plans resume from.

    ``blocks[b]`` holds parallel arrays ``lab`` (N, 4 label columns),
    ``rix`` (resource index), ``msk`` (must-use mask), ``sta`` (open-seg
    start, -1 untracked), ``par`` (parent row in block b-1) and ``used``
    (bitmask over the resource axis of every resource on the row's path).
    """

    names: list[str]
    must: list[str]
    epsilon: float
    blocks: list[dict]
    configs: list[PartitionConfig]
    supports_inc: bool


def _concat_blocks(a: dict, b: dict) -> dict:
    return {k: np.concatenate([a[k], b[k]]) for k in a}


def _remap_block(blk: dict, remap: np.ndarray, n_old: int) -> dict:
    """Re-index a replayed block onto a shrunken resource axis (`remap`
    maps old resource index -> new, -1 for departed; no row of a replayed
    block touches a departed resource, so every lookup is valid)."""
    used = np.zeros_like(blk["used"])
    for i_old in range(n_old):
        i_new = remap[i_old]
        if i_new >= 0:
            used |= ((blk["used"] >> np.int64(i_old)) & np.int64(1)) \
                << np.int64(i_new)
    return {"lab": blk["lab"], "rix": remap[blk["rix"]], "msk": blk["msk"],
            "sta": blk["sta"], "par": blk["par"], "used": used}
