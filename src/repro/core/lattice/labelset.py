"""Vectorised label-set kernels shared by every lattice DP.

Each lattice state keeps a *set of labels* — rows of a float array whose
columns are monotone-composing cost components (all minimised).  The DPs
spend almost all of their time deciding which labels survive, so the two
primitives here are the hot kernels of the whole query path:

* :func:`nondominated_rows` — dominance pruning of one label array
  (exact Pareto filter, optional multiplicative ε-dominance archive).
  Profiling showed the previous ``np.unique(axis=0)``-based filter paying
  ~110-170 µs per call in structured-dtype machinery alone; this version
  deduplicates via one ``np.lexsort`` pass and switches between a single
  pairwise dominance matrix (small sets) and a chunked frontier sweep
  (large sets), keeping the exact same keep semantics.
* :func:`grouped_nondominated` — dominance pruning of *many* states at
  once.  At ε == 0 a group key can be embedded as an extra objective
  pair ``(key, -key)``: a row can then only dominate a row with the same
  key, so one fused kernel call prunes every state of a DP block instead
  of one Python-level call per state.
* :func:`grouped_topk` — per-group k-smallest selection (the scalar
  k-best lattice's replacement for per-label ``bisect.insort``).

Keep semantics (pinned by ``tests/test_partition.py`` and the
hypothesis property in ``tests/test_vectorized_labels.py``):
exact-duplicate rows collapse to their first occurrence; a row is pruned
iff a distinct row is <= in every column; with ε > 0 a greedy archive in
lexicographic row order additionally drops rows within a factor (1+ε) of
a kept row in every column; returned indices are ascending.
:func:`nondominated_rows_scalar` is the retained scalar reference the
property tests compare against.
"""

from __future__ import annotations

import numpy as np

# above this many unique rows the (m, m, k) pairwise dominance tensor is
# replaced by a chunked sweep in lexicographic order (bounded memory, same
# result)
_PAIRWISE_MAX = 512
_CHUNK = 256


def _lex_unique(pts: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Unique rows of ``pts`` in ascending lexicographic order (first
    column most significant) plus the original index of each row's first
    occurrence — what ``np.unique(pts, axis=0, return_index=True)``
    returns, without the structured-dtype round trip."""
    n = len(pts)
    order = np.lexsort(pts.T[::-1])      # stable: ties keep index order
    spts = pts[order]
    new = np.empty(n, dtype=bool)
    new[0] = True
    np.any(spts[1:] != spts[:-1], axis=1, out=new[1:])
    return spts[new], order[new]


def _pairwise_alive(uniq: np.ndarray) -> np.ndarray:
    """Boolean mask of rows of ``uniq`` (all distinct) not dominated by
    any other row.

    ``le[i, j] == row j <= row i in every column`` is accumulated one
    column at a time as chained 2-D comparisons — an order of magnitude
    cheaper than the equivalent (m, m, k) broadcast tensor, which spends
    most of its time materialising the 3-D intermediate."""
    c = uniq[:, 0]
    le = c[:, None] >= c[None, :]
    for ci in range(1, uniq.shape[1]):
        c = uniq[:, ci]
        le &= c[:, None] >= c[None, :]
    np.fill_diagonal(le, False)
    return ~le.any(axis=1)


def _covered_by(archive: np.ndarray, cand: np.ndarray) -> np.ndarray:
    """Boolean mask over ``cand`` rows having some archive row <= them in
    every column (same chained 2-D accumulation as
    :func:`_pairwise_alive`)."""
    a = archive[:, 0]
    c = cand[:, 0]
    le = a[None, :] <= c[:, None]
    for ci in range(1, cand.shape[1]):
        a = archive[:, ci]
        c = cand[:, ci]
        le &= a[None, :] <= c[:, None]
    return le.any(axis=1)


def _swept_frontier(uniq: np.ndarray, eps: float) -> np.ndarray:
    """Boolean keep-mask over ``uniq`` (distinct rows in ascending
    lexicographic order) from the greedy frontier sweep.

    Every exact dominator of a row sorts lexicographically before it, so
    checking candidates only against already-kept rows is exact at
    ε == 0 and is the canonical greedy archive at ε > 0.  Candidates are
    processed in chunks: each chunk is first tested against the kept
    archive in one batched comparison, then (ε == 0) against itself with
    one pairwise tensor — within-chunk dominance composes transitively
    with the archive, so the union test is exact — or (ε > 0)
    sequentially, because the archive grows inside the chunk.
    """
    m = len(uniq)
    scale = 1.0 + eps
    keep = np.zeros(m, dtype=bool)
    kept = np.empty_like(uniq)
    kcount = 0
    for s in range(0, m, _CHUNK):
        c = uniq[s:s + _CHUNK]
        if kcount:
            covered = _covered_by(kept[:kcount],
                                  c if eps == 0.0 else c * scale)
        else:
            covered = np.zeros(len(c), dtype=bool)
        if eps == 0.0:
            alive = _pairwise_alive(c) & ~covered
            rows = np.flatnonzero(alive)
        else:
            rows = []
            for i in np.flatnonzero(~covered):
                u = c[i] * scale
                lo = kcount - len(rows)   # archive rows added this chunk
                if len(rows) and (kept[lo:kcount] <= u).all(1).any():
                    continue
                kept[kcount] = c[i]
                kcount += 1
                rows.append(i)
            rows = np.asarray(rows, dtype=np.intp)
            keep[s + rows] = True
            continue
        nc = len(rows)
        kept[kcount:kcount + nc] = c[rows]
        kcount += nc
        keep[s + rows] = True
    return keep


def _direct_keep(pts: np.ndarray) -> np.ndarray:
    """Exact ε == 0 keep-indices for small arrays without the
    lexsort/dedup round trip: row i is dropped iff some row j is <= in
    every column and either differs somewhere (strict dominance) or is
    an identical earlier row (duplicate collapse to first occurrence).
    One chained (n, n) comparison pair per column."""
    c = pts[:, 0]
    le = c[:, None] <= c[None, :]
    eq = c[:, None] == c[None, :]
    for ci in range(1, pts.shape[1]):
        c = pts[:, ci]
        le &= c[:, None] <= c[None, :]
        eq &= c[:, None] == c[None, :]
    strict = le & ~eq
    dom = strict.any(axis=0) | np.triu(eq, 1).any(axis=0)
    return np.flatnonzero(~dom)


def nondominated_rows(pts: np.ndarray, eps: float = 0.0) -> np.ndarray:
    """Indices of rows of ``pts`` (every column minimised) surviving
    dominance pruning, ascending.

    Exact-duplicate rows collapse to one representative (the first
    occurrence).  With ``eps == 0`` the filter is exact: a row is pruned
    iff some distinct row is <= in every column.  With ``eps > 0`` a row
    is additionally pruned when a *kept* row is within a factor (1+eps)
    in every column (multiplicative ε-dominance, applied greedily in
    lexicographic order so mutually ε-close rows keep exactly one
    representative).
    """
    pts = np.asarray(pts)
    n = len(pts)
    if n <= 1:
        return np.arange(n)
    if n == 2:
        a, b = pts[0], pts[1]
        a_le = bool((a <= b).all())
        b_le = bool((b <= a).all())
        if a_le and b_le:                       # duplicates
            return np.array([0])
        if a_le or b_le:                        # strict dominance
            return np.array([0 if a_le else 1])
        if eps > 0.0:
            lex = 0 if tuple(a) < tuple(b) else 1
            if (pts[lex] <= pts[1 - lex] * (1.0 + eps)).all():
                return np.array([lex])
        return np.array([0, 1])
    if eps == 0.0 and n <= _PAIRWISE_MAX:
        return _direct_keep(pts)
    uniq, first = _lex_unique(pts)
    m = len(uniq)
    if m <= _PAIRWISE_MAX:
        alive = _pairwise_alive(uniq)
        uniq, first = uniq[alive], first[alive]
    if eps > 0.0 or m > _PAIRWISE_MAX:
        keep = _swept_frontier(uniq, eps)
        first = first[keep]
    return np.sort(first)


def nondominated_rows_scalar(pts: np.ndarray, eps: float = 0.0) -> np.ndarray:
    """Scalar reference implementation of :func:`nondominated_rows` —
    the unvectorised specification the hypothesis property tests compare
    the fast kernel against, label for label.

    Semantics, spelled out: deduplicate to first occurrences; drop every
    row some distinct row dominates (<= in all columns); then sweep the
    survivors in ascending lexicographic order keeping a greedy archive —
    a row is dropped when an already-kept row is <= row * (1+eps) in all
    columns (a no-op at eps == 0).  Returns ascending original indices.
    """
    pts = np.asarray(pts)
    rows = [tuple(map(float, r)) for r in pts]
    firsts: dict[tuple, int] = {}
    for i, r in enumerate(rows):
        firsts.setdefault(r, i)
    uniq = sorted(firsts)
    alive = []
    for r in uniq:
        dominated = any(o != r and all(x <= y for x, y in zip(o, r))
                        for o in uniq)
        if not dominated:
            alive.append(r)
    scale = 1.0 + eps
    kept: list[tuple] = []
    out: list[int] = []
    for r in alive:
        if any(all(x <= y * scale for x, y in zip(k, r)) for k in kept):
            continue
        kept.append(r)
        out.append(firsts[r])
    return np.asarray(sorted(out), dtype=np.intp)


def grouped_nondominated(pts: np.ndarray, keys: np.ndarray,
                         eps: float = 0.0) -> np.ndarray:
    """Indices (ascending) of rows surviving *per-group* dominance
    pruning: row i may only be pruned by rows j with ``keys[j] ==
    keys[i]``, with the exact per-group semantics of
    :func:`nondominated_rows`.

    At ε == 0 all groups are pruned in one fused kernel call by
    embedding the key as an extra objective pair ``(key, -key)``: a row
    is then <= another in every column only when their keys are equal,
    so plain dominance on the extended array *is* grouped dominance
    (duplicate collapse included — rows equal in the label columns but
    in different groups differ in the key columns).  ε > 0 falls back to
    one kernel call per group: the multiplicative archive test has no
    faithful encoding over the signed key column.
    """
    n = len(pts)
    if n <= 1:
        return np.arange(n)
    keys = np.asarray(keys)
    if eps == 0.0 and n <= _PAIRWISE_MAX:
        # direct pairwise path: group equality gates the comparison
        # matrices, so no key-embedding array is ever built
        gm = keys[:, None] == keys[None, :]
        c = pts[:, 0]
        le = gm & (c[:, None] <= c[None, :])
        eq = gm & (c[:, None] == c[None, :])
        for ci in range(1, pts.shape[1]):
            c = pts[:, ci]
            le &= c[:, None] <= c[None, :]
            eq &= c[:, None] == c[None, :]
        strict = le & ~eq
        dom = strict.any(axis=0) | np.triu(eq, 1).any(axis=0)
        return np.flatnonzero(~dom)
    if eps == 0.0:
        kf = keys.astype(np.float64)
        ext = np.concatenate([pts, kf[:, None], -kf[:, None]], axis=1)
        return nondominated_rows(ext, 0.0)
    out: list[np.ndarray] = []
    order = np.argsort(keys, kind="stable")
    skeys = keys[order]
    starts = np.flatnonzero(np.r_[True, skeys[1:] != skeys[:-1]])
    bounds = np.r_[starts, len(skeys)]
    for s, e in zip(bounds[:-1], bounds[1:]):
        idx = order[s:e]       # ascending: stable sort over sorted ranges
        out.append(idx[nondominated_rows(pts[idx], eps)])
    return np.sort(np.concatenate(out))


def grouped_topk(keys: np.ndarray, scores: np.ndarray, k: int) -> np.ndarray:
    """Indices (ascending) of the k smallest-score rows of every group.

    Ties on the score keep the earliest rows (stable), matching the
    (score, insertion-order) tie counter of the scalar bounded-insort
    this replaces.  One ``np.lexsort`` + one segmented rank computation —
    no per-row Python.
    """
    n = len(keys)
    if n == 0:
        return np.arange(0)
    order = np.lexsort((scores, keys))   # group-major, score-minor, stable
    skeys = keys[order]
    new_group = np.r_[True, skeys[1:] != skeys[:-1]]
    # rank of each sorted row within its group: position minus the
    # position of the group's first row
    group_start = np.maximum.accumulate(
        np.where(new_group, np.arange(n), 0))
    rank = np.arange(n) - group_start
    return np.sort(order[rank < k])
