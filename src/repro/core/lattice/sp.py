"""SPSolver — the exact DP over a series-parallel decomposition tree.

The chain lattices walk blocks left to right with a single open tensor;
this engine walks the :class:`~repro.core.graph.SPNode` tree instead:

* **series** composition is exactly the chain transition — extend the
  open tail block's label by the next leaf, paying per-edge comm on every
  crossing edge (a join leaf pays one comm term per incoming branch: the
  "cut crossed by k tensors transfers the sum of edge bytes" rule);
* **parallel** composition solves each branch *relative* to the fork
  label (cached per fork resource) and merges the per-branch label sets:
  latencies concatenate (one column per open tail — the max is deferred
  to the join leaf, which is where branch finish times actually meet),
  transfer and per-resource compute times add, hop bottlenecks max.

A label is a vector over monotone-composing components

    (finish time per open tail, hop-period max, transfer bytes,
     per-resource compute time T_r ..., −blocks hosted per floored
     resource ...)

grouped by state ``(open tails with their resources, must-use mask)``.
Within a state every component composes monotonically into any completion
(critical-path latency is max/+ in each tail finish; the pipelined
bottleneck is monotone in each ``T_r`` and the hop max; feasibility of
``max_resource_time`` is monotone in ``T_r``, ``min_blocks_on``
anti-monotone in the block counts — hence the negation), so per-state
dominance pruning is exact: the top-1 solve and the frontier match the
DAG-aware exhaustive oracle label-for-label, constraints included
(``max_resource_time`` prunes in-flight, ``min_blocks_on`` gates
finalisation, ``pin``/``exclude``/``max_link_bytes`` gate transitions,
``must_use`` lives in the mask).

k-best beyond the winner uses widened retention (non-dominated set ∪ the
per-state top-k by an objective proxy).  Unlike the chain
:class:`PartitionLattice`, that is not provably exact for ``top_n > 1``
on DAGs — a scalar score does not order multi-tail prefixes — so ranked
tails beyond the top-1 are best-effort; the query engine's exhaustive
strategy remains the ground truth there.

Carried resources: because parallel branches are *unordered*, a resource
may receive blocks from several branches, so — unlike the chain lattices,
which exploit strict tier ordering to close segments eagerly — each label
carries the full per-resource time vector.  That costs label-set width on
large fleets; chain-shaped models keep using the chain lattices, which
are untouched.
"""

from __future__ import annotations

import itertools

import numpy as np

from .chain import (Constraints, LATENCY, Objective, ThroughputObjective,
                    _LatticeBase, _nondominated_rows, pareto_frontier, rank)
from .dag import DagCostModel, DagPartitionConfig


class SPSolver(_LatticeBase):
    """Exact partitioning DP over a block DAG's SP decomposition tree."""

    def __init__(self, cost: DagCostModel,
                 constraints: Constraints | None = None,
                 epsilon: float = 0.0):
        if epsilon < 0.0:
            raise ValueError(f"epsilon must be >= 0, got {epsilon}")
        super().__init__(cost, constraints)
        self.epsilon = float(epsilon)
        self.preds = cost.block_preds
        tree = getattr(cost, "tree", None)
        if tree is None:
            from ..graph import SPNode
            tree = SPNode("series", children=[
                SPNode("leaf", block=i) for i in range(cost.n_blocks)])
        self.tree = tree
        self.ridx = {n: i for i, n in enumerate(self.names)}
        self.floored = [n for n in self.names if n in self.nmin]
        self.fidx = {n: i for i, n in enumerate(self.floored)}
        self.R = len(self.names)
        self.F = len(self.floored)
        self.labels_kept = 0
        self.labels_pruned = 0
        self._retain = 0
        self._proxy = None

    # -- label geometry ----------------------------------------------------
    # a state's array has m = len(tails) leading latency columns, then
    # [bmax, xfer, T_0..T_{R-1}, -cnt_0..-cnt_{F-1}]
    def _width(self, m: int) -> int:
        return m + 2 + self.R + self.F

    def _proxy_for(self, objective: Objective):
        div = np.array([self.cost.replicas_for(n) * self.cost.batch_size
                        for n in self.names])

        def proxy(arr: np.ndarray) -> np.ndarray:
            m = arr.shape[1] - 2 - self.R - self.F
            lat = arr[:, :m].max(axis=1) if m else np.zeros(len(arr))
            if isinstance(objective, ThroughputObjective):
                return np.maximum(arr[:, m],
                                  (arr[:, m + 2:m + 2 + self.R] / div).max(1))
            return (objective.w_latency * lat
                    + objective.w_transfer_per_mb * arr[:, m + 1] / 1e6)

        return proxy

    def _prune_group(self, arr: np.ndarray, assigns: list) -> tuple[np.ndarray, list]:
        keep = _nondominated_rows(arr, self.epsilon)
        if self._retain > 1 and self._proxy is not None and len(keep) < len(arr):
            extra = np.argsort(self._proxy(arr), kind="stable")[:self._retain]
            keep = np.unique(np.concatenate([keep, extra]))
        self.labels_kept += len(keep)
        self.labels_pruned += len(arr) - len(keep)
        return arr[keep], [assigns[i] for i in keep]

    # -- tree walk ---------------------------------------------------------
    def _run_series(self, node, states: dict) -> dict:
        for child in node.children:
            if not states:
                return states
            if child.kind == "leaf":
                states = self._leaf(child.block, states)
            elif child.kind == "parallel":
                states = self._parallel(child, states)
            else:
                states = self._run_series(child, states)
        return states

    def _leaf(self, b: int, states: dict) -> dict:
        cost, cons = self.cost, self.cons
        P = list(self.preds[b])
        t_by_r = {r: cost.segment_time(r, b, b) for r in self.names}
        out: dict = {}
        for (tails, mask), (arr, assigns) in states.items():
            if b > 0 and {u for u, _ in tails} != set(P):
                raise ValueError(
                    f"SP tree out of sync with block edges at block {b}: "
                    f"open tails {sorted(u for u, _ in tails)} vs preds {P}")
            cols = {u: j for j, (u, _) in enumerate(tails)}
            res_of = {u: ru for u, ru in tails}
            m = len(tails)
            L = len(arr)
            for r in self.names:
                if not cons.allowed(b, r):
                    continue
                inp = bneck0 = x0 = 0.0
                if b == 0 and r != cost.source:
                    nb = cost.batch_input_bytes
                    if not cons.transition_allowed(cost.source, r, nb):
                        continue
                    inp = cost.comm(cost.source, r, nb)
                    bneck0 = cost.hop_period(cost.source, r, nb)
                    x0 = nb
                ok = True
                terms = []          # (column, comm seconds)
                hop_max = bneck0
                nbytes_sum = x0
                for u in P:
                    ru = res_of[u]
                    if ru == r:
                        terms.append((cols[u], 0.0))
                        continue
                    if self.order[r] <= self.order[ru]:
                        ok = False
                        break
                    nb = float(cost.out_bytes[u])
                    if not cons.transition_allowed(ru, r, nb):
                        ok = False
                        break
                    terms.append((cols[u], cost.comm(ru, r, nb)))
                    hop_max = max(hop_max, cost.hop_period(ru, r, nb))
                    nbytes_sum += nb
                if not ok:
                    continue
                t = t_by_r[r]
                ri = self.ridx[r]
                tcap = self.tmax.get(r)
                if tcap is not None and t > tcap:
                    continue
                new = np.empty((L, self._width(1)))
                if terms:
                    new[:, 0] = np.max(
                        np.stack([arr[:, j] + c for j, c in terms], axis=1),
                        axis=1) + t
                else:
                    new[:, 0] = inp + t
                new[:, 1] = np.maximum(arr[:, m], hop_max)
                new[:, 2] = arr[:, m + 1] + nbytes_sum
                new[:, 3:] = arr[:, m + 2:]
                new[:, 3 + ri] += t
                rows = np.arange(L)
                if tcap is not None:
                    rows = rows[new[rows, 3 + ri] <= tcap]
                    if not len(rows):
                        continue
                if r in self.fidx:
                    new[:, 3 + self.R + self.fidx[r]] -= 1.0
                key = (((b, r),), self._mask_with(mask, r))
                prev = out.get(key)
                add_assigns = [assigns[i] + (r,) for i in rows]
                if prev is None:
                    out[key] = (new[rows], add_assigns)
                else:
                    out[key] = (np.concatenate([prev[0], new[rows]]),
                                prev[1] + add_assigns)
        return {k: self._prune_group(a, s) for k, (a, s) in out.items()}

    def _parallel(self, node, states: dict) -> dict:
        cache: dict = {}
        out: dict = {}
        for (tails, mask), (arr, assigns) in states.items():
            if len(tails) != 1:
                raise ValueError("parallel node entered with >1 open tail")
            f, rf = tails[0]
            results = []
            for bi, branch in enumerate(node.children):
                ck = (bi, rf)
                if ck not in cache:
                    seed = {(((f, rf),), 0):
                            (np.zeros((1, self._width(1))), [()])}
                    cache[ck] = self._run_series(branch, seed)
                results.append(cache[ck])
            if not all(results):
                continue
            L0 = len(arr)
            for combo in itertools.product(
                    *[list(br.items()) for br in results]):
                bmask = mask
                for (_, bm), _ in combo:
                    bmask |= bm
                # one open tail per branch exit (+ the fork when a direct
                # fork→join edge keeps its tensor alive)
                tail_list = [bts[0] for (bts, _), _ in combo]
                if node.direct:
                    tail_list.append((f, rf))
                order = np.argsort([u for u, _ in tail_list], kind="stable")
                new_tails = tuple(tail_list[i] for i in order)
                key = (new_tails, bmask)
                k = len(combo)
                for rows in itertools.product(
                        *[range(len(ba)) for (_, (ba, _)) in combo]):
                    brows = [combo[j][1][0][rows[j]] for j in range(k)]
                    bassigns = tuple(combo[j][1][1][rows[j]]
                                     for j in range(k))
                    mlen = len(tail_list)
                    new = np.empty((L0, self._width(mlen)))
                    lat_cols = []
                    for j in range(k):
                        lat_cols.append(arr[:, 0] + brows[j][0])
                    if node.direct:
                        lat_cols.append(arr[:, 0])
                    for dst, srcidx in enumerate(order):
                        new[:, dst] = lat_cols[srcidx]
                    bm_rel = max(br[1] for br in brows)
                    new[:, mlen] = np.maximum(arr[:, 1], bm_rel)
                    new[:, mlen + 1] = arr[:, 2] + sum(br[2] for br in brows)
                    tail_block = new[:, mlen + 2:]
                    tail_block[:] = arr[:, 3:]
                    for br in brows:
                        tail_block += br[3:]
                    keep = np.arange(L0)
                    for rn, cap in self.tmax.items():
                        c = mlen + 2 + self.ridx[rn]
                        keep = keep[new[keep, c] <= cap]
                        if not len(keep):
                            break
                    if not len(keep):
                        continue
                    badd = ()
                    for a in bassigns:
                        badd = badd + a
                    add_assigns = [assigns[i] + badd for i in keep]
                    prev = out.get(key)
                    if prev is None:
                        out[key] = (new[keep], add_assigns)
                    else:
                        out[key] = (np.concatenate([prev[0], new[keep]]),
                                    prev[1] + add_assigns)
        return {k: self._prune_group(a, s) for k, (a, s) in out.items()}

    # -- entry points ------------------------------------------------------
    def _finals(self) -> list[tuple]:
        self.labels_kept = self.labels_pruned = 0
        if self.infeasible:
            return []
        seed = {((), 0): (np.zeros((1, self._width(0))), [()])}
        states = self._run_series(self.tree, seed)
        finals: list[tuple] = []
        for (tails, mask), (arr, assigns) in states.items():
            if mask != self.full_mask:
                continue
            ok = np.ones(len(arr), dtype=bool)
            for rn, floor in self.nmin.items():
                c = len(tails) + 2 + self.R + self.fidx[rn]
                ok &= arr[:, c] <= -float(floor)
            finals.extend(assigns[i] for i in np.nonzero(ok)[0])
        return list(dict.fromkeys(finals))

    def solve(self, objective: Objective = LATENCY,
              top_n: int = 1) -> list[DagPartitionConfig]:
        """Ranked feasible configs; the winner is exact (see module doc)."""
        self._retain = max(1, int(top_n))
        self._proxy = self._proxy_for(objective)
        configs = [self.cost.evaluate_assignment(a) for a in self._finals()]
        return rank(configs, objective, top_n)

    def frontier(self) -> list[DagPartitionConfig]:
        """The exact (ε = 0) non-dominated set over (latency, bottleneck,
        transfer); ε > 0 applies the same ε-dominance as ParetoLattice."""
        self._retain = 0
        self._proxy = None
        configs = [self.cost.evaluate_assignment(a) for a in self._finals()]
        return pareto_frontier(configs)
