"""SPSolver — the exact DP over a series-parallel decomposition tree.

The chain lattices walk blocks left to right with a single open tensor;
this engine walks the :class:`~repro.core.graph.SPNode` tree instead:

* **series** composition is exactly the chain transition — extend the
  open tail block's label by the next leaf, paying per-edge comm on every
  crossing edge (a join leaf pays one comm term per incoming branch: the
  "cut crossed by k tensors transfers the sum of edge bytes" rule);
* **parallel** composition solves each branch *relative* to the fork
  label (cached per fork resource) and merges the per-branch label sets:
  latencies concatenate (one column per open tail — the max is deferred
  to the join leaf, which is where branch finish times actually meet),
  transfer and per-resource compute times add, hop bottlenecks max.

A label is a vector over monotone-composing components

    (finish time per open tail, hop-period max, transfer bytes,
     per-resource compute time T_r ..., −blocks hosted per floored
     resource ...)

grouped by state ``(open tails with their resources, must-use mask)``.
Within a state every component composes monotonically into any completion
(critical-path latency is max/+ in each tail finish; the pipelined
bottleneck is monotone in each ``T_r`` and the hop max; feasibility of
``max_resource_time`` is monotone in ``T_r``, ``min_blocks_on``
anti-monotone in the block counts — hence the negation), so per-state
dominance pruning is exact: the top-1 solve and the frontier match the
DAG-aware exhaustive oracle label-for-label, constraints included
(``max_resource_time`` prunes in-flight, ``min_blocks_on`` gates
finalisation, ``pin``/``exclude``/``max_link_bytes`` gate transitions,
``must_use`` lives in the mask).

k-best beyond the winner uses widened retention (non-dominated set ∪ the
per-state top-k by an objective proxy).  Unlike the chain
:class:`PartitionLattice`, that is not provably exact for ``top_n > 1``
on DAGs — a scalar score does not order multi-tail prefixes — so ranked
tails beyond the top-1 are best-effort; the query engine's exhaustive
strategy remains the ground truth there.

Carried resources: because parallel branches are *unordered*, a resource
may receive blocks from several branches, so — unlike the chain lattices,
which exploit strict tier ordering to close segments eagerly — each label
carries the full per-resource time vector.  That costs label-set width on
large fleets; chain-shaped models keep using the chain lattices, which
are untouched.
"""

from __future__ import annotations

import itertools

import numpy as np

from .chain import (Constraints, LATENCY, Objective, ThroughputObjective,
                    _LatticeBase, pareto_frontier, rank)
from .dag import DagCostModel, DagPartitionConfig
from .labelset import grouped_nondominated, grouped_topk


class SPSolver(_LatticeBase):
    """Exact partitioning DP over a block DAG's SP decomposition tree."""

    def __init__(self, cost: DagCostModel,
                 constraints: Constraints | None = None,
                 epsilon: float = 0.0, plan=None):
        if epsilon < 0.0:
            raise ValueError(f"epsilon must be >= 0, got {epsilon}")
        super().__init__(cost, constraints)
        if plan is not None and plan.names == self.names:
            self._plan = plan
        self.epsilon = float(epsilon)
        self.preds = cost.block_preds
        tree = getattr(cost, "tree", None)
        if tree is None:
            from ..graph import SPNode
            tree = SPNode("series", children=[
                SPNode("leaf", block=i) for i in range(cost.n_blocks)])
        self.tree = tree
        self.ridx = {n: i for i, n in enumerate(self.names)}
        self.floored = [n for n in self.names if n in self.nmin]
        self.fidx = {n: i for i, n in enumerate(self.floored)}
        self.R = len(self.names)
        self.F = len(self.floored)
        self.labels_kept = 0
        self.labels_pruned = 0
        self._retain = 0
        self._proxy = None
        self._leaf_cache: dict = {}
        # completed-DP cache: (finals, label rows, label stats) keyed by
        # the knobs that steer the DP itself (retain width + proxy
        # objective).  The label sets depend only on (cost, constraints,
        # epsilon, retain, proxy), so a warm re-query at the same
        # operating point re-prices cached finals instead of re-running
        # the DP — the engine keeps solvers per (constraints, operating
        # point) to exploit this
        self._finals_cache: dict = {}

    # -- label geometry ----------------------------------------------------
    # a state's array has m = len(tails) leading latency columns, then
    # [bmax, xfer, T_0..T_{R-1}, -cnt_0..-cnt_{F-1}]
    def _width(self, m: int) -> int:
        return m + 2 + self.R + self.F

    def _proxy_for(self, objective: Objective):
        div = np.array([self.cost.replicas_for(n) * self.cost.batch_size
                        for n in self.names])

        def proxy(arr: np.ndarray) -> np.ndarray:
            m = arr.shape[1] - 2 - self.R - self.F
            lat = arr[:, :m].max(axis=1) if m else np.zeros(len(arr))
            if isinstance(objective, ThroughputObjective):
                return np.maximum(arr[:, m],
                                  (arr[:, m + 2:m + 2 + self.R] / div).max(1))
            return (objective.w_latency * lat
                    + objective.w_transfer_per_mb * arr[:, m + 1] / 1e6)

        return proxy

    def _finish(self, chunks: list, keys: list) -> dict:
        """Prune every state's candidate labels in one fused kernel call
        and materialise assignment tuples only for survivors.

        ``chunks`` is ``[(gid_rows, label_rows, build), ...]`` where
        ``gid_rows[i]`` indexes the row's state in ``keys`` and
        ``build(loc)`` produces the assignment tuples for chunk-local row
        indices ``loc``.  All chunks of one node share a label width, so
        the whole node prunes via a single :func:`grouped_nondominated`
        call (state index as the group key) instead of one kernel call per
        state; deferring assignment construction makes the DP's Python
        cost proportional to *kept* labels rather than generated
        candidates — the pruned majority never exists as tuples at all.
        """
        states: dict = {}
        if not chunks:
            return states
        big = chunks[0][1] if len(chunks) == 1 \
            else np.concatenate([c[1] for c in chunks])
        gid = chunks[0][0] if len(chunks) == 1 \
            else np.concatenate([c[0] for c in chunks])
        keep = grouped_nondominated(big, gid, self.epsilon)
        if self._retain > 1 and self._proxy is not None \
                and len(keep) < len(big):
            # widen per state by the proxy top-k; states that kept every
            # row contribute only indices already present
            extra = grouped_topk(gid, self._proxy(big), self._retain)
            keep = np.unique(np.concatenate([keep, extra]))
        self.labels_kept += len(keep)
        self.labels_pruned += len(big) - len(keep)
        # keep is ascending, so one forward walk over the chunks maps it
        # back to chunk-local survivors; rows scatter into their states in
        # global candidate order, preserving first-occurrence semantics
        rows_by: list[list] = [[] for _ in keys]
        asg_by: list[list] = [[] for _ in keys]
        off = 0
        ki = 0
        nkeep = len(keep)
        for cg, carr, build in chunks:
            nc = len(carr)
            lo = ki
            while ki < nkeep and keep[ki] < off + nc:
                ki += 1
            if ki > lo:
                sel = keep[lo:ki]
                for i, a in zip(sel.tolist(), build(sel - off)):
                    g = gid[i]
                    rows_by[g].append(i)
                    asg_by[g].append(a)
            off += nc
        for g, key in enumerate(keys):
            if rows_by[g]:
                states[key] = (big[rows_by[g]], asg_by[g])
        return states

    # -- tree walk ---------------------------------------------------------
    def _run_series(self, node, states: dict) -> dict:
        for child in node.children:
            if not states:
                return states
            if child.kind == "leaf":
                states = self._leaf(child.block, states)
            elif child.kind == "parallel":
                states = self._parallel(child, states)
            else:
                states = self._run_series(child, states)
        return states

    def _leaf(self, b: int, states: dict) -> dict:
        cost, cons = self.cost, self.cons
        P = list(self.preds[b])
        keyid: dict = {}
        keys: list = []
        chunks: list = []
        plan = self._get_plan()
        # per-leaf admissibility, compute times, block-0 input comm and
        # per-pred (R, R) comm/hop/validity tables are state-independent —
        # hoist them out of the state loop and cache them per block
        # (parallel-branch leaves re-run once per fork resource)
        cached = self._leaf_cache.get(b)
        if cached is None:
            rinfo = []
            for r in self.names:
                if not cons.allowed(b, r):
                    continue
                t = cost.segment_time(r, b, b)
                tcap = self.tmax.get(r)
                if tcap is not None and t > tcap:
                    continue
                inp = bneck0 = x0 = 0.0
                if b == 0 and r != cost.source:
                    nb = cost.batch_input_bytes
                    if not cons.transition_allowed(cost.source, r, nb):
                        continue
                    inp = cost.comm(cost.source, r, nb)
                    bneck0 = cost.hop_period(cost.source, r, nb)
                    x0 = nb
                rinfo.append((r, self.ridx[r], t, tcap, inp, bneck0, x0))
            # one packed (R, R, 3) table per pred: [comm, hop, bytes] —
            # comm/hop diagonals are exactly 0.0 (zero-latency infinite-
            # bandwidth self link), bytes is zeroed explicitly, and the
            # validity table absorbs the same-resource case, so the
            # transition needs no same-resource special-casing at all
            pmats = {}
            eye = np.eye(len(plan.names), dtype=bool)
            for u in P:
                nb = float(cost.out_bytes[u])
                commu = plan.latm + nb / plan.bwm
                tbl = np.empty((*commu.shape, 3))
                tbl[:, :, 0] = commu
                tbl[:, :, 1] = commu / cost.batch_size
                tbl[:, :, 2] = np.where(eye, 0.0, nb)
                valid = (plan.ok_pair & (nb <= plan.limitm)) | eye
                pmats[u] = (tbl, valid)
            rnames = [ri[0] for ri in rinfo]
            riv = np.array([ri[1] for ri in rinfo], dtype=np.intp)
            tv = np.array([ri[2] for ri in rinfo])
            tcapv = np.array([np.inf if ri[3] is None else ri[3]
                              for ri in rinfo])
            inpv = np.array([ri[4] for ri in rinfo])
            b0v = np.array([ri[5] for ri in rinfo])
            x0v = np.array([ri[6] for ri in rinfo])
            has_cap = any(ri[3] is not None for ri in rinfo)
            bits = [self._bit(r) for r in rnames]
            fsel = np.array([ai for ai, r in enumerate(rnames)
                             if r in self.fidx], dtype=np.intp)
            fcol = np.array([self.fidx[r] for r in rnames
                             if r in self.fidx], dtype=np.intp)
            cached = self._leaf_cache[b] = (
                rnames, riv, tv, tcapv, inpv, b0v, x0v,
                has_cap, bits, fsel, fcol, pmats)
        (rnames, riv, tv, tcapv, inpv, b0v, x0v,
         has_cap, bits, fsel, fcol, pmats) = cached
        Ra = len(rnames)
        if not Ra:
            return {}
        # every state's open-tail set equals the leaf's pred set, and tail
        # tuples are sorted by node id — so label column j holds pred
        # sorted(P)[j] in *every* state, only its resource varies.  That
        # lets the whole transition run once over the concatenation of all
        # state arrays, with per-pred resource-index row vectors selecting
        # each row's comm/hop/validity from (R, R) lookup tables
        members = list(states.items())
        for (tails, _), _ in members:
            if b > 0 and {u for u, _ in tails} != set(P):
                raise ValueError(
                    f"SP tree out of sync with block edges at block {b}: "
                    f"open tails {sorted(u for u, _ in tails)} vs preds {P}")
        arrs = [a for _, (a, _) in members]
        big = arrs[0] if len(members) == 1 else np.concatenate(arrs)
        counts = [len(a) for a in arrs]
        bounds = np.cumsum([0] + counts)
        n = len(big)
        kP = len(P)
        m = kP
        all_assigns: list = []
        for _, (_, asg) in members:
            all_assigns.extend(asg)
        colofu = {u: j for j, u in enumerate(sorted(P))}
        # one (Ra, n, width) candidate block covers every (state row,
        # target resource) pair at once — the per-resource loop is gone;
        # its C-order ravel (resource-major, row-minor) reproduces the
        # old per-resource chunk order exactly
        if kP:
            ruv = np.empty((kP, n), dtype=np.intp)
            for mi, ((tails, _), _) in enumerate(members):
                for u, ru in tails:
                    ruv[colofu[u], bounds[mi]:bounds[mi + 1]] = self.ridx[ru]
            ok = acc = hop = nbsum = None
            for u in P:
                rj = ruv[colofu[u]][:, None]
                tbl, valid = pmats[u]
                g = tbl[rj, riv[None, :]]            # (n, Ra, 3)
                v = valid[rj, riv[None, :]]
                ok = v if ok is None else ok & v
                term = big[:, colofu[u], None] + g[:, :, 0]
                acc = term if acc is None else np.maximum(acc, term)
                hop = g[:, :, 1] if hop is None \
                    else np.maximum(hop, g[:, :, 1])
                nbsum = g[:, :, 2] if nbsum is None \
                    else nbsum + g[:, :, 2]
            lat0 = acc + tv[None, :]
            if ok.all():
                ok = None
        else:
            ok = None
            lat0 = np.broadcast_to(inpv + tv, (n, Ra))
            hop = np.broadcast_to(b0v, (n, Ra))
            nbsum = np.broadcast_to(x0v, (n, Ra))
        w = self._width(1)
        cand = np.empty((Ra, n, w))
        cand[:, :, 0] = lat0.T
        cand[:, :, 1] = np.maximum(big[None, :, m], hop.T)
        cand[:, :, 2] = big[None, :, m + 1] + nbsum.T
        cand[:, :, 3:] = big[None, :, m + 2:]
        ar = np.arange(Ra)
        cand[ar, :, 3 + riv] += tv[:, None]
        if len(fsel):
            cand[fsel, :, 3 + self.R + fcol] -= 1.0
        admit = ok.T if ok is not None else None
        if has_cap:
            tm = cand[ar, :, 3 + riv] <= tcapv[:, None]
            admit = tm if admit is None else admit & tm
        flat = cand.reshape(Ra * n, w)
        # key ids per (target resource, source state) — integer-only
        mids = np.empty((Ra, len(members)), dtype=np.intp)
        for ai in range(Ra):
            r, bit = rnames[ai], bits[ai]
            for mi, ((tails, mask), _) in enumerate(members):
                key = (((b, r),), mask | bit)
                kid = keyid.get(key)
                if kid is None:
                    kid = keyid[key] = len(keys)
                    keys.append(key)
                mids[ai, mi] = kid
        grow = np.repeat(mids.ravel(), np.tile(counts, Ra))
        if admit is None:
            def build(loc):
                return [all_assigns[i % n] + (rnames[i // n],) for i in loc]

            chunks.append((grow, flat, build))
        else:
            rows = np.flatnonzero(admit.ravel())
            if not len(rows):
                return self._finish(chunks, keys)

            def build(loc, rows=rows):
                out = []
                for i in loc:
                    gi = rows[i]
                    out.append(all_assigns[gi % n] + (rnames[gi // n],))
                return out

            chunks.append((grow[rows], flat[rows], build))
        return self._finish(chunks, keys)

    def _parallel(self, node, states: dict) -> dict:
        cache: dict = {}
        keyid: dict = {}
        keys: list = []
        chunks: list = []
        # prefix states entering with the same fork resource see identical
        # branch sub-solves, so they merge in one fused candidate block
        groups: dict = {}
        for (tails, mask), (arr, assigns) in states.items():
            if len(tails) != 1:
                raise ValueError("parallel node entered with >1 open tail")
            groups.setdefault(tails[0], []).append((mask, arr, assigns))
        for (f, rf), members in groups.items():
            results = []
            for bi, branch in enumerate(node.children):
                ck = (bi, rf)
                if ck not in cache:
                    seed = {(((f, rf),), 0):
                            (np.zeros((1, self._width(1))), [()])}
                    cache[ck] = self._run_series(branch, seed)
                results.append(cache[ck])
            if not all(results):
                continue
            k = len(results)
            # flatten each branch's state dict: one label array, one
            # state-id row vector, one concatenated assignment list
            barr, bgid, bmasks, btails, basg = [], [], [], [], []
            for br in results:
                items = list(br.items())
                arrs_b = [a for _, (a, _) in items]
                barr.append(arrs_b[0] if len(items) == 1
                            else np.concatenate(arrs_b))
                bgid.append(np.repeat(np.arange(len(items)),
                                      [len(a) for a in arrs_b]))
                bmasks.append([bm for (_, bm), _ in items])
                btails.append([bts[0] for (bts, _), _ in items])
                flat_asg: list = []
                for _, (_, asg) in items:
                    flat_asg.extend(asg)
                basg.append(flat_asg)
            src_arrs = [a for _, a, _ in members]
            src = src_arrs[0] if len(members) == 1 \
                else np.concatenate(src_arrs)
            sgid = np.repeat(np.arange(len(members)),
                             [len(a) for a in src_arrs])
            src_asg: list = []
            for _, _, asg in members:
                src_asg.extend(asg)
            Ls = len(src)
            # one meshgrid over (branch rows ..., prefix rows) covers every
            # branch-state combo and every prefix state of the group at once
            grids = np.indices(
                (*[len(ba) for ba in barr], Ls)).reshape(k + 1, -1)
            I0 = grids[k]
            # branch exit blocks (and the kept fork tensor on a direct
            # fork→join edge) fix the tail column order for every combo
            us = [btails[j][0][0] for j in range(k)]
            if node.direct:
                us.append(f)
            order = np.argsort(us, kind="stable")
            mlen = len(us)
            # per-combo state keys, built once per (branch states...,
            # member) tuple in integer space and gathered per row
            S = [len(bm) for bm in bmasks]
            M = len(members)
            lut = np.empty(int(np.prod(S)) * M, dtype=np.intp)
            for ci, combo in enumerate(
                    itertools.product(*[range(s) for s in S])):
                tail_list = [btails[j][combo[j]] for j in range(k)]
                if node.direct:
                    tail_list.append((f, rf))
                new_tails = tuple(tail_list[i] for i in order)
                cmask = 0
                for j in range(k):
                    cmask |= bmasks[j][combo[j]]
                for mi, (mask, _, _) in enumerate(members):
                    key = (new_tails, mask | cmask)
                    kid = keyid.get(key)
                    if kid is None:
                        kid = keyid[key] = len(keys)
                        keys.append(key)
                    lut[ci * M + mi] = kid
            cidx = bgid[0][grids[0]]
            for j in range(1, k):
                cidx = cidx * S[j] + bgid[j][grids[j]]
            grow = lut[cidx * M + sgid[I0]]
            new = np.empty((grids.shape[1], self._width(mlen)))
            lat_cols = [src[I0, 0] + barr[j][grids[j], 0]
                        for j in range(k)]
            if node.direct:
                lat_cols.append(src[I0, 0])
            for dst, srcidx in enumerate(order):
                new[:, dst] = lat_cols[srcidx]
            bm_rel = barr[0][grids[0], 1]
            for j in range(1, k):
                bm_rel = np.maximum(bm_rel, barr[j][grids[j], 1])
            new[:, mlen] = np.maximum(src[I0, 1], bm_rel)
            xfer = barr[0][grids[0], 2]
            for j in range(1, k):
                xfer = xfer + barr[j][grids[j], 2]
            new[:, mlen + 1] = src[I0, 2] + xfer
            tail_block = new[:, mlen + 2:]
            tail_block[:] = src[I0, 3:]
            for j in range(k):
                tail_block += barr[j][grids[j], 3:]
            rows = np.arange(grids.shape[1])
            for rn, cap in self.tmax.items():
                c = mlen + 2 + self.ridx[rn]
                rows = rows[new[rows, c] <= cap]
                if not len(rows):
                    break
            if not len(rows):
                continue
            sub = grids[:, rows]

            def build(loc, sub=sub, src_asg=src_asg, basg=basg, k=k):
                res = []
                for i in loc:
                    a = src_asg[sub[k][i]]
                    for j in range(k):
                        a = a + basg[j][sub[j][i]]
                    res.append(a)
                return res

            chunks.append((grow[rows], new[rows], build))
        return self._finish(chunks, keys)

    # -- entry points ------------------------------------------------------
    def _finals(self) -> tuple[list, np.ndarray]:
        """Feasible complete assignments plus their final label rows.

        The labels let the entry points rank/filter candidates *before*
        pricing them — ``evaluate_assignment`` is the dominant cost of a
        solve once the DP itself is vectorised."""
        self.labels_kept = self.labels_pruned = 0
        if self.infeasible:
            return [], np.empty((0, self._width(1)))
        seed = {((), 0): (np.zeros((1, self._width(0))), [()])}
        states = self._run_series(self.tree, seed)
        finals: list[tuple] = []
        rows: list[np.ndarray] = []
        seen: set = set()
        for (tails, mask), (arr, assigns) in states.items():
            if mask != self.full_mask:
                continue
            ok = np.ones(len(arr), dtype=bool)
            for rn, floor in self.nmin.items():
                c = len(tails) + 2 + self.R + self.fidx[rn]
                ok &= arr[:, c] <= -float(floor)
            for i in np.nonzero(ok)[0]:
                a = assigns[i]
                if a not in seen:
                    seen.add(a)
                    finals.append(a)
                    rows.append(arr[i])
        if not rows:
            return finals, np.empty((0, self._width(1)))
        return finals, np.stack(rows)

    def _finals_for(self, key: tuple) -> tuple[list, np.ndarray]:
        """Memoised :meth:`_finals` — ``key`` must capture every knob that
        steers the DP (retain width, proxy objective); callers set
        ``_retain``/``_proxy`` before calling."""
        hit = self._finals_cache.get(key)
        if hit is not None:
            finals, rows, kept, pruned = hit
            self.labels_kept, self.labels_pruned = kept, pruned
            return finals, rows
        finals, rows = self._finals()
        self._finals_cache[key] = (finals, rows,
                                   self.labels_kept, self.labels_pruned)
        return finals, rows

    # relative safety band for label-based pre-ranking: label columns are
    # built from the same comm/compute floats as evaluate_assignment but
    # parallel merges may sum per-resource times in a different order, so
    # scores can differ in the last ulps.  Any candidate within the band
    # of the provisional cutoff is still priced exactly.
    _SCORE_BAND = 1e-9

    def solve(self, objective: Objective = LATENCY,
              top_n: int = 1) -> list[DagPartitionConfig]:
        """Ranked feasible configs; the winner is exact (see module doc)."""
        self._retain = max(1, int(top_n))
        self._proxy = self._proxy_for(objective)
        finals, rows = self._finals_for(
            ("solve", self._retain, type(objective).__name__,
             getattr(objective, "w_latency", None),
             getattr(objective, "w_transfer_per_mb", None)))
        if len(finals) > 2 * self._retain \
                and type(objective) in (Objective, ThroughputObjective):
            scores = self._proxy(rows)
            order = np.argsort(scores, kind="stable")
            kth = scores[order[min(self._retain, len(order)) - 1]]
            cut = kth + abs(kth) * self._SCORE_BAND + 1e-300
            sel = np.sort(order[scores[order] <= cut])
            configs = [self.cost.evaluate_assignment(finals[i])
                       for i in sel]
        else:
            configs = [self.cost.evaluate_assignment(a) for a in finals]
        return rank(configs, objective, top_n)

    def frontier(self) -> list[DagPartitionConfig]:
        """The exact (ε = 0) non-dominated set over (latency, bottleneck,
        transfer); ε > 0 applies the same ε-dominance as ParetoLattice."""
        self._retain = 0
        self._proxy = None
        finals, rows = self._finals_for(("front",))
        if len(finals) > 8:
            # drop finals some other final beats by more than the band in
            # every objective — they cannot be frontier members; ties and
            # near-ties all survive to exact pricing
            m = rows.shape[1] - 2 - self.R - self.F
            div = np.array([self.cost.replicas_for(n) * self.cost.batch_size
                            for n in self.names])
            pts = np.stack([
                rows[:, :m].max(axis=1),
                np.maximum(rows[:, m],
                           (rows[:, m + 2:m + 2 + self.R] / div).max(1)),
                rows[:, m + 1]], axis=1)
            shr = pts - (np.abs(pts) * self._SCORE_BAND + 1e-300)
            dominated = (pts[:, None, :] <= shr[None, :, :]).all(2).any(0)
            finals = [a for a, d in zip(finals, dominated) if not d]
        configs = [self.cost.evaluate_assignment(a) for a in finals]
        return pareto_frontier(configs)
