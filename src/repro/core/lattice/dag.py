"""DAG cost semantics: pricing resource *assignments* over a block DAG.

The chain cost model prices a sequence of segments; on a block DAG the
unit of decision is an **assignment** — one resource per block, monotone
along edges (a consumer runs on its producer's resource or a strictly
later tier).  The multi-edge generalisation of the paper's cut costs:

* a cut crossed by ``k`` tensors transfers the **sum of the edge bytes**
  (each crossing edge ``u→v`` with ``assignment[u] != assignment[v]`` is
  priced independently: ``comm(r_u, r_v, out_bytes[u])``);
* **latency** composes by critical path — parallel branches placed on
  distinct resources overlap, so
  ``finish(v) = max_u(finish(u) + comm(u→v)) + time(v)``;
* **throughput** keeps the existing bottleneck math: a resource's stage
  period is its *total* assigned compute time over ``replicas × batch``,
  and every crossing edge (plus the input hop) contributes a hop period —
  the steady-state rate is 1 / max over all periods.

On a chain every block has one predecessor, the critical path degenerates
to the plain sum, and these formulas reduce exactly to
:meth:`CostModel.evaluate`.
"""

from __future__ import annotations

from dataclasses import dataclass, field

from .chain import CostModel, PartitionConfig, Segment


@dataclass
class DagPartitionConfig(PartitionConfig):
    """A ranked DAG configuration: an assignment-based operating point.

    ``assignment[i]`` is the resource hosting block ``i``.  ``segments``
    holds the maximal index-contiguous runs of equal resource (so chain
    consumers can still render/describe the config), but the pipelined
    stage model is **per resource**: ``stage_compute_s[k]`` is the total
    compute time of the k-th pipeline resource (tier order), ``replicas``
    aligns with it, and ``stage_comm_s`` holds one per-batch transfer time
    per crossing edge.  ``stage_periods_s`` therefore does not interleave
    compute and comm — it is the flat set of effective periods the
    bottleneck is the max of.
    """

    assignment: tuple[str, ...] = ()
    # resources in pipeline (tier) order, aligned with stage_compute_s /
    # replicas — a resource may host blocks from several segments
    pipeline: tuple[str, ...] = ()
    # crossing block-edges (u, v), aligned with stage_comm_s
    cut_edges: tuple[tuple[int, int], ...] = ()

    @property
    def resources(self) -> tuple[str, ...]:
        return self.pipeline

    @property
    def stage_periods_s(self) -> tuple[float, ...]:
        b = max(1, self.batch_size)
        periods: list[float] = []
        if self.input_comm_s > 0.0:
            periods.append(self.input_comm_s / b)
        for k, t in enumerate(self.stage_compute_s):
            periods.append(t / (self.replica_count(k) * b))
        periods.extend(h / b for h in self.stage_comm_s)
        return tuple(periods)

    def describe(self) -> str:
        groups: dict[str, list[int]] = {}
        for i, r in enumerate(self.assignment):
            groups.setdefault(r, []).append(i)
        parts = [f"{r}: {','.join(map(str, groups[r]))}" for r in self.pipeline]
        op = ""
        if self.batch_size != 1:
            op += f" batch={self.batch_size}"
        if any(r != 1 for r in self.replicas):
            op += " reps=" + "x".join(str(self.replica_count(k))
                                      for k in range(len(self.pipeline)))
        return (f"[{self.model}] " + " | ".join(parts)
                + f"  latency={self.latency_s * 1e3:.1f}ms"
                + f" thpt={self.throughput_rps:.1f}rps"
                + f" transfer={self.transfer_bytes / 1e6:.3f}MB" + op)


@dataclass
class DagCostModel(CostModel):
    """:class:`CostModel` plus the block-edge structure of a
    :class:`~repro.core.graph.BlockDag`.

    ``block_preds[i]`` lists the producer blocks of block ``i`` (empty =
    chain predecessor semantics are *not* implied — an empty
    ``block_preds`` means "this is a chain" and the model behaves exactly
    like its base class).  ``tree`` optionally carries the SP
    decomposition (:class:`~repro.core.graph.SPNode`) the
    :class:`~repro.core.lattice.sp.SPSolver` runs over.
    """

    block_preds: list = field(default_factory=list)
    tree: object = None          # SPNode | None (kept untyped: graph import)

    def __post_init__(self):
        super().__post_init__()
        if self.block_preds and len(self.block_preds) != self.n_blocks:
            raise ValueError(
                f"block_preds has {len(self.block_preds)} entries for "
                f"{self.n_blocks} blocks")
        if not self.block_preds:
            self.block_preds = [[] if i == 0 else [i - 1]
                                for i in range(self.n_blocks)]

    @property
    def is_chain(self) -> bool:
        return all(ps == ([] if i == 0 else [i - 1])
                   for i, ps in enumerate(self.block_preds))

    def edges(self) -> list[tuple[int, int]]:
        return [(u, v) for v, ps in enumerate(self.block_preds) for u in ps]

    def _tier(self, resource: str) -> int:
        for r in self.resources:
            if r.name == resource:
                return r.order
        raise KeyError(resource)

    def evaluate_assignment(self, assignment) -> DagPartitionConfig:
        """Price one complete assignment (resource name per block).

        This is the single cost definition shared by the exhaustive DAG
        oracle and the SP solver — both produce configs through it, which
        is what makes label-for-label agreement meaningful.
        """
        assignment = tuple(assignment)
        B = self.n_blocks
        if len(assignment) != B:
            raise ValueError(
                f"assignment names {len(assignment)} blocks, model has {B}")
        r0 = assignment[0]
        input_comm = 0.0
        xfer = 0.0
        if r0 != self.source:
            input_comm = self.comm(self.source, r0, self.batch_input_bytes)
            xfer += self.batch_input_bytes
        finish = [0.0] * B
        compute: dict[str, float] = {}
        comm_total = 0.0
        stage_comm: list[float] = []
        cut_edges: list[tuple[int, int]] = []
        for v in range(B):
            rv = assignment[v]
            t = self.segment_time(rv, v, v)
            compute[rv] = compute.get(rv, 0.0) + t
            arrive = input_comm if v == 0 else 0.0
            for u in self.block_preds[v]:
                c = 0.0
                if assignment[u] != rv:
                    nb = float(self.out_bytes[u])
                    c = self.comm(assignment[u], rv, nb)
                    comm_total += c
                    xfer += nb
                    stage_comm.append(c)
                    cut_edges.append((u, v))
                arrive = max(arrive, finish[u] + c)
            finish[v] = arrive + t

        # index-contiguous runs of equal resource, for chain-style display
        segs: list[Segment] = []
        for v, r in enumerate(assignment):
            if segs and segs[-1].resource == r:
                segs[-1] = Segment(r, segs[-1].start, v)
            else:
                segs.append(Segment(r, v, v))
        pipeline = sorted(dict.fromkeys(assignment),
                          key=lambda r: (self._tier(r), assignment.index(r)))
        return DagPartitionConfig(
            model=self.db.model, segments=tuple(segs),
            latency_s=finish[B - 1], compute_s=compute, comm_s=comm_total,
            transfer_bytes=xfer, input_comm_s=input_comm,
            stage_compute_s=tuple(compute[r] for r in pipeline),
            stage_comm_s=tuple(stage_comm),
            batch_size=self.batch_size,
            replicas=tuple(self.replicas_for(r) for r in pipeline),
            assignment=assignment, pipeline=tuple(pipeline),
            cut_edges=tuple(cut_edges))
