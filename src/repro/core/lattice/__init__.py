"""Partitioning engines: exact DPs over block chains and block DAGs.

The package splits the former ``core/partition.py`` monolith into

* :mod:`~repro.core.lattice.chain` — the cost model, configuration /
  constraint types, the exhaustive chain oracle, and the three exact
  chain DPs (:class:`PartitionLattice`, :class:`BottleneckLattice`,
  :class:`ParetoLattice`).  A chain is the degenerate series-only case of
  the series-parallel decomposition, so everything here is byte-identical
  to the pre-refactor behaviour.
* :mod:`~repro.core.lattice.dag` — the DAG generalisation of the cost
  model: :class:`DagCostModel` prices *assignments* (one resource per
  block) over a :class:`~repro.core.graph.BlockDag`, with per-edge
  transfer costs, critical-path latency and per-resource pipelined
  bottleneck math; :class:`DagPartitionConfig` is the operating-point
  carrier.
* :mod:`~repro.core.lattice.oracle` — the DAG-aware exhaustive oracle
  (tier-monotone assignment enumeration) and the counted search space the
  query engine's strategy auto-dispatch uses.
* :mod:`~repro.core.lattice.sp` — :class:`SPSolver`, the DP over the
  series-parallel decomposition tree: series composition is the chain
  transition, parallel composition merges per-branch label sets, and the
  in-state constraint handling (``max_resource_time`` / ``min_blocks_on``)
  carries over from the chain lattices.

``core/partition.py`` remains as a thin re-export shim over this package.
"""

from .chain import *                                   # noqa: F401,F403
from .chain import (_LatticeBase, _nondominated_rows,  # noqa: F401
                    _objective_vector)
from .dag import (DagCostModel, DagPartitionConfig)    # noqa: F401
from .oracle import (dag_config_satisfies, dag_search_space,  # noqa: F401
                     enumerate_dag_partitions)
from .sp import SPSolver                               # noqa: F401
