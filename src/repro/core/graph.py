"""Layer-graph IR and partition-point analysis (Scission §II-A, §II-C Step 1-2).

A model is represented as a DAG of :class:`LayerNode` s with a single input
node and a single output node.  Scission's partitioning rules:

* **linear models** — every inter-layer edge is a valid partition point,
  except the edge leaving the input layer (the paper's ``N-2`` rule: a first
  partition holding only the input layer would duplicate the input layer in
  the second partition);
* **branching models** — a cut may never split a parallel region, so layers
  inside a branch are fused into a *block* and treated as a single entity
  (ResNet50: 177 layers -> 23 partition points).

Both rules reduce to one graph property: a valid partition point is a
position in the topological order where exactly **one** edge crosses from the
prefix to the suffix (a "bridge" of the layer DAG).  :func:`fuse_blocks`
linearises the DAG into the block sequence that the benchmarking and
partitioning stages (bench.py / partition.py) operate on.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Callable, Sequence

import jax
import numpy as np


def _nbytes(sds: jax.ShapeDtypeStruct) -> int:
    return int(math.prod(sds.shape)) * np.dtype(sds.dtype).itemsize


@dataclass
class LayerNode:
    """One layer of a DNN.

    ``apply`` consumes the outputs of the node's predecessors (a single array
    for unary layers, a list for merge layers such as residual-add or
    concat).  ``flops`` is an optional analytic estimate used by the
    analytic benchmark provider; the timing and compiled-cost providers do
    not need it.
    """

    name: str
    kind: str
    apply: Callable[..., Any] | None = None
    flops: float = 0.0
    param_bytes: int = 0
    # Optional: compute FLOPs from (input specs, output spec) at trace time
    # (layers whose cost depends on activation shapes, e.g. convs).
    flops_fn: Callable[..., float] | None = None
    # Optional autotuner hooks (kernels/substrate.py): ``kernel`` names the
    # substrate kernel this layer wraps, ``kernel_factory(params)`` rebuilds
    # ``apply`` for a candidate block-size dict, ``kernel_params`` holds the
    # current (default or tuned) block sizes, and ``kernel_defaults`` is the
    # immutable construction-time baseline sweeps are compared against.
    kernel: str | None = None
    kernel_factory: Callable[[dict], Callable[..., Any]] | None = None
    kernel_params: dict = field(default_factory=dict)
    kernel_defaults: dict = field(default_factory=dict)
    # Non-shape configuration baked into ``kernel_factory`` closures
    # (causal/window/softcap, cache sizes, ...) — part of the sweep cache
    # key, so nodes with equal input shapes but different behaviour are
    # tuned separately.
    kernel_options: dict = field(default_factory=dict)
    # Filled in by LayerGraph.trace():
    out_spec: jax.ShapeDtypeStruct | None = None

    @property
    def output_bytes(self) -> int:
        if self.out_spec is None:
            raise ValueError(f"layer {self.name!r} has not been traced")
        return _nbytes(self.out_spec)


class LayerGraph:
    """A single-input single-output DAG of :class:`LayerNode` s.

    Nodes must be added in a valid topological order (standard for layer
    definitions).  Edges point from producer to consumer.
    """

    def __init__(self, name: str):
        self.name = name
        self.nodes: list[LayerNode] = []
        self.preds: list[list[int]] = []
        self.input_spec: jax.ShapeDtypeStruct | None = None

    # -- construction -----------------------------------------------------
    def add(self, node: LayerNode, preds: Sequence[int] = ()) -> int:
        idx = len(self.nodes)
        for p in preds:
            if not 0 <= p < idx:
                raise ValueError(
                    f"node {node.name!r}: predecessor {p} is not an earlier node"
                )
        self.nodes.append(node)
        self.preds.append(list(preds))
        return idx

    def input(self, spec: jax.ShapeDtypeStruct, name: str = "input") -> int:
        if self.nodes:
            raise ValueError("input() must create the first node")
        self.input_spec = spec
        node = LayerNode(name=name, kind="input", apply=None)
        node.out_spec = spec
        return self.add(node)

    # -- basic properties --------------------------------------------------
    @property
    def n_layers(self) -> int:
        return len(self.nodes)

    @property
    def succs(self) -> list[list[int]]:
        out: list[list[int]] = [[] for _ in self.nodes]
        for i, ps in enumerate(self.preds):
            for p in ps:
                out[p].append(i)
        return out

    def validate(self, check_shapes: bool = False) -> None:
        """Run the graph IR checker (repro.analysis.graph_lint) and raise
        :class:`~repro.analysis.graph_lint.GraphLintError` — a
        ``ValueError`` carrying every named-node diagnostic, not just the
        first — when the graph is malformed.  ``check_shapes=True`` also
        verifies each traced node's declared ``out_spec`` against the spec
        recomputed from its predecessors (SCN306)."""
        from ..analysis.diagnostics import errors
        from ..analysis.graph_lint import GraphLintError, lint_graph

        bad = errors(lint_graph(self, check_shapes=check_shapes))
        if bad:
            raise GraphLintError(f"graph {self.name!r} is malformed", bad)

    # -- shape tracing -----------------------------------------------------
    def trace(self) -> None:
        """Fill every node's ``out_spec`` via ``jax.eval_shape`` (no FLOPs,
        no allocation)."""
        self.validate()

        def run(x):
            vals: list[Any] = [x]
            for i in range(1, len(self.nodes)):
                ins = [vals[p] for p in self.preds[i]]
                fn = self.nodes[i].apply
                if fn is None:
                    raise ValueError(f"node {self.nodes[i].name!r} has no apply")
                vals.append(fn(*ins))
            return tuple(vals[1:])

        outs = jax.eval_shape(run, self.input_spec)
        for node, o in zip(self.nodes[1:], outs):
            node.out_spec = o
        for i, node in enumerate(self.nodes):
            if node.flops_fn is not None:
                ins = [self.nodes[p].out_spec for p in self.preds[i]]
                node.flops = float(node.flops_fn(ins, node.out_spec))

    # -- partition points --------------------------------------------------
    def crossing_counts(self) -> list[int]:
        """``counts[i]`` = number of **distinct producers** with edges from
        nodes ``0..i`` to nodes ``i+1..``.

        A cut is valid when exactly one tensor crosses it — i.e. all crossing
        edges emanate from one producer.  A fork (a->b1, a->b2) therefore does
        not invalidate the cut after ``a``: both edges carry ``a``'s output.
        A residual skip (a->add bypassing b) keeps two producers open between
        ``b`` and ``add``, so cuts inside the residual region are invalid —
        exactly the paper's branch-fusion rule.
        """
        succs = self.succs
        last_use = [max(s) if s else i for i, s in enumerate(succs)]
        counts = []
        open_prod = 0
        closing_at: dict[int, int] = {}
        for i in range(len(self.nodes)):
            if last_use[i] > i:
                open_prod += 1
                closing_at[last_use[i]] = closing_at.get(last_use[i], 0) + 1
            open_prod -= closing_at.pop(i, 0)
            counts.append(open_prod)
        return counts

    def partition_points(self) -> list[int]:
        """Valid partition points: positions ``i`` such that cutting between
        node ``i`` and node ``i+1`` transfers exactly one tensor.

        Position 0 (right after the input layer) is excluded per the paper's
        ``N-2`` rule, as is the position after the final layer.  With a
        single open producer at position ``i``, that producer is necessarily
        node ``i`` itself (node ``i`` must feed someone later), so the block
        ending at ``i`` owns the crossing tensor.
        """
        counts = self.crossing_counts()
        return [i for i in range(1, len(self.nodes) - 1) if counts[i] == 1]


@dataclass
class Block:
    """A fused unit: maximal run of layers between consecutive partition
    points.  This is the entity Scission benchmarks and assigns to
    resources."""

    index: int
    node_ids: list[int]
    graph: LayerGraph = field(repr=False)

    @property
    def name(self) -> str:
        ns = [self.graph.nodes[i].name for i in (self.node_ids[0], self.node_ids[-1])]
        return ns[0] if len(self.node_ids) == 1 else f"{ns[0]}..{ns[1]}"

    @property
    def kinds(self) -> list[str]:
        return [self.graph.nodes[i].kind for i in self.node_ids]

    @property
    def flops(self) -> float:
        return sum(self.graph.nodes[i].flops for i in self.node_ids)

    @property
    def param_bytes(self) -> int:
        return sum(self.graph.nodes[i].param_bytes for i in self.node_ids)

    @property
    def output_bytes(self) -> int:
        """Bytes crossing the cut after this block (the paper's layer
        'output data size')."""
        return self.graph.nodes[self.node_ids[-1]].output_bytes

    @property
    def in_spec(self) -> jax.ShapeDtypeStruct:
        first = self.node_ids[0]
        preds = self.graph.preds[first]
        # By construction a block's first node has exactly one predecessor
        # (the single crossing edge of the preceding cut) unless it is the
        # input node.
        src = preds[0] if preds else first
        return self.graph.nodes[src].out_spec  # type: ignore[return-value]

    @property
    def out_spec(self) -> jax.ShapeDtypeStruct:
        return self.graph.nodes[self.node_ids[-1]].out_spec  # type: ignore[return-value]

    def make_callable(self) -> Callable[[Any], Any]:
        """Build the standalone sub-model for this block (paper Step 2: each
        sub-model gets an input layer fed with the previous block's
        output)."""
        g = self.graph
        ids = self.node_ids
        id_set = set(ids)
        first = ids[0]

        def apply(x):
            vals: dict[int, Any] = {}
            entry = g.preds[first][0] if g.preds[first] else first
            vals[entry] = x
            for i in ids:
                if i == first and not g.preds[first]:  # the input node itself
                    vals[i] = x
                    continue
                ins = [vals[p] for p in g.preds[i]]
                for p in g.preds[i]:
                    if p not in id_set and p != entry:
                        raise ValueError(
                            f"block {self.index} node {g.nodes[i].name!r} reads "
                            f"from outside the block (node {p}) — invalid cut")
                vals[i] = g.nodes[i].apply(*ins)
            return vals[ids[-1]]

        return apply


def fuse_blocks(graph: LayerGraph) -> list[Block]:
    """Linearise ``graph`` into its block sequence (Scission Step 1-2).

    Cuts are the valid partition points; each maximal segment between
    consecutive cuts becomes one :class:`Block`.  The number of *inter-block*
    positions, ``len(blocks) - 1``, equals the paper's "partition points"
    column in Table I.
    """
    if not graph.nodes or graph.nodes[-1].out_spec is None:
        graph.trace()               # trace() validates first
    else:
        graph.validate()            # already traced: still well-formedness-check
    points = graph.partition_points()
    blocks: list[Block] = []
    start = 0
    for bi, p in enumerate([*points, len(graph.nodes) - 1]):
        blocks.append(Block(index=bi, node_ids=list(range(start, p + 1)), graph=graph))
        start = p + 1
    return blocks


# ---------------------------------------------------------------------------
# Convenience constructors for linear graphs (the common case for tests and
# the LM-family architectures, whose residual stream is linear at block level)
# ---------------------------------------------------------------------------

def linear_graph(name: str, input_spec: jax.ShapeDtypeStruct,
                 layers: Sequence[LayerNode]) -> LayerGraph:
    g = LayerGraph(name)
    prev = g.input(input_spec)
    for node in layers:
        prev = g.add(node, preds=[prev])
    g.trace()
    return g
