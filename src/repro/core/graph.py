"""Layer-graph IR and partition-point analysis (Scission §II-A, §II-C Step 1-2).

A model is represented as a DAG of :class:`LayerNode` s with a single input
node and a single output node.  Scission's partitioning rules:

* **linear models** — every inter-layer edge is a valid partition point,
  except the edge leaving the input layer (the paper's ``N-2`` rule: a first
  partition holding only the input layer would duplicate the input layer in
  the second partition);
* **branching models** — a cut may never split a parallel region, so layers
  inside a branch are fused into a *block* and treated as a single entity
  (ResNet50: 177 layers -> 23 partition points).

Both rules reduce to one graph property: a valid partition point is a
position in the topological order where exactly **one** edge crosses from the
prefix to the suffix (a "bridge" of the layer DAG).  :func:`fuse_blocks`
linearises the DAG into the block sequence that the benchmarking and
partitioning stages (bench.py / partition.py) operate on.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Callable, Sequence

import jax
import numpy as np


def _nbytes(sds: jax.ShapeDtypeStruct) -> int:
    return int(math.prod(sds.shape)) * np.dtype(sds.dtype).itemsize


@dataclass
class LayerNode:
    """One layer of a DNN.

    ``apply`` consumes the outputs of the node's predecessors (a single array
    for unary layers, a list for merge layers such as residual-add or
    concat).  ``flops`` is an optional analytic estimate used by the
    analytic benchmark provider; the timing and compiled-cost providers do
    not need it.
    """

    name: str
    kind: str
    apply: Callable[..., Any] | None = None
    flops: float = 0.0
    param_bytes: int = 0
    # Optional: compute FLOPs from (input specs, output spec) at trace time
    # (layers whose cost depends on activation shapes, e.g. convs).
    flops_fn: Callable[..., float] | None = None
    # Optional autotuner hooks (kernels/substrate.py): ``kernel`` names the
    # substrate kernel this layer wraps, ``kernel_factory(params)`` rebuilds
    # ``apply`` for a candidate block-size dict, ``kernel_params`` holds the
    # current (default or tuned) block sizes, and ``kernel_defaults`` is the
    # immutable construction-time baseline sweeps are compared against.
    kernel: str | None = None
    kernel_factory: Callable[[dict], Callable[..., Any]] | None = None
    kernel_params: dict = field(default_factory=dict)
    kernel_defaults: dict = field(default_factory=dict)
    # Non-shape configuration baked into ``kernel_factory`` closures
    # (causal/window/softcap, cache sizes, ...) — part of the sweep cache
    # key, so nodes with equal input shapes but different behaviour are
    # tuned separately.
    kernel_options: dict = field(default_factory=dict)
    # Filled in by LayerGraph.trace():
    out_spec: jax.ShapeDtypeStruct | None = None

    @property
    def output_bytes(self) -> int:
        if self.out_spec is None:
            raise ValueError(f"layer {self.name!r} has not been traced")
        return _nbytes(self.out_spec)


class LayerGraph:
    """A single-input single-output DAG of :class:`LayerNode` s.

    Nodes must be added in a valid topological order (standard for layer
    definitions).  Edges point from producer to consumer.
    """

    def __init__(self, name: str):
        self.name = name
        self.nodes: list[LayerNode] = []
        self.preds: list[list[int]] = []
        self.input_spec: jax.ShapeDtypeStruct | None = None

    # -- construction -----------------------------------------------------
    def add(self, node: LayerNode, preds: Sequence[int] = ()) -> int:
        idx = len(self.nodes)
        for p in preds:
            if not 0 <= p < idx:
                raise ValueError(
                    f"node {node.name!r}: predecessor {p} is not an earlier node"
                )
        self.nodes.append(node)
        self.preds.append(list(preds))
        return idx

    def input(self, spec: jax.ShapeDtypeStruct, name: str = "input") -> int:
        if self.nodes:
            raise ValueError("input() must create the first node")
        self.input_spec = spec
        node = LayerNode(name=name, kind="input", apply=None)
        node.out_spec = spec
        return self.add(node)

    # -- basic properties --------------------------------------------------
    @property
    def n_layers(self) -> int:
        return len(self.nodes)

    @property
    def succs(self) -> list[list[int]]:
        out: list[list[int]] = [[] for _ in self.nodes]
        for i, ps in enumerate(self.preds):
            for p in ps:
                out[p].append(i)
        return out

    def validate(self, check_shapes: bool = False) -> None:
        """Run the graph IR checker (repro.analysis.graph_lint) and raise
        :class:`~repro.analysis.graph_lint.GraphLintError` — a
        ``ValueError`` carrying every named-node diagnostic, not just the
        first — when the graph is malformed.  ``check_shapes=True`` also
        verifies each traced node's declared ``out_spec`` against the spec
        recomputed from its predecessors (SCN306)."""
        from ..analysis.diagnostics import errors
        from ..analysis.graph_lint import GraphLintError, lint_graph

        bad = errors(lint_graph(self, check_shapes=check_shapes))
        if bad:
            raise GraphLintError(f"graph {self.name!r} is malformed", bad)

    # -- shape tracing -----------------------------------------------------
    def trace(self) -> None:
        """Fill every node's ``out_spec`` via ``jax.eval_shape`` (no FLOPs,
        no allocation)."""
        self.validate()

        def run(x):
            vals: list[Any] = [x]
            for i in range(1, len(self.nodes)):
                ins = [vals[p] for p in self.preds[i]]
                fn = self.nodes[i].apply
                if fn is None:
                    raise ValueError(f"node {self.nodes[i].name!r} has no apply")
                vals.append(fn(*ins))
            return tuple(vals[1:])

        outs = jax.eval_shape(run, self.input_spec)
        for node, o in zip(self.nodes[1:], outs):
            node.out_spec = o
        for i, node in enumerate(self.nodes):
            if node.flops_fn is not None:
                ins = [self.nodes[p].out_spec for p in self.preds[i]]
                node.flops = float(node.flops_fn(ins, node.out_spec))

    # -- partition points --------------------------------------------------
    def crossing_counts(self) -> list[int]:
        """``counts[i]`` = number of **distinct producers** with edges from
        nodes ``0..i`` to nodes ``i+1..``.

        A cut is valid when exactly one tensor crosses it — i.e. all crossing
        edges emanate from one producer.  A fork (a->b1, a->b2) therefore does
        not invalidate the cut after ``a``: both edges carry ``a``'s output.
        A residual skip (a->add bypassing b) keeps two producers open between
        ``b`` and ``add``, so cuts inside the residual region are invalid —
        exactly the paper's branch-fusion rule.
        """
        succs = self.succs
        last_use = [max(s) if s else i for i, s in enumerate(succs)]
        counts = []
        open_prod = 0
        closing_at: dict[int, int] = {}
        for i in range(len(self.nodes)):
            if last_use[i] > i:
                open_prod += 1
                closing_at[last_use[i]] = closing_at.get(last_use[i], 0) + 1
            open_prod -= closing_at.pop(i, 0)
            counts.append(open_prod)
        return counts

    def partition_points(self) -> list[int]:
        """Valid partition points: positions ``i`` such that cutting between
        node ``i`` and node ``i+1`` transfers exactly one tensor.

        Position 0 (right after the input layer) is excluded per the paper's
        ``N-2`` rule, as is the position after the final layer.  With a
        single open producer at position ``i``, that producer is necessarily
        node ``i`` itself (node ``i`` must feed someone later), so the block
        ending at ``i`` owns the crossing tensor.
        """
        counts = self.crossing_counts()
        return [i for i in range(1, len(self.nodes) - 1) if counts[i] == 1]


@dataclass
class Block:
    """A fused unit: maximal run of layers between consecutive partition
    points.  This is the entity Scission benchmarks and assigns to
    resources."""

    index: int
    node_ids: list[int]
    graph: LayerGraph = field(repr=False)

    @property
    def name(self) -> str:
        ns = [self.graph.nodes[i].name for i in (self.node_ids[0], self.node_ids[-1])]
        return ns[0] if len(self.node_ids) == 1 else f"{ns[0]}..{ns[1]}"

    @property
    def kinds(self) -> list[str]:
        return [self.graph.nodes[i].kind for i in self.node_ids]

    @property
    def flops(self) -> float:
        return sum(self.graph.nodes[i].flops for i in self.node_ids)

    @property
    def param_bytes(self) -> int:
        return sum(self.graph.nodes[i].param_bytes for i in self.node_ids)

    @property
    def output_bytes(self) -> int:
        """Bytes crossing the cut after this block (the paper's layer
        'output data size')."""
        return self.graph.nodes[self.node_ids[-1]].output_bytes

    @property
    def entry_nodes(self) -> list[int]:
        """External producer node ids feeding this block, in first-use
        order.  Chain blocks have exactly one (the previous cut's single
        crossing tensor); a DAG join block fused by :func:`fuse_block_dag`
        has one per incoming branch.  The input block has none."""
        ids = set(self.node_ids)
        ext: list[int] = []
        for i in self.node_ids:
            for p in self.graph.preds[i]:
                if p not in ids and p not in ext:
                    ext.append(p)
        return ext

    @property
    def in_specs(self) -> list[jax.ShapeDtypeStruct]:
        """One input spec per entry tensor (the multi-edge generalisation
        of :attr:`in_spec`; equal to ``[in_spec]`` for chain blocks)."""
        ext = self.entry_nodes
        if not ext:
            first = self.node_ids[0]
            return [self.graph.nodes[first].out_spec]  # the input block
        return [self.graph.nodes[p].out_spec for p in ext]

    @property
    def in_spec(self) -> jax.ShapeDtypeStruct:
        first = self.node_ids[0]
        preds = self.graph.preds[first]
        ext = self.entry_nodes
        if len(ext) > 1:
            raise ValueError(
                f"block {self.index} ({self.name}) has {len(ext)} entry "
                "tensors; use in_specs for DAG blocks")
        # By construction a chain block's first node has exactly one
        # predecessor (the single crossing edge of the preceding cut)
        # unless it is the input node.
        src = preds[0] if preds else first
        return self.graph.nodes[src].out_spec  # type: ignore[return-value]

    @property
    def out_spec(self) -> jax.ShapeDtypeStruct:
        return self.graph.nodes[self.node_ids[-1]].out_spec  # type: ignore[return-value]

    def make_callable(self) -> Callable[..., Any]:
        """Build the standalone sub-model for this block (paper Step 2: each
        sub-model gets an input layer fed with the previous block's
        output).  The callable takes one argument per entry tensor, in
        :attr:`entry_nodes` order — a single argument for every chain
        block, so existing single-tensor call sites are unchanged."""
        g = self.graph
        ids = self.node_ids
        id_set = set(ids)
        entries = self.entry_nodes

        def apply(*xs):
            want = max(1, len(entries))
            if len(xs) != want:
                raise ValueError(
                    f"block {self.index} ({self.name}) takes {want} input "
                    f"tensor(s), got {len(xs)}")
            vals: dict[int, Any] = dict(zip(entries, xs))
            for i in ids:
                if not g.preds[i]:            # the input node itself
                    vals[i] = xs[0]
                    continue
                ins = []
                for p in g.preds[i]:
                    if p not in id_set and p not in vals:
                        raise ValueError(
                            f"block {self.index} node {g.nodes[i].name!r} reads "
                            f"from outside the block (node {p}) — invalid cut")
                    ins.append(vals[p])
                vals[i] = g.nodes[i].apply(*ins)
            return vals[ids[-1]]

        return apply


@dataclass
class SPNode:
    """One node of the series-parallel decomposition tree.

    * ``leaf`` — a single :class:`Block` (``block`` is its index).
    * ``series`` — ``children`` executed in order; each child's entry tensor
      is the previous child's exit tensor.
    * ``parallel`` — ``children`` are the branch subtrees (each a ``series``
      node), all fed by the preceding sibling's exit tensor (the fork).
      ``direct=True`` records a fork→join edge alongside the branches
      (the residual-skip case).  The join block is the *next* leaf in the
      enclosing series — a parallel node never owns its join, so nested
      forks that share a join stay representable.
    """

    kind: str                     # 'leaf' | 'series' | 'parallel'
    block: int = -1               # leaf only
    children: list["SPNode"] = field(default_factory=list)
    direct: bool = False          # parallel only: fork→join edge exists

    def leaves(self) -> list[int]:
        if self.kind == "leaf":
            return [self.block]
        return [b for c in self.children for b in c.leaves()]


class BlockDag(list):
    """A block sequence plus its edge structure and SP decomposition tree.

    Subclasses ``list`` so every chain-era consumer (indexing, ``len``,
    iteration over :class:`Block` s) keeps working unchanged; DAG-aware
    consumers read ``preds`` (block-level edges), ``tree`` (the
    :class:`SPNode` decomposition) and the fallback bookkeeping:
    ``parallel_regions`` (node-id groups that chain fusing would collapse)
    and ``collapsed`` (node-id groups that are not series-parallel and were
    linearised into a single block — the diagnosed fallback).
    """

    def __init__(self, blocks: Sequence[Block], preds: list[list[int]] | None = None,
                 tree: SPNode | None = None,
                 parallel_regions: Sequence[Sequence[int]] = (),
                 collapsed: Sequence[Sequence[int]] = ()):
        super().__init__(blocks)
        n = len(self)
        self.preds: list[list[int]] = (
            [list(ps) for ps in preds] if preds is not None
            else [[] if i == 0 else [i - 1] for i in range(n)])
        self.tree: SPNode = tree if tree is not None else SPNode(
            "series", children=[SPNode("leaf", block=i) for i in range(n)])
        self.parallel_regions = [list(r) for r in parallel_regions]
        self.collapsed = [list(r) for r in collapsed]

    @property
    def succs(self) -> list[list[int]]:
        out: list[list[int]] = [[] for _ in self]
        for i, ps in enumerate(self.preds):
            for p in ps:
                out[p].append(i)
        return out

    @property
    def is_chain(self) -> bool:
        return all(ps == ([] if i == 0 else [i - 1])
                   for i, ps in enumerate(self.preds))

    def edges(self) -> list[tuple[int, int]]:
        """Block-level edges ``(producer, consumer)`` with producer < consumer."""
        return [(p, i) for i, ps in enumerate(self.preds) for p in ps]


def fuse_blocks(graph: LayerGraph) -> BlockDag:
    """Linearise ``graph`` into its block sequence (Scission Step 1-2).

    Cuts are the valid partition points; each maximal segment between
    consecutive cuts becomes one :class:`Block`.  The number of *inter-block*
    positions, ``len(blocks) - 1``, equals the paper's "partition points"
    column in Table I.

    Returns a :class:`BlockDag` in *chain* form (``preds`` is the linear
    chain) — parallel regions are fused whole, exactly as in the paper.
    Use :func:`fuse_block_dag` to keep branch structure instead.
    """
    if not graph.nodes or graph.nodes[-1].out_spec is None:
        graph.trace()               # trace() validates first
    else:
        graph.validate()            # already traced: still well-formedness-check
    points = graph.partition_points()
    blocks: list[Block] = []
    start = 0
    for bi, p in enumerate([*points, len(graph.nodes) - 1]):
        blocks.append(Block(index=bi, node_ids=list(range(start, p + 1)), graph=graph))
        start = p + 1
    return BlockDag(blocks)


# ---------------------------------------------------------------------------
# Series-parallel decomposition (the DAG-general fusing pass)
# ---------------------------------------------------------------------------

def _undirected_components(nodes: Sequence[int], preds: list[list[int]],
                           succs: list[list[int]]) -> list[list[int]]:
    """Connected components of the induced subgraph, each in topo order,
    ordered by first node."""
    member = set(nodes)
    seen: set[int] = set()
    comps: list[list[int]] = []
    for n in nodes:
        if n in seen:
            continue
        stack, comp = [n], []
        while stack:
            u = stack.pop()
            if u in seen or u not in member:
                continue
            seen.add(u)
            comp.append(u)
            stack.extend(p for p in preds[u] if p in member)
            stack.extend(s for s in succs[u] if s in member)
        comps.append(sorted(comp))
    return comps


def _sp_parts(preds: list[list[int]], succs: list[list[int]],
              nodes: list[int], entry: int | None, top: bool,
              parallel_regions: list[list[int]],
              collapsed: list[list[int]]) -> list[tuple]:
    """Decompose a two-terminal region into series parts.

    ``nodes`` is the region in topo order; ``entry`` is the graph node whose
    output tensor feeds the region (``None`` only for the whole graph, whose
    first node is the input layer).  Each returned part is either
    ``('leaf', [node_ids])`` or ``('par', [branch_parts, ...], direct)``
    where every ``branch_parts`` is itself a part list and ``direct`` marks
    a fork→join edge.  A ``'par'`` part is always followed by the leaf
    holding its join node.

    Cuts are positions where exactly one producer (counting the entry
    tensor) stays open — the same crossing-count rule as
    :meth:`LayerGraph.partition_points`, applied region-locally, with nodes
    feeding *outside* the region held open to the region's end.  A region
    that cannot be split series-wise is examined as a fork-join: the
    undirected components of its interior become parallel branches when
    each has a single exit; otherwise the region is recorded in
    ``collapsed`` and fused into one block (the non-SP fallback).
    """
    member = set(nodes)
    m = len(nodes)
    pos = {n: k for k, n in enumerate(nodes)}
    open_until = list(range(m))
    entry_until = -1
    for k, nd in enumerate(nodes):
        for p in preds[nd]:
            if p in pos:
                if open_until[pos[p]] < k:
                    open_until[pos[p]] = k
            else:
                entry_until = k
        if any(s not in member for s in succs[nd]):
            open_until[k] = m       # feeds the region's consumer: open to end
    lo = 1 if top else 0            # the paper's N-2 rule, top level only
    cuts = [k for k in range(lo, m - 1)
            if sum(1 for j in range(k + 1) if open_until[j] > k)
            + (1 if entry_until > k else 0) == 1]

    parts: list[tuple] = []
    prev_exit = entry
    start = 0
    for cut in [*cuts, m - 1]:
        seg = nodes[start:cut + 1]
        start = cut + 1
        if len(seg) == 1:
            parts.append(("leaf", seg))
        else:
            parts.extend(_fork_join(preds, succs, seg, prev_exit,
                                    parallel_regions, collapsed))
        prev_exit = seg[-1]
    return parts


def _fork_join(preds: list[list[int]], succs: list[list[int]],
               seg: list[int], entry: int | None,
               parallel_regions: list[list[int]],
               collapsed: list[list[int]]) -> list[tuple]:
    """Decompose one un-splittable multi-node segment as fork → branches →
    join, or fall back to a single fused leaf (recorded in ``collapsed``)."""
    if entry is None:
        # Whole-graph head segment: the input node is the fork.  Peel it,
        # decompose the rest, and re-merge it into a leading leaf so pure
        # chains fuse exactly as fuse_blocks does.
        head, rest = seg[0], seg[1:]
        sub = _sp_parts(preds, succs, rest, head, False,
                        parallel_regions, collapsed)
        if sub and sub[0][0] == "leaf":
            sub[0] = ("leaf", [head, *sub[0][1]])
        else:
            sub.insert(0, ("leaf", [head]))
        return sub

    join, interior = seg[-1], seg[:-1]
    comps = _undirected_components(interior, preds, succs)
    direct = entry in preds[join]
    ok = len(comps) >= 2 or direct
    for comp in comps:
        cs = set(comp)
        exits = [n for n in comp if any(s not in cs for s in succs[n])]
        if exits != [comp[-1]]:
            ok = False              # multi-exit branch: one block per branch
            break                   # would need several output tensors
    if not ok:
        collapsed.append(list(seg))
        return [("leaf", list(seg))]
    parallel_regions.append([n for c in comps for n in c])
    branches = [_sp_parts(preds, succs, comp, entry, False,
                          parallel_regions, collapsed)
                for comp in comps]
    return [("par", branches, direct), ("leaf", [join])]


def _build_sp(parts: list[tuple], graph: LayerGraph, blocks: list[Block],
              bpreds: list[list[int]], owner: dict[int, int]) -> list[SPNode]:
    children: list[SPNode] = []
    for part in parts:
        if part[0] == "leaf":
            ids = part[1]
            bid = len(blocks)
            blocks.append(Block(index=bid, node_ids=list(ids), graph=graph))
            id_set = set(ids)
            ext: list[int] = []
            for i in ids:
                for p in graph.preds[i]:
                    if p not in id_set and owner[p] not in ext:
                        ext.append(owner[p])
            bpreds.append(ext)
            for i in ids:
                owner[i] = bid
            children.append(SPNode("leaf", block=bid))
        else:                        # ('par', branches, direct)
            branches = [SPNode("series",
                               children=_build_sp(bp, graph, blocks, bpreds, owner))
                        for bp in part[1]]
            children.append(SPNode("parallel", children=branches,
                                   direct=part[2]))
    return children


def fuse_block_dag(graph: LayerGraph) -> BlockDag:
    """Fuse ``graph`` into a block **DAG** via series-parallel decomposition.

    Where :func:`fuse_blocks` collapses every parallel region into one
    block, this pass keeps the branch structure: the fork, each branch's
    blocks, and the join become separate blocks connected by multi-tensor
    block edges, and the returned :class:`BlockDag.tree` records the
    series/parallel recursion the partitioning DP runs over.  On a linear
    graph the result is block-for-block identical to :func:`fuse_blocks`
    (chain = trivial decomposition).  Regions that are not series-parallel
    (or whose branches need more than one output tensor) are fused into a
    single block and listed in ``BlockDag.collapsed`` — the diagnosed
    linearization fallback surfaced by ``scission-lint`` as SCN309.
    """
    if not graph.nodes or graph.nodes[-1].out_spec is None:
        graph.trace()
    else:
        graph.validate()
    parallel_regions: list[list[int]] = []
    collapsed: list[list[int]] = []
    parts = _sp_parts(graph.preds, graph.succs, list(range(len(graph.nodes))),
                      None, True, parallel_regions, collapsed)
    blocks: list[Block] = []
    bpreds: list[list[int]] = []
    children = _build_sp(parts, graph, blocks, bpreds, {})
    return BlockDag(blocks, preds=bpreds,
                    tree=SPNode("series", children=children),
                    parallel_regions=parallel_regions, collapsed=collapsed)


def sp_summary(graph: LayerGraph) -> tuple[list[list[int]], list[list[int]]]:
    """Topology-only SP analysis: ``(parallel_regions, collapsed_regions)``
    as node-id groups, without tracing the graph.  Used by the graph
    linter (SCN309/SCN310)."""
    parallel_regions: list[list[int]] = []
    collapsed: list[list[int]] = []
    if len(graph.nodes) > 1:
        _sp_parts(graph.preds, graph.succs, list(range(len(graph.nodes))),
                  None, True, parallel_regions, collapsed)
    return parallel_regions, collapsed


# ---------------------------------------------------------------------------
# Convenience constructors for linear graphs (the common case for tests and
# the LM-family architectures, whose residual stream is linear at block level)
# ---------------------------------------------------------------------------

def linear_graph(name: str, input_spec: jax.ShapeDtypeStruct,
                 layers: Sequence[LayerNode]) -> LayerGraph:
    g = LayerGraph(name)
    prev = g.input(input_spec)
    for node in layers:
        prev = g.add(node, preds=[prev])
    g.trace()
    return g
