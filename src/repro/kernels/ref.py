"""Pure-jnp oracles for the Pallas kernels.

These are deliberately naive (full score materialisation, direct scans) —
they define correctness, not performance.  Kernel tests sweep shapes/dtypes
and assert_allclose against these.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal=True, window=None, softcap=None):
    """q: (B, Sq, H, hd); k, v: (B, Sk, Hk, hd) with H % Hk == 0."""
    B, Sq, H, hd = q.shape
    Sk, Hk = k.shape[1], k.shape[2]
    G = H // Hk
    scale = 1.0 / math.sqrt(hd)
    qf = q.astype(jnp.float32).reshape(B, Sq, Hk, G, hd)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, kf) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= qpos - kpos < window
    s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, vf)
    return o.reshape(B, Sq, H, hd).astype(q.dtype)


def decode_attention_ref(q, k, v, lengths, *, softcap=None):
    """Single-token decode over a KV cache.

    q: (B, H, hd); k, v: (B, Smax, Hk, hd); lengths: (B,) valid entries.
    """
    B, H, hd = q.shape
    Smax, Hk = k.shape[1], k.shape[2]
    G = H // Hk
    scale = 1.0 / math.sqrt(hd)
    qf = q.astype(jnp.float32).reshape(B, Hk, G, hd)
    s = jnp.einsum("bhgd,bkhd->bhgk", qf, k.astype(jnp.float32)) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    mask = jnp.arange(Smax)[None, :] < lengths[:, None]      # (B, Smax)
    s = jnp.where(mask[:, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgk,bkhd->bhgd", p, v.astype(jnp.float32))
    return o.reshape(B, H, hd).astype(q.dtype)


def ssd_ref(x, log_a, b, c, initial_state=None):
    """Sequential (step-by-step) SSD reference.

    x: (B, S, H, P); log_a: (B, S, H); b, c: (B, S, H, N).
    Returns (y: (B, S, H, P), final_state: (B, H, N, P)).
    """
    B, S, H, P = x.shape
    N = b.shape[-1]
    xf = x.astype(jnp.float32)
    af = log_a.astype(jnp.float32)
    bf = b.astype(jnp.float32)
    cf = c.astype(jnp.float32)
    state = (jnp.zeros((B, H, N, P), jnp.float32) if initial_state is None
             else initial_state.astype(jnp.float32))

    def step(st, t):
        xt, at, bt, ct = t
        st = st * jnp.exp(at)[..., None, None] + \
            jnp.einsum("bhn,bhp->bhnp", bt, xt)
        y = jnp.einsum("bhn,bhnp->bhp", ct, st)
        return st, y

    state, ys = jax.lax.scan(
        step, state,
        (xf.transpose(1, 0, 2, 3), af.transpose(1, 0, 2),
         bf.transpose(1, 0, 2, 3), cf.transpose(1, 0, 2, 3)))
    return ys.transpose(1, 0, 2, 3).astype(x.dtype), state
