from .ops import decode_attention, flash_attention, ssd_scan

__all__ = ["decode_attention", "flash_attention", "ssd_scan"]
