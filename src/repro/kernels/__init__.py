from .ops import (decode_attention, decode_attention_node, flash_attention,
                  flash_attention_node, ssd_scan, ssd_scan_node)
from .substrate import (DEFAULT_CANDIDATES, DEFAULT_PARAMS, KernelAutotuner,
                        TuneRecord, default_interpret,
                        normalize_cost_analysis, tpu_compiler_params)

__all__ = [
    "decode_attention", "flash_attention", "ssd_scan",
    "decode_attention_node", "flash_attention_node", "ssd_scan_node",
    "DEFAULT_CANDIDATES", "DEFAULT_PARAMS", "KernelAutotuner", "TuneRecord",
    "default_interpret", "normalize_cost_analysis", "tpu_compiler_params",
]
