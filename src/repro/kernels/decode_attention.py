"""Decode attention (single new token vs. a long KV cache) Pallas TPU kernel.

Flash-decoding adaptation for TPU: the KV sequence is the *sequential* grid
dimension; each step loads a (bk × hd) cache tile into VMEM, updates the
online-softmax accumulators for every (batch, head) pair, and masks tile
entries beyond the valid cache length.  The query row for a head stays
resident in VMEM across all KV tiles, so HBM traffic is exactly one pass
over the cache — the decode roofline's memory term.  Across chips the cache
is sequence-sharded and XLA combines per-shard partial softmaxes (see
models/layers.py); this kernel is the per-shard worker.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .substrate import pad_axis_to, round_up, tpu_compiler_params

NEG_INF = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, softcap: float | None, bk: int, nk: int, G: int):
    b = pl.program_id(0)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = len_ref[b]
    k_start = ik * bk

    @pl.when(k_start < length)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)                # (G, hd)
        k = k_ref[0, :, 0, :].astype(jnp.float32)          # (bk, hd)
        v = v_ref[0, :, 0, :].astype(jnp.float32)          # (bk, hd)

        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)            # (G, bk)

        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)
        s = jnp.where(kpos < length, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("softcap", "block_k",
                                             "interpret"))
def decode_attention(q, k, v, lengths, *, softcap=None, block_k=256,
                     interpret=False):
    """q: (B, H, hd); k, v: (B, Smax, Hk, hd); lengths: (B,) int32.

    Returns (B, H, hd).  All q heads of one kv group are processed together
    as the (G, hd) left operand of each MXU matmul.

    ``Smax`` need not divide ``block_k``: the cache is zero-padded to the
    next block boundary; padded positions sit past every ``lengths[b]`` and
    are masked by the existing ``kpos < length`` guard.
    """
    B, H, hd = q.shape
    Smax, Hk = k.shape[1], k.shape[2]
    G = H // Hk
    bk = min(block_k, Smax)
    Smax_p = round_up(Smax, bk)
    k = pad_axis_to(k, 1, Smax_p)
    v = pad_axis_to(v, 1, Smax_p)
    nk = Smax_p // bk
    scale = 1.0 / math.sqrt(hd)

    qg = q.reshape(B, Hk, G, hd)
    grid = (B, Hk, nk)
    kernel = functools.partial(_kernel, scale=scale, softcap=softcap, bk=bk,
                               nk=nk, G=G)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),          # lengths (B,)
            pl.BlockSpec((1, 1, G, hd), lambda b, h, ik: (b, h, 0, 0)),
            pl.BlockSpec((1, bk, 1, hd), lambda b, h, ik: (b, ik, h, 0)),
            pl.BlockSpec((1, bk, 1, hd), lambda b, h, ik: (b, ik, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd), lambda b, h, ik: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hk, G, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(lengths, qg, k, v)
    return out.reshape(B, H, hd)
