"""Version-portable substrate under the Pallas TPU kernels.

All three kernels (``flash_attention``, ``decode_attention``, ``ssd_scan``)
and the measurement layer (``core/bench.py``, ``launch/dryrun.py``) route
through this module instead of touching version-sensitive JAX surfaces
directly.  It provides:

* **Compiler-params compat shim** — JAX renamed
  ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams`` across releases;
  :func:`tpu_compiler_params` resolves whichever the installed JAX exposes
  (and silently drops keyword arguments the resolved class does not accept),
  so the same kernel source compiles on both old and new JAX.
* **Cost-analysis normalizer** — ``jit(...).lower().compile()
  .cost_analysis()`` returns a plain dict on some JAX versions and a
  list-of-dicts (one per computation) on others;
  :func:`normalize_cost_analysis` collapses either form into one flat
  ``{metric: float}`` dict so providers can always call ``.get``.
* **Pad-and-mask helpers** — :func:`round_up` / :func:`pad_axis_to` let the
  kernels accept sequence lengths that are not multiples of the block size:
  inputs are zero-padded up to the next block boundary, padded key/value
  positions are masked to ``-inf`` inside the kernel, and padded query/time
  rows are sliced off the output.
* **Block-size autotuner** — :class:`KernelAutotuner` sweeps
  ``(block_q, block_k, chunk)`` candidates per (kernel, shape, resource),
  caches the winner, and rewrites tunable graph nodes in place so the
  benchmark providers measure *tuned* kernel timings.  Winners are carried
  into ``BenchmarkDB`` records (``BlockBenchmark.tuned_params``), which is
  what the partition/query engines consume.
"""

from __future__ import annotations

import json
import math
import time
from dataclasses import dataclass, field, asdict
from typing import Any, Callable

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# compiler-params compat shim
# ---------------------------------------------------------------------------

_COMPILER_PARAMS_NAMES = ("CompilerParams", "TPUCompilerParams")


def resolve_compiler_params_cls():
    """Return the TPU compiler-params class of the installed JAX, or None.

    Newer JAX exposes ``pltpu.CompilerParams``; older releases call it
    ``pltpu.TPUCompilerParams``.  Returns ``None`` when the Pallas TPU
    extension is unavailable entirely (pure-CPU builds) — ``pallas_call``
    accepts ``compiler_params=None``.
    """
    try:
        from jax.experimental.pallas import tpu as pltpu
    except ImportError:  # pragma: no cover - pallas always present here
        return None
    for name in _COMPILER_PARAMS_NAMES:
        cls = getattr(pltpu, name, None)
        if cls is not None:
            return cls
    return None


def _accepted_fields(cls) -> set[str]:
    fields = getattr(cls, "__dataclass_fields__", None)
    if fields:
        return set(fields)
    init = getattr(cls, "__init__", None)
    code = getattr(init, "__code__", None)
    if code is not None:
        return set(code.co_varnames[1:code.co_argcount + code.co_kwonlyargcount])
    return set()


def tpu_compiler_params(**kwargs):
    """Instantiate TPU compiler params portably.

    Unknown keyword arguments (fields added/removed between JAX versions)
    are dropped rather than raising, so kernels can always request e.g.
    ``dimension_semantics`` without guarding on the JAX version.
    """
    cls = resolve_compiler_params_cls()
    if cls is None:
        return None
    accepted = _accepted_fields(cls)
    if accepted:
        kwargs = {k: v for k, v in kwargs.items() if k in accepted}
    try:
        return cls(**kwargs)
    except TypeError:
        return None


# ---------------------------------------------------------------------------
# cost-analysis normalizer
# ---------------------------------------------------------------------------

def normalize_cost_analysis(cost: Any) -> dict[str, float]:
    """Collapse any ``compile().cost_analysis()`` return into one flat dict.

    Handles the three shapes seen across JAX versions/backends:

    * ``dict``                       -> copied through;
    * ``list``/``tuple`` of dicts    -> numeric entries summed per key
      (one dict per computation; summing is the per-module total);
    * ``None`` / anything else      -> ``{}``.
    """
    if cost is None:
        return {}
    if isinstance(cost, dict):
        return {k: float(v) for k, v in cost.items()
                if isinstance(v, (int, float))}
    if isinstance(cost, (list, tuple)):
        out: dict[str, float] = {}
        for entry in cost:
            if not isinstance(entry, dict):
                continue
            for k, v in entry.items():
                if isinstance(v, (int, float)):
                    out[k] = out.get(k, 0.0) + float(v)
        return out
    return {}


def compiled_costs(compiled) -> dict[str, float]:
    """``normalize_cost_analysis`` straight off a compiled executable."""
    return normalize_cost_analysis(compiled.cost_analysis())


# ---------------------------------------------------------------------------
# interpret default + pad/mask helpers
# ---------------------------------------------------------------------------

def default_interpret() -> bool:
    """Pallas kernels interpret on non-TPU backends so the same call sites
    work in CPU tests/examples; on TPU they compile through Mosaic."""
    return jax.default_backend() != "tpu"


def round_up(n: int, multiple: int) -> int:
    if multiple <= 0:
        raise ValueError(f"multiple must be positive, got {multiple}")
    return ((n + multiple - 1) // multiple) * multiple


def pad_axis_to(x, axis: int, target: int):
    """Zero-pad ``x`` along ``axis`` up to length ``target`` (no-op when
    already there)."""
    size = x.shape[axis]
    if size == target:
        return x
    if size > target:
        raise ValueError(f"cannot pad axis {axis} from {size} down to {target}")
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, target - size)
    return jnp.pad(x, pads)


# ---------------------------------------------------------------------------
# block-size autotuner
# ---------------------------------------------------------------------------

# Candidate sweeps per kernel.  Defaults (the kernels' keyword defaults) are
# always included so "tuned == default" is an observable outcome.
DEFAULT_CANDIDATES: dict[str, list[dict[str, int]]] = {
    "flash_attention": [{"block_q": bq, "block_k": bk}
                        for bq in (64, 128, 256)
                        for bk in (64, 128, 256)],
    "decode_attention": [{"block_k": bk} for bk in (128, 256, 512)],
    "ssd_scan": [{"chunk": c} for c in (32, 64, 128, 256)],
}

DEFAULT_PARAMS: dict[str, dict[str, int]] = {
    "flash_attention": {"block_q": 128, "block_k": 128},
    "decode_attention": {"block_k": 256},
    "ssd_scan": {"chunk": 128},
}


@dataclass
class TuneRecord:
    """Outcome of one (kernel, shape, resource) sweep."""

    kernel: str
    shape_key: str
    resource: str
    params: dict[str, int]            # winning block sizes
    time_s: float                     # winner's measured time
    default_params: dict[str, int]
    default_time_s: float               # NaN when the default never compiled
    trials: dict[str, float] = field(default_factory=dict)  # json(params) -> s
    # candidates statically pruned by the VMEM analyzer before timing:
    # json(params) -> computed footprint in bytes (empty when unconstrained)
    pruned: dict[str, float] = field(default_factory=dict)
    vmem_limit: float | None = None   # the budget the sweep ran under
    # candidates statically pruned by the TPU tiling analyzer before
    # timing: json(params) -> misalignment reason (repro.analysis.tiling)
    tile_pruned: dict[str, str] = field(default_factory=dict)

    @property
    def changed_default(self) -> bool:
        return self.params != self.default_params

    @property
    def speedup_vs_default(self) -> float:
        # default_time_s is NaN when the default candidate never compiled
        # on this JAX version — no meaningful baseline, report parity.
        if not self.time_s or math.isnan(self.default_time_s):
            return 1.0
        return self.default_time_s / self.time_s


def _shape_key(specs) -> str:
    parts = []
    for s in jax.tree.leaves(specs):
        shape = getattr(s, "shape", None)
        dtype = getattr(s, "dtype", None)
        parts.append(f"{jnp.dtype(dtype).name if dtype is not None else '?'}"
                     f"{list(shape) if shape is not None else '?'}")
    return "x".join(parts)


class KernelAutotuner:
    """Sweeps block-size candidates and caches per-(kernel, shape, resource)
    winners.

    ``tune`` measures wall-clock of a jit'd candidate callable (min over
    ``runs`` after a compile warm-up) — the same measurement discipline as
    ``TimingProvider``.  Candidates that fail to trace/compile (e.g. an
    unsupported block shape) are skipped, which keeps sweeps safe across JAX
    versions.  A custom ``measure`` hook replaces wall-clock timing (used by
    unit tests and by roofline-style offline tuning).
    """

    def __init__(self, candidates: dict[str, list[dict[str, int]]] | None = None,
                 runs: int = 2,
                 measure: Callable[[Callable, tuple], float] | None = None,
                 vmem_limits: dict[str, float] | None = None,
                 tile_check: bool = True):
        self.candidates = dict(DEFAULT_CANDIDATES)
        if candidates:
            self.candidates.update(candidates)
        self.runs = runs
        self.measure = measure
        self.records: dict[tuple[str, str, str], TuneRecord] = {}
        # Measurements are host wall-clock and independent of the emulated
        # resource (speed factors scale uniformly), so trial tables are
        # shared across resources; each resource still gets its own record.
        self._trials: dict[tuple[str, str], dict[str, float]] = {}
        # Per-resource VMEM budgets in bytes: candidates whose static
        # footprint (repro.analysis.kernel_vmem) exceeds the tuned
        # resource's budget are pruned before timing.
        self.vmem_limits: dict[str, float] = dict(vmem_limits or {})
        # Static TPU tile-alignment pruning (repro.analysis.tiling):
        # sublane-misaligned candidates are dropped before compile/measure
        # unless that would empty the sweep.
        self.tile_check = tile_check

    def register_resources(self, resources) -> None:
        """Adopt ``Resource.vmem_bytes`` budgets from a testbed (called by
        ``benchmark_model`` so the sweep and the fleet stay in sync)."""
        for r in resources:
            budget = getattr(r, "vmem_bytes", None)
            if budget is not None:
                self.vmem_limits[r.name] = float(budget)

    # -- measurement --------------------------------------------------------
    def _time_candidate(self, fn: Callable, args: tuple) -> float:
        if self.measure is not None:
            return self.measure(fn, args)
        jf = jax.jit(fn)
        out = jf(*args)             # warm-up / compile
        jax.block_until_ready(out)
        best = float("inf")
        for _ in range(max(1, self.runs)):
            t0 = time.perf_counter()
            jax.block_until_ready(jf(*args))
            best = min(best, time.perf_counter() - t0)
        return best

    # -- core sweep ---------------------------------------------------------
    def tune(self, kernel: str, factory: Callable[[dict[str, int]], Callable],
             args: tuple, *, resource: str = "host",
             defaults: dict[str, int] | None = None,
             shape_key: str | None = None,
             config_key: str = "",
             options: dict | None = None) -> TuneRecord:
        """Sweep candidates for ``kernel`` at the shapes of ``args``.

        ``factory(params)`` returns the callable to measure.  ``config_key``
        distinguishes factories whose behaviour differs beyond the argument
        shapes (causal/window/softcap, closed-over cache sizes, ...);
        ``options`` are the node's ``kernel_options``, consumed by the
        static VMEM analyzer for dimensions the args don't expose.  The
        winning record is cached per (kernel, shape+config, resource), and
        the underlying trial table is shared across resources — mirroring
        ``BenchmarkDB``'s benchmark-once/query-many contract.

        When the tuned resource has a VMEM budget (``self.vmem_limits``),
        candidates whose static footprint exceeds it are pruned *before*
        timing (``TuneRecord.pruned`` records them) and the winner is the
        fastest *admissible* candidate — so a shared trial table measured
        under one budget serves stricter budgets without re-timing.  With
        ``tile_check`` (the default), sublane-misaligned candidates are
        likewise pruned statically (``TuneRecord.tile_pruned`` records the
        reason) unless no aligned candidate would remain.
        """
        defaults = dict(defaults or DEFAULT_PARAMS.get(kernel, {}))
        shape_key = shape_key or _shape_key(
            [jax.ShapeDtypeStruct(a.shape, a.dtype) for a in args])
        if config_key:
            shape_key = f"{shape_key}|{config_key}"
        key = (kernel, shape_key, resource)
        if key in self.records:
            return self.records[key]

        candidates = list(self.candidates.get(kernel, []))
        if defaults and defaults not in candidates:
            candidates.insert(0, defaults)
        if not candidates:
            candidates = [defaults]

        budget = self.vmem_limits.get(resource)
        pruned: dict[str, float] = {}
        kept = candidates
        if budget is not None:
            from ..analysis.kernel_vmem import lint_candidates
            kept, pruned_b, _ = lint_candidates(
                kernel, candidates, args, vmem_limit=budget,
                options=options, subject=f"{kernel}@{resource}")
            pruned = {k: float(v) for k, v in pruned_b.items()}
            if not kept:
                sizes = "; ".join(f"{k} -> {v / 2**20:.2f}MiB"
                                  for k, v in sorted(pruned.items()))
                raise RuntimeError(
                    f"autotune: every candidate of {kernel} {shape_key} "
                    f"exceeds the {budget / 2**20:.2f}MiB VMEM budget of "
                    f"resource {resource!r}: {sizes}")

        tile_pruned: dict[str, str] = {}
        if self.tile_check and kept:
            from ..analysis.tiling import misaligned_candidates
            flagged = misaligned_candidates(kernel, kept, args, options)
            aligned = [p for p in kept
                       if json.dumps(p, sort_keys=True) not in flagged]
            # static analysis narrows a sweep but never empties it: with no
            # aligned candidate left, measure the flagged ones anyway
            if aligned and flagged:
                kept = aligned
                tile_pruned = flagged

        trials = self._trials.setdefault((kernel, shape_key), {})
        failures: dict[str, str] = {}
        for params in kept:
            pkey = json.dumps(params, sort_keys=True)
            if pkey in trials:
                continue
            try:
                trials[pkey] = self._time_candidate(factory(params), args)
            except Exception as e:  # unsupported block shape on this version
                failures[pkey] = f"{type(e).__name__}: {e}"

        kept_keys = {json.dumps(p, sort_keys=True) for p in kept}
        admissible = {k: t for k, t in trials.items() if k in kept_keys}
        if not admissible:
            detail = "; ".join(f"{k} -> {err}"
                               for k, err in sorted(failures.items())) \
                or "no candidate produced a measurement"
            raise RuntimeError(
                f"autotune: every candidate failed for {kernel} "
                f"{shape_key}: {detail}")

        best_key = min(admissible, key=admissible.get)
        best = json.loads(best_key)
        dkey = json.dumps(defaults, sort_keys=True)
        rec = TuneRecord(kernel=kernel, shape_key=shape_key, resource=resource,
                         params=best, time_s=admissible[best_key],
                         default_params=defaults,
                         default_time_s=admissible.get(dkey, float("nan")),
                         trials=admissible, pruned=pruned, vmem_limit=budget,
                         tile_pruned=tile_pruned)
        self.records[key] = rec
        return rec

    # -- graph integration --------------------------------------------------
    def tune_node(self, node, resource: str = "host",
                  in_specs=None) -> TuneRecord | None:
        """Tune one kernel-bearing ``LayerNode`` in place.

        Nodes opt in by carrying ``kernel`` (substrate kernel name),
        ``kernel_factory`` (params -> apply callable) and optionally
        ``kernel_params`` (defaults).  ``in_specs`` are the node's input
        ShapeDtypeStructs (``tune_block`` derives them from the graph).
        The node's ``apply`` is rewritten to the tuned callable, so any
        provider measuring the node afterwards measures tuned timings.
        """
        kernel = getattr(node, "kernel", None)
        factory = getattr(node, "kernel_factory", None)
        if not kernel or factory is None:
            return None
        args = tuple(jnp.zeros(s.shape, s.dtype)
                     for s in (in_specs or []))
        if not args:
            return None
        options = getattr(node, "kernel_options", None)
        rec = self.tune(kernel, factory, args, resource=resource,
                        defaults=getattr(node, "kernel_defaults", None)
                        or DEFAULT_PARAMS.get(kernel),
                        config_key=json.dumps(options, sort_keys=True,
                                              default=str)
                        if options else "",
                        options=options)
        node.kernel_params = dict(rec.params)
        node.apply = factory(rec.params)
        return rec

    def tune_block(self, block, resource: str = "host") -> list[TuneRecord]:
        """Tune every kernel node of a fused block (providers call this right
        before measuring the block)."""
        out = []
        g = block.graph
        for i in block.node_ids:
            node = g.nodes[i]
            if getattr(node, "kernel", None) and \
                    getattr(node, "kernel_factory", None) is not None:
                specs = [g.nodes[p].out_spec for p in g.preds[i]]
                rec = self.tune_node(node, resource=resource, in_specs=specs)
                if rec is not None:
                    out.append(rec)
        return out

    def params_for_block(self, block) -> dict[str, dict[str, int]]:
        """Winning block sizes per kernel node of ``block`` (for embedding
        into ``BlockBenchmark.tuned_params``)."""
        out: dict[str, dict[str, int]] = {}
        for i in block.node_ids:
            node = block.graph.nodes[i]
            if getattr(node, "kernel", None) and \
                    getattr(node, "kernel_params", None):
                out[node.name] = dict(node.kernel_params)
        return out

    # -- persistence --------------------------------------------------------
    def to_json(self) -> str:
        return json.dumps([asdict(r) for r in self.records.values()])

    @classmethod
    def from_json(cls, s: str) -> "KernelAutotuner":
        tuner = cls()
        for d in json.loads(s):
            rec = TuneRecord(**d)
            tuner.records[(rec.kernel, rec.shape_key, rec.resource)] = rec
        return tuner


# ---------------------------------------------------------------------------
# serving-time tuned-params registry
# ---------------------------------------------------------------------------
# A tuned BenchmarkDB documents the block sizes its timings were measured
# with (``BlockBenchmark.tuned_params``).  Adopting it here makes those
# winners the process-wide serving defaults, so model-zoo layers
# (``models/layers.py`` attention, ``models/ssm.py`` SSD) run the same
# kernel configuration the cost model priced — not just the benchmark
# graphs built from ``kernels/ops.py``.

_SERVING_PARAMS: dict[str, dict[str, int]] = {}


def kernel_for_params(params: dict) -> str | None:
    """Map a tuned-params dict to the kernel it configures by exact
    parameter-name match ({block_q, block_k} -> flash_attention, ...).
    ``BlockBenchmark.tuned_params`` is keyed by node name, not kernel, so
    adoption needs this reverse lookup."""
    keys = frozenset(params)
    for kernel, defaults in DEFAULT_PARAMS.items():
        if keys == frozenset(defaults):
            return kernel
    return None


def adopt_tuned_params(db, *, dtype="float32") -> dict[str, dict[str, int]]:
    """Adopt a BenchmarkDB's tuned winners as serving defaults.

    Walks every record's ``tuned_params`` in deterministic order (sorted
    resources, blocks in order, sorted node names; later entries win),
    validates each candidate against the static tile-alignment analyzer
    for ``dtype`` — a misaligned winner is *rejected*, the lint-validated
    discipline — and installs the survivors.  Returns the adopted
    ``{kernel: params}`` mapping."""
    import numpy as np

    from ..analysis.tiling import min_tile

    sublane, _ = min_tile(np.dtype(dtype))
    adopted: dict[str, dict[str, int]] = {}
    records = getattr(db, "records", {})
    for rname in sorted(records):
        for rec in records[rname]:
            tuned = getattr(rec, "tuned_params", None) or {}
            for node in sorted(tuned):
                params = dict(tuned[node])
                kernel = kernel_for_params(params)
                if kernel is None:
                    continue
                values_ok = all(
                    isinstance(v, int) and v > 0 and v % sublane == 0
                    for v in params.values())
                if values_ok:
                    adopted[kernel] = params
    _SERVING_PARAMS.update(adopted)
    return adopted


def serving_param(kernel: str, name: str, fallback: int) -> int:
    """The adopted tuned value of ``kernel``'s ``name`` parameter, or
    ``fallback`` when no tuned DB has been adopted."""
    return int(_SERVING_PARAMS.get(kernel, {}).get(name, fallback))


def clear_tuned_params() -> None:
    """Drop adopted serving defaults (tests / model switches)."""
    _SERVING_PARAMS.clear()
