"""Public jit'd wrappers for the Pallas kernels + tunable graph nodes.

``interpret`` defaults to True off-TPU (via ``substrate.default_interpret``)
so the same call sites work in CPU tests/examples; on TPU backends the
kernels compile through Mosaic.

The ``*_node`` builders wrap each kernel as a ``LayerNode`` carrying the
substrate autotuner metadata (``kernel``, ``kernel_factory``,
``kernel_params``): benchmark providers constructed with a
:class:`~repro.kernels.substrate.KernelAutotuner` sweep block sizes for
these nodes before timing them, so partition decisions are made from tuned,
not default, kernel timings.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention as _flash
from .decode_attention import decode_attention as _decode
from .ssd_scan import ssd_scan as _ssd
from .substrate import DEFAULT_PARAMS, default_interpret


def flash_attention(q, k, v, *, causal=True, window=None, softcap=None,
                    block_q=128, block_k=128, interpret=None):
    if interpret is None:
        interpret = default_interpret()
    return _flash(q, k, v, causal=causal, window=window, softcap=softcap,
                  block_q=block_q, block_k=block_k, interpret=interpret)


def decode_attention(q, k, v, lengths, *, softcap=None, block_k=256,
                     interpret=None):
    if interpret is None:
        interpret = default_interpret()
    return _decode(q, k, v, lengths, softcap=softcap, block_k=block_k,
                   interpret=interpret)


def ssd_scan(x, log_a, b, c, *, chunk=128, interpret=None):
    if interpret is None:
        interpret = default_interpret()
    return _ssd(x, log_a, b, c, chunk=chunk, interpret=interpret)


# ---------------------------------------------------------------------------
# Tunable LayerNode builders (autotuner integration)
# ---------------------------------------------------------------------------

def _layer_node(name, kind, kernel, factory, params, options, flops=0.0):
    from repro.core.graph import LayerNode  # lazy: core imports substrate
    params = dict(DEFAULT_PARAMS[kernel], **(params or {}))
    return LayerNode(name=name, kind=kind, apply=factory(params),
                     flops=flops, kernel=kernel, kernel_factory=factory,
                     kernel_params=params, kernel_defaults=dict(params),
                     kernel_options={k: v for k, v in options.items()
                                     if v is not None})


def flash_attention_node(name="flash_attention", *, causal=True, window=None,
                         softcap=None, params=None, interpret=None):
    """Self-attention layer over an (B, S, H, hd) activation (q = k = v)."""

    def factory(p):
        def apply(x):
            return flash_attention(x, x, x, causal=causal, window=window,
                                   softcap=softcap, block_q=p["block_q"],
                                   block_k=p["block_k"], interpret=interpret)
        return apply

    return _layer_node(name, "attention", "flash_attention", factory, params,
                       {"causal": causal, "window": window,
                        "softcap": softcap})


def decode_attention_node(name="decode_attention", *, cache_len, kv_heads,
                          head_dim, batch=1, softcap=None, params=None,
                          interpret=None, seed=0):
    """Decode step over a fixed synthetic (cache_len, kv_heads, head_dim) KV
    cache; the node input is the (batch, H, hd) query batch.

    The cache is materialised once here (a jit constant), so timed runs
    measure only the attention kernel — not cache generation.
    """
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    kc = jax.random.normal(ks[0], (batch, cache_len, kv_heads, head_dim))
    vc = jax.random.normal(ks[1], (batch, cache_len, kv_heads, head_dim))
    lengths = jnp.full((batch,), cache_len, jnp.int32)

    def factory(p):
        def apply(q):
            return decode_attention(q, kc.astype(q.dtype),
                                    vc.astype(q.dtype), lengths,
                                    softcap=softcap, block_k=p["block_k"],
                                    interpret=interpret)
        return apply

    return _layer_node(name, "attention", "decode_attention", factory, params,
                       {"cache_len": cache_len, "kv_heads": kv_heads,
                        "head_dim": head_dim, "softcap": softcap,
                        "seed": seed})


def ssd_scan_node(name="ssd_scan", *, state_dim=16, params=None,
                  interpret=None):
    """SSD mixer over an (B, S, H, P) activation; B/C projections are cheap
    slices of the input so the node stays single-input."""

    def factory(p):
        def apply(x):
            log_a = -jax.nn.softplus(x.mean(axis=-1))
            bc = x[..., :state_dim]
            y, _ = ssd_scan(x, log_a, bc, bc, chunk=p["chunk"],
                            interpret=interpret)
            return y
        return apply

    return _layer_node(name, "ssm", "ssd_scan", factory, params,
                       {"state_dim": state_dim})
