"""Public jit'd wrappers for the Pallas kernels.

``interpret`` defaults to True off-TPU so the same call sites work in CPU
tests/examples; on TPU backends the kernels compile through Mosaic.
"""

from __future__ import annotations

import jax

from .flash_attention import flash_attention as _flash
from .decode_attention import decode_attention as _decode
from .ssd_scan import ssd_scan as _ssd


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def flash_attention(q, k, v, *, causal=True, window=None, softcap=None,
                    block_q=128, block_k=128, interpret=None):
    if interpret is None:
        interpret = _default_interpret()
    return _flash(q, k, v, causal=causal, window=window, softcap=softcap,
                  block_q=block_q, block_k=block_k, interpret=interpret)


def decode_attention(q, k, v, lengths, *, softcap=None, block_k=256,
                     interpret=None):
    if interpret is None:
        interpret = _default_interpret()
    return _decode(q, k, v, lengths, softcap=softcap, block_k=block_k,
                   interpret=interpret)


def ssd_scan(x, log_a, b, c, *, chunk=128, interpret=None):
    if interpret is None:
        interpret = _default_interpret()
    return _ssd(x, log_a, b, c, chunk=chunk, interpret=interpret)
