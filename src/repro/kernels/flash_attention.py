"""Flash attention (prefill) Pallas TPU kernel.

TPU-native design (DESIGN.md §6): the grid iterates (batch, q-head, q-block)
in parallel and the kv-block dimension sequentially ("arbitrary"), keeping
the online-softmax running max/denominator/accumulator in VMEM scratch.
Every matmul is (bq×hd)·(hd×bk) / (bq×bk)·(bk×hd) with 128-aligned tiles so
it lands on the MXU.  GQA is handled by indexing the kv head as
``h // (H // Hk)`` in the k/v BlockSpec index maps — no head replication in
HBM.  Sliding-window and logit-softcap (gemma2) are fused into the score
path.  Causal q-blocks that lie entirely outside the kv block are skipped
via ``pl.when`` (block-level masking).

Uneven sequence lengths are handled by the substrate layer: q/k/v are
zero-padded up to the next block boundary, padded key positions are masked
to ``-inf`` via ``kpos < k_len``, and padded query rows are sliced off the
output.  Compiler params resolve through ``substrate.tpu_compiler_params``
so both old (``TPUCompilerParams``) and new (``CompilerParams``) JAX work.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .substrate import pad_axis_to, round_up, tpu_compiler_params

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, causal: bool, window: int | None,
            softcap: float | None, bq: int, bk: int, nk: int, k_len: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = iq * bq
    k_start = ik * bk

    # block-level skip: causal => kv blocks entirely in the future contribute
    # nothing; sliding window => kv blocks entirely before the window too;
    # padding => kv blocks entirely past the true key length.
    relevant = k_start < k_len
    if causal:
        relevant &= k_start <= q_start + bq - 1
    if window is not None:
        relevant &= k_start + bk - 1 >= q_start - window + 1

    @pl.when(relevant)
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32)          # (bq, hd)
        k = k_ref[0, :, 0, :].astype(jnp.float32)          # (bk, hd)
        v = v_ref[0, :, 0, :].astype(jnp.float32)          # (bk, hd)

        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)

        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = kpos < k_len
        if causal:
            mask &= qpos >= kpos
        if window is not None:
            mask &= qpos - kpos < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)    # fully-masked rows -> zeros
        o_ref[0, :, 0, :] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "softcap",
                                             "block_q", "block_k",
                                             "interpret"))
def flash_attention(q, k, v, *, causal=True, window=None, softcap=None,
                    block_q=128, block_k=128, interpret=False):
    """q: (B, Sq, H, hd); k, v: (B, Sk, Hk, hd) -> (B, Sq, H, hd).

    ``Sq``/``Sk`` need not divide the block sizes: inputs are zero-padded to
    the next block boundary and the pad is masked/sliced away.
    """
    B, Sq, H, hd = q.shape
    Sk, Hk = k.shape[1], k.shape[2]
    group = H // Hk
    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    Sq_p, Sk_p = round_up(Sq, bq), round_up(Sk, bk)
    q = pad_axis_to(q, 1, Sq_p)
    k = pad_axis_to(k, 1, Sk_p)
    v = pad_axis_to(v, 1, Sk_p)
    nq, nk = Sq_p // bq, Sk_p // bk
    scale = 1.0 / math.sqrt(hd)

    grid = (B, H, nq, nk)
    kernel = functools.partial(_kernel, scale=scale, causal=causal,
                               window=window, softcap=softcap, bq=bq, bk=bk,
                               nk=nk, k_len=Sk)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, 1, hd), lambda b, h, iq, ik: (b, iq, h, 0)),
            pl.BlockSpec((1, bk, 1, hd),
                         lambda b, h, iq, ik, g=group: (b, ik, h // g, 0)),
            pl.BlockSpec((1, bk, 1, hd),
                         lambda b, h, iq, ik, g=group: (b, ik, h // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, hd),
                               lambda b, h, iq, ik: (b, iq, h, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
    return out[:, :Sq] if Sq_p != Sq else out
