"""Mamba-2 SSD chunked-scan Pallas TPU kernel.

TPU adaptation of the GPU selective-scan (DESIGN.md §6): instead of a
warp-parallel recurrence, the sequence is tiled into chunks of length L.
The grid walks (batch, head) in parallel and chunks *sequentially*; per
step the kernel computes the dense intra-chunk part with three MXU matmuls
((L×N)·(N×L) decay-masked scores, (L×L)·(L×P) output, (N×L)·(L×P) chunk
state) and carries the (N×P) running state in VMEM scratch across chunk
steps — the cross-chunk recurrence costs one rank-1 update per chunk
instead of S sequential steps.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .substrate import pad_axis_to, round_up, tpu_compiler_params


def _kernel(x_ref, a_ref, b_ref, c_ref, y_ref, fin_ref, state_scr, *,
            L: int, nc: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0, :, 0, :].astype(jnp.float32)        # (L, P)
    la = a_ref[0, :, 0].astype(jnp.float32)          # (L,)
    b = b_ref[0, :, 0, :].astype(jnp.float32)        # (L, N)
    c = c_ref[0, :, 0, :].astype(jnp.float32)        # (L, N)

    seg = jnp.cumsum(la)                             # (L,)
    total = seg[-1]

    # intra-chunk: scores_ij = c_i·b_j * exp(seg_i - seg_j) for j <= i
    scores = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    diff = seg[:, None] - seg[None, :]
    causal = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    decay = jnp.where(causal, jnp.exp(diff), 0.0)
    y = jax.lax.dot_general(scores * decay, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # inter-chunk: y += exp(seg_i) * c_i · state_in
    state_in = state_scr[...]                        # (N, P)
    y += jnp.exp(seg)[:, None] * jax.lax.dot_general(
        c, state_in, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    # state update: state = state * exp(total) + Σ_j exp(total - seg_j) b_j x_jᵀ
    w = jnp.exp(total - seg)                         # (L,)
    state_scr[...] = state_in * jnp.exp(total) + jax.lax.dot_general(
        b * w[:, None], x, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    @pl.when(ic == nc - 1)
    def _final():
        fin_ref[0, 0] = state_scr[...].astype(fin_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, log_a, b, c, *, chunk=128, interpret=False):
    """x: (B, S, H, P); log_a: (B, S, H); b, c: (B, S, H, N).

    Returns (y: (B, S, H, P), final_state: (B, H, N, P) fp32).

    ``S`` need not divide the chunk length: inputs are zero-padded to the
    next chunk boundary.  Padded steps carry ``log_a = 0`` and ``x = b = 0``,
    so the recurrence ``state <- state·exp(0) + 0`` leaves the final state
    untouched; padded output rows are sliced away.
    """
    B, S, H, P = x.shape
    N = b.shape[-1]
    L = min(chunk, S)
    S_p = round_up(S, L)
    x = pad_axis_to(x, 1, S_p)
    log_a = pad_axis_to(log_a, 1, S_p)
    b = pad_axis_to(b, 1, S_p)
    c = pad_axis_to(c, 1, S_p)
    nc = S_p // L

    grid = (B, H, nc)
    kernel = functools.partial(_kernel, L=L, nc=nc)
    y, fin = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, L, 1, P), lambda bi, h, ic: (bi, ic, h, 0)),
            pl.BlockSpec((1, L, 1), lambda bi, h, ic: (bi, ic, h)),
            pl.BlockSpec((1, L, 1, N), lambda bi, h, ic: (bi, ic, h, 0)),
            pl.BlockSpec((1, L, 1, N), lambda bi, h, ic: (bi, ic, h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, L, 1, P), lambda bi, h, ic: (bi, ic, h, 0)),
            pl.BlockSpec((1, 1, N, P), lambda bi, h, ic: (bi, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(x.shape, x.dtype),
            jax.ShapeDtypeStruct((B, H, N, P), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, log_a, b, c)
    return (y[:, :S] if S_p != S else y), fin
