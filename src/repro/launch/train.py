"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch xlstm-125m --tiny \
        --steps 50 --ckpt-dir /tmp/ckpt

Single-host execution of the same train_step the dry-run lowers for the
production mesh; the fleet path differs only in mesh/shardings (steps.py)
and per-host data sharding (data/pipeline.py).  Fault tolerance is live:
checkpoint/restart via CheckpointManager, straggler + heartbeat via
TrainSupervisor.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.data import DataConfig, SyntheticLM
from repro.launch.steps import make_train_step
from repro.models import build_model, get_config
from repro.optim import AdamWConfig, cosine_with_warmup, init_state
from repro.runtime.ft import TrainSupervisor


def tiny(cfg):
    return cfg.replace(
        n_layers=len(cfg.pattern) * 2 if not cfg.shared_attn_period
        else cfg.shared_attn_period,
        d_model=128, n_heads=4,
        n_kv_heads=min(4, cfg.n_kv_heads), head_dim=32,
        d_ff=0 if cfg.d_ff == 0 else 256, vocab=512,
        moe_experts=8 if cfg.moe_experts else 0,
        moe_top_k=min(2, cfg.moe_top_k) if cfg.moe_top_k else 0,
        moe_shared_dff=64 if cfg.moe_shared_dff else 0,
        moe_group_size=64, ssm_chunk=32, ssm_head_dim=16,
        encoder_layers=2 if cfg.is_encdec else 0,
        encoder_len=32 if cfg.is_encdec else cfg.encoder_len,
        n_img_tokens=4 if cfg.n_img_tokens else 0,
        window=16 if cfg.window else None,
        query_pre_attn_scalar=32.0 if cfg.query_pre_attn_scalar else None,
        remat=False, q_chunk=64, loss_seq_chunk=None)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-125m")
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.tiny:
        cfg = tiny(cfg)
    model = build_model(cfg)
    print(f"arch={cfg.name} params={sum(x.size for x in jax.tree.leaves(model.abstract_params())):,}")

    data = SyntheticLM(DataConfig(
        vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch,
        encoder_len=cfg.encoder_len if cfg.is_encdec else 0,
        n_img_tokens=cfg.n_img_tokens, d_model=cfg.d_model))
    adamw = AdamWConfig(lr=cosine_with_warmup(args.lr, 10, args.steps))
    step_fn = jax.jit(make_train_step(model, adamw, None, None),
                      donate_argnums=(0, 1))

    params = model.init(jax.random.PRNGKey(0))
    opt = init_state(params)
    start = 0
    sup = None
    if args.ckpt_dir:
        sup = TrainSupervisor(CheckpointManager(args.ckpt_dir, keep=2),
                              ckpt_every=args.ckpt_every)
        (state := {"p": params, "o": opt})
        state, start = sup.resume_or_init(lambda: state, like=state)
        params, opt = state["p"], state["o"]
        if start:
            print(f"resumed at step {start}")

    losses = []
    for step in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in
                 data.global_batch_at(step).items()}
        if cfg.n_img_tokens and "patch_embeds" in batch:
            batch["patch_embeds"] = batch["patch_embeds"].astype(jnp.bfloat16)
        if cfg.is_encdec and "frames" in batch:
            batch["frames"] = batch["frames"].astype(jnp.bfloat16)
        t0 = time.perf_counter()
        params, opt, metrics = step_fn(params, opt, batch)
        wall = time.perf_counter() - t0
        losses.append(float(metrics["loss"]))
        if sup:
            sup.after_step(step, {"p": params, "o": opt}, wall)
        if step % 10 == 0 or step == args.steps - 1:
            print(f"step {step:4d} loss={losses[-1]:.4f} "
                  f"{wall * 1e3:.0f}ms")
    if sup:
        sup.ckpt.wait()
    first = sum(losses[:5]) / max(len(losses[:5]), 1)
    last = sum(losses[-5:]) / max(len(losses[-5:]), 1)
    print(f"loss {first:.3f} -> {last:.3f} "
          f"({'improved' if last < first else 'NO IMPROVEMENT'})")


if __name__ == "__main__":
    main()
