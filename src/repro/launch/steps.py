"""Step functions (train / prefill / decode) + input specs + shardings.

Everything here is mesh-agnostic: the dry-run, the trainer and the serving
engine all build their jitted programs from these factories.
"""

from __future__ import annotations

import math
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import build_model
from repro.optim import AdamWConfig, apply_updates, init_state
from repro.runtime.sharding import (AxisRules, _divisible_spec, use_rules)


# ---------------------------------------------------------------------------
# step factories
# ---------------------------------------------------------------------------

def make_train_step(model, adamw_cfg: AdamWConfig, rules: AxisRules | None,
                    mesh: Mesh | None):
    def train_step(params, opt_state, batch):
        with use_rules(rules, mesh):
            (loss, metrics), grads = jax.value_and_grad(
                model.loss, has_aux=True)(params, batch)
            params, opt_state, om = apply_updates(adamw_cfg, params, grads,
                                                  opt_state)
            return params, opt_state, {"loss": loss, **metrics, **om}

    return train_step


def make_prefill_step(model, rules: AxisRules | None, mesh: Mesh | None):
    def prefill_step(params, cache, batch):
        with use_rules(rules, mesh):
            kw = {}
            if "frames" in batch:
                kw["frames"] = batch["frames"]
            if "patch_embeds" in batch:
                kw["patch_embeds"] = batch["patch_embeds"]
            logits, cache = model.prefill(params, batch["tokens"], cache,
                                          **kw)
            return logits, cache

    return prefill_step


def make_decode_step(model, rules: AxisRules | None, mesh: Mesh | None):
    def decode_step(params, cache, token, cache_len):
        with use_rules(rules, mesh):
            logits, cache = model.decode_step(params, token, cache,
                                              cache_len)
            # greedy next token: what the serving engine feeds back
            next_tok = jnp.argmax(logits[:, -1], axis=-1
                                  ).astype(jnp.int32)[:, None]
            return next_tok, logits, cache

    return decode_step


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStructs — no allocation)
# ---------------------------------------------------------------------------

def batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if cfg.is_encdec:
        return {
            "tokens": jax.ShapeDtypeStruct((B, S), i32),
            "labels": jax.ShapeDtypeStruct((B, S), i32),
            "frames": jax.ShapeDtypeStruct((B, cfg.encoder_len, cfg.d_model),
                                           jnp.bfloat16),
        }
    if cfg.n_img_tokens:
        S_text = S - cfg.n_img_tokens
        return {
            "tokens": jax.ShapeDtypeStruct((B, S_text), i32),
            "labels": jax.ShapeDtypeStruct((B, S_text), i32),
            "patch_embeds": jax.ShapeDtypeStruct(
                (B, cfg.n_img_tokens, cfg.d_model), jnp.bfloat16),
        }
    return {"tokens": jax.ShapeDtypeStruct((B, S), i32),
            "labels": jax.ShapeDtypeStruct((B, S), i32)}


def input_specs(cfg: ModelConfig, shape: ShapeConfig,
                dtype=jnp.bfloat16) -> dict[str, Any]:
    """All abstract inputs for the given cell.  Keys depend on the kind:

    train   -> params, opt_state, batch
    prefill -> params, cache, batch (labels dropped)
    decode  -> params, cache, token, cache_len
    """
    model = build_model(cfg)
    params = model.abstract_params(dtype)
    if shape.kind == "train":
        mu = jax.tree.map(
            lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), params)
        opt = {"mu": mu, "nu": mu, "step": jax.ShapeDtypeStruct((), jnp.int32)}
        return {"params": params, "opt_state": opt,
                "batch": batch_specs(cfg, shape)}

    cache = jax.tree.map(
        lambda t: t[0], model.cache_spec(shape.global_batch, shape.seq_len),
        is_leaf=_is_spec_leaf)
    if shape.kind == "prefill":
        batch = batch_specs(cfg, shape)
        batch.pop("labels")
        return {"params": params, "cache": cache, "batch": batch}

    # decode
    return {"params": params, "cache": cache,
            "token": jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32),
            "cache_len": jax.ShapeDtypeStruct((), jnp.int32)}


def _is_spec_leaf(t):
    return (isinstance(t, tuple) and len(t) == 2
            and hasattr(t[0], "shape") and isinstance(t[1], tuple))


# ---------------------------------------------------------------------------
# shardings
# ---------------------------------------------------------------------------

def _shard(mesh: Mesh, rules: AxisRules, axes: tuple, shape: tuple
           ) -> NamedSharding:
    spec = _divisible_spec(mesh, rules.spec(axes), shape)
    return NamedSharding(mesh, spec)


def shardings_for(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                  rules: AxisRules, specs: dict[str, Any]) -> dict[str, Any]:
    """NamedSharding pytrees matching :func:`input_specs` output."""
    model = build_model(cfg)
    paxes = model.param_axes()
    pshard = jax.tree.map(
        lambda sds, axes: _shard(mesh, rules, axes, sds.shape),
        specs["params"], paxes,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    out: dict[str, Any] = {"params": pshard}

    if shape.kind == "train":
        out["opt_state"] = {
            "mu": pshard, "nu": pshard,
            "step": NamedSharding(mesh, P())}
        out["batch"] = {
            k: _shard(mesh, rules, ("act_batch", None, None)[:v.ndim],
                      v.shape)
            for k, v in specs["batch"].items()}
        return out

    cspec = model.cache_spec(shape.global_batch, shape.seq_len)
    out["cache"] = jax.tree.map(
        lambda t: _shard(mesh, rules, t[1], t[0].shape), cspec,
        is_leaf=_is_spec_leaf)
    if shape.kind == "prefill":
        out["batch"] = {
            k: _shard(mesh, rules, ("act_batch", None, None)[:v.ndim],
                      v.shape)
            for k, v in specs["batch"].items()}
    else:
        out["token"] = _shard(mesh, rules, ("act_batch", None),
                              specs["token"].shape)
        out["cache_len"] = NamedSharding(mesh, P())
    return out


def rules_for(shape: ShapeConfig, *, multi_pod: bool) -> AxisRules:
    from repro.runtime.sharding import multi_pod_rules, single_pod_rules
    rules = multi_pod_rules() if multi_pod else single_pod_rules()
    if shape.kind == "decode":
        # single-token step: no sequence dim to shard
        rules = rules.with_overrides(act_seq=None)
    return rules


# ---------------------------------------------------------------------------
# MODEL_FLOPS (the roofline's "useful work" yardstick)
# ---------------------------------------------------------------------------

def count_params(cfg: ModelConfig) -> int:
    model = build_model(cfg)
    return sum(int(np.prod(s.shape))
               for s in jax.tree.leaves(model.abstract_params()))


def count_active_params(cfg: ModelConfig) -> int:
    n = count_params(cfg)
    if cfg.moe_experts:
        from repro.models.moe import pad_experts
        E = pad_experts(cfg.moe_experts)
        inactive = (E - cfg.moe_top_k) * 3 * cfg.d_model * cfg.d_ff
        n -= inactive * cfg.n_layers // len(cfg.pattern)
    return n


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """6·N·D for training, 2·N·D for inference (MoE: N_active)."""
    n = count_active_params(cfg)
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch          # one token per sequence
