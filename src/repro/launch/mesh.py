"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — required because the dry-run must set
XLA_FLAGS before any jax initialisation.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips with a leading 'pod'
    axis for cross-pod data parallelism."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_smoke_mesh():
    """Single-device mesh for CPU tests."""
    return jax.make_mesh((1, 1), ("data", "model"))
