import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × input shape) on
the production meshes and extract memory/cost/collective analysis.

MUST be executed as its own process (``python -m repro.launch.dryrun``) so
the XLA_FLAGS above take effect before jax initialises its backends.

    python -m repro.launch.dryrun --arch gemma2-9b --shape train_4k
    python -m repro.launch.dryrun --all [--multi-pod-only|--single-pod-only]
    python -m repro.launch.dryrun --all --out results/dryrun.json
"""

import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs.base import ALL_SHAPES, ShapeConfig, shape_by_name
from repro.kernels.substrate import compiled_costs
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import (input_specs, make_decode_step,
                                make_prefill_step, make_train_step,
                                model_flops, rules_for, shardings_for)
from repro.models import build_model, get_config
from repro.optim import AdamWConfig

ARCHS = ["gemma2-9b", "starcoder2-15b", "gemma-7b", "granite-8b",
         "zamba2-2.7b", "xlstm-125m", "whisper-medium", "internvl2-76b",
         "qwen2-moe-a2.7b", "granite-moe-3b-a800m"]

# long_500k needs sub-quadratic attention (DESIGN.md §Arch-applicability)
def cell_skipped(arch: str, shape: ShapeConfig) -> str | None:
    cfg = get_config(arch)
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return "full-attention arch: 512k decode KV is quadratic-infeasible"
    return None


_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_SHAPE_RE = re.compile(r"(f32|bf16|f16|s32|u32|s8|u8|pred|s64|f64)"
                       r"\[([0-9,]*)\]")
_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
          "u8": 1, "pred": 1, "s64": 8, "f64": 8}


def collective_bytes(hlo_text: str) -> dict[str, float]:
    """Sum output-shape bytes of every collective op in the partitioned HLO
    (per-device traffic; ring-algorithm bytes ≈ output size)."""
    out: dict[str, float] = {c: 0.0 for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.lstrip()
        # match result-producing collective instructions, e.g.
        #   %all-reduce.5 = bf16[...] all-reduce(...)
        m = re.search(r"=\s*[^=]*?\b(" + "|".join(_COLLECTIVES)
                      + r")(?:-start|-done)?\(", stripped)
        if not m:
            continue
        if "-done(" in stripped:      # avoid double counting start/done pairs
            continue
        op = m.group(1)
        shapes = _SHAPE_RE.findall(stripped.split("=")[1].split("(")[0])
        nbytes = 0.0
        for dt, dims in shapes:
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _BYTES[dt]
        out[op] += nbytes
    out["total"] = sum(out[c] for c in _COLLECTIVES)
    return out


def reduced_groups_cfg(cfg, n_groups: int):
    """Same architecture with only ``n_groups`` scan groups — used for the
    two-point cost extrapolation (XLA cost analysis counts a while-loop
    body once, so scanned-layer costs must be recovered by fitting
    cost(G) = base + G·slope from G=1 and G=2)."""
    if cfg.shared_attn_period:
        n_layers = cfg.shared_attn_period * n_groups
    else:
        n_layers = len(cfg.pattern) * n_groups
    kw = {"n_layers": n_layers}
    if cfg.is_encdec:
        kw["encoder_layers"] = n_groups
    return cfg.replace(**kw)


def build_step_and_args(arch: str, shape: ShapeConfig, mesh, multi_pod: bool,
                        cfg=None):
    cfg = cfg if cfg is not None else get_config(arch)
    model = build_model(cfg)
    rules = rules_for(shape, multi_pod=multi_pod)
    specs = input_specs(cfg, shape)
    shards = shardings_for(cfg, shape, mesh, rules, specs)

    if shape.kind == "train":
        step = make_train_step(model, AdamWConfig(), rules, mesh)
        args = (specs["params"], specs["opt_state"], specs["batch"])
        in_sh = (shards["params"], shards["opt_state"], shards["batch"])
        out_sh = (shards["params"], shards["opt_state"], None)
        donate = (0, 1)
    elif shape.kind == "prefill":
        step = make_prefill_step(model, rules, mesh)
        args = (specs["params"], specs["cache"], specs["batch"])
        in_sh = (shards["params"], shards["cache"], shards["batch"])
        out_sh = (None, shards["cache"])
        donate = (1,)
    else:
        step = make_decode_step(model, rules, mesh)
        args = (specs["params"], specs["cache"], specs["token"],
                specs["cache_len"])
        in_sh = (shards["params"], shards["cache"], shards["token"],
                 shards["cache_len"])
        out_sh = (None, None, shards["cache"])
        donate = (1,)
    return step, args, in_sh, out_sh, donate


def run_cell(arch: str, shape: ShapeConfig, *, multi_pod: bool,
             verbose: bool = True) -> dict:
    mesh_name = "2x16x16" if multi_pod else "16x16"
    rec = {"arch": arch, "shape": shape.name, "mesh": mesh_name}
    skip = cell_skipped(arch, shape)
    if skip:
        rec.update(status="SKIP", reason=skip)
        return rec

    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)

        def compile_cfg(cfg):
            step, args, in_sh, out_sh, donate = build_step_and_args(
                arch, shape, mesh, multi_pod, cfg=cfg)
            with mesh:
                jitted = jax.jit(step, in_shardings=in_sh,
                                 out_shardings=out_sh,
                                 donate_argnums=donate)
                return jitted.lower(*args).compile()

        def costs(compiled):
            cost = compiled_costs(compiled)
            coll = collective_bytes(compiled.as_text())
            return (cost.get("flops", 0.0),
                    cost.get("bytes accessed", 0.0), coll)

        full_cfg = get_config(arch)
        compiled = compile_cfg(full_cfg)
        t_compile = time.time() - t0

        # two-point extrapolation over *unrolled* 2- and 3-group variants:
        # XLA cost analysis counts a while body once and ignores trip
        # counts, so every scan (layer stack, q-chunks, SSD chunks, loss
        # chunks) must be unrolled/maximised in the costing variant for the
        # per-group slope to be real.  cost(G) = base + G·slope.
        G = full_cfg.n_groups

        def costing_cfg(g):
            # q-chunking/loss-chunking do the same work dense, so maximise
            # the chunk; SSD's chunked algorithm does *different* (O(S·L))
            # work than its dense form, so unroll its chunk scan instead.
            return reduced_groups_cfg(full_cfg, g).replace(
                scan_layers=False, q_chunk=1_000_000_000,
                loss_seq_chunk=None, unroll_scans=True)

        f1, b1, c1 = costs(compile_cfg(costing_cfg(2)))
        f2, b2, c2 = costs(compile_cfg(costing_cfg(3)))

        def extrap(v1, v2):
            return v1 + (G - 2) * (v2 - v1)

        mem = compiled.memory_analysis()
        coll_raw = costs(compiled)[2]
        coll = {k: extrap(c1[k], c2[k]) for k in c1}
        n_dev = mesh.devices.size
        t_lower = 0.0

        flops_per_dev = extrap(f1, f2)
        bytes_per_dev = extrap(b1, b2)
        rec.update(
            status="OK",
            lower_s=round(t_lower, 1),
            compile_s=round(t_compile, 1),
            n_devices=n_dev,
            hlo_flops_per_device=flops_per_dev,
            hlo_bytes_per_device=bytes_per_dev,
            collective_bytes_per_device=coll["total"],
            collectives=coll,
            collectives_scan_body_once=coll_raw,
            memory={
                "argument_bytes": int(getattr(mem, "argument_size_in_bytes",
                                              0)),
                "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
                "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
                "alias_bytes": int(getattr(mem, "alias_size_in_bytes", 0)),
            },
            model_flops_total=model_flops(get_config(arch), shape),
        )
        if verbose:
            print(f"[{arch} × {shape.name} × {mesh_name}] OK "
                  f"compile={t_compile:.0f}s "
                  f"flops/dev={flops_per_dev:.3e} "
                  f"bytes/dev={bytes_per_dev:.3e} "
                  f"coll/dev={coll['total']:.3e} "
                  f"temp={rec['memory']['temp_bytes'] / 2**30:.2f}GiB")
    except Exception as e:  # noqa: BLE001 — a failing cell is a bug report
        rec.update(status="FAIL", error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc()[-2000:])
        if verbose:
            print(f"[{arch} × {shape.name} × {mesh_name}] FAIL: "
                  f"{rec['error']}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--single-pod-only", action="store_true")
    ap.add_argument("--multi-pod-only", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    cells: list[tuple[str, ShapeConfig, bool]] = []
    if args.all:
        meshes = [False, True]
        if args.single_pod_only:
            meshes = [False]
        if args.multi_pod_only:
            meshes = [True]
        for arch in ARCHS:
            for shape in ALL_SHAPES:
                for mp in meshes:
                    cells.append((arch, shape, mp))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells.append((args.arch, shape_by_name(args.shape), args.multi_pod))

    results = [run_cell(a, s, multi_pod=mp) for a, s, mp in cells]

    ok = sum(r["status"] == "OK" for r in results)
    skip = sum(r["status"] == "SKIP" for r in results)
    fail = sum(r["status"] == "FAIL" for r in results)
    print(f"\n== dry-run: {ok} OK, {skip} SKIP, {fail} FAIL "
          f"of {len(results)} cells ==")

    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
        print(f"wrote {args.out}")
    return 1 if fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
