"""Serving launcher: batched greedy generation through the engine.

    PYTHONPATH=src python -m repro.launch.serve --arch granite-8b --tiny \
        --requests 8 --width 4
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.models import build_model, get_config
from repro.serving import Request, ServingEngine
from .train import tiny


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--tiny", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--width", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=64)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.tiny:
        cfg = tiny(cfg)
    if cfg.is_encdec or cfg.n_img_tokens:
        raise SystemExit("serve CLI supports decoder-only archs; use the "
                         "examples for enc-dec")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    eng = ServingEngine(model, params, width=args.width,
                        max_len=args.max_len)
    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    for rid in range(args.requests):
        eng.submit(Request(
            rid=rid, prompt=rng.integers(0, cfg.vocab,
                                         int(rng.integers(4, 16))),
            max_new_tokens=args.max_new))
    done = eng.run()
    wall = time.perf_counter() - t0
    n_tok = sum(len(r.tokens) for r in done)
    print(f"served {len(done)} requests, {n_tok} tokens in "
          f"{wall:.2f}s ({n_tok / wall:.1f} tok/s aggregate)")
    ttfts = [r.first_token_at - r.submitted_at for r in done]
    print(f"TTFT p50={np.percentile(ttfts, 50) * 1e3:.0f}ms "
          f"p95={np.percentile(ttfts, 95) * 1e3:.0f}ms")


if __name__ == "__main__":
    main()
