"""Roofline analysis over the dry-run results (EXPERIMENTS.md §Roofline).

    compute term    = HLO_FLOPs / (chips × peak_FLOP/s)
    memory term     = HLO_bytes / (chips × HBM_bw)
    collective term = collective_bytes / (chips × link_bw)

``cost_analysis()`` on this backend reports *per-device* FLOPs/bytes of the
SPMD-partitioned module, and the collective bytes are parsed per-device from
the partitioned HLO, so each term is simply value / peak — already per chip.
Hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.

    python -m repro.launch.roofline --in results/dryrun.json
"""

from __future__ import annotations

import argparse
import json

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
HBM_PER_CHIP = 16 * 2**30     # v5e


def analyse(rec: dict) -> dict:
    if rec["status"] != "OK":
        return dict(rec)
    chips = rec["n_devices"]
    t_compute = rec["hlo_flops_per_device"] / PEAK_FLOPS
    t_memory = rec["hlo_bytes_per_device"] / HBM_BW
    t_coll = rec["collective_bytes_per_device"] / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    model_fl = rec["model_flops_total"]
    hlo_total = rec["hlo_flops_per_device"] * chips
    useful = model_fl / hlo_total if hlo_total else 0.0
    # roofline fraction: useful-FLOPs time over the bound set by the
    # dominant term (1.0 == the dominant resource is saturated by useful work)
    t_useful = model_fl / (chips * PEAK_FLOPS)
    frac = t_useful / bound if bound else 0.0
    mem = rec.get("memory", {})
    fits = (mem.get("temp_bytes", 0) + mem.get("argument_bytes", 0)
            ) <= HBM_PER_CHIP
    out = dict(rec)
    out.update(
        t_compute_s=t_compute, t_memory_s=t_memory, t_collective_s=t_coll,
        dominant=dominant, useful_flops_ratio=useful,
        roofline_fraction=frac, fits_hbm=fits,
        hbm_gib=round((mem.get("temp_bytes", 0)
                       + mem.get("argument_bytes", 0)) / 2**30, 2),
    )
    return out


def table(records: list[dict], mesh: str = "16x16") -> str:
    rows = []
    hdr = (f"{'arch':<22}{'shape':<13}{'comp(ms)':>9}{'mem(ms)':>9}"
           f"{'coll(ms)':>9} {'dom':<5}{'useful':>7}{'roofl%':>7}"
           f"{'HBM GiB':>9}{'fits':>6}")
    rows.append(hdr)
    rows.append("-" * len(hdr))
    for r in records:
        if r.get("mesh") != mesh:
            continue
        if r["status"] == "SKIP":
            rows.append(f"{r['arch']:<22}{r['shape']:<13}"
                        f"{'SKIP: ' + r['reason'][:58]}")
            continue
        if r["status"] != "OK":
            rows.append(f"{r['arch']:<22}{r['shape']:<13}FAIL")
            continue
        a = analyse(r)
        rows.append(
            f"{r['arch']:<22}{r['shape']:<13}"
            f"{a['t_compute_s'] * 1e3:>9.2f}{a['t_memory_s'] * 1e3:>9.2f}"
            f"{a['t_collective_s'] * 1e3:>9.2f} {a['dominant'][:4]:<5}"
            f"{a['useful_flops_ratio']:>7.2f}"
            f"{a['roofline_fraction'] * 100:>7.1f}"
            f"{a['hbm_gib']:>9.2f}{'y' if a['fits_hbm'] else 'N':>6}")
    return "\n".join(rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--in", dest="inp", default="results/dryrun.json")
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args()
    records = json.load(open(args.inp))
    print(table(records, args.mesh))
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump([analyse(r) for r in records], f, indent=1)


if __name__ == "__main__":
    main()
