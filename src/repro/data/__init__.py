from .pipeline import DataConfig, SyntheticLM, make_iterator

__all__ = ["DataConfig", "SyntheticLM", "make_iterator"]
