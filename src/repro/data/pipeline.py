"""Deterministic synthetic data pipeline (shardable per host, restartable).

Produces the same token stream for a given (seed, step) regardless of host
count — each host materialises only its shard of the global batch, which is
what a 1000-node fleet needs (no host reads the full batch).  Restart after
failure resumes from the step counter alone (no iterator state to persist,
a deliberate fault-tolerance property; see runtime/ft.py).
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    # modality extras
    encoder_len: int = 0
    n_img_tokens: int = 0
    d_model: int = 0


class SyntheticLM:
    """Markov-ish synthetic LM stream: next token depends on the previous
    one through a fixed random permutation + noise, so a real model can
    actually reduce loss on it (used by examples/train_small_lm.py)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        self._perm = rng.permutation(cfg.vocab)

    def global_batch_at(self, step: int) -> dict[str, np.ndarray]:
        return self.host_batch_at(step, host_id=0, n_hosts=1)

    def host_batch_at(self, step: int, host_id: int, n_hosts: int
                      ) -> dict[str, np.ndarray]:
        cfg = self.cfg
        assert cfg.global_batch % n_hosts == 0
        b = cfg.global_batch // n_hosts
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + step) * 4096 + host_id)
        first = rng.integers(0, cfg.vocab, size=(b, 1))
        toks = [first]
        for _ in range(cfg.seq_len):
            prev = toks[-1]
            nxt = self._perm[prev]
            noise = rng.integers(0, cfg.vocab, size=prev.shape)
            use_noise = rng.random(prev.shape) < 0.1
            toks.append(np.where(use_noise, noise, nxt))
        seq = np.concatenate(toks, axis=1)
        out = {"tokens": seq[:, :-1].astype(np.int32),
               "labels": seq[:, 1:].astype(np.int32)}
        if cfg.encoder_len:
            out["frames"] = rng.standard_normal(
                (b, cfg.encoder_len, cfg.d_model)).astype(np.float32)
        if cfg.n_img_tokens:
            out["patch_embeds"] = rng.standard_normal(
                (b, cfg.n_img_tokens, cfg.d_model)).astype(np.float32)
        return out


def make_iterator(cfg: DataConfig, start_step: int = 0, host_id: int = 0,
                  n_hosts: int = 1):
    ds = SyntheticLM(cfg)
    step = start_step
    while True:
        yield step, ds.host_batch_at(step, host_id, n_hosts)
        step += 1
