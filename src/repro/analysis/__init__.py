"""scission-lint: static analysis for kernels, plans, and graphs.

Three analyzers over one shared :class:`Diagnostic` type:

* :mod:`repro.analysis.kernel_vmem` (SCN2xx) — static VMEM footprints of
  Pallas kernel candidates; feeds the autotuner's pre-timing pruning.
* :mod:`repro.analysis.plan_lint` (SCN1xx) — pre-solve query/constraint
  linting plus the exact joint-satisfiability backstop; feeds
  ``QueryResult.diagnostics``.
* :mod:`repro.analysis.graph_lint` (SCN3xx) — LayerGraph IR
  well-formedness; feeds ``LayerGraph.validate``.

Only the diagnostics vocabulary is exported eagerly — the analyzers (and
the ``python -m repro.analysis`` CLI) import their heavyweight
dependencies lazily so ``repro.core`` modules can depend on this package
without cycles.
"""

from .diagnostics import (CODES, Diagnostic, ERROR, INFO, WARNING, dedupe,
                          errors, has_errors, render_report,
                          sort_by_severity)

__all__ = [
    "CODES", "Diagnostic", "ERROR", "INFO", "WARNING", "dedupe", "errors",
    "has_errors", "render_report", "sort_by_severity",
]
