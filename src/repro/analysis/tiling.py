"""Static TPU tile-alignment analysis of kernel block-size candidates.

The TPU vector unit loads VMEM in fixed (sublane, lane) tiles whose
minimum size depends on the dtype — ``(8, 128)`` for float32, ``(16,
128)`` for bfloat16, ``(32, 128)`` for int8/fp8 (one 32-byte sublane
group by 128 lanes).  A Pallas block whose second-minor dimension is not
a multiple of the sublane count is silently padded to the next tile by
the compiler: the candidate still runs, but part of every vector op is
wasted work and the measured time stops being representative of an
aligned deployment.  Likewise a block size that does not divide its grid
axis leaves a padded remainder step (the kernels pad-and-mask uneven
lengths), so a fraction of the grid's compute is thrown away.

Both properties are static functions of (kernel, candidate params,
argument shapes) — the same inputs as the VMEM footprint model in
:mod:`repro.analysis.kernel_vmem`, whose per-kernel ``blocks`` dicts this
analyzer reuses so the two passes cannot drift apart.  The autotuner
(:class:`repro.kernels.substrate.KernelAutotuner`) consumes
:func:`misaligned_candidates` to prune misaligned candidates *before*
compile/measure, exactly like the SCN201 VMEM pruning; the CLI's
``tiling`` target runs the full :func:`lint_tiling` report.

Codes: SCN204 (warning, misaligned block), SCN205 (info, grid-remainder
padding waste), SCN206 (error, every candidate misaligned), SCN207
(info, sub-128-lane minor dimension).
"""

from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Sequence

import numpy as np

from .diagnostics import Diagnostic, ERROR, INFO, WARNING
from .kernel_vmem import kernel_footprint

LANE = 128

# Second-minor (sublane) tile requirement per dtype itemsize: one native
# 32-byte register row — 8 f32 / 16 bf16 / 32 int8 sublanes.
_SUBLANE_BY_ITEMSIZE = {4: 8, 2: 16, 1: 32}


def min_tile(dtype) -> tuple[int, int]:
    """Minimum TPU (sublane, lane) tile for ``dtype``: (8, 128) f32,
    (16, 128) bf16/f16, (32, 128) int8/fp8.  Wider dtypes fall back to
    the f32 tile."""
    itemsize = int(np.dtype(dtype).itemsize)
    return _SUBLANE_BY_ITEMSIZE.get(itemsize, 8), LANE


def _round_up(n: int, m: int) -> int:
    return -(-int(n) // int(m)) * int(m)


def _layout_dims(shape: Sequence[int]) -> tuple[int, int]:
    """(second-minor, minor) extents of a block once unit dimensions are
    squeezed away — the two dimensions the TPU tiles physically."""
    dims = [int(d) for d in shape if int(d) != 1]
    if not dims:
        return 1, 1
    if len(dims) == 1:
        return 1, dims[0]
    return dims[-2], dims[-1]


def _grid_axes(kernel: str, params: dict, args: Sequence,
               options: dict) -> dict[str, tuple[int, int]]:
    """The grid axes a candidate tiles, as ``{axis: (extent, block)}`` —
    the pad-and-mask remainder of each axis is the candidate's padding
    waste.  Mirrors the kernels' grid arithmetic (incl. block clamping)."""
    if kernel == "flash_attention":
        q = args[0]
        Sq = int(q.shape[1])
        Sk = int(args[1].shape[1]) if len(args) >= 3 else Sq
        return {"seq_q": (Sq, min(int(params.get("block_q", 128)), Sq)),
                "seq_k": (Sk, min(int(params.get("block_k", 128)), Sk))}
    if kernel == "decode_attention":
        Smax = int(args[1].shape[1]) if len(args) >= 3 \
            else int(options.get("cache_len", 0))
        if Smax <= 0:
            return {}
        return {"cache": (Smax, min(int(params.get("block_k", 256)), Smax))}
    if kernel == "ssd_scan":
        S = int(args[0].shape[1])
        return {"seq": (S, min(int(params.get("chunk", 128)), S))}
    return {}


@dataclass(frozen=True)
class TileAnalysis:
    """Static tiling report for one (kernel, candidate, shape) combination.

    ``misaligned`` maps block names to ``(second_minor, required_sublane)``
    for blocks whose second-minor extent is neither 1 nor a sublane
    multiple; ``lane_padded`` maps block names to ``(minor, padded_to)``
    for sub-128-lane minor dimensions (shape-inherent, not tunable);
    ``grid_waste`` maps grid axes to the fraction of the padded grid that
    is remainder padding."""

    kernel: str
    params: dict
    dtype: str
    sublane: int
    lane: int
    misaligned: dict[str, tuple[int, int]] = field(default_factory=dict)
    lane_padded: dict[str, tuple[int, int]] = field(default_factory=dict)
    grid_waste: dict[str, float] = field(default_factory=dict)

    @property
    def is_aligned(self) -> bool:
        return not self.misaligned

    @property
    def waste_fraction(self) -> float:
        return max(self.grid_waste.values(), default=0.0)


def analyze_tiling(kernel: str, params: dict, args: Sequence,
                   options: dict | None = None) -> TileAnalysis | None:
    """Tile-alignment analysis of one candidate, or ``None`` for a kernel
    unknown to the footprint model (same contract as
    :func:`repro.analysis.kernel_vmem.kernel_footprint`)."""
    options = options or {}
    try:
        fp = kernel_footprint(kernel, params, args, options)
    except Exception:
        # args that don't match the kernel's expected rank (synthetic
        # sweeps, partial shapes): statically unanalyzable, no opinion
        return None
    if fp is None:
        return None
    dtype = np.dtype(getattr(args[0], "dtype", np.float32))
    sublane, lane = min_tile(dtype)
    misaligned: dict[str, tuple[int, int]] = {}
    lane_padded: dict[str, tuple[int, int]] = {}
    for name, shape in sorted(fp.blocks.items()):
        second, minor = _layout_dims(shape)
        if second > 1 and second % sublane:
            misaligned[name] = (second, sublane)
        if minor % lane:
            lane_padded[name] = (minor, _round_up(minor, lane))
    grid_waste: dict[str, float] = {}
    for axis, (extent, block) in _grid_axes(kernel, params or {}, args,
                                            options).items():
        padded = _round_up(extent, block)
        if padded != extent:
            grid_waste[axis] = 1.0 - extent / padded
    return TileAnalysis(kernel, dict(params or {}), str(dtype), sublane,
                        lane, misaligned, lane_padded, grid_waste)


def misaligned_candidates(kernel: str, candidates: Sequence[dict],
                          args: Sequence,
                          options: dict | None = None) -> dict[str, str]:
    """The autotuner's pruning predicate: map each statically
    tile-misaligned candidate's canonical JSON key to a one-line reason.
    Unknown kernels (no footprint model) flag nothing."""
    flagged: dict[str, str] = {}
    for params in candidates:
        ta = analyze_tiling(kernel, params, args, options)
        if ta is None or ta.is_aligned:
            continue
        parts = ", ".join(f"{n}: {got} % {need} != 0"
                          for n, (got, need) in sorted(ta.misaligned.items()))
        flagged[json.dumps(params, sort_keys=True)] = (
            f"sublane-misaligned for {ta.dtype} "
            f"(min tile {ta.sublane}x{ta.lane}): {parts}")
    return flagged


# Grid-remainder waste below this fraction is not worth a diagnostic.
WASTE_THRESHOLD = 0.05


def lint_tiling(kernel: str, candidates: Sequence[dict], args: Sequence,
                *, options: dict | None = None,
                subject: str = "") -> tuple[list[dict], dict[str, str],
                                            list[Diagnostic]]:
    """Split a candidate sweep into (aligned, flagged, diagnostics) — the
    tiling twin of :func:`repro.analysis.kernel_vmem.lint_candidates`.

    ``flagged`` maps the candidate's canonical JSON key to the misalignment
    reason.  SCN204 (warning) per misaligned candidate, SCN205 (info) per
    candidate whose grid remainder pads away more than
    :data:`WASTE_THRESHOLD` of the work, SCN206 (error) when no candidate
    is aligned, SCN207 (info, once per sweep) for shape-inherent
    sub-128-lane minor dimensions.
    """
    subject = subject or kernel
    diags: list[Diagnostic] = []
    kept: list[dict] = []
    flagged: dict[str, str] = {}
    lane_reported = False
    for params in candidates:
        ta = analyze_tiling(kernel, params, args, options)
        if ta is None:
            kept.append(params)
            continue
        if ta.is_aligned:
            kept.append(params)
        else:
            key = json.dumps(params, sort_keys=True)
            parts = ", ".join(
                f"{n} second-minor {got} not a multiple of {need}"
                for n, (got, need) in sorted(ta.misaligned.items()))
            flagged[key] = parts
            diags.append(Diagnostic(
                "SCN204", WARNING,
                f"candidate {params} is misaligned to the {ta.dtype} "
                f"minimum tile {ta.sublane}x{ta.lane}: {parts}; the "
                f"compiler pads every block and the measured time stops "
                f"being representative", subject=subject,
                hint=f"use multiples of {ta.sublane} for tiled block "
                     f"dimensions"))
        if ta.waste_fraction > WASTE_THRESHOLD:
            worst = max(ta.grid_waste, key=ta.grid_waste.get)
            diags.append(Diagnostic(
                "SCN205", INFO,
                f"candidate {params} pads {ta.waste_fraction:.0%} of the "
                f"{worst!r} grid axis away as remainder (pad-and-mask "
                f"steps compute masked-out work)", subject=subject,
                hint="prefer block sizes dividing the sequence length"))
        if ta.lane_padded and not lane_reported:
            lane_reported = True
            parts = ", ".join(f"{n}: {got} -> {pad}"
                              for n, (got, pad) in
                              sorted(ta.lane_padded.items()))
            diags.append(Diagnostic(
                "SCN207", INFO,
                f"minor dimensions below the {LANE}-lane tile are "
                f"relayout-padded: {parts}", subject=subject,
                hint="shape-inherent (head/state dim), not tunable per "
                     "candidate"))
    if candidates and not kept and flagged:
        diags.append(Diagnostic(
            "SCN206", ERROR,
            f"every candidate of {kernel!r} is tile-misaligned for "
            f"{np.dtype(getattr(args[0], 'dtype', np.float32))!s} inputs",
            subject=subject,
            hint="add sublane-multiple block sizes to the sweep"))
    return kept, flagged, diags
