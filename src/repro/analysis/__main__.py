"""``python -m repro.analysis`` — the scission-lint entry point."""

import sys

from .cli import main

sys.exit(main())
