"""Static VMEM-footprint analysis of the Pallas kernel candidates (SCN2xx).

Each kernel's ``pallas_call`` declares exactly which tiles live in VMEM at
once: the gridded input/output blocks (shape × dtype from the BlockSpecs)
plus the scratch buffers.  That makes the footprint of a block-size
candidate a *static* function of (kernel, candidate params, argument
shapes) — no tracing, no compilation — so over-budget candidates can be
pruned before the autotuner spends compile/measure time on them, and a
deployment plan can be checked against a resource's ``vmem_bytes``
capability offline.

Footprint model (documented assumption, same shape as the guide's
``compute_vmem_bytes`` discipline): the Pallas TPU pipeline double-buffers
every gridded input and output block (compute on one buffer while DMA
fills the other), scratch buffers are single-buffered, and SMEM operands
(e.g. ``decode_attention``'s lengths vector) do not count against VMEM:

    vmem = 2 * (sum of input blocks + sum of output blocks) + scratch

The per-kernel functions below mirror the BlockSpecs in ``kernels/*.py``
one for one — including the ``min(block, dim)`` clamping the kernels apply
— so the analyzer and the kernels cannot drift apart silently (the unit
tests assert the mirrored shapes against the kernel sources' specs).
"""

from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Any, Sequence

import numpy as np

from .diagnostics import Diagnostic, ERROR, INFO

# Pallas TPU pipelining: gridded in/out blocks are double-buffered.
DOUBLE_BUFFER = 2

# A practical per-core budget for TPU targets (the guide's ~16 MB/core);
# exported so testbeds can write ``vmem_bytes=TPU_VMEM_BYTES`` instead of a
# magic number.
TPU_VMEM_BYTES = 16 * 1024 * 1024


def _itemsize(dtype) -> int:
    return int(np.dtype(dtype).itemsize)


def _nbytes(shape: Sequence[int], dtype) -> int:
    n = 1
    for d in shape:
        n *= int(d)
    return n * _itemsize(dtype)


@dataclass(frozen=True)
class KernelFootprint:
    """Static VMEM footprint of one (kernel, candidate, shape) combination.

    ``parts`` break the total down into double-buffered input blocks,
    double-buffered output blocks and single-buffered scratch.
    """

    kernel: str
    params: dict
    in_bytes: int                   # already double-buffered
    out_bytes: int                  # already double-buffered
    scratch_bytes: int
    blocks: dict[str, tuple] = field(default_factory=dict)

    @property
    def vmem_bytes(self) -> int:
        return self.in_bytes + self.out_bytes + self.scratch_bytes


def _flash_attention_footprint(params: dict, args: Sequence,
                               options: dict) -> KernelFootprint:
    q = args[0]
    B, Sq, H, hd = q.shape
    if len(args) >= 3:
        Sk = args[1].shape[1]
    else:                           # self-attention node: q == k == v
        Sk = Sq
    bq = min(int(params.get("block_q", 128)), int(Sq))
    bk = min(int(params.get("block_k", 128)), int(Sk))
    blocks = {
        "q": (1, bq, 1, hd), "k": (1, bk, 1, hd), "v": (1, bk, 1, hd),
        "o": (1, bq, 1, hd),
    }
    in_b = sum(_nbytes(blocks[n], q.dtype) for n in ("q", "k", "v"))
    out_b = _nbytes(blocks["o"], q.dtype)
    scratch = _nbytes((bq,), np.float32) * 2 + _nbytes((bq, hd), np.float32)
    return KernelFootprint("flash_attention", dict(params),
                           DOUBLE_BUFFER * in_b, DOUBLE_BUFFER * out_b,
                           scratch, blocks)


def _decode_attention_footprint(params: dict, args: Sequence,
                                options: dict) -> KernelFootprint:
    q = args[0]
    if q.ndim == 4:                 # already grouped (B, Hk, G, hd)
        B, Hk, G, hd = q.shape
        H = Hk * G
    else:                           # public layout (B, H, hd)
        B, H, hd = q.shape
        Hk = int(options.get("kv_heads",
                             args[1].shape[2] if len(args) >= 3 else H))
        G = H // max(1, Hk)
    Smax = int(args[1].shape[1]) if len(args) >= 3 \
        else int(options.get("cache_len", 0))
    if Smax <= 0:
        raise ValueError("decode_attention footprint needs the cache "
                         "length (k/v argument or options['cache_len'])")
    bk = min(int(params.get("block_k", 256)), Smax)
    blocks = {
        "q": (1, 1, G, hd), "k": (1, bk, 1, hd), "v": (1, bk, 1, hd),
        "o": (1, 1, G, hd),
    }
    # the lengths vector lives in SMEM — excluded from the VMEM budget
    in_b = sum(_nbytes(blocks[n], q.dtype) for n in ("q", "k", "v"))
    out_b = _nbytes(blocks["o"], q.dtype)
    scratch = _nbytes((G,), np.float32) * 2 + _nbytes((G, hd), np.float32)
    return KernelFootprint("decode_attention", dict(params),
                           DOUBLE_BUFFER * in_b, DOUBLE_BUFFER * out_b,
                           scratch, blocks)


def _ssd_scan_footprint(params: dict, args: Sequence,
                        options: dict) -> KernelFootprint:
    x = args[0]
    B, S, H, P = x.shape
    N = int(args[2].shape[-1]) if len(args) >= 4 \
        else int(options.get("state_dim", 16))
    L = min(int(params.get("chunk", 128)), int(S))
    blocks = {
        "x": (1, L, 1, P), "log_a": (1, L, 1), "b": (1, L, 1, N),
        "c": (1, L, 1, N), "y": (1, L, 1, P), "final": (1, 1, N, P),
    }
    in_b = sum(_nbytes(blocks[n], x.dtype)
               for n in ("x", "log_a", "b", "c"))
    out_b = _nbytes(blocks["y"], x.dtype) \
        + _nbytes(blocks["final"], np.float32)
    scratch = _nbytes((N, P), np.float32)
    return KernelFootprint("ssd_scan", dict(params),
                           DOUBLE_BUFFER * in_b, DOUBLE_BUFFER * out_b,
                           scratch, blocks)


_FOOTPRINTS = {
    "flash_attention": _flash_attention_footprint,
    "decode_attention": _decode_attention_footprint,
    "ssd_scan": _ssd_scan_footprint,
}


def known_kernels() -> tuple[str, ...]:
    return tuple(sorted(_FOOTPRINTS))


def kernel_footprint(kernel: str, params: dict, args: Sequence,
                     options: dict | None = None) -> KernelFootprint | None:
    """Static VMEM footprint of one candidate, or ``None`` for a kernel the
    analyzer does not know.  ``args`` are the kernel's positional arguments
    (arrays or ShapeDtypeStructs — only ``.shape``/``.dtype`` are read);
    ``options`` are the node's ``kernel_options`` (used when a graph node's
    single input does not expose every dimension, e.g. a closed-over KV
    cache)."""
    fn = _FOOTPRINTS.get(kernel)
    if fn is None:
        return None
    return fn(params or {}, args, options or {})


def kernel_vmem_bytes(kernel: str, params: dict, args: Sequence,
                      options: dict | None = None) -> int | None:
    fp = kernel_footprint(kernel, params, args, options)
    return None if fp is None else fp.vmem_bytes


def _mb(n: float) -> str:
    return f"{n / 2**20:.2f}MiB"


def lint_candidates(kernel: str, candidates: Sequence[dict], args: Sequence,
                    *, vmem_limit: float | None,
                    options: dict | None = None,
                    subject: str = "") -> tuple[list[dict], dict[str, int],
                                                list[Diagnostic]]:
    """Split a candidate sweep into (admissible, pruned, diagnostics).

    ``pruned`` maps the candidate's canonical JSON key to its computed
    footprint in bytes.  With no ``vmem_limit`` (or an unknown kernel)
    every candidate is admissible.  SCN201 (info) is emitted per pruned
    candidate, SCN202 (error) when nothing survives, SCN203 (info) when
    the kernel is unknown to the analyzer.
    """
    subject = subject or kernel
    diags: list[Diagnostic] = []
    if vmem_limit is None:
        return list(candidates), {}, diags
    kept: list[dict] = []
    pruned: dict[str, int] = {}
    for params in candidates:
        fp = kernel_footprint(kernel, params, args, options)
        if fp is None:
            diags.append(Diagnostic(
                "SCN203", INFO,
                f"kernel {kernel!r} is unknown to the VMEM analyzer; "
                f"candidate {params} kept unchecked", subject=subject,
                hint="register a footprint function in "
                     "repro.analysis.kernel_vmem._FOOTPRINTS"))
            kept.append(params)
            continue
        if fp.vmem_bytes > vmem_limit:
            key = json.dumps(params, sort_keys=True)
            pruned[key] = fp.vmem_bytes
            diags.append(Diagnostic(
                "SCN201", INFO,
                f"candidate {params} needs {_mb(fp.vmem_bytes)} VMEM "
                f"(> budget {_mb(vmem_limit)}); pruned before timing",
                subject=subject,
                hint="shrink the block sizes or raise the resource's "
                     "vmem_bytes"))
        else:
            kept.append(params)
    if candidates and not kept:
        smallest = min(pruned.values(), default=0)
        diags.append(Diagnostic(
            "SCN202", ERROR,
            f"every candidate of {kernel!r} exceeds the "
            f"{_mb(vmem_limit)} VMEM budget (smallest needs "
            f"{_mb(smallest)})", subject=subject,
            hint="add smaller block-size candidates to the sweep or raise "
                 "the resource's vmem_bytes"))
    return kept, pruned, diags
