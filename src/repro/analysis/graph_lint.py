"""Graph IR checker (SCN3xx): LayerGraph well-formedness.

``LayerGraph`` malformations used to surface in one of two bad ways: a
terse ``ValueError`` from ``validate()`` naming a node *index*, or — for
shape bugs — a deep JAX trace error from ``eval_shape`` pages away from
the offending layer.  This checker turns both into named-node
:class:`Diagnostic` s:

* structural well-formedness — non-empty, acyclic (predecessor indices
  strictly earlier: the topological-insertion invariant), no dangling
  predecessor indices, exactly one sink (the last node), no orphan
  sources beyond the input node, every non-input node callable;
* shape-chain consistency (``check_shapes=True``, traced graphs only) —
  each node's declared ``out_spec`` must equal the spec recomputed from
  its predecessors' ``out_spec`` s via ``jax.eval_shape``, so a stale or
  hand-edited spec is caught at the node that declares it;
* benchmark cross-check (:func:`lint_db_against_graph`) — a DB's recorded
  per-block output bytes must match the graph the blocks were fused from.

``LayerGraph.validate`` raises :class:`GraphLintError` (a ``ValueError``
subclass, so existing ``except ValueError`` call sites keep working) that
carries the full diagnostic list; ``fuse_blocks`` and the model-zoo
adapters run through it.

Import-light: ``jax`` is imported lazily (only the shape-chain check
needs it), so the analysis package stays usable for plan linting in
environments without an accelerator stack.
"""

from __future__ import annotations

from typing import Any

from .diagnostics import Diagnostic, ERROR, INFO, errors, render_report


class GraphLintError(ValueError):
    """Raised by ``LayerGraph.validate`` when the checker finds errors.

    Subclasses ``ValueError`` for drop-in compatibility with the previous
    ad-hoc raises; ``diagnostics`` carries every finding (not only the
    first), each naming the offending node.
    """

    def __init__(self, title: str, diagnostics: list[Diagnostic]):
        self.diagnostics = list(diagnostics)
        super().__init__(render_report(self.diagnostics, title))


def _name(graph: Any, i: int) -> str:
    if 0 <= i < len(graph.nodes):
        return f"{graph.nodes[i].name!r} (node {i})"
    return f"node {i}"


def lint_graph(graph: Any, *, check_shapes: bool = False) -> list[Diagnostic]:
    """Well-formedness diagnostics for a :class:`repro.core.graph.LayerGraph`.

    With ``check_shapes=True`` the declared ``out_spec`` of every traced
    node is re-derived from its predecessors and compared (SCN306); an
    untraced graph gets a single SCN308 info instead.
    """
    diags: list[Diagnostic] = []
    n = len(graph.nodes)
    if n == 0:
        return [Diagnostic("SCN301", ERROR,
                           f"graph {graph.name!r} is empty",
                           subject=graph.name,
                           hint="add an input node first (graph.input(spec))")]

    # SCN302 — dangling / non-topological predecessor indices.  add()
    # enforces this at insert time, but graphs are plain lists and adapters
    # may rewrite preds; a violation here also rules out every later check
    # that walks the edges, so report and stop early.
    bad_edges = False
    for i, ps in enumerate(graph.preds):
        for p in ps:
            if not 0 <= p < i:
                bad_edges = True
                what = "dangling" if not 0 <= p < n else \
                    "non-topological (would create a cycle)"
                diags.append(Diagnostic(
                    "SCN302", ERROR,
                    f"{_name(graph, i)} has {what} predecessor index {p}",
                    subject=graph.nodes[i].name,
                    hint="predecessors must be strictly earlier nodes"))
    if bad_edges:
        return diags

    succs = graph.succs
    sinks = [i for i, s in enumerate(succs) if not s]
    for i in sinks:
        if i != n - 1:
            diags.append(Diagnostic(
                "SCN303", ERROR,
                f"{_name(graph, i)} has no successors but is not the final "
                f"node; a LayerGraph has exactly one sink (the last node)",
                subject=graph.nodes[i].name,
                hint="connect the node forward, or drop it"))
    for i in range(1, n):
        if not graph.preds[i]:
            diags.append(Diagnostic(
                "SCN304", ERROR,
                f"{_name(graph, i)} is an orphan source; only node 0 (the "
                "input) may have no predecessors",
                subject=graph.nodes[i].name,
                hint="pass preds=[...] when adding the node"))
        if graph.nodes[i].apply is None:
            diags.append(Diagnostic(
                "SCN305", ERROR,
                f"{_name(graph, i)} has no apply function",
                subject=graph.nodes[i].name,
                hint="every non-input node needs a callable apply"))

    if check_shapes and not errors(diags):
        if any(node.out_spec is None for node in graph.nodes):
            diags.append(Diagnostic(
                "SCN308", INFO,
                f"graph {graph.name!r} is untraced: shape-chain checks "
                "skipped", subject=graph.name,
                hint="call graph.trace() first"))
        else:
            diags.extend(_lint_shape_chain(graph))
    if not errors(diags):
        diags.extend(_lint_sp_structure(graph))
    return diags


def _lint_sp_structure(graph: Any) -> list[Diagnostic]:
    """SCN309/SCN310: series-parallel structure of a branchy graph.

    * SCN309 — a region is **not series-parallel** (a branch exits through
      more than one node, or crossing skips leave no fork-join shape):
      ``fuse_block_dag`` linearises it into one block, so no cut can land
      inside it.  Names the offending subgraph's nodes.
    * SCN310 — the graph has a parallel region but chain fusing
      (``fuse_blocks``) is in use semantics-wise: any consumer that fuses
      this graph as a chain collapses the region into a single block and
      the branch-placement freedom is silently lost.  Emitted whenever a
      parallel region exists and the chain fusing would merge its nodes
      into one block — i.e. always, since chain cuts cannot enter a
      multi-producer region.

    Both are WARNINGs: the graph is well-formed either way; only the
    partitioner's freedom is affected.
    """
    from .diagnostics import WARNING

    diags: list[Diagnostic] = []
    try:
        from ..core.graph import sp_summary
    except Exception:                               # noqa: BLE001
        return diags                # core (jax) unavailable: skip
    parallel_regions, collapsed = sp_summary(graph)
    for seg in collapsed:
        names = ", ".join(graph.nodes[i].name for i in seg[:6])
        if len(seg) > 6:
            names += f", … ({len(seg)} nodes)"
        diags.append(Diagnostic(
            "SCN309", WARNING,
            f"graph {graph.name!r}: subgraph [{names}] is not "
            "series-parallel; fuse_block_dag linearises it into one block "
            "and no partition point can land inside it",
            subject=graph.nodes[seg[0]].name,
            hint="restructure crossing skip connections into nested "
                 "fork-join regions to expose its cut points"))
    if parallel_regions:
        total = sum(len(r) for r in parallel_regions)
        diags.append(Diagnostic(
            "SCN310", WARNING,
            f"graph {graph.name!r} has {len(parallel_regions)} parallel "
            f"region(s) ({total} branch nodes) that chain fusing "
            "(fuse_blocks) collapses into single blocks, discarding "
            "branch-placement freedom",
            subject=graph.name,
            hint="fuse with fuse_block_dag / benchmark(dag=True) to "
                 "partition branches across resources"))
    return diags


def _lint_shape_chain(graph: Any) -> list[Diagnostic]:
    """SCN306: re-derive each node's out_spec from its predecessors'
    declared specs and compare.  Runs node-at-a-time so a mismatch is
    reported at the node that *declares* the stale spec, not at the first
    downstream consumer that trips over it."""
    import jax

    diags: list[Diagnostic] = []
    for i in range(1, len(graph.nodes)):
        node = graph.nodes[i]
        ins = [graph.nodes[p].out_spec for p in graph.preds[i]]
        try:
            computed = jax.eval_shape(node.apply, *ins)
        except Exception as e:                      # noqa: BLE001
            diags.append(Diagnostic(
                "SCN306", ERROR,
                f"{_name(graph, i)}: apply does not accept its "
                f"predecessors' out_specs ({type(e).__name__}: {e})",
                subject=node.name,
                hint="the upstream node's out_spec is probably stale"))
            continue
        declared = node.out_spec
        if (tuple(computed.shape) != tuple(declared.shape)
                or computed.dtype != declared.dtype):
            diags.append(Diagnostic(
                "SCN306", ERROR,
                f"{_name(graph, i)} declares out_spec "
                f"{tuple(declared.shape)}/{declared.dtype} but its "
                f"predecessors' specs compute "
                f"{tuple(computed.shape)}/{computed.dtype}",
                subject=node.name,
                hint="re-run graph.trace() after editing the graph"))
    return diags


def lint_db_against_graph(db: Any, blocks: list[Any]) -> list[Diagnostic]:
    """SCN307: a benchmark DB's recorded output bytes vs the graph's
    computed ones — catches a DB paired with the wrong (or since-edited)
    model graph before its transfer costs poison a solve."""
    from .diagnostics import WARNING

    diags: list[Diagnostic] = []
    if db.n_blocks != len(blocks):
        diags.append(Diagnostic(
            "SCN307", WARNING,
            f"DB for model {db.model!r} records {db.n_blocks} blocks but "
            f"the graph fuses into {len(blocks)}",
            subject=db.model,
            hint="re-run benchmark_model against the current graph"))
        return diags
    for i, blk in enumerate(blocks):
        recorded = float(db.output_bytes(i))
        computed = float(blk.output_bytes)
        if recorded != computed:
            diags.append(Diagnostic(
                "SCN307", WARNING,
                f"block {i} ({blk.name}): DB records "
                f"{recorded:.0f} output bytes but the graph computes "
                f"{computed:.0f}", subject=blk.name,
                hint="re-run benchmark_model against the current graph"))
    return diags
