"""Pre-solve plan linter (SCN1xx): Query × Constraints × fleet × network.

The lattices and the exhaustive strategy are deliberately silent about
*why* a query is infeasible — an unsatisfiable constraint set yields ``[]``
from every solver (matching the oracle).  This module explains those
empties before (or after) the solve ever runs:

* :func:`lint_plan` — cheap structural checks over the query against the
  fleet, the benchmark DB and the network model.  Each finding is an
  itemized, coded :class:`Diagnostic` (contradictory must_use/exclude,
  impossible floors, caps below every single-block time, tier collisions,
  one-way links, ...).
* :func:`feasible_exists` — an exact chain-feasibility DP over (pipeline,
  cut positions) mirroring the engine's ``_config_satisfies`` semantics.
  Sound and complete on the same search space the solvers range over, so
  when no itemized check fires it still proves joint unsatisfiability
  (SCN109) — the backstop that makes "empty result ⇒ error diagnostic"
  a theorem rather than a heuristic.

``QueryEngine.run`` / ``frontier`` attach the combined findings to
``QueryResult.diagnostics`` (the deep DP only runs on empty results that
no itemized error already explains).

The module is import-light on purpose: ``repro.core`` is imported lazily
inside functions, so ``core`` modules may import this one without cycles.
"""

from __future__ import annotations

from typing import Any, Iterable, Sequence

from .diagnostics import Diagnostic, ERROR, WARNING, has_errors

# feasible_exists() gives up (returns None) beyond this many candidate
# pipelines — fleet-sized spaces get their explanation from the itemized
# checks only, never from an exponential sweep
MAX_PIPELINES = 50_000


def _fmt_s(t: float) -> str:
    return f"{t * 1e3:.3f}ms"


# ---------------------------------------------------------------------------
# structural checks
# ---------------------------------------------------------------------------

def lint_plan(query: Any, resources: Sequence[Any], network: Any = None,
              db: Any = None, *, source: str | None = None,
              batches: Sequence[int] | None = None,
              check_top_n: bool = True) -> list[Diagnostic]:
    """Structural lint of one query against a fleet.

    ``query`` is duck-typed (a ``repro.core.Query`` or anything with the
    same constraint fields); ``db`` (a ``BenchmarkDB``) enables the
    block-count and timing checks; ``batches`` are the operating points the
    caller will price (an error that needs timing data is only emitted when
    it holds at *every* batch, matching frontier semantics).
    """
    diags: list[Diagnostic] = []
    names = {r.name for r in resources}
    order = {r.name: r.order for r in resources}
    bench = names & set(db.records) if db is not None else set(names)
    n_blocks = db.n_blocks if db is not None else None
    batches = [int(b) for b in (batches or (getattr(query, "batch_size", 1),))]

    must = tuple(getattr(query, "must_use", ()))
    excl = set(getattr(query, "exclude", ()))
    pin = dict(getattr(query, "pin", {}) or {})
    caps = dict(getattr(query, "max_resource_time", {}) or {})
    floors = {r: int(k) for r, k in
              (getattr(query, "min_blocks_on", {}) or {}).items()}
    demanded = list(dict.fromkeys(
        [*must, *(r for r, k in floors.items() if k >= 1)]))

    if check_top_n and getattr(query, "top_n", 1) <= 0:
        diags.append(Diagnostic(
            "SCN112", ERROR,
            f"top_n={query.top_n} requests an empty result by construction",
            hint="ask for top_n >= 1"))

    # SCN101 — direct contradictions
    for r in sorted(set(must) & excl):
        diags.append(Diagnostic(
            "SCN101", ERROR,
            f"resource {r!r} is in both must_use and exclude", subject=r,
            hint="drop it from one of the two lists"))
    for r in sorted({r for r in floors if floors[r] >= 1} & excl):
        diags.append(Diagnostic(
            "SCN101", ERROR,
            f"excluded resource {r!r} has a min_blocks_on floor of "
            f"{floors[r]} (a floor >= 1 demands presence)", subject=r,
            hint="drop the exclusion or the floor"))
    for b, r in sorted(pin.items()):
        if r in excl:
            diags.append(Diagnostic(
                "SCN101", ERROR,
                f"block {b} is pinned to excluded resource {r!r}", subject=r,
                hint="drop the exclusion or move the pin"))

    # SCN102 — unknown / un-benchmarked names
    def check_name(r: str, where: str, hard: bool) -> bool:
        if r in bench:
            return True
        what = "not benchmarked" if r in names else "unknown"
        diags.append(Diagnostic(
            "SCN102", ERROR if hard else WARNING,
            f"{where} names {what} resource {r!r}", subject=r,
            hint="benchmark it first, or fix the name"
            if r in names else "fix the name (no such resource in the fleet)"))
        return False

    for r in demanded:
        check_name(r, "must_use/min_blocks_on", hard=True)
    for b, r in sorted(pin.items()):
        check_name(r, f"pin of block {b}", hard=True)
    for r in sorted(excl):
        if r not in names:
            check_name(r, "exclude", hard=False)
    for r in sorted(caps):
        if r not in names:
            check_name(r, "max_resource_time", hard=False)
    for r in sorted(getattr(query, "replicas", {}) or {}):
        if r not in names:
            check_name(r, "replicas", hard=False)
    for pair in sorted(getattr(query, "max_link_bytes", {}) or {}):
        for r in pair:
            if r not in names and r != (source or ""):
                check_name(r, f"max_link_bytes[{pair}]", hard=False)

    # SCN103 / SCN104 — block-count arithmetic
    if n_blocks is not None:
        for r, k in sorted(floors.items()):
            if k > n_blocks:
                diags.append(Diagnostic(
                    "SCN103", ERROR,
                    f"min_blocks_on floor {k} on {r!r} exceeds the model's "
                    f"{n_blocks} blocks", subject=r,
                    hint=f"the floor can be at most {n_blocks}"))
        present = [r for r in demanded if r in bench]
        need = sum(max(1, floors.get(r, 1)) for r in present)
        if need > n_blocks and \
                all(floors.get(r, 1) <= n_blocks for r in present):
            diags.append(Diagnostic(
                "SCN104", ERROR,
                f"the demanded resources ({', '.join(present)}) need at "
                f"least {need} blocks between them but the model has only "
                f"{n_blocks}",
                hint="relax a floor or drop a must_use entry"))

    # SCN106 — tier collisions among demanded resources, pin-order sanity
    tier_of: dict[int, str] = {}
    for r in demanded:
        if r not in order:
            continue
        prev = tier_of.setdefault(order[r], r)
        if prev != r:
            diags.append(Diagnostic(
                "SCN106", ERROR,
                f"demanded resources {prev!r} and {r!r} share a tier; a "
                "pipeline holds at most one resource per tier", subject=r,
                hint="demand at most one resource per tier"))
    pins = sorted((int(b), r) for b, r in pin.items() if r in order)
    for b, r in pins:
        if n_blocks is not None and not 0 <= b < n_blocks:
            diags.append(Diagnostic(
                "SCN106", ERROR,
                f"pin targets block {b}, outside the model's blocks "
                f"0..{n_blocks - 1}", subject=r,
                hint="fix the block index"))
    for (b1, r1), (b2, r2) in zip(pins, pins[1:]):
        if r1 == r2:
            continue
        if order[r1] > order[r2]:
            diags.append(Diagnostic(
                "SCN106", ERROR,
                f"pins violate tier order: block {b1} on {r1!r} "
                f"(tier {order[r1]}) precedes block {b2} on {r2!r} "
                f"(tier {order[r2]}) but data flows device -> edge -> "
                "cloud", subject=r2,
                hint="pin earlier blocks to earlier tiers"))
        elif order[r1] == order[r2]:
            diags.append(Diagnostic(
                "SCN106", ERROR,
                f"blocks {b1} and {b2} are pinned to different resources "
                f"({r1!r}, {r2!r}) on the same tier; a pipeline holds at "
                "most one resource per tier", subject=r2,
                hint="pin both to one resource, or to different tiers"))

    # SCN105 — compute-time caps below every single-block time
    if db is not None:
        for r, cap in sorted(caps.items()):
            if r not in bench or r in excl:
                continue
            if all(min(db.time(r, b, batch) for b in range(n_blocks)) > cap
                   for batch in batches):
                hard = r in demanded
                diags.append(Diagnostic(
                    "SCN105", ERROR if hard else WARNING,
                    f"max_resource_time {_fmt_s(cap)} on {r!r} is below "
                    "every single-block time"
                    + ("" if len(batches) == 1
                       else " at every swept batch size")
                    + (" — no feasible configuration can use it" if hard
                       else f" — {r!r} can never host a block"),
                    subject=r,
                    hint="raise the cap or drop the resource instead"))
        for b, r in pins:
            cap = caps.get(r)
            if cap is None or r not in bench or not (
                    n_blocks is not None and 0 <= b < n_blocks):
                continue
            if all(db.time(r, b, batch) > cap for batch in batches):
                diags.append(Diagnostic(
                    "SCN105", ERROR,
                    f"block {b} is pinned to {r!r} but its single-block "
                    f"time already exceeds the {_fmt_s(cap)} cap",
                    subject=r, hint="raise the cap or move the pin"))

    # SCN108 — the pipelines restriction (or blanket exclusion) admits none
    if names and names <= excl:
        diags.append(Diagnostic(
            "SCN108", ERROR,
            "every fleet resource is excluded: no pipeline can be formed",
            hint="keep at least one resource admissible"))
    restriction = getattr(query, "pipelines", None)
    if restriction is not None:
        valid = [tuple(p) for p in restriction
                 if all(n in order for n in p)
                 and all(order[a] < order[b] for a, b in zip(p, p[1:]))]
        dset = set(demanded)
        admissible = [p for p in valid
                      if not (dset - set(p)) and not (set(p) & excl)]
        if not admissible:
            why = "no pipeline is tier-ordered over known resources" \
                if not valid else \
                "every valid pipeline misses a demanded resource or " \
                "contains an excluded one"
            diags.append(Diagnostic(
                "SCN108", ERROR,
                f"the pipelines restriction admits no valid pipeline: {why}",
                hint="list pipelines in strictly ascending tier order and "
                     "keep them consistent with must_use/exclude"))

    # SCN107 / SCN110 — network introspection (needs NetworkModel.links())
    links = network.links() if network is not None \
        and hasattr(network, "links") else None
    if links is not None:
        forced: list[tuple[str, str]] = []
        if source and 0 in pin and pin[0] != source:
            forced.append((source, pin[0]))
        for (b1, r1), (b2, r2) in zip(pins, pins[1:]):
            if b2 == b1 + 1 and r1 != r2:
                forced.append((r1, r2))
        for src, dst in forced:
            if (src, dst) not in links:
                diags.append(Diagnostic(
                    "SCN107", WARNING,
                    f"pinned hop {src!r} -> {dst!r} has no explicit link; "
                    "the default link prices it", subject=f"{src}->{dst}",
                    hint="connect() the pair explicitly if the default "
                         "does not describe this hop"))
        for (a, b) in sorted(links):
            if a == b or (b, a) in links:
                continue
            if a in order and b in order and order[b] < order[a]:
                # the explicit link points against the data-flow direction;
                # the direction the planner can actually use falls back
                diags.append(Diagnostic(
                    "SCN110", WARNING,
                    f"one-way link {a!r} -> {b!r}: the planner-usable "
                    f"direction {b!r} -> {a!r} silently falls back to the "
                    "default link", subject=f"{b}->{a}",
                    hint="connect(src, dst, link) with symmetric=True, or "
                         "add the reverse direction explicitly"))
    return diags


# ---------------------------------------------------------------------------
# exact chain-feasibility backstop (SCN109)
# ---------------------------------------------------------------------------

def _candidate_pipelines(resources: Sequence[Any],
                         restriction: Iterable[Sequence[str]] | None,
                         limit: int = MAX_PIPELINES
                         ) -> list[tuple[str, ...]] | None:
    """The pipeline set a query ranges over, or ``None`` when it would
    exceed ``limit`` (fleet-sized spaces: the DP declines to run)."""
    order = {r.name: r.order for r in resources}
    if restriction is not None:
        pipes = [tuple(p) for p in restriction
                 if all(n in order for n in p)
                 and all(order[a] < order[b] for a, b in zip(p, p[1:]))]
        return None if len(pipes) > limit else pipes
    tiers: dict[int, list[str]] = {}
    for r in sorted(resources, key=lambda r: r.order):
        tiers.setdefault(r.order, []).append(r.name)
    total = 1
    for lvl in tiers.values():
        total *= len(lvl) + 1
    if total - 1 > limit:
        return None
    from repro.core.partition import ordered_pipelines
    return ordered_pipelines(list(resources))


def _pipe_feasible(cost: Any, cons: Any, pipe: tuple[str, ...]) -> bool:
    """Exact DP over cut positions: can blocks 0..B-1 be split into
    ``len(pipe)`` contiguous segments hosted by ``pipe`` in order, under
    every constraint?  Mirrors ``QueryEngine._config_satisfies`` bit for
    bit (``allowed`` covers exclude+pin, ``transition_allowed`` the link
    caps, ``segment_time`` the compute-time caps, floors at close)."""
    B = cost.n_blocks
    k = len(pipe)
    if k > B:
        return False
    if pipe[0] != cost.source and not cons.transition_allowed(
            cost.source, pipe[0], cost.batch_input_bytes):
        return False
    starts = {0}
    for j, r in enumerate(pipe):
        last = j == k - 1
        cap = cons.max_resource_time.get(r)
        floor = cons.min_blocks_on.get(r, 0)
        nxt: set[int] = set()
        for b in sorted(starts):
            e_max = B - 1 - (k - 1 - j)
            for e in range(b, e_max + 1):
                if not cons.allowed(e, r):
                    break               # contiguity: no later e works either
                if cap is not None and cost.segment_time(r, b, e) > cap:
                    break               # segment time is monotone in e
                if e - b + 1 < floor:
                    continue
                if last:
                    if e == B - 1:
                        return True
                    continue
                if cons.transition_allowed(r, pipe[j + 1],
                                           float(cost.out_bytes[e])):
                    nxt.add(e + 1)
        if last:
            return False
        starts = nxt
        if not starts:
            return False
    return False


def feasible_exists(cost: Any, cons: Any,
                    pipelines: Iterable[Sequence[str]] | None = None,
                    limit: int = MAX_PIPELINES) -> bool | None:
    """Whether any configuration satisfies ``cons`` at ``cost``'s operating
    point — exactly the exhaustive strategy's feasible set being non-empty.
    Returns ``None`` (unknown) when the pipeline space exceeds ``limit``.
    """
    pipes = _candidate_pipelines(cost.resources, pipelines, limit)
    if pipes is None:
        return None
    demanded = set(cons.must_use) | {
        r for r, n in cons.min_blocks_on.items() if n >= 1}
    pinned = set(cons.pin.values())
    for pipe in pipes:
        members = set(pipe)
        if demanded - members or (members & cons.exclude) \
                or (pinned - members):
            continue
        if _pipe_feasible(cost, cons, pipe):
            return True
    return False


def explain_empty(query: Any, cons: Any, costs: Sequence[Any],
                  prior: Sequence[Diagnostic] = ()) -> list[Diagnostic]:
    """The SCN109 backstop for an empty result: prove (exactly) that the
    constraints are jointly unsatisfiable at *every* priced operating
    point.  Skipped when an itemized error in ``prior`` already explains
    the empty, or when the space is too large to sweep."""
    if has_errors(list(prior)):
        return []
    restriction = getattr(query, "pipelines", None)
    for cost in costs:
        verdict = feasible_exists(cost, cons, pipelines=restriction)
        if verdict is None or verdict:
            return []
    points = "" if len(costs) == 1 else \
        f" at every swept operating point ({len(costs)} batch sizes)"
    return [Diagnostic(
        "SCN109", ERROR,
        "the constraints are jointly unsatisfiable: an exact sweep over "
        f"every (pipeline, cut) combination found no feasible "
        f"configuration{points}",
        hint="relax one constraint at a time (caps and floors interact "
             "with pins and link limits) and re-run the linter")]
