"""Offline soundness checks of a BenchmarkDB + NetworkModel (SCN4xx).

The exact DPs (``core/lattice``) are only exact *under premises*: stage
times and byte counts are finite and non-negative (additive accumulation
and dominance pruning), batch profiles are monotone in batch (the
log-linear interpolation between measured points stays meaningful),
every active resource covers the batches the fleet prices (otherwise
SCN111 clamps silently distort operating points), links behave like the
paper's ``latency + bytes/bandwidth`` model, and the cost model composes
latency additively / bottleneck by max.  None of that is checked at
measurement time — a corrupted DB row or a miswired link silently
produces a confidently-wrong "optimal" partition.

This pass makes the premises checkable offline.  ``QueryEngine`` runs it
once at construction and attaches the findings to every
``QueryResult.diagnostics``; the CLI exposes it as ``scission-lint cost
<db.json | plan.json>``.

Severities: data that breaks an exactness guarantee outright (negative /
NaN times, non-positive bandwidth, broken composition) is an *error*;
data the engine still handles but that degrades fidelity (non-monotone
profiles, coverage gaps, asymmetric or costly self links) is a
*warning* — randomly-wired but well-formed test fleets must stay
error-free.
"""

from __future__ import annotations

import math
from typing import Sequence

from .diagnostics import Diagnostic, ERROR, WARNING

# Reference payload for comparing link costs (asymmetry / self-link
# checks): 1 MiB, a mid-sized activation tensor.
_REF_BYTES = float(1 << 20)

# Relative slack before a profile counts as non-monotone / a link pair as
# asymmetric — real wall-clock profiles carry measurement noise.
_REL_TOL = 0.05


def _finite_nonneg(x: float) -> bool:
    return math.isfinite(x) and x >= 0.0


def lint_cost_db(db, network=None,
                 resources: Sequence[str] | None = None
                 ) -> list[Diagnostic]:
    """SCN401-406: check a :class:`repro.core.bench.BenchmarkDB` (and
    optionally its :class:`repro.core.network.NetworkModel`) against the
    DP premises.  ``resources`` restricts the DB checks to the active
    fleet (a DB may carry stale records for departed resources)."""
    diags: list[Diagnostic] = []
    active = {r: recs for r, recs in db.records.items()
              if resources is None or r in resources}

    # -- SCN401 / SCN402: per-record value sanity + profile monotonicity ----
    batches_by_resource: dict[str, set[int]] = {}
    for rname in sorted(active):
        covered: set[int] = set()
        for rec in active[rname]:
            subject = f"{rname}/block{rec.block}"
            bad: list[str] = []
            if not _finite_nonneg(rec.mean_time_s):
                bad.append(f"mean_time_s={rec.mean_time_s!r}")
            if not (_finite_nonneg(float(rec.output_bytes))):
                bad.append(f"output_bytes={rec.output_bytes!r}")
            for b in sorted(rec.batch_profile):
                t, nbytes = rec.batch_profile[b]
                if not _finite_nonneg(float(t)):
                    bad.append(f"batch_profile[{b}] time={t!r}")
                if not _finite_nonneg(float(nbytes)):
                    bad.append(f"batch_profile[{b}] bytes={nbytes!r}")
            if bad:
                diags.append(Diagnostic(
                    "SCN401", ERROR,
                    f"block {rec.block} on {rname!r} records "
                    f"{'; '.join(bad)} — negative or non-finite stage "
                    f"costs void the lattices' additive accumulation and "
                    f"dominance pruning (a negative-cost stage makes "
                    f"'longer segment is never cheaper' false)",
                    subject=subject,
                    hint="re-benchmark the block; the record is corrupt"))
            bs = sorted(rec.batch_profile)
            covered.update(bs)
            finite = all(_finite_nonneg(float(rec.batch_profile[b][0]))
                         for b in bs)
            if finite:
                for b0, b1 in zip(bs, bs[1:]):
                    t0 = float(rec.batch_profile[b0][0])
                    t1 = float(rec.batch_profile[b1][0])
                    if t1 < t0 * (1.0 - _REL_TOL):
                        diags.append(Diagnostic(
                            "SCN402", WARNING,
                            f"block {rec.block} on {rname!r}: per-batch "
                            f"time drops from {t0:.3g}s @ batch {b0} to "
                            f"{t1:.3g}s @ batch {b1} — a non-monotone "
                            f"profile voids the log-linear interpolation "
                            f"premise, so times at unmeasured batches in "
                            f"({b0}, {b1}) are unreliable",
                            subject=subject,
                            hint="re-measure both batch points (likely a "
                                 "noisy or mislabelled run)"))
        batches_by_resource[rname] = covered

    # -- SCN403: per-resource batch coverage vs the fleet union -------------
    fleet_union: set[int] = set()
    for bs in batches_by_resource.values():
        fleet_union |= bs
    for rname in sorted(batches_by_resource):
        missing = sorted(fleet_union - batches_by_resource[rname])
        if missing:
            have = sorted(batches_by_resource[rname])
            diags.append(Diagnostic(
                "SCN403", WARNING,
                f"resource {rname!r} measured batches {have} but the "
                f"fleet union is {sorted(fleet_union)}: pricing batches "
                f"{missing} on it clamps to the nearest measured point "
                f"(SCN111) and frontier sweeps lose those operating "
                f"points fleet-wide", subject=rname,
                hint=f"benchmark_batches(..., batch_sizes={missing}) for "
                     f"{rname!r}"))

    if network is not None:
        diags.extend(lint_network(network))
    return diags


def lint_network(network) -> list[Diagnostic]:
    """SCN404-406: link-model anomalies."""
    diags: list[Diagnostic] = []
    links = network.links()
    # the default link backs every pair not explicitly connected; probe it
    # through the public fallback path
    default = network.link("__scission_lint__a", "__scission_lint__b")

    def check_link(link, subject: str):
        bad: list[str] = []
        if not math.isfinite(link.latency_s) or link.latency_s < 0.0:
            bad.append(f"latency_s={link.latency_s!r}")
        if math.isnan(link.bandwidth) or link.bandwidth <= 0.0:
            bad.append(f"bandwidth={link.bandwidth!r}")
        if bad:
            diags.append(Diagnostic(
                "SCN404", ERROR,
                f"link {link.name!r} ({subject}) has {', '.join(bad)} — "
                f"hop costs must be finite and non-negative for the DPs' "
                f"additive/minimax composition to hold", subject=subject,
                hint="fix the link definition; comm_time would be "
                     "negative, NaN or infinite"))

    check_link(default, "default")
    for (src, dst) in sorted(links):
        check_link(links[(src, dst)], f"{src}->{dst}")

    # SCN405: both directions explicit but priced differently
    for (src, dst) in sorted(links):
        if src >= dst or (dst, src) not in links:
            continue
        fwd, rev = links[(src, dst)], links[(dst, src)]
        try:
            ta, tb = fwd.comm_time(_REF_BYTES), rev.comm_time(_REF_BYTES)
        except ZeroDivisionError:           # already an SCN404
            continue
        if not (math.isfinite(ta) and math.isfinite(tb)):
            continue
        if abs(ta - tb) > _REL_TOL * max(abs(ta), abs(tb), 1e-12):
            diags.append(Diagnostic(
                "SCN405", WARNING,
                f"explicit link pair {src!r}<->{dst!r} is asymmetric "
                f"({fwd.name!r}: {ta:.3g}s vs {rev.name!r}: {tb:.3g}s per "
                f"{int(_REF_BYTES)} bytes) — plans moving data in the "
                f"unexpected direction are priced differently",
                subject=f"{src}<->{dst}",
                hint="intended? connect(symmetric=True) keeps both "
                     "directions identical"))

    # SCN406: explicit self-link costlier than the default network link
    if math.isfinite(default.comm_time(_REF_BYTES)):
        for (src, dst) in sorted(links):
            if src != dst:
                continue
            t_self = links[(src, dst)].comm_time(_REF_BYTES)
            t_net = default.comm_time(_REF_BYTES)
            if math.isfinite(t_self) and t_self > t_net * (1.0 + _REL_TOL):
                diags.append(Diagnostic(
                    "SCN406", WARNING,
                    f"self-link on {src!r} prices same-box staging at "
                    f"{t_self:.3g}s per {int(_REF_BYTES)} bytes — slower "
                    f"than the default inter-resource link "
                    f"({t_net:.3g}s); a local hop costlier than the "
                    f"network is usually a miswired link table",
                    subject=f"{src}->{src}",
                    hint="check the (src, src) entry; implicit self-links "
                         "are free (LOOPBACK)"))
    return diags


def _close(a: float, b: float) -> bool:
    return math.isclose(a, b, rel_tol=1e-9, abs_tol=1e-12)


def lint_cost_model(cost) -> list[Diagnostic]:
    """SCN407: verify on the *actual* cost model that latency composes
    additively and the bottleneck by max over every recorded block — the
    two composition laws the Viterbi / minimax / Pareto lattices assume
    when they accumulate prefix sums and max-merge stage periods.

    The check recomputes ``segment_time`` / ``evaluate`` output from the
    raw DB records and compares; a subclass (or corrupted precompute)
    that breaks either law is named with the exact segment and the voided
    guarantee.  Resources with non-finite recorded times are skipped —
    SCN401 already owns those.
    """
    from ..core.lattice.chain import Segment

    diags: list[Diagnostic] = []
    db = cost.db
    batch = cost.batch_size
    B = cost.n_blocks
    names = [r.name for r in cost.resources]

    def block_time(rname: str, j: int) -> float:
        # mirrors BenchmarkDB.time(): the batch-1 scalar short-circuits the
        # profile, larger batches interpolate (without noting clamps)
        rec = db.records[rname][j]
        return float(rec.mean_time_s) if batch == 1 \
            else float(rec.time_at(batch))

    usable: list[str] = []
    for rname in names:
        times = [block_time(rname, j) for j in range(B)]
        if not all(math.isfinite(t) for t in times):
            continue
        usable.append(rname)
        # additivity: segment_time over any prefix == sum of block times
        acc = 0.0
        for j in range(B):
            acc += times[j]
            got = cost.segment_time(rname, 0, j)
            if not _close(got, acc):
                diags.append(Diagnostic(
                    "SCN407", ERROR,
                    f"segment_time({rname!r}, 0, {j}) = {got:.6g}s but the "
                    f"recorded block times sum to {acc:.6g}s — latency is "
                    f"not additive over blocks, voiding the Viterbi "
                    f"lattice's prefix-sum accumulation (its optimum is "
                    f"no longer the true latency optimum)",
                    subject=f"{rname}/blocks0-{j}",
                    hint="the cost model diverges from its DB; rebuild it "
                         "or fix the override"))
                break               # one finding per resource is enough

    # composition of evaluate(): latency additive over stages, bottleneck
    # the max over effective stage periods — sampled over whole-model
    # placements and two-stage splits at representative cuts
    samples: list[list[Segment]] = []
    for rname in usable:
        samples.append([Segment(rname, 0, B - 1)])
    if len(usable) >= 2 and B >= 2:
        r0, r1 = usable[0], usable[1]
        for cut in sorted({0, B // 2, B - 2}):
            if 0 <= cut < B - 1:
                samples.append([Segment(r0, 0, cut),
                                Segment(r1, cut + 1, B - 1)])

    for segs in samples:
        cfg = cost.evaluate(segs)
        first = segs[0].resource
        input_comm = 0.0 if first == cost.source else cost.comm(
            cost.source, first, cost.batch_input_bytes)
        stage_t = [sum(block_time(s.resource, j)
                       for j in range(s.start, s.end + 1)) for s in segs]
        hops = [cost.comm(a.resource, b.resource,
                          float(cost.out_bytes[a.end]))
                for a, b in zip(segs, segs[1:])]
        want_latency = input_comm + sum(stage_t) + sum(hops)
        desc = " | ".join(f"{s.resource}:{s.start}-{s.end}" for s in segs)
        if not _close(cfg.latency_s, want_latency):
            diags.append(Diagnostic(
                "SCN407", ERROR,
                f"evaluate([{desc}]) reports latency {cfg.latency_s:.6g}s "
                f"but input hop + stage times + cut hops sum to "
                f"{want_latency:.6g}s — latency is not additive over this "
                f"placement, voiding the additive DP's exactness",
                subject=desc,
                hint="the cost model diverges from its DB records"))
            continue
        b = max(1, batch)
        periods = ([input_comm / b] if input_comm > 0.0 else [])
        for k, (s, t) in enumerate(zip(segs, stage_t)):
            reps = cost.replicas_for(s.resource)
            periods.append(t / (reps * b))
            if k < len(hops):
                periods.append(hops[k] / b)
        want_bottleneck = max(periods) if periods else cfg.latency_s
        if not _close(cfg.bottleneck_s, want_bottleneck):
            diags.append(Diagnostic(
                "SCN407", ERROR,
                f"evaluate([{desc}]) reports bottleneck "
                f"{cfg.bottleneck_s:.6g}s but the max over effective "
                f"stage periods is {want_bottleneck:.6g}s — the "
                f"bottleneck does not max-compose, voiding the minimax "
                f"DP's exactness", subject=desc,
                hint="the cost model diverges from its DB records"))
    return diags


def lint_cost(db, network=None, resources: Sequence[str] | None = None,
              cost=None) -> list[Diagnostic]:
    """The full SCN4xx pass: DB + network checks, plus the composition
    check when a cost model is supplied."""
    diags = lint_cost_db(db, network=network, resources=resources)
    if cost is not None:
        diags.extend(lint_cost_model(cost))
    return diags
