"""Jaxpr dataflow lint of fused blocks (SCN5xx).

Each :class:`repro.core.graph.Block` is a standalone sub-model — the
entity Scission benchmarks, ships across a cut and serves.  This pass
traces every block's ``make_callable`` with :func:`jax.make_jaxpr` /
:func:`jax.eval_shape` on *abstract* inputs (the block's ``in_specs``)
and lints the resulting dataflow:

* **SCN501** — float64 values inside the traced block.  f64 leakage
  doubles VMEM/transfer per element and silently falls back to slow
  emulation on TPU; every measured time then describes a program the
  deployment never runs.
* **SCN502** — the traced boundary tensor (shape x dtype of the block's
  output) disagrees with the byte count the cost model charges per cut
  edge (``BenchmarkDB.output_bytes`` / the graph's ``out_spec``).
* **SCN503** — host callbacks (``pure_callback``, ``io_callback``,
  ``debug_callback``, ...) or primitives that fail abstract tracing: a
  host round-trip inside a block is invisible to jit wall-clock on the
  target and breaks the "block == one device program" premise.
* **SCN504** — contractions (``dot_general``) on a *kernel-bearing*
  block whose output dtype is below float32: the flash/decode/SSD paths
  accumulate in f32 scratch by design, so a bf16/f16 accumulator there
  is a numerics regression, not mixed-precision intent.

Tracing is abstract — no FLOPs run, caches and weights appear only as
shapes — so the pass is cheap enough for CI over the whole model zoo.
"""

from __future__ import annotations

from typing import Any, Iterable, Sequence

import numpy as np

from .diagnostics import Diagnostic, ERROR, WARNING

# Primitives whose presence inside a block voids the measured-stage-time
# premise (host round-trips) — matched by jaxpr primitive name.
HOST_CALLBACK_PRIMITIVES = frozenset({
    "pure_callback", "io_callback", "debug_callback", "callback",
    "outside_call", "host_callback_call", "infeed", "outfeed",
})

_SUB_F32 = {"bfloat16", "float16"}


def _walk_jaxprs(jaxpr) -> Iterable[Any]:
    """The jaxpr plus every sub-jaxpr reachable through eqn params
    (scan/cond/while bodies, pallas_call kernels, custom_* rules), by
    duck typing so no private jax modules are imported."""
    seen: set[int] = set()
    stack = [jaxpr]
    while stack:
        j = stack.pop()
        if id(j) in seen:
            continue
        seen.add(id(j))
        yield j
        for eqn in j.eqns:
            for v in eqn.params.values():
                stack.extend(_extract_jaxprs(v))


def _extract_jaxprs(v) -> list[Any]:
    if hasattr(v, "eqns"):                       # a Jaxpr
        return [v]
    if hasattr(v, "jaxpr") and hasattr(v.jaxpr, "eqns"):  # a ClosedJaxpr
        return [v.jaxpr]
    if isinstance(v, (list, tuple)):
        out: list[Any] = []
        for x in v:
            out.extend(_extract_jaxprs(x))
        return out
    return []


def _block_specs(block) -> list:
    import jax
    return [jax.ShapeDtypeStruct(s.shape, s.dtype) for s in block.in_specs]


def _has_kernel_node(block) -> bool:
    return any(block.graph.nodes[i].kernel for i in block.node_ids)


def lint_block(block, db=None, *, subject: str | None = None
               ) -> list[Diagnostic]:
    """SCN501-504 for one fused block (see module docstring)."""
    import jax

    subject = subject or f"block{block.index}/{block.name}"
    diags: list[Diagnostic] = []
    fn = block.make_callable()
    specs = _block_specs(block)
    try:
        closed = jax.make_jaxpr(fn)(*specs)
        out_aval = jax.eval_shape(fn, *specs)
    except Exception as e:                       # noqa: BLE001 - reported
        diags.append(Diagnostic(
            "SCN503", ERROR,
            f"block {block.index} ({block.name}) fails abstract tracing: "
            f"{type(e).__name__}: {e} — it cannot be jit-compiled as a "
            f"standalone sub-model, so it cannot be benchmarked or "
            f"served as a stage", subject=subject,
            hint="the block must be a pure jax function of its entry "
                 "tensors"))
        return diags

    f64_sites: list[str] = []
    callback_prims: list[str] = []
    subf32_dots: list[str] = []
    for j in _walk_jaxprs(closed.jaxpr):
        for eqn in j.eqns:
            prim = eqn.primitive.name
            if prim in HOST_CALLBACK_PRIMITIVES:
                callback_prims.append(prim)
            for var in eqn.outvars:
                aval = getattr(var, "aval", None)
                dt = getattr(aval, "dtype", None)
                if dt is None:
                    continue
                if str(dt) == "float64":
                    f64_sites.append(prim)
                elif prim == "dot_general" and str(dt) in _SUB_F32:
                    subf32_dots.append(str(dt))

    if f64_sites:
        uniq = sorted(set(f64_sites))
        diags.append(Diagnostic(
            "SCN501", WARNING,
            f"block {block.index} ({block.name}) carries float64 values "
            f"(produced by {', '.join(uniq)}): f64 doubles boundary/VMEM "
            f"bytes and falls back to emulation on TPU, so measured "
            f"times and charged cut bytes describe a different program",
            subject=subject,
            hint="cast to float32 (or audit jax_enable_x64 usage)"))

    if callback_prims:
        uniq = sorted(set(callback_prims))
        diags.append(Diagnostic(
            "SCN503", ERROR,
            f"block {block.index} ({block.name}) contains host "
            f"callback(s) {', '.join(uniq)}: a host round-trip inside a "
            f"stage is not captured by device wall-clock, so the "
            f"recorded stage time undercounts the deployed cost",
            subject=subject,
            hint="move the callback out of the partitioned graph (or "
                 "drop jax.debug.* from serving paths)"))

    if _has_kernel_node(block) and subf32_dots:
        uniq = sorted(set(subf32_dots))
        diags.append(Diagnostic(
            "SCN504", WARNING,
            f"block {block.index} ({block.name}) is a kernel path but "
            f"contracts with {', '.join(uniq)} accumulation: the "
            f"flash/decode/SSD kernels accumulate in f32 scratch by "
            f"design — a sub-f32 accumulator here is a numerics "
            f"regression", subject=subject,
            hint="set preferred_element_type=jnp.float32 on the "
                 "contraction"))

    # SCN502: traced boundary tensor vs the bytes the cost model charges
    out = jax.tree_util.tree_leaves(out_aval)
    traced_bytes = sum(
        int(np.prod(o.shape)) * np.dtype(o.dtype).itemsize for o in out)
    declared = block.out_spec
    declared_bytes = (int(np.prod(declared.shape))
                      * np.dtype(declared.dtype).itemsize)
    charged = declared_bytes
    source = "graph out_spec"
    if db is not None:
        try:
            charged = int(db.output_bytes(block.index))
            source = "BenchmarkDB.output_bytes"
        except (KeyError, IndexError):
            pass
    if traced_bytes != charged:
        dt = ", ".join(sorted({str(o.dtype) for o in out}))
        diags.append(Diagnostic(
            "SCN502", WARNING,
            f"block {block.index} ({block.name}) traces to "
            f"{traced_bytes} boundary bytes (dtype {dt}) but {source} "
            f"charges {charged} bytes per cut edge — every hop cost in "
            f"the DP prices the wrong transfer", subject=subject,
            hint="re-trace the graph / re-benchmark so out_spec and the "
                 "DB agree with the real boundary tensor"))
    return diags


def lint_blocks(blocks: Sequence, db=None) -> list[Diagnostic]:
    """SCN5xx over a fused block list (the unit ``benchmark_model``
    measures and the lattices cut between)."""
    diags: list[Diagnostic] = []
    for block in blocks:
        diags.extend(lint_block(block, db=db))
    return diags
