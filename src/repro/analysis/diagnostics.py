"""Shared diagnostic type for the static-analysis layer (``scission-lint``).

Every analyzer — the plan linter (SCN1xx), the kernel memory / tiling
analyzers (SCN2xx), the graph IR checker (SCN3xx), the cost-model
soundness pass (SCN4xx) and the jaxpr dataflow lint (SCN5xx) — reports
findings as
:class:`Diagnostic` values: a stable machine-checkable ``code``, a
``severity``, a human message, the ``subject`` the finding is about (a
resource name, a kernel candidate, a graph node) and an actionable
``hint``.  Engine surfaces attach them (``QueryResult.diagnostics``),
exceptions carry them (:class:`repro.analysis.graph_lint.GraphLintError`)
and the CLI renders them, so one representation serves programmatic and
human consumers alike.
"""

from __future__ import annotations

from dataclasses import dataclass, field

# Severities, ordered: an ``error`` means the subject cannot work (an
# infeasible plan, an over-budget kernel, a malformed graph); a ``warning``
# means it works but probably not as intended (silent fallback, invisible
# clamp); ``info`` is advisory context (e.g. which candidates were pruned).
ERROR = "error"
WARNING = "warning"
INFO = "info"
_SEVERITY_RANK = {ERROR: 0, WARNING: 1, INFO: 2}


@dataclass(frozen=True)
class Diagnostic:
    """One static-analysis finding.

    ``code`` is stable across releases (``SCN1xx`` plan, ``SCN2xx`` kernel,
    ``SCN3xx`` graph — see :data:`CODES`); ``subject`` names the entity the
    finding is about so tools can key on (code, subject) pairs.
    """

    code: str
    severity: str
    message: str
    subject: str = ""
    hint: str = ""

    def __post_init__(self):
        if self.severity not in _SEVERITY_RANK:
            raise ValueError(f"unknown severity {self.severity!r}")
        if not (len(self.code) == 6 and self.code.startswith("SCN")
                and self.code[3:].isdigit()):
            raise ValueError(f"malformed diagnostic code {self.code!r}")

    @property
    def is_error(self) -> bool:
        return self.severity == ERROR

    def render(self) -> str:
        subj = f" [{self.subject}]" if self.subject else ""
        hint = f"\n        hint: {self.hint}" if self.hint else ""
        return f"{self.code} {self.severity}{subj}: {self.message}{hint}"


# The full diagnostic-code table (also rendered in the README).  Codes are
# append-only: a retired check keeps its number reserved.
CODES: dict[str, str] = {
    # -- SCN1xx: plan linter (Query x Constraints x fleet x NetworkModel) ----
    "SCN101": "must_use and exclude name the same resource",
    "SCN102": "constraint names an unknown / un-benchmarked resource",
    "SCN103": "min_blocks_on floor exceeds the model's block count",
    "SCN104": "demanded block floors cannot all fit in the block count",
    "SCN105": "max_resource_time is below every admissible segment time",
    "SCN106": "demanded resources collide on a tier (or pins violate "
              "tier order)",
    "SCN107": "consecutive pinned resources have no explicit link "
              "(default-link fallback)",
    "SCN108": "pipelines restriction admits no valid pipeline",
    "SCN109": "constraints are jointly unsatisfiable (no feasible "
              "configuration exists)",
    "SCN110": "one-way link: reverse direction falls back to the default "
              "link",
    "SCN111": "batch size outside the measured profile range was clamped",
    "SCN112": "top_n <= 0 requests an empty result by construction",
    # -- SCN2xx: kernel memory analyzer (Pallas candidates vs VMEM budget) ---
    "SCN201": "kernel candidate statically exceeds the VMEM budget",
    "SCN202": "every candidate of a kernel sweep exceeds the VMEM budget",
    "SCN203": "unknown kernel: VMEM footprint cannot be computed statically",
    # -- SCN3xx: graph IR checker (LayerGraph well-formedness) ---------------
    "SCN301": "empty graph",
    "SCN302": "predecessor index is dangling or non-topological",
    "SCN303": "extra sink: a non-final node has no successors",
    "SCN304": "orphan source: a non-input node has no predecessors",
    "SCN305": "non-input node has no apply function",
    "SCN306": "declared out_spec disagrees with the shape computed from "
              "predecessor out_specs",
    "SCN307": "benchmarked output bytes disagree with the graph's computed "
              "output bytes",
    "SCN308": "graph is untraced: shape-chain checks skipped",
    "SCN309": "graph is not series-parallel: non-SP region linearised",
    "SCN310": "series-parallel decomposition failed: chain fallback",
    # -- SCN2xx (cont.): TPU tile-alignment analyzer (repro.analysis.tiling) --
    "SCN204": "kernel candidate block shape is misaligned to the dtype's "
              "minimum TPU tile",
    "SCN205": "kernel candidate leaves grid-remainder padding waste",
    "SCN206": "every candidate of a kernel sweep is tile-misaligned",
    "SCN207": "minor (lane) dimension below the 128-lane tile: relayout "
              "padding",
    # -- SCN4xx: cost-model soundness (BenchmarkDB x NetworkModel vs the ----
    # -- invariants the exact DPs assume) -----------------------------------
    "SCN401": "non-finite or negative stage time / byte count in the "
              "benchmark DB",
    "SCN402": "batch profile is non-monotone: per-batch time decreases "
              "with batch size",
    "SCN403": "batch-profile coverage gap: resource misses batches other "
              "resources measured",
    "SCN404": "link model anomaly: negative latency or non-positive "
              "bandwidth",
    "SCN405": "asymmetric explicit link pair: a->b and b->a cost differ",
    "SCN406": "self-link staging is costlier than the default "
              "inter-resource link",
    "SCN407": "cost-model composition violated: latency not additive or "
              "bottleneck not max-composing",
    # -- SCN5xx: jaxpr dataflow lint (traced Block.make_callable) ------------
    "SCN501": "float64 value inside a traced block (f64 leakage)",
    "SCN502": "traced boundary tensor disagrees with BenchmarkDB / graph "
              "output bytes",
    "SCN503": "host callback or non-jittable primitive inside a block",
    "SCN504": "sub-f32 accumulation dtype on a kernel-path contraction",
}


def errors(diags: list[Diagnostic]) -> list[Diagnostic]:
    return [d for d in diags if d.severity == ERROR]


def has_errors(diags: list[Diagnostic]) -> bool:
    return any(d.severity == ERROR for d in diags)


def dedupe(diags: list[Diagnostic]) -> list[Diagnostic]:
    """Collapse repeated (code, subject) findings, preserving order — a
    frontier sweep re-derives the same fact once per operating point with
    the batch size baked into the message, so keying on the message would
    let one clamp render dozens of times.  The first message wins."""
    seen: set[tuple[str, str]] = set()
    out = []
    for d in diags:
        k = (d.code, d.subject)
        if k not in seen:
            seen.add(k)
            out.append(d)
    return out


def sort_by_severity(diags: list[Diagnostic]) -> list[Diagnostic]:
    return sorted(diags, key=lambda d: (_SEVERITY_RANK[d.severity], d.code,
                                        d.subject))


def render_report(diags: list[Diagnostic], title: str = "") -> str:
    """Human-readable multi-line report (the CLI's output unit)."""
    lines = []
    if title:
        lines.append(f"== {title} ==")
    if not diags:
        lines.append("  clean (no diagnostics)")
    for d in sort_by_severity(dedupe(diags)):
        lines.append("  " + d.render())
    return "\n".join(lines)
