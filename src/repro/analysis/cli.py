"""``scission-lint`` — the static-analysis CLI.

Usage (the module is the entry point; ``scission-lint`` is the alias used
throughout the docs)::

    PYTHONPATH=src python -m repro.analysis [--strict] [--vmem BYTES] \
        [--allow CODE ...] [TARGET ...]

Targets:

* ``kernels`` — run the VMEM footprint analyzer over the default
  autotuner candidate grids at representative shapes, against ``--vmem``
  (default: the TPU ~16 MiB/core budget).
* ``graphs`` — build representative model-zoo graphs and run the graph
  IR checker with shape-chain verification.
* ``tiling`` — static TPU tile-alignment analysis (SCN204-207) of the
  default candidate grids at the same representative shapes.
* ``jaxpr`` — trace every fused block of a kernel-bearing demo graph and
  the model zoo with ``jax.make_jaxpr`` and lint the dataflow (SCN5xx:
  f64 leakage, boundary-byte disagreement, host callbacks, sub-f32
  kernel accumulation).
* ``cost PATH [PATH ...]`` — cost-model soundness (SCN4xx) over each
  JSON file following the keyword: a persisted ``BenchmarkDB``
  (``"records"`` payload) gets the DB checks; a deployment plan
  (``"block_times"`` payload) additionally gets the link checks and the
  additive/minimax composition check on its constructed cost model.
* ``path/to/plan.json`` — lint a deployment-plan file: structural plan
  diagnostics plus (when no structural error already explains it) the
  exact SCN109 joint-satisfiability sweep.

With no targets, ``kernels`` and ``graphs`` both run.  ``--strict`` exits
non-zero when any error- **or warning**-severity diagnostic survives
``--allow`` waivers (the CI gate; ``--allow SCN309`` waives a code
without silencing its report).  Diagnostics are deduped by (code,
subject) before rendering and counting.

Plan-file schema (see ``examples/plans/``)::

    {"model": ..., "n_blocks": N, "source": name, "input_bytes": B,
     "resources": [{"name", "tier", "speed_factor"?, "vmem_bytes"?}, ...],
     "block_times": {resource: [seconds per block]},
     "out_bytes": [bytes per block],
     "links": [{"src", "dst", "latency_s", "bandwidth", "symmetric"?}],
     "query": {"top_n"?, "batch_size"?, "must_use"?, "exclude"?, "pin"?,
               "max_resource_time"?, "min_blocks_on"?, "max_link_bytes"?,
               "pipelines"?}}
"""

from __future__ import annotations

import argparse
import json
import sys
from dataclasses import dataclass

from .diagnostics import (Diagnostic, ERROR, WARNING, dedupe, errors,
                          render_report)
from .kernel_vmem import TPU_VMEM_BYTES, lint_candidates


@dataclass(frozen=True)
class _Spec:
    """Shape/dtype carrier for the footprint analyzer (keeps the kernel
    target jax-free until the candidate grids themselves are imported)."""

    shape: tuple
    dtype: str = "float32"

    @property
    def ndim(self) -> int:
        return len(self.shape)


# Representative shapes for the ``kernels`` target: one decode step of a
# mid-sized LM and a prefill-length attention/SSD layer.
_KERNEL_SHAPES: dict[str, tuple[tuple, dict]] = {
    "flash_attention": ((_Spec((1, 1024, 8, 64)),), {}),
    "decode_attention": ((_Spec((1, 8, 64)),),
                         {"cache_len": 4096, "kv_heads": 8}),
    "ssd_scan": ((_Spec((1, 1024, 4, 64)),), {"state_dim": 64}),
}


def _lint_kernels(vmem_limit: float) -> list[Diagnostic]:
    from repro.kernels.substrate import DEFAULT_CANDIDATES

    diags: list[Diagnostic] = []
    for kernel, candidates in sorted(DEFAULT_CANDIDATES.items()):
        args, options = _KERNEL_SHAPES.get(kernel, ((), {}))
        kept, pruned, kdiags = lint_candidates(
            kernel, candidates, args, vmem_limit=vmem_limit,
            options=options, subject=kernel)
        diags.extend(kdiags)
        print(f"  {kernel}: {len(kept)} kept / {len(pruned)} pruned "
              f"of {len(candidates)} candidates")
    return diags


def _lint_tiling_target() -> list[Diagnostic]:
    from .tiling import lint_tiling

    from repro.kernels.substrate import DEFAULT_CANDIDATES

    diags: list[Diagnostic] = []
    for kernel, candidates in sorted(DEFAULT_CANDIDATES.items()):
        args, options = _KERNEL_SHAPES.get(kernel, ((), {}))
        kept, flagged, kdiags = lint_tiling(
            kernel, candidates, args, options=options, subject=kernel)
        diags.extend(kdiags)
        print(f"  {kernel}: {len(kept)} aligned / {len(flagged)} flagged "
              f"of {len(candidates)} candidates")
    return diags


def _non_sp_example():
    """A graph with a *crossed* skip (a→c and b→d crossing): deliberately
    not series-parallel, so the ``graphs`` target demonstrably exercises
    SCN309 — its linearisation fallback — alongside the zoo's SP graphs."""
    import jax
    import jax.numpy as jnp
    from repro.core.graph import LayerGraph, LayerNode

    def node(name):
        return LayerNode(name=name, kind="dense", apply=lambda *xs: sum(xs))

    g = LayerGraph("crossed-skips")
    i = g.input(jax.ShapeDtypeStruct((1, 8), jnp.float32))
    a = g.add(node("a"), [i])
    b = g.add(node("b"), [a])
    c = g.add(node("c"), [b, a])     # skip a→c
    g.add(node("d"), [c, b])         # skip b→d crosses it
    g.trace()
    return g


def _lint_graphs() -> list[Diagnostic]:
    from .graph_lint import lint_graph
    from repro.models import cnn_zoo

    diags: list[Diagnostic] = []
    for builder in (cnn_zoo.mobilenetv2, cnn_zoo.resnet50, _non_sp_example):
        g = builder()
        gdiags = lint_graph(g, check_shapes=True)
        diags.extend(gdiags)
        codes = sorted({d.code for d in gdiags})
        print(f"  {g.name}: {len(g.nodes)} nodes, "
              f"{len(gdiags)} diagnostics"
              + (f" [{', '.join(codes)}]" if codes else ""))
    return diags


def _demo_kernel_graph():
    """A small graph carrying both prefill kernels, for the ``jaxpr``
    target: its blocks trace through the Pallas paths the SCN5xx checks
    are about."""
    import jax
    import jax.numpy as jnp
    from repro.core import linear_graph
    from repro.kernels.ops import flash_attention_node, ssd_scan_node

    return linear_graph(
        "jaxpr-demo", jax.ShapeDtypeStruct((1, 128, 2, 32), jnp.float32),
        [flash_attention_node("attn", interpret=True),
         ssd_scan_node("ssd", state_dim=16, interpret=True)])


def _lint_jaxpr_target() -> list[Diagnostic]:
    from .jaxpr_lint import lint_blocks
    from repro.core.bench import AnalyticProvider, benchmark_model
    from repro.core.graph import fuse_blocks
    from repro.core.resources import CLOUD_VM, Resource
    from repro.models import cnn_zoo

    diags: list[Diagnostic] = []
    fleet = [Resource("cloud", "cloud", CLOUD_VM)]
    for graph in (_demo_kernel_graph(), cnn_zoo.mobilenetv2()):
        blocks = fuse_blocks(graph)
        # an analytic DB so the SCN502 byte cross-check runs against what
        # the cost model would actually charge
        db = benchmark_model(graph, fleet, AnalyticProvider(), runs=1,
                             blocks=list(blocks))
        gdiags = lint_blocks(blocks, db=db)
        diags.extend(gdiags)
        print(f"  {graph.name}: {len(blocks)} block(s) traced, "
              f"{len(gdiags)} diagnostics")
    return diags


def _plan_components(plan: dict, path: str):
    from repro.core.bench import BenchmarkDB, BlockBenchmark
    from repro.core.network import Link, NetworkModel
    from repro.core.query import Query
    from repro.core.resources import CLOUD_VM, Resource

    resources = [
        Resource(r["name"], r["tier"], CLOUD_VM,
                 speed_factor=float(r.get("speed_factor", 1.0)),
                 vmem_bytes=r.get("vmem_bytes"))
        for r in plan["resources"]]
    n_blocks = int(plan["n_blocks"])
    out_bytes = [int(b) for b in plan["out_bytes"]]
    db = BenchmarkDB(model=plan.get("model", path), n_blocks=n_blocks)
    for name, times in plan["block_times"].items():
        db.records[name] = [
            BlockBenchmark(block=i, resource=name, mean_time_s=float(t),
                           std_time_s=0.0, output_bytes=out_bytes[i], runs=1)
            for i, t in enumerate(times)]
    net = NetworkModel()
    for ln in plan.get("links", ()):
        net.connect(ln["src"], ln["dst"],
                    Link(ln.get("name", f"{ln['src']}-{ln['dst']}"),
                         float(ln["latency_s"]), float(ln["bandwidth"])),
                    symmetric=bool(ln.get("symmetric", True)))

    q = dict(plan.get("query", {}))
    query = Query(
        top_n=int(q.get("top_n", 3)),
        batch_size=int(q.get("batch_size", 1)),
        must_use=tuple(q.get("must_use", ())),
        exclude=tuple(q.get("exclude", ())),
        pin={int(k): v for k, v in q.get("pin", {}).items()},
        max_link_bytes={(a, b): float(v)
                        for a, b, v in q.get("max_link_bytes", ())},
        max_resource_time={k: float(v)
                           for k, v in q.get("max_resource_time", {}).items()},
        min_blocks_on={k: int(v)
                       for k, v in q.get("min_blocks_on", {}).items()},
        pipelines=q.get("pipelines"))
    return db, net, resources, query, plan["source"], float(plan["input_bytes"])


def _load_plan(path: str) -> list[Diagnostic]:
    from repro.core.partition import CostModel

    from .plan_lint import explain_empty, lint_plan

    with open(path) as f:
        plan = json.load(f)
    db, net, resources, query, source, input_bytes = \
        _plan_components(plan, path)

    diags = lint_plan(query, resources, net, db, source=source,
                      batches=[query.batch_size])
    if not errors(diags):
        cost = CostModel(db=db, resources=resources, network=net,
                         source=source, input_bytes=input_bytes,
                         batch_size=query.batch_size)
        diags.extend(explain_empty(query, query.constraints(), [cost],
                                   prior=diags))
    return diags


def _lint_cost_file(path: str) -> list[Diagnostic]:
    from repro.core.bench import BenchmarkDB

    from .cost_lint import lint_cost, lint_cost_db

    with open(path) as f:
        payload = json.load(f)

    if "records" in payload:                  # a persisted BenchmarkDB
        db = BenchmarkDB.from_json(json.dumps(payload))
        print(f"  {db.model}: {len(db.records)} resource(s) x "
              f"{db.n_blocks} block(s)")
        return lint_cost_db(db)

    if "block_times" in payload:              # a deployment plan: full pass
        from repro.core.partition import CostModel

        db, net, resources, query, source, input_bytes = \
            _plan_components(payload, path)
        print(f"  {db.model}: {len(resources)} resource(s) x "
              f"{db.n_blocks} block(s), {len(net.links())} link(s)")
        cost = CostModel(db=db, resources=resources, network=net,
                         source=source, input_bytes=input_bytes,
                         batch_size=query.batch_size)
        return lint_cost(db, network=net,
                         resources=[r.name for r in resources], cost=cost)

    raise ValueError(
        f"{path}: neither a persisted BenchmarkDB ('records') nor a "
        f"deployment plan ('block_times')")


_KEYWORDS = {"kernels", "graphs", "tiling", "jaxpr", "cost"}


def main(argv: list[str] | None = None) -> int:
    parser = argparse.ArgumentParser(
        prog="scission-lint",
        description="Static analysis for Scission kernels, plans, graphs, "
                    "cost models and block dataflow")
    parser.add_argument("targets", nargs="*",
                        help="'kernels', 'graphs', 'tiling', 'jaxpr', "
                             "'cost JSON...', and/or plan JSON paths "
                             "(default: kernels graphs)")
    parser.add_argument("--strict", action="store_true",
                        help="exit 1 when any error or warning diagnostic "
                             "survives --allow waivers")
    parser.add_argument("--allow", action="append", default=[],
                        metavar="CODE",
                        help="waive a diagnostic code from the strict "
                             "verdict (repeatable; still reported)")
    parser.add_argument("--vmem", type=float, default=float(TPU_VMEM_BYTES),
                        help="VMEM budget in bytes for the kernels target "
                             "(default: %(default).0f)")
    args = parser.parse_args(argv)
    targets = args.targets or ["kernels", "graphs"]
    allow = set(args.allow)

    jobs: list[tuple[str, object]] = []
    i = 0
    while i < len(targets):
        t = targets[i]
        if t == "cost":
            i += 1
            paths = []
            while i < len(targets) and targets[i] not in _KEYWORDS:
                paths.append(targets[i])
                i += 1
            if not paths:
                parser.error("the 'cost' target needs at least one JSON "
                             "path after it")
            for p in paths:
                jobs.append((f"cost {p}", lambda p=p: _lint_cost_file(p)))
            continue
        if t == "kernels":
            jobs.append(("kernels", lambda: _lint_kernels(args.vmem)))
        elif t == "graphs":
            jobs.append(("graphs", _lint_graphs))
        elif t == "tiling":
            jobs.append(("tiling", _lint_tiling_target))
        elif t == "jaxpr":
            jobs.append(("jaxpr", _lint_jaxpr_target))
        else:
            jobs.append((t, lambda t=t: _load_plan(t)))
        i += 1

    n_errors = n_warnings = 0
    for label, runner in jobs:
        print(f"== scission-lint: {label} ==")
        diags = runner()
        report = render_report(diags)
        if report:
            print(report)
        counted = [d for d in dedupe(diags) if d.code not in allow]
        n_errors += sum(d.severity == ERROR for d in counted)
        n_warnings += sum(d.severity == WARNING for d in counted)
    waived = f", {len(allow)} code(s) waived" if allow else ""
    print(f"scission-lint: {len(jobs)} target(s), {n_errors} error(s), "
          f"{n_warnings} warning(s){waived}")
    if args.strict and (n_errors or n_warnings):
        return 1
    return 0


if __name__ == "__main__":           # pragma: no cover - exercised via CI
    sys.exit(main())
