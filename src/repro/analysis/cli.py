"""``scission-lint`` — the static-analysis CLI.

Usage (the module is the entry point; ``scission-lint`` is the alias used
throughout the docs)::

    PYTHONPATH=src python -m repro.analysis [--strict] [--vmem BYTES] \
        [TARGET ...]

Targets:

* ``kernels`` — run the VMEM footprint analyzer over the default
  autotuner candidate grids at representative shapes, against ``--vmem``
  (default: the TPU ~16 MiB/core budget).
* ``graphs`` — build representative model-zoo graphs and run the graph
  IR checker with shape-chain verification.
* ``path/to/plan.json`` — lint a deployment-plan file: structural plan
  diagnostics plus (when no structural error already explains it) the
  exact SCN109 joint-satisfiability sweep.

With no targets, ``kernels`` and ``graphs`` both run.  ``--strict`` exits
non-zero when any error-severity diagnostic was emitted (the CI gate).

Plan-file schema (see ``examples/plans/``)::

    {"model": ..., "n_blocks": N, "source": name, "input_bytes": B,
     "resources": [{"name", "tier", "speed_factor"?, "vmem_bytes"?}, ...],
     "block_times": {resource: [seconds per block]},
     "out_bytes": [bytes per block],
     "links": [{"src", "dst", "latency_s", "bandwidth", "symmetric"?}],
     "query": {"top_n"?, "batch_size"?, "must_use"?, "exclude"?, "pin"?,
               "max_resource_time"?, "min_blocks_on"?, "max_link_bytes"?,
               "pipelines"?}}
"""

from __future__ import annotations

import argparse
import json
import sys
from dataclasses import dataclass

from .diagnostics import Diagnostic, ERROR, errors, render_report
from .kernel_vmem import TPU_VMEM_BYTES, lint_candidates


@dataclass(frozen=True)
class _Spec:
    """Shape/dtype carrier for the footprint analyzer (keeps the kernel
    target jax-free until the candidate grids themselves are imported)."""

    shape: tuple
    dtype: str = "float32"

    @property
    def ndim(self) -> int:
        return len(self.shape)


# Representative shapes for the ``kernels`` target: one decode step of a
# mid-sized LM and a prefill-length attention/SSD layer.
_KERNEL_SHAPES: dict[str, tuple[tuple, dict]] = {
    "flash_attention": ((_Spec((1, 1024, 8, 64)),), {}),
    "decode_attention": ((_Spec((1, 8, 64)),),
                         {"cache_len": 4096, "kv_heads": 8}),
    "ssd_scan": ((_Spec((1, 1024, 4, 64)),), {"state_dim": 64}),
}


def _lint_kernels(vmem_limit: float) -> list[Diagnostic]:
    from repro.kernels.substrate import DEFAULT_CANDIDATES

    diags: list[Diagnostic] = []
    for kernel, candidates in sorted(DEFAULT_CANDIDATES.items()):
        args, options = _KERNEL_SHAPES.get(kernel, ((), {}))
        kept, pruned, kdiags = lint_candidates(
            kernel, candidates, args, vmem_limit=vmem_limit,
            options=options, subject=kernel)
        diags.extend(kdiags)
        print(f"  {kernel}: {len(kept)} kept / {len(pruned)} pruned "
              f"of {len(candidates)} candidates")
    return diags


def _non_sp_example():
    """A graph with a *crossed* skip (a→c and b→d crossing): deliberately
    not series-parallel, so the ``graphs`` target demonstrably exercises
    SCN309 — its linearisation fallback — alongside the zoo's SP graphs."""
    import jax
    import jax.numpy as jnp
    from repro.core.graph import LayerGraph, LayerNode

    def node(name):
        return LayerNode(name=name, kind="dense", apply=lambda *xs: sum(xs))

    g = LayerGraph("crossed-skips")
    i = g.input(jax.ShapeDtypeStruct((1, 8), jnp.float32))
    a = g.add(node("a"), [i])
    b = g.add(node("b"), [a])
    c = g.add(node("c"), [b, a])     # skip a→c
    g.add(node("d"), [c, b])         # skip b→d crosses it
    g.trace()
    return g


def _lint_graphs() -> list[Diagnostic]:
    from .graph_lint import lint_graph
    from repro.models import cnn_zoo

    diags: list[Diagnostic] = []
    for builder in (cnn_zoo.mobilenetv2, cnn_zoo.resnet50, _non_sp_example):
        g = builder()
        gdiags = lint_graph(g, check_shapes=True)
        diags.extend(gdiags)
        codes = sorted({d.code for d in gdiags})
        print(f"  {g.name}: {len(g.nodes)} nodes, "
              f"{len(gdiags)} diagnostics"
              + (f" [{', '.join(codes)}]" if codes else ""))
    return diags


def _load_plan(path: str) -> list[Diagnostic]:
    from repro.core.bench import BenchmarkDB, BlockBenchmark
    from repro.core.network import Link, NetworkModel
    from repro.core.partition import CostModel
    from repro.core.query import Query
    from repro.core.resources import CLOUD_VM, Resource

    from .plan_lint import explain_empty, lint_plan

    with open(path) as f:
        plan = json.load(f)

    resources = [
        Resource(r["name"], r["tier"], CLOUD_VM,
                 speed_factor=float(r.get("speed_factor", 1.0)),
                 vmem_bytes=r.get("vmem_bytes"))
        for r in plan["resources"]]
    n_blocks = int(plan["n_blocks"])
    out_bytes = [int(b) for b in plan["out_bytes"]]
    db = BenchmarkDB(model=plan.get("model", path), n_blocks=n_blocks)
    for name, times in plan["block_times"].items():
        db.records[name] = [
            BlockBenchmark(block=i, resource=name, mean_time_s=float(t),
                           std_time_s=0.0, output_bytes=out_bytes[i], runs=1)
            for i, t in enumerate(times)]
    net = NetworkModel()
    for ln in plan.get("links", ()):
        net.connect(ln["src"], ln["dst"],
                    Link(ln.get("name", f"{ln['src']}-{ln['dst']}"),
                         float(ln["latency_s"]), float(ln["bandwidth"])),
                    symmetric=bool(ln.get("symmetric", True)))

    q = dict(plan.get("query", {}))
    query = Query(
        top_n=int(q.get("top_n", 3)),
        batch_size=int(q.get("batch_size", 1)),
        must_use=tuple(q.get("must_use", ())),
        exclude=tuple(q.get("exclude", ())),
        pin={int(k): v for k, v in q.get("pin", {}).items()},
        max_link_bytes={(a, b): float(v)
                        for a, b, v in q.get("max_link_bytes", ())},
        max_resource_time={k: float(v)
                           for k, v in q.get("max_resource_time", {}).items()},
        min_blocks_on={k: int(v)
                       for k, v in q.get("min_blocks_on", {}).items()},
        pipelines=q.get("pipelines"))

    source = plan["source"]
    diags = lint_plan(query, resources, net, db, source=source,
                      batches=[query.batch_size])
    if not errors(diags):
        cost = CostModel(db=db, resources=resources, network=net,
                         source=source,
                         input_bytes=float(plan["input_bytes"]),
                         batch_size=query.batch_size)
        diags.extend(explain_empty(query, query.constraints(), [cost],
                                   prior=diags))
    return diags


def main(argv: list[str] | None = None) -> int:
    parser = argparse.ArgumentParser(
        prog="scission-lint",
        description="Static analysis for Scission kernels, plans and graphs")
    parser.add_argument("targets", nargs="*",
                        help="'kernels', 'graphs', and/or plan JSON paths "
                             "(default: kernels graphs)")
    parser.add_argument("--strict", action="store_true",
                        help="exit 1 when any error diagnostic is emitted")
    parser.add_argument("--vmem", type=float, default=float(TPU_VMEM_BYTES),
                        help="VMEM budget in bytes for the kernels target "
                             "(default: %(default).0f)")
    args = parser.parse_args(argv)
    targets = args.targets or ["kernels", "graphs"]

    n_errors = 0
    for target in targets:
        print(f"== scission-lint: {target} ==")
        if target == "kernels":
            diags = _lint_kernels(args.vmem)
        elif target == "graphs":
            diags = _lint_graphs()
        else:
            diags = _load_plan(target)
        report = render_report(diags)
        if report:
            print(report)
        n_errors += len(errors(diags))
    print(f"scission-lint: {len(targets)} target(s), {n_errors} error(s)")
    if args.strict and n_errors:
        return 1
    return 0


if __name__ == "__main__":           # pragma: no cover - exercised via CI
    sys.exit(main())
