"""granite-8b [dense] — 36L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=49152; llama-style SwiGLU + RMSNorm + RoPE (code model).
[arXiv:2405.04324]"""

from repro.models.registry import register
from .base import ModelConfig


@register("granite-8b")
def config() -> ModelConfig:
    return ModelConfig(
        name="granite-8b",
        family="dense",
        n_layers=36,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab=49152,
        pattern=(("attn", "mlp"),),
        norm="rmsnorm",
        activation="silu",
        mlp_gated=True,                  # SwiGLU
        rope_theta=10000.0,
    )
