"""granite-moe-3b-a800m [moe] — 32L d_model=1536 24H (GQA kv=8) expert
d_ff=512, 40 routed experts top-8 (padded to 48 for EP sharding), no shared
expert, vocab=49155 (padded to 49168 for even TP sharding).
[hf:ibm-granite/granite-3.0-1b-a400m-base; assigned dims used verbatim]"""

from repro.models.registry import register
from .base import ModelConfig


@register("granite-moe-3b-a800m")
def config() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-3b-a800m",
        family="moe",
        n_layers=32,
        d_model=1536,
        n_heads=24,
        n_kv_heads=8,
        head_dim=64,
        d_ff=512,                        # per-expert width
        vocab=49168,                     # real 49155, padded %16==0
        pattern=(("attn", "moe"),),
        norm="rmsnorm",
        activation="silu",
        mlp_gated=True,
        rope_theta=10000.0,
        moe_experts=40,
        moe_top_k=8,
        moe_group_size=512,
    )
