"""zamba2-2.7b [hybrid] — 54 Mamba2 layers d_model=2560, shared attention
block (32H MHA, head_dim 80) applied every 6 layers, shared-block MLP
d_ff=10240, ssm_state=64, vocab=32000.  [arXiv:2411.15242]

Sub-quadratic: the Mamba2 backbone is O(S); the periodic shared-attention
applications carry the only KV state (sharded over the mesh for long_500k).
"""

from repro.models.registry import register
from .base import ModelConfig


@register("zamba2-2.7b")
def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-2.7b",
        family="hybrid",
        n_layers=54,
        d_model=2560,
        n_heads=32,
        n_kv_heads=32,
        head_dim=80,
        d_ff=10240,
        vocab=32000,
        pattern=(("mamba2",),),
        shared_attn_period=6,            # shared attn+mlp after every 6 mamba
        norm="rmsnorm",
        activation="gelu",
        mlp_gated=True,
        rope_theta=10000.0,
        ssm_state=64,
        ssm_conv=4,
        ssm_expand=2,
        ssm_head_dim=64,
        ssm_chunk=128,
        sub_quadratic=True,
    )
