"""gemma-7b [dense] — 28L d_model=3072 16H (kv=16, MHA) d_ff=24576
vocab=256000; GeGLU, head_dim=256 (attention width 4096 > d_model).
[arXiv:2403.08295]"""

from repro.models.registry import register
from .base import ModelConfig


@register("gemma-7b")
def config() -> ModelConfig:
    return ModelConfig(
        name="gemma-7b",
        family="dense",
        n_layers=28,
        d_model=3072,
        n_heads=16,
        n_kv_heads=16,
        head_dim=256,
        d_ff=24576,
        vocab=256000,
        pattern=(("attn", "mlp"),),
        norm="rmsnorm",
        activation="gelu",
        mlp_gated=True,                  # GeGLU
        rope_theta=10000.0,
        query_pre_attn_scalar=256.0,
        embed_scale=True,
    )
