"""gemma2-9b [dense] — 42L d_model=3584 16H (GQA kv=8) d_ff=14336
vocab=256000; local+global alternating attention, logit softcapping, GeGLU,
pre+post block norms.  [arXiv:2408.00118]"""

from repro.models.registry import register
from .base import ModelConfig


@register("gemma2-9b")
def config() -> ModelConfig:
    return ModelConfig(
        name="gemma2-9b",
        family="dense",
        n_layers=42,
        d_model=3584,
        n_heads=16,
        n_kv_heads=8,
        head_dim=256,
        d_ff=14336,
        vocab=256000,
        pattern=(("attn_local", "mlp"), ("attn", "mlp")),
        norm="rmsnorm",
        activation="gelu",
        mlp_gated=True,                  # GeGLU
        rope_theta=10000.0,
        window=4096,                     # local layers: sliding window
        attn_softcap=50.0,
        final_softcap=30.0,
        query_pre_attn_scalar=256.0,
        embed_scale=True,
        post_block_norm=True,
        sub_quadratic=False,             # global layers are full attention
    )
