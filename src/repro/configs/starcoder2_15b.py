"""starcoder2-15b [dense] — 40L d_model=6144 48H (GQA kv=4) d_ff=24576
vocab=49152; GQA + RoPE, LayerNorm, plain GELU MLP, qkv bias.
[arXiv:2402.19173]"""

from repro.models.registry import register
from .base import ModelConfig


@register("starcoder2-15b")
def config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-15b",
        family="dense",
        n_layers=40,
        d_model=6144,
        n_heads=48,
        n_kv_heads=4,
        head_dim=128,
        d_ff=24576,
        vocab=49152,
        pattern=(("attn", "mlp"),),
        norm="layernorm",
        activation="gelu",
        mlp_gated=False,
        rope_theta=100000.0,
        qkv_bias=True,
    )
