"""qwen2-moe-a2.7b [moe] — 24L d_model=2048 16H (kv=16) expert d_ff=1408,
60 routed experts top-4 (padded to 64 for EP sharding) + fused shared expert
(4x1408=5632) with sigmoid gate, vocab=151936.
[hf:Qwen/Qwen1.5-MoE-A2.7B]"""

from repro.models.registry import register
from .base import ModelConfig


@register("qwen2-moe-a2.7b")
def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-moe-a2.7b",
        family="moe",
        n_layers=24,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        head_dim=128,
        d_ff=1408,                       # per-expert width
        vocab=151936,
        pattern=(("attn", "moe"),),
        norm="rmsnorm",
        activation="silu",
        mlp_gated=True,
        rope_theta=1000000.0,
        qkv_bias=True,
        moe_experts=60,
        moe_top_k=4,
        moe_shared_dff=5632,
        moe_group_size=512,
    )
