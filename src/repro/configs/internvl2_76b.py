"""internvl2-76b [vlm] — LM backbone 80L d_model=8192 64H (GQA kv=8)
d_ff=28672 vocab=128256; InternViT frontend STUBBED — input_specs supplies
256 precomputed patch embeddings per sample at d_model.  [arXiv:2404.16821]"""

from repro.models.registry import register
from .base import ModelConfig


@register("internvl2-76b")
def config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-76b",
        family="vlm",
        n_layers=80,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        head_dim=128,
        d_ff=28672,
        vocab=128256,
        pattern=(("attn", "mlp"),),
        norm="rmsnorm",
        activation="silu",
        mlp_gated=True,
        rope_theta=500000.0,
        n_img_tokens=256,
    )
