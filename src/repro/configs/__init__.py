"""Assigned-architecture configs.  Importing this package registers all of
them with repro.models.registry."""

from . import (gemma2_9b, starcoder2_15b, gemma_7b, granite_8b, zamba2_2p7b,
               xlstm_125m, whisper_medium, internvl2_76b, qwen2_moe_a2p7b,
               granite_moe_3b_a800m)
from .base import (ModelConfig, ShapeConfig, TRAIN_4K, PREFILL_32K,
                   DECODE_32K, LONG_500K, ALL_SHAPES, shape_by_name)

__all__ = ["ModelConfig", "ShapeConfig", "TRAIN_4K", "PREFILL_32K",
           "DECODE_32K", "LONG_500K", "ALL_SHAPES", "shape_by_name"]
