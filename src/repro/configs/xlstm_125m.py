"""xlstm-125m [ssm] — 12L d_model=768 4H vocab=50304; alternating
mLSTM (matrix memory, SSD-form chunkwise) and sLSTM (scalar memory,
recurrent-gate scan) blocks; d_ff=0 — projections live inside the blocks.
[arXiv:2405.04517]"""

from repro.models.registry import register
from .base import ModelConfig


@register("xlstm-125m")
def config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-125m",
        family="ssm",
        n_layers=12,
        d_model=768,
        n_heads=4,
        n_kv_heads=4,
        head_dim=192,
        d_ff=0,
        vocab=50304,
        pattern=(("mlstm",), ("slstm",)),
        norm="rmsnorm",
        activation="gelu",
        use_rope=False,
        ssm_chunk=128,
        sub_quadratic=True,
    )
