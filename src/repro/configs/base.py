"""Model/run configuration for the architecture zoo."""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Any


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense | hybrid | ssm | audio | vlm | moe
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                 # 0 => d_model // n_heads

    # repeating layer pattern; each inner tuple is one layer's sub-layers
    pattern: tuple[tuple[str, ...], ...] = (("attn", "mlp"),)

    norm: str = "rmsnorm"             # rmsnorm | layernorm
    activation: str = "gelu"
    mlp_gated: bool = True
    rope_theta: float = 10000.0
    use_rope: bool = True
    qkv_bias: bool = False
    window: int | None = None         # sliding window for 'attn_local'
    attn_softcap: float | None = None
    final_softcap: float | None = None
    query_pre_attn_scalar: float | None = None
    embed_scale: bool = False         # gemma: embeddings *= sqrt(d_model)
    post_block_norm: bool = False     # gemma2 extra post-norms

    # MoE
    moe_experts: int = 0
    moe_top_k: int = 0
    moe_shared_dff: int = 0           # fused shared-expert width (0 = none)
    moe_group_size: int = 512
    moe_capacity_factor: float = 1.25
    moe_impl: str = "sort"            # "sort" (optimised) | "onehot" (GShard)

    # SSM / xLSTM
    ssm_state: int = 64
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 128
    shared_attn_period: int = 0       # zamba2: shared attn every N layers

    # encoder-decoder (whisper)
    is_encdec: bool = False
    encoder_layers: int = 0
    encoder_len: int = 1500

    # VLM
    n_img_tokens: int = 0

    # execution
    remat: bool = True
    scan_layers: bool = True
    unroll_scans: bool = False        # costing variants only (dryrun.py)
    q_chunk: int = 512
    loss_seq_chunk: int | None = 1024
    sub_quadratic: bool = False       # eligible for long_500k

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        assert self.n_layers % len(self.pattern) == 0, \
            (self.name, self.n_layers, len(self.pattern))

    @property
    def n_groups(self) -> int:
        if self.shared_attn_period:
            return self.n_layers // self.shared_attn_period
        return self.n_layers // len(self.pattern)

    @property
    def group_kinds(self) -> tuple[str, ...]:
        """Flattened sub-layer kinds of one scan group."""
        if self.shared_attn_period:
            # zamba2-style: N backbone layers then the shared block (params
            # live outside the scan; the cache entry is per-group)
            per_layer = tuple(k for layer in self.pattern for k in layer)
            return per_layer * self.shared_attn_period
        return tuple(k for layer in self.pattern for k in layer)

    def params_estimate(self) -> float:
        """Approximate parameter count (for roofline MODEL_FLOPS)."""
        d, f, v = self.d_model, self.d_ff, self.vocab
        kinds = self.group_kinds
        per_group = 0.0
        for k in kinds:
            if k in ("attn", "attn_local"):
                per_group += d * self.head_dim * (self.n_heads * 2
                                                  + self.n_kv_heads * 2)
            elif k == "mlp":
                per_group += d * f * (3 if self.mlp_gated else 2)
            elif k == "moe":
                per_group += (self.moe_experts * 3 * d * f
                              + (3 * d * self.moe_shared_dff))
            elif k == "mamba2":
                d_in = self.ssm_expand * d
                per_group += d * (2 * d_in + 2 * self.ssm_state
                                  + d_in // self.ssm_head_dim) + d_in * d
            elif k == "mlstm":
                d_in = 2 * d
                per_group += d * 2 * d_in + 3 * d_in * d_in + d_in * d
            elif k == "slstm":
                per_group += 4 * d * d + 2 * d * int(4 * d / 3) * 2
        total = per_group * self.n_groups + v * d
        if self.shared_attn_period:
            total += d * self.head_dim * (self.n_heads * 2
                                          + self.n_kv_heads * 2) + 3 * d * f
        if self.is_encdec:
            # encoder layers + decoder cross-attn (rough)
            total += self.encoder_layers * (4 * d * d + 2 * d * f)
            total += self.n_layers * 4 * d * d
        return total

    def replace(self, **kw) -> "ModelConfig":
        return replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str                         # train | prefill | decode

    @property
    def is_serve(self) -> bool:
        return self.kind in ("prefill", "decode")


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")

ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)


def shape_by_name(name: str) -> ShapeConfig:
    for s in ALL_SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)
