"""whisper-medium [audio] — enc-dec, 24+24L d_model=1024 16H (MHA)
d_ff=4096 vocab=51865 (padded to 51872 for even TP sharding); conv/mel
frontend STUBBED — input_specs supplies frame embeddings (B, 1500, d).
[arXiv:2212.04356]"""

from repro.models.registry import register
from .base import ModelConfig


@register("whisper-medium")
def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-medium",
        family="audio",
        n_layers=24,                     # decoder layers
        d_model=1024,
        n_heads=16,
        n_kv_heads=16,
        head_dim=64,
        d_ff=4096,
        vocab=51872,                     # real 51865, padded %16==0
        pattern=(("attn", "mlp"),),      # informational; EncDecLM owns layout
        norm="layernorm",
        activation="gelu",
        mlp_gated=False,
        use_rope=False,                  # sinusoidal absolute positions
        qkv_bias=True,
        is_encdec=True,
        encoder_layers=24,
        encoder_len=1500,
    )
