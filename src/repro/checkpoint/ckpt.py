"""Pytree checkpointing: msgpack + zstd, atomic writes, async option,
step-indexed directory layout with automatic latest-resume — the
checkpoint/restart half of the fault-tolerance story (runtime/ft.py).

Format: one ``.ckpt.zst`` file per save containing
    {"step": int, "tree": <flattened leaves>, "meta": {...}}
Leaves are serialised as (dtype, shape, raw bytes); bfloat16 round-trips via
a uint16 view.  Writes go to ``<name>.tmp`` then ``os.replace`` so a crash
mid-write never corrupts the latest checkpoint.

``zstandard`` is optional: without it, checkpoints are written as raw
msgpack (same file layout, no compression).  ``restore`` detects the zstd
magic bytes, so compressed and uncompressed checkpoints interoperate
whenever the library is present.
"""

from __future__ import annotations

import os
import threading
import time
from typing import Any

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

try:
    import zstandard
except ImportError:          # optional dep — fall back to uncompressed
    zstandard = None

_ZSTD_MAGIC = b"\x28\xb5\x2f\xfd"


def _compress(raw: bytes) -> bytes:
    if zstandard is None:
        return raw
    return zstandard.ZstdCompressor(level=3).compress(raw)


def _decompress(data: bytes) -> bytes:
    if not data.startswith(_ZSTD_MAGIC):
        return data              # written without compression
    if zstandard is None:
        raise RuntimeError(
            "checkpoint is zstd-compressed but the 'zstandard' package is "
            "not installed (pip install -r requirements-dev.txt)")
    return zstandard.ZstdDecompressor().decompress(data)


def _encode_leaf(x) -> dict:
    a = np.asarray(x)
    if a.dtype == jnp.bfloat16:
        return {"dtype": "bfloat16", "shape": list(a.shape),
                "data": a.view(np.uint16).tobytes()}
    return {"dtype": a.dtype.str, "shape": list(a.shape),
            "data": a.tobytes()}


def _decode_leaf(d) -> np.ndarray:
    if d["dtype"] == "bfloat16":
        a = np.frombuffer(d["data"], np.uint16).reshape(d["shape"])
        return a.view(jnp.bfloat16)
    return np.frombuffer(d["data"], np.dtype(d["dtype"])
                         ).reshape(d["shape"])


def save(path: str, tree: Any, step: int = 0, meta: dict | None = None
         ) -> None:
    leaves, treedef = jax.tree.flatten(tree)
    payload = {
        "step": step,
        "meta": meta or {},
        "leaves": [_encode_leaf(x) for x in leaves],
    }
    raw = msgpack.packb(payload, use_bin_type=True)
    comp = _compress(raw)
    tmp = path + ".tmp"
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(tmp, "wb") as f:
        f.write(comp)
    os.replace(tmp, path)          # atomic


def restore(path: str, like: Any) -> tuple[Any, int, dict]:
    """``like`` supplies the treedef (and optionally shardings via
    device_put by the caller)."""
    with open(path, "rb") as f:
        raw = _decompress(f.read())
    payload = msgpack.unpackb(raw, raw=False)
    leaves = [_decode_leaf(d) for d in payload["leaves"]]
    _, treedef = jax.tree.flatten(like)
    return (jax.tree.unflatten(treedef, leaves), payload["step"],
            payload["meta"])


# -- step-indexed manager -----------------------------------------------------

class CheckpointManager:
    """``dir/step_000123.ckpt.zst`` layout with retention + async writes.

    ``save`` offloads serialisation to a worker thread (double-buffered: at
    most one pending write; callers block only if a previous write is still
    in flight — standard async-checkpoint behaviour so the train loop is not
    stalled by I/O).
    """

    def __init__(self, directory: str, keep: int = 3, async_writes: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_writes = async_writes
        self._pending: threading.Thread | None = None
        os.makedirs(directory, exist_ok=True)

    def _path(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:08d}.ckpt.zst")

    def steps(self) -> list[int]:
        out = []
        for f in os.listdir(self.dir):
            if f.startswith("step_") and f.endswith(".ckpt.zst"):
                out.append(int(f[5:13]))
        return sorted(out)

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def save(self, step: int, tree: Any, meta: dict | None = None) -> None:
        self.wait()
        # pull to host before handing to the writer thread
        host_tree = jax.tree.map(np.asarray, tree)

        def work():
            save(self._path(step), host_tree, step, meta)
            self._gc()

        if self.async_writes:
            self._pending = threading.Thread(target=work, daemon=True)
            self._pending.start()
        else:
            work()

    def restore_latest(self, like: Any) -> tuple[Any, int, dict] | None:
        steps = self.steps()
        if not steps:
            return None
        return restore(self._path(steps[-1]), like)

    def _gc(self) -> None:
        steps = self.steps()
        for s in steps[:-self.keep] if self.keep else []:
            try:
                os.remove(self._path(s))
            except OSError:
                pass
