from .ckpt import CheckpointManager, restore, save

__all__ = ["CheckpointManager", "restore", "save"]
