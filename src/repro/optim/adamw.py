"""AdamW with bf16 params / fp32 moments, global-norm clipping, and an
optional int8 error-feedback compression hook for cross-pod gradient
all-reduce (see compress.py).

Implemented directly on pytrees (no optax dependency in this container).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float | Callable[[jnp.ndarray], jnp.ndarray] = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float | None = 1.0


def init_state(params):
    """Optimizer state: fp32 first/second moments + step counter."""
    zeros = jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return {"mu": zeros,
            "nu": jax.tree.map(jnp.copy, zeros),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(g.astype(jnp.float32)))
              for g in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), norm


def apply_updates(cfg: AdamWConfig, params, grads, state):
    """One AdamW step.  Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    lr = cfg.lr(step) if callable(cfg.lr) else jnp.float32(cfg.lr)

    gnorm = global_norm(grads)
    if cfg.clip_norm is not None:
        grads, _ = clip_by_global_norm(grads, cfg.clip_norm)

    b1, b2 = cfg.b1, cfg.b2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g32 = g.astype(jnp.float32)
        mu = b1 * mu + (1 - b1) * g32
        nu = b2 * nu + (1 - b2) * jnp.square(g32)
        mhat = mu / c1
        nhat = nu / c2
        delta = mhat / (jnp.sqrt(nhat) + cfg.eps)
        if cfg.weight_decay and p.ndim >= 2:     # no decay on norms/biases
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return new_p, mu, nu

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_mu = tdef.flatten_up_to(state["mu"])
    flat_nu = tdef.flatten_up_to(state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n
           in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_params = tdef.unflatten([o[0] for o in out])
    new_state = {"mu": tdef.unflatten([o[1] for o in out]),
                 "nu": tdef.unflatten([o[2] for o in out]),
                 "step": step}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
