"""int8 error-feedback gradient compression for cross-pod all-reduce.

At 2-pod scale the DCN all-reduce of bf16 gradients is the slowest
collective; quantising the cross-pod payload to int8 with per-tensor scales
halves it.  Error feedback (residual carried to the next step) keeps the
compression unbiased in the long run (1-bit Adam / EF-SGD lineage).

The compression is applied *around* the pod-axis psum only:
    g_local  -> q = quant(g + residual) -> psum(q) over 'pod' -> dequant
intra-pod reduction stays full-precision.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize(g, residual=None):
    """Returns (int8 values, fp32 scale, new residual)."""
    g32 = g.astype(jnp.float32)
    if residual is not None:
        g32 = g32 + residual
    amax = jnp.max(jnp.abs(g32))
    scale = jnp.maximum(amax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    new_residual = g32 - q.astype(jnp.float32) * scale
    return q, scale, new_residual


def dequantize(q, scale):
    return q.astype(jnp.float32) * scale


def compressed_psum(g, axis_name: str, residual=None):
    """psum over ``axis_name`` with int8 payload + error feedback.

    The scale is itself psum-maxed so every pod dequantises identically.
    """
    q, scale, new_residual = quantize(g, residual)
    scale = jax.lax.pmax(scale, axis_name)
    # requantise against the shared scale so the int8 sum is exact
    g32 = g.astype(jnp.float32) + (residual if residual is not None else 0.0)
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    new_residual = g32 - q.astype(jnp.float32) * scale
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    return (total.astype(jnp.float32) * scale / n).astype(g.dtype), \
        new_residual


def tree_compressed_psum(grads, axis_name: str, residuals=None):
    if residuals is None:
        residuals = jax.tree.map(lambda g: None, grads,
                                 is_leaf=lambda x: x is None)
    flat_g, tdef = jax.tree.flatten(grads)
    flat_r = (tdef.flatten_up_to(residuals)
              if jax.tree.leaves(residuals) else [None] * len(flat_g))
    out = [compressed_psum(g, axis_name, r)
           for g, r in zip(flat_g, flat_r)]
    return tdef.unflatten([o[0] for o in out]), \
        tdef.unflatten([o[1] for o in out])
