from .adamw import (AdamWConfig, apply_updates, clip_by_global_norm,
                    global_norm, init_state)
from .schedule import constant, cosine_with_warmup
from .compress import compressed_psum, dequantize, quantize, \
    tree_compressed_psum

__all__ = ["AdamWConfig", "apply_updates", "clip_by_global_norm",
           "global_norm", "init_state", "constant", "cosine_with_warmup",
           "compressed_psum", "dequantize", "quantize",
           "tree_compressed_psum"]
