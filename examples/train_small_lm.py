"""Train a ~100M-parameter xLSTM on the synthetic pipeline for a few
hundred steps with checkpoint/restart.

    PYTHONPATH=src python examples/train_small_lm.py --steps 200
    PYTHONPATH=src python examples/train_small_lm.py --tiny --steps 30

``--tiny`` shrinks the model (~1M params) so the example finishes in
seconds on CPU; the default config is the real xlstm-125m geometry.
Interrupt and re-run to see checkpoint resume (runtime/ft.py).
"""

import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.data import DataConfig, SyntheticLM
from repro.launch.steps import make_train_step
from repro.models import build_model, get_config
from repro.optim import AdamWConfig, cosine_with_warmup, init_state
from repro.runtime.ft import StragglerDetector, TrainSupervisor


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    args = ap.parse_args()

    cfg = get_config("xlstm-125m").replace(remat=False,
                                           loss_seq_chunk=None)
    if args.tiny:
        cfg = cfg.replace(d_model=128, n_heads=4, head_dim=32, vocab=512,
                          n_layers=4, ssm_chunk=32)
    model = build_model(cfg)

    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                                  global_batch=args.batch, seed=0))
    adamw = AdamWConfig(lr=cosine_with_warmup(3e-3, 20, args.steps),
                        weight_decay=0.01)
    step_fn = jax.jit(make_train_step(model, adamw, None, None),
                      donate_argnums=(0, 1))

    mgr = CheckpointManager(args.ckpt_dir, keep=2)
    sup = TrainSupervisor(mgr, ckpt_every=50)

    params = model.init(jax.random.PRNGKey(0))
    opt = init_state(params)
    state, start = sup.resume_or_init(lambda: {"p": params, "o": opt},
                                      like={"p": params, "o": opt})
    params, opt = state["p"], state["o"]
    if start:
        print(f"resumed from checkpoint at step {start}")

    for step in range(start, args.steps):
        batch = jax.tree.map(jnp.asarray, data.global_batch_at(step))
        t0 = time.perf_counter()
        params, opt, metrics = step_fn(params, opt, batch)
        wall = time.perf_counter() - t0
        sup.after_step(step, {"p": params, "o": opt}, wall)
        if step % 10 == 0 or step == args.steps - 1:
            print(f"step {step:4d} loss={float(metrics['loss']):.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} "
                  f"lr={float(metrics['lr']):.2e} {wall * 1e3:.0f}ms")
    mgr.wait()
    print("events:", sup.events[-4:])


if __name__ == "__main__":
    main()
