"""Elastic re-planning (the paper's 'operational change' scenario).

    PYTHONPATH=src python examples/elastic_repartition.py

Starts with the full testbed, then: (1) the edge box is drained for
maintenance, (2) the network degrades from 4G to 3G, (3) a new edge
resource joins (benchmarked incrementally).  Each event triggers a
re-plan from cached benchmark data — well inside the paper's 50 ms budget.
"""

import sys

sys.path.insert(0, "src")
sys.path.insert(0, ".")

from benchmarks.common import NETWORKS, benchmark_cached, scission_for
from repro.core import Resource, paper_network
from repro.core.resources import EDGE_BOX_2
from repro.models import cnn_zoo
from repro.runtime.elastic import ElasticController


def main():
    s = scission_for("4g")
    graph = cnn_zoo.build("ResNet50")
    benchmark_cached(s, "ResNet50")

    ctl = ElasticController(s, "ResNet50", graph=graph)
    print("initial:", ctl.current.describe())

    ev = ctl.on_resource_lost("edge1")
    print(f"\n[edge1 drained] re-planned in {ev.plan_time_s * 1e3:.1f}ms")
    print("   ->", ev.config.describe())

    net3g = paper_network(NETWORKS["3g"], edges=("edge2",),
                          clouds=("cloud", "cloud_gpu"))
    ev = ctl.on_network_change(net3g)
    print(f"\n[4G -> 3G] re-planned in {ev.plan_time_s * 1e3:.1f}ms")
    print("   ->", ev.config.describe())

    new_edge = Resource("edge3", "edge", EDGE_BOX_2, speed_factor=2.0)
    ev = ctl.on_resource_joined(new_edge)
    print(f"\n[edge3 joined] benchmarked incrementally + re-planned in "
          f"{ev.plan_time_s * 1e3:.1f}ms (includes Step-3 enumeration)")
    print("   ->", ev.config.describe())

    # the paper's 50ms budget applies to queries over cached benchmark
    # data; the first query after a membership change also (re)builds the
    # enumeration cache — every subsequent query is warm:
    import time
    from repro.core import Query
    t0 = time.perf_counter()
    ctl.scission.query("ResNet50", Query(top_n=3))
    warm = time.perf_counter() - t0
    print(f"\nwarm re-query after all changes: {warm * 1e3:.1f}ms")
    assert warm < 0.05, "warm query exceeded the 50ms budget"
    print("warm queries < 50ms ✓")


if __name__ == "__main__":
    main()
