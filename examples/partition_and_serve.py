"""End-to-end driver: partition a transformer LM with Scission and serve
batched requests through the pipeline executor + serving engine.

    PYTHONPATH=src python examples/partition_and_serve.py

1. Builds a reduced gemma2-family LM, adapts it to a Scission LayerGraph
   (one node per layer group).
2. Benchmarks it on the emulated device/edge/cloud testbed and picks the
   lowest-latency partition (paper Steps 1-6).
3. Executes the partitioned forward pipeline on a prompt batch and checks
   it against the unpartitioned model.
4. Serves a batch of generation requests with the continuous-batching
   engine (greedy decode, ragged lengths).
"""

import sys

sys.path.insert(0, "src")
sys.path.insert(0, ".")

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import scission_for
from repro.core import Query
from repro.models import build_model, get_config
from repro.models.graph_adapter import lm_to_graph
from repro.runtime.pipeline import PipelineExecutor
from repro.serving import Request, ServingEngine


def reduced_lm():
    cfg = get_config("gemma2-9b").replace(
        n_layers=4, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
        d_ff=256, vocab=512, window=16, remat=False, q_chunk=64,
        loss_seq_chunk=None, query_pre_attn_scalar=32.0)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def main():
    cfg, model, params = reduced_lm()
    B, S = 2, 32

    print("== 1. adapt LM -> Scission layer graph ==")
    graph = lm_to_graph(model, params, batch=B, seq_len=S)
    print(f"   {graph.name}: {graph.n_layers} nodes, "
          f"{len(graph.partition_points())} partition points")

    print("== 2. benchmark + query (Steps 1-6) ==")
    s = scission_for("4g")
    s.benchmark(graph)
    res = s.query(graph.name, Query(top_n=3),
                  input_bytes=B * S * 4)
    for cfgp in res.configs:
        print("   ", cfgp.describe())
    best = res.configs[0]

    print("== 3. execute the partitioned pipeline ==")
    execu = PipelineExecutor(graph, best, s.network, source="device")
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    got, timings = execu.run(tokens, collect_timing=True)
    for t in timings:
        print(f"   stage on {t.resource}: compute={t.compute_s * 1e3:.1f}ms "
              f"(host) comm_in={t.comm_in_s * 1e3:.1f}ms "
              f"({t.bytes_in / 1e3:.0f}KB)")
    # parity with the unpartitioned model
    hidden, _ = model.forward(params, tokens)
    from repro.models import layers as L
    want = L.unembed(params["embed"], hidden[:, -1:],
                     softcap=cfg.final_softcap)
    # bf16 reassociation noise between the scan and per-stage paths is
    # expected; decisions (argmax) must match exactly
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=1e-1)
    assert (np.argmax(np.asarray(got), -1)
            == np.argmax(np.asarray(want), -1)).all()
    print("   partitioned == unpartitioned (argmax exact, values ±bf16) ✓")

    print("== 4. serve batched requests (continuous batching) ==")
    eng = ServingEngine(model, params, width=4, max_len=64)
    rng = np.random.default_rng(0)
    for rid in range(6):
        plen = int(rng.integers(4, 12))
        eng.submit(Request(rid=rid,
                           prompt=rng.integers(0, cfg.vocab, plen),
                           max_new_tokens=8))
    done = eng.run()
    for r in sorted(done, key=lambda r: r.rid):
        lat = (r.finished_at - r.submitted_at) * 1e3
        print(f"   req{r.rid}: prompt={len(r.prompt)} -> "
              f"{len(r.tokens)} tokens in {lat:.0f}ms: {r.tokens}")
    assert len(done) == 6
    print("   served 6/6 ✓")


if __name__ == "__main__":
    main()
