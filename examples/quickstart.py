"""Quickstart: Scission end-to-end on MobileNetV2 (the paper's Figure 8).

    PYTHONPATH=src python examples/quickstart.py

Benchmarks the model on the emulated device/edge/cloud testbed (Steps 1-3),
then queries the optimal partition under 3G and 4G (Steps 4-6) — showing
the paper's headline result: the optimum flips from device-native under 3G
to cloud-native under 4G.
"""

import sys

sys.path.insert(0, "src")

from repro.core import Query
from repro.models import cnn_zoo

sys.path.insert(0, ".")
from benchmarks.common import benchmark_cached, scission_for  # noqa: E402


def main():
    print("== Scission quickstart: MobileNetV2 on device/edge/cloud ==")
    for net in ("3g", "4g"):
        s = scission_for(net)
        print(f"\n[{net}] benchmarking (Steps 1-3, cached after first run)…")
        benchmark_cached(s, "MobileNetV2")
        res = s.query("MobileNetV2", Query(top_n=3))
        print(f"[{net}] top-3 partitions "
              f"(query took {res.query_time_s * 1e3:.1f}ms):")
        for cfg in res.configs:
            print("   ", cfg.describe())

    # a constrained query: keep data on the device+edge (privacy)
    s = scission_for("4g")
    benchmark_cached(s, "MobileNetV2")
    res = s.query("MobileNetV2",
                  Query(top_n=1, exclude=("cloud", "cloud_gpu")))
    print("\n[4g, privacy: no cloud]", res.best.describe())


if __name__ == "__main__":
    main()
