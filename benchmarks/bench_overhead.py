"""Table III reproduction: Scission benchmarking overhead per DNN per
resource (seconds to run Steps 2-3)."""

from __future__ import annotations

import time

from repro.core import benchmark_model, TimingProvider
from repro.models import cnn_zoo

from .common import testbed


def run(quick: bool = True):
    names = (["MobileNetV2", "ResNet50", "VGG16"] if quick
             else ["Xception", "VGG16", "VGG19", "ResNet50", "MobileNet",
                   "MobileNetV2", "DenseNet121", "InceptionV3"])
    resources = testbed()
    rows = []
    print("\n# Table III — benchmarking overhead (s) per resource")
    hdr = f"{'model':<16}" + "".join(f"{r.name:>11}" for r in resources)
    print(hdr)
    for name in names:
        g = cnn_zoo.build(name)
        times = []
        for r in resources:
            t0 = time.perf_counter()
            benchmark_model(g, [r], TimingProvider(), runs=5)
            wall = time.perf_counter() - t0
            # emulated overhead: measurement wall-time scaled to the tier
            times.append(wall * r.speed_factor)
        print(f"{name:<16}" + "".join(f"{t:>11.2f}" for t in times))
        rows.append((f"overhead/{name}", times[-2] * 1e6,
                     round(times[0] / times[-2], 2)))
        # derived: device/cloud overhead ratio (paper: ~10x)
    return rows
