"""Shared setup for the paper-reproduction benchmarks.

This host plays the paper's 'Cloud' box; the other tiers are emulated with
speed factors calibrated so the device/cloud end-to-end latency ratios land
in the regime of the paper's Figures 6-9 (the paper itself emulates the
network conditions; we additionally emulate tier speeds since only one
machine is available).  Benchmark DBs are cached on disk under
``results/benchdb`` so repeated runs skip Steps 2-3, like the real tool.
"""

from __future__ import annotations

import os

from repro.core import (AnalyticProvider, Link, NetworkModel, QueryEngine,
                        Resource, Scission, TimingProvider, benchmark_model,
                        linear_graph, paper_network, THREE_G, FOUR_G, WIRED)
from repro.core.graph import LayerNode
from repro.core.resources import (CLOUD_VM, EDGE_BOX_1, EDGE_BOX_2, GTX_1070,
                                  RPI4)
from repro.models import cnn_zoo

CACHE_DIR = os.path.join(os.path.dirname(__file__), "..", "results",
                         "benchdb")

# Scaled-time emulation: this host's CNN compute is ~6x slower than the
# paper's cloud box, so network times are scaled by the same factor to keep
# the comm/compute ratio — and hence the paper's decision geometry — intact.
TIME_SCALE = 6.0
# tier speed ratios calibrated from the paper (Table III overheads and the
# Fig 6-8 end-to-end latencies): device ~8x cloud, edges ~2x, GPU ~0.5x
SPEED = {"device": 8.0, "edge1": 2.1, "edge2": 1.7, "cloud": 1.0,
         "cloud_gpu": 0.5}


def testbed() -> list[Resource]:
    return [
        Resource("device", "device", RPI4, speed_factor=SPEED["device"]),
        Resource("edge1", "edge", EDGE_BOX_1, speed_factor=SPEED["edge1"]),
        Resource("edge2", "edge", EDGE_BOX_2, speed_factor=SPEED["edge2"]),
        Resource("cloud", "cloud", CLOUD_VM, speed_factor=SPEED["cloud"]),
        Resource("cloud_gpu", "cloud", GTX_1070,
                 speed_factor=SPEED["cloud_gpu"]),
    ]


def _scaled(link: Link) -> Link:
    return Link(link.name, link.latency_s * TIME_SCALE,
                link.bandwidth / TIME_SCALE)


NETWORKS = {"3g": _scaled(THREE_G), "4g": _scaled(FOUR_G),
            "wired": _scaled(WIRED)}


def scission_for(network_name: str = "4g",
                 resources: list[Resource] | None = None) -> Scission:
    res = resources if resources is not None else testbed()
    net = paper_network(NETWORKS[network_name],
                        edges=tuple(r.name for r in res if r.tier == "edge"),
                        clouds=tuple(r.name for r in res
                                     if r.tier == "cloud"))
    return Scission(resources=res, network=net, source="device",
                    provider=TimingProvider(), runs=5)


def fleet_testbed(n_per_tier: int = 9) -> list[Resource]:
    """A fleet-sized resource set: ``n_per_tier`` heterogeneous resources
    per tier (slightly different speed factors), for search spaces beyond
    ``EXHAUSTIVE_LIMIT`` where only the lattice strategies are viable."""
    res: list[Resource] = []
    for i in range(n_per_tier):
        res.append(Resource(f"device{i}", "device", RPI4,
                            speed_factor=8.0 + i * 0.37))
        res.append(Resource(f"edge{i}", "edge", EDGE_BOX_1,
                            speed_factor=1.6 + i * 0.21))
        res.append(Resource(f"cloud{i}", "cloud", CLOUD_VM,
                            speed_factor=0.5 + i * 0.13))
    return res


def fleet_engine(n_per_tier: int = 9, n_blocks: int = 32,
                 network_name: str = "4g",
                 input_bytes: float = 150e3) -> QueryEngine:
    """A QueryEngine over a synthetic ``n_blocks``-block model benchmarked
    (analytically, for speed) on :func:`fleet_testbed` — the fleet-scale
    query-path benchmark substrate.  With the defaults the search space is
    ~350k configs, past the exhaustive limit."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    rng = np.random.default_rng(n_blocks)
    layers = []
    for i in range(n_blocks):
        d = int(rng.integers(4, 16)) * 2
        layers.append(LayerNode(
            f"l{i}", "dense",
            apply=lambda x, d=d: jnp.tile(x[..., :1], (1, d)),
            flops=float(rng.integers(1, 100)) * 1e7))
    graph = linear_graph(f"fleet{n_blocks}",
                         jax.ShapeDtypeStruct((1, 8), jnp.float32), layers)
    resources = fleet_testbed(n_per_tier)
    db = benchmark_model(graph, resources, AnalyticProvider(), runs=1)
    link = NETWORKS[network_name]
    net = NetworkModel(default=link)
    return QueryEngine(db, resources, net, source="device0",
                       input_bytes=input_bytes)


def benchmark_cached(scission: Scission, model_name: str,
                     batch_sizes: tuple[int, ...] = (1,)):
    """Steps 1-3 with a disk cache (the paper's offline benchmarking).

    The cache is reused only when it covers the requested resources AND
    batch sizes; otherwise the model is re-benchmarked with the union of
    cached and requested batches, so a batched scenario upgrades the cached
    DB in place (old scalar caches load as batch-1 profiles).
    """
    os.makedirs(CACHE_DIR, exist_ok=True)
    path = os.path.join(CACHE_DIR, f"{model_name}.json")
    want_batches = set(batch_sizes) | {1}
    if os.path.exists(path):
        db = scission.restore(path)
        names = [r.name for r in scission.resources]
        have_resources = set(names) <= set(db.records)
        # coverage over the *active* testbed only: the cache may hold stale
        # records for departed resources at fewer batch sizes, which must
        # neither mask covered batches nor make the upgrade loop diverge
        missing = want_batches - set(db.measured_batches(names))
        if have_resources and not missing:
            return db
        if have_resources:
            # resources covered, batches not: measure only the missing
            # batch sizes and merge (no re-timing of the cached sweep)
            graph = cnn_zoo.build(model_name)
            db = scission.benchmark_batches(
                graph, batch_sizes=tuple(sorted(missing)))
            scission.save(model_name, path)
            return db
        want_batches |= set(db.measured_batches())
    graph = cnn_zoo.build(model_name)
    db = scission.benchmark(graph, batch_sizes=tuple(sorted(want_batches)))
    scission.save(model_name, path)
    return db
