"""Elastic re-planning latency (paper motivation (vi): respond to
operational changes rapidly)."""

from __future__ import annotations

import time

from repro.core import Query, Resource
from repro.core.resources import EDGE_BOX_2
from repro.models import cnn_zoo
from repro.runtime.elastic import ElasticController

from .common import benchmark_cached, scission_for


def run(quick: bool = True):
    s = scission_for("4g")
    graph = cnn_zoo.build("ResNet50")
    benchmark_cached(s, "ResNet50")
    ctl = ElasticController(s, "ResNet50", graph=graph)

    rows = []
    print("\n# Elastic re-planning (motivation vi)")
    ev = ctl.on_resource_lost("edge1")
    print(f"  drain edge1:   {ev.plan_time_s * 1e3:7.1f}ms -> "
          f"{ev.config.describe()}")
    rows.append(("elastic/drain", ev.plan_time_s * 1e6,
                 round(ev.config.latency_s, 4)))

    ev = ctl.on_resource_joined(Resource("edge3", "edge", EDGE_BOX_2,
                                         speed_factor=2.0))
    print(f"  join edge3:    {ev.plan_time_s * 1e3:7.1f}ms (+ incremental "
          f"benchmark) -> {ev.config.describe()}")
    rows.append(("elastic/join", ev.plan_time_s * 1e6,
                 round(ev.config.latency_s, 4)))

    t0 = time.perf_counter()
    ctl.scission.query("ResNet50", Query(top_n=3))
    warm = time.perf_counter() - t0
    print(f"  warm re-query: {warm * 1e3:7.1f}ms "
          f"({'<50ms PASS' if warm < 0.05 else 'FAIL'})")
    rows.append(("elastic/warm_query", warm * 1e6, round(warm * 1e3, 3)))

    # frontier-mode controllers: the incremental one hands its kept label
    # arrays back to frontier_incremental on each re-plan, so a
    # steady-state network-settle/re-plan cycle replays labels instead of
    # re-running the DP from scratch
    for inc in (False, True):
        s2 = scission_for("4g")
        benchmark_cached(s2, "ResNet50")
        ctl2 = ElasticController(s2, "ResNet50", graph=graph,
                                 track_frontier=True, incremental=inc)
        ev = ctl2.on_resource_lost("edge1")
        tag = "inc" if inc else "cold"
        print(f"  frontier re-plan ({tag}): {ev.plan_time_s * 1e3:7.1f}ms "
              f"front={ev.frontier_size}")
        rows.append((f"elastic/frontier_replan_{tag}",
                     ev.plan_time_s * 1e6, ev.frontier_size))
    return rows
