"""Table I reproduction: the 18 DNNs — layer counts, partition points,
linear/branching classification."""

from __future__ import annotations

import time

from repro.core import fuse_blocks
from repro.models import cnn_zoo


def run(quick: bool = True):
    names = (["VGG16", "ResNet50", "MobileNet", "MobileNetV2",
              "DenseNet121", "InceptionV3"] if quick
             else sorted(cnn_zoo.ZOO))
    rows = []
    print("\n# Table I — model zoo (layers / partition points / type)")
    print(f"{'model':<20}{'layers':>8}{'points':>8}{'type':>6}{'approx':>8}")
    for name in names:
        t0 = time.perf_counter()
        g = cnn_zoo.build(name)
        blocks = fuse_blocks(g)
        dt = time.perf_counter() - t0
        typ = "L" if name in cnn_zoo.LINEAR else "B"
        print(f"{name:<20}{g.n_layers:>8}{len(blocks) - 1:>8}{typ:>6}"
              f"{'~' if name in cnn_zoo.APPROX else '':>8}")
        rows.append((f"zoo/{name}", dt * 1e6, len(blocks) - 1))
    return rows
