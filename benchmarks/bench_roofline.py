"""§Roofline report over the dry-run artifact (results/dryrun.json)."""

from __future__ import annotations

import json
import os

from repro.launch.roofline import analyse, table

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results",
                       "dryrun.json")


def run(quick: bool = True):
    if not os.path.exists(RESULTS):
        print("\n# Roofline: results/dryrun.json missing — run "
              "`python -m repro.launch.dryrun --all --out "
              "results/dryrun.json` first")
        return []
    records = json.load(open(RESULTS))
    print("\n# §Roofline — single-pod 16x16 (from the dry-run)")
    print(table(records, "16x16"))
    rows = []
    for r in records:
        if r["status"] != "OK" or r["mesh"] != "16x16":
            continue
        a = analyse(r)
        dom_ms = max(a["t_compute_s"], a["t_memory_s"],
                     a["t_collective_s"]) * 1e3
        rows.append((f"roofline/{r['arch']}/{r['shape']}", dom_ms * 1e3,
                     round(a["roofline_fraction"], 4)))
    return rows
