"""Figures 6-15 + Table IV reproduction: Scission decisions under network
conditions, input sizes, constraints, pipelines, and top-N rankings."""

from __future__ import annotations

import time

from repro.core import Query, LATENCY

from .common import benchmark_cached, scission_for, testbed


def _best(scission, model, query=None, input_bytes=150e3):
    res = scission.query(model, query or Query(top_n=1), input_bytes)
    return res.best, res.query_time_s


def scenario_network(quick=True):
    """Figs 6-8: optimal partition vs network condition."""
    print("\n# Figs 6-8 — lowest-latency partition per network condition")
    rows = []
    models = ["VGG19", "ResNet50", "MobileNetV2"] if not quick else \
        ["ResNet50", "MobileNetV2"]
    for net in ("3g", "4g", "wired"):
        s = scenario_network._cache.setdefault(net, scission_for(net))
        for m in models:
            benchmark_cached(s, m)
            best, qt = _best(s, m)
            print(f"  [{net}] {best.describe()}")
            rows.append((f"net/{net}/{m}", qt * 1e6,
                         round(best.latency_s, 4)))
    return rows


scenario_network._cache = {}


def scenario_input_size(quick=True):
    """Fig 9: partition sensitivity to input size (3G).  The paper's flip
    happens at 170KB on its testbed; we report the flip threshold on ours
    (the exact value depends on tier speeds — the sensitivity is the
    claim)."""
    print("\n# Fig 9 — input size sensitivity (ResNet50, 3G)")
    s = scenario_network._cache.setdefault("3g", scission_for("3g"))
    benchmark_cached(s, "ResNet50")
    rows = []
    for kb in (150, 170, 220, 300):
        best, qt = _best(s, "ResNet50", input_bytes=kb * 1e3)
        print(f"  [{kb}KB] {best.describe()}")
        rows.append((f"input/{kb}kb", qt * 1e6, round(best.latency_s, 4)))
    return rows


def scenario_constraints(quick=True):
    """Figs 10-11: entire resource pipeline must be used."""
    print("\n# Figs 10-11 — constraint: device+edge+cloud must all be used")
    rows = []
    q = Query(top_n=1, must_use=("device", "edge1", "cloud_gpu"))
    models = ["VGG19", "ResNet50"] if not quick else ["ResNet50"]
    for net in ("3g", "4g"):
        s = scenario_network._cache.setdefault(net, scission_for(net))
        for m in models:
            benchmark_cached(s, m)
            best, qt = _best(s, m, q)
            print(f"  [{net}] {best.describe()}")
            rows.append((f"cons/{net}/{m}", qt * 1e6,
                         round(best.latency_s, 4)))
    return rows


def scenario_pipelines(quick=True):
    """Figs 12-14: Edge(1) vs Edge(2) hardware sensitivity (wired)."""
    print("\n# Figs 12-14 — edge hardware sensitivity (wired)")
    rows = []
    s = scenario_network._cache.setdefault("wired", scission_for("wired"))
    models = ["InceptionV3", "DenseNet169"] if not quick else \
        ["InceptionV3"]
    for m in models:
        benchmark_cached(s, m)
        for edge in ("edge1", "edge2"):
            other = "edge2" if edge == "edge1" else "edge1"
            q = Query(top_n=1, must_use=(edge,), exclude=(other,))
            best, qt = _best(s, m, q)
            print(f"  [{edge}] {best.describe()}")
            rows.append((f"pipe/{edge}/{m}", qt * 1e6,
                         round(best.latency_s, 4)))
    return rows


def scenario_topn(quick=True):
    """Table IV + Fig 15: top-3 per distributed pipeline (ResNet50)."""
    print("\n# Table IV — top-3 partitions per pipeline (ResNet50, wired)")
    s = scenario_network._cache.setdefault("wired", scission_for("wired"))
    benchmark_cached(s, "ResNet50")
    pipelines = {
        "device-edge": (("device", "edge1"),),
        "device-cloud": (("device", "cloud_gpu"),),
        "edge-cloud": (("edge1", "cloud_gpu"),),
        "device-edge-cloud": (("device", "edge1", "cloud_gpu"),),
    }
    rows = []
    for name, pipes in pipelines.items():
        res = s.query("ResNet50", Query(top_n=3, pipelines=pipes))
        print(f"  [{name}]")
        for cfg in res.configs:
            print(f"    {cfg.describe()}")
        if res.configs:
            rows.append((f"topn/{name}", res.query_time_s * 1e6,
                         round(res.configs[0].latency_s, 4)))
    return rows


def run(quick: bool = True):
    rows = []
    rows += scenario_network(quick)
    rows += scenario_input_size(quick)
    rows += scenario_constraints(quick)
    rows += scenario_pipelines(quick)
    rows += scenario_topn(quick)
    return rows
