"""Figures 6-15 + Table IV reproduction: Scission decisions under network
conditions, input sizes, constraints, pipelines, and top-N rankings — plus
the beyond-paper pipelined-serving scenarios: throughput-optimal partitions
(predicted vs. simulated), Pareto-front queries, and batched/replicated
operating points (benchmark DBs carry per-batch profiles; queries carry a
``batch_size`` and a per-resource ``replicas`` budget; ``frontier()`` sweeps
the measured batch sizes).

Run standalone in smoke mode for CI::

    PYTHONPATH=src python -m benchmarks.bench_partitions --smoke \
        --out results/bench_partitions_smoke.json

    # batched/replicated path (two batch sizes, replicated stages); fails
    # if predicted vs simulated throughput diverges by more than 25%:
    PYTHONPATH=src python -m benchmarks.bench_partitions --smoke-batched \
        --out results/bench_partitions_smoke_batched.json

    # frontier exactness + scaling: fails unless the ParetoLattice frontier
    # equals the exhaustive frontier (vector-set equality) on the paper
    # networks x operating points — including under binding path-dependent
    # constraints (max_resource_time / min_blocks_on, folded into the DP
    # state) — and the fleet-sized frontier query stays interactive (label
    # statistics land in the JSON artifact):
    PYTHONPATH=src python -m benchmarks.bench_partitions --smoke-frontier \
        --out results/bench_partitions_smoke_frontier.json

    # DAG-general partitioning: branchy MoE / enc-dec graphs fused with
    # fuse_block_dag over 3G/4G/wired; fails unless the SP-lattice solve
    # and frontier equal the DAG-aware exhaustive oracle on every query,
    # and unless some optimal config splits a parallel region across
    # resources (the capability chain fusing cannot express):
    PYTHONPATH=src python -m benchmarks.bench_partitions --smoke-dag \
        --out results/bench_partitions_smoke_dag.json
"""

from __future__ import annotations

import argparse
import json
import os
import time

from repro.core import Query, LATENCY, THROUGHPUT
from repro.core import objective_vector as _vec
from repro.serving.engine import simulate_pipeline_throughput

from .common import benchmark_cached, fleet_engine, scission_for, testbed


def _best(scission, model, query=None, input_bytes=150e3):
    res = scission.query(model, query or Query(top_n=1), input_bytes)
    return res.best, res.query_time_s


def scenario_network(quick=True):
    """Figs 6-8: optimal partition vs network condition."""
    print("\n# Figs 6-8 — lowest-latency partition per network condition")
    rows = []
    models = ["VGG19", "ResNet50", "MobileNetV2"] if not quick else \
        ["ResNet50", "MobileNetV2"]
    for net in ("3g", "4g", "wired"):
        s = scenario_network._cache.setdefault(net, scission_for(net))
        for m in models:
            benchmark_cached(s, m)
            best, qt = _best(s, m)
            print(f"  [{net}] {best.describe()}")
            rows.append((f"net/{net}/{m}", qt * 1e6,
                         round(best.latency_s, 4)))
    return rows


scenario_network._cache = {}


def scenario_input_size(quick=True):
    """Fig 9: partition sensitivity to input size (3G).  The paper's flip
    happens at 170KB on its testbed; we report the flip threshold on ours
    (the exact value depends on tier speeds — the sensitivity is the
    claim)."""
    print("\n# Fig 9 — input size sensitivity (ResNet50, 3G)")
    s = scenario_network._cache.setdefault("3g", scission_for("3g"))
    benchmark_cached(s, "ResNet50")
    rows = []
    for kb in (150, 170, 220, 300):
        best, qt = _best(s, "ResNet50", input_bytes=kb * 1e3)
        print(f"  [{kb}KB] {best.describe()}")
        rows.append((f"input/{kb}kb", qt * 1e6, round(best.latency_s, 4)))
    return rows


def scenario_constraints(quick=True):
    """Figs 10-11: entire resource pipeline must be used."""
    print("\n# Figs 10-11 — constraint: device+edge+cloud must all be used")
    rows = []
    q = Query(top_n=1, must_use=("device", "edge1", "cloud_gpu"))
    models = ["VGG19", "ResNet50"] if not quick else ["ResNet50"]
    for net in ("3g", "4g"):
        s = scenario_network._cache.setdefault(net, scission_for(net))
        for m in models:
            benchmark_cached(s, m)
            best, qt = _best(s, m, q)
            print(f"  [{net}] {best.describe()}")
            rows.append((f"cons/{net}/{m}", qt * 1e6,
                         round(best.latency_s, 4)))
    return rows


def scenario_pipelines(quick=True):
    """Figs 12-14: Edge(1) vs Edge(2) hardware sensitivity (wired)."""
    print("\n# Figs 12-14 — edge hardware sensitivity (wired)")
    rows = []
    s = scenario_network._cache.setdefault("wired", scission_for("wired"))
    models = ["InceptionV3", "DenseNet169"] if not quick else \
        ["InceptionV3"]
    for m in models:
        benchmark_cached(s, m)
        for edge in ("edge1", "edge2"):
            other = "edge2" if edge == "edge1" else "edge1"
            q = Query(top_n=1, must_use=(edge,), exclude=(other,))
            best, qt = _best(s, m, q)
            print(f"  [{edge}] {best.describe()}")
            rows.append((f"pipe/{edge}/{m}", qt * 1e6,
                         round(best.latency_s, 4)))
    return rows


def scenario_topn(quick=True):
    """Table IV + Fig 15: top-3 per distributed pipeline (ResNet50)."""
    print("\n# Table IV — top-3 partitions per pipeline (ResNet50, wired)")
    s = scenario_network._cache.setdefault("wired", scission_for("wired"))
    benchmark_cached(s, "ResNet50")
    pipelines = {
        "device-edge": (("device", "edge1"),),
        "device-cloud": (("device", "cloud_gpu"),),
        "edge-cloud": (("edge1", "cloud_gpu"),),
        "device-edge-cloud": (("device", "edge1", "cloud_gpu"),),
    }
    rows = []
    for name, pipes in pipelines.items():
        res = s.query("ResNet50", Query(top_n=3, pipelines=pipes))
        print(f"  [{name}]")
        for cfg in res.configs:
            print(f"    {cfg.describe()}")
        if res.configs:
            rows.append((f"topn/{name}", res.query_time_s * 1e6,
                         round(res.configs[0].latency_s, 4)))
    return rows


def scenario_throughput(quick=True, models=None):
    """Beyond-paper: throughput-optimal partition per network condition,
    with the cost-model prediction validated against a pipelined-serving
    simulation (steady-state rate of the bottleneck stage).  Validation
    failures accumulate in ``scenario_throughput.failures`` so smoke mode
    can turn them into a non-zero exit code."""
    print("\n# Pipelined serving — predicted vs simulated throughput")
    scenario_throughput.failures = []
    rows = []
    models = models or (["ResNet50", "MobileNetV2"] if quick else
                        ["VGG19", "ResNet50", "MobileNetV2"])
    for net in ("3g", "4g", "wired"):
        s = scenario_network._cache.setdefault(net, scission_for(net))
        for m in models:
            benchmark_cached(s, m)
            res = s.query(m, Query(top_n=1, objective=THROUGHPUT))
            best = res.best
            pred = best.throughput_rps
            t0 = time.perf_counter()
            sim = simulate_pipeline_throughput(best, n_requests=256)
            sim_us = (time.perf_counter() - t0) * 1e6
            err = abs(sim - pred) / pred if pred > 0 else 0.0
            ok = "PASS" if err < 0.02 else "FAIL"
            if ok == "FAIL":
                scenario_throughput.failures.append(f"{net}/{m}")
            print(f"  [{net}] {m}: pred={pred:8.2f}rps sim={sim:8.2f}rps "
                  f"err={err * 100:.2f}% {ok}  {best.describe()}")
            rows.append((f"thpt/{net}/{m}", res.query_time_s * 1e6,
                         round(pred, 3)))
            rows.append((f"thpt_sim/{net}/{m}", sim_us, round(sim, 3)))
    return rows


scenario_throughput.failures = []


def scenario_frontier(quick=True, models=None):
    """Beyond-paper: Pareto front over (latency, throughput, transfer) —
    the operating points a deployment actually chooses between."""
    print("\n# Pareto frontier — (latency, throughput, transfer)")
    rows = []
    models = models or ["ResNet50"]
    for net in ("3g", "wired") if quick else ("3g", "4g", "wired"):
        s = scenario_network._cache.setdefault(net, scission_for(net))
        for m in models:
            benchmark_cached(s, m)
            res = s.frontier(m)
            print(f"  [{net}] {m}: {len(res.configs)} non-dominated configs "
                  f"({res.strategy}, {res.query_time_s * 1e3:.1f}ms)")
            for cfg in res.configs[:3]:
                print(f"    {cfg.describe()}")
            rows.append((f"front/{net}/{m}", res.query_time_s * 1e6,
                         len(res.configs)))
    return rows


def _frontiers_match(a, b, rtol=1e-9):
    """Vector-set equality of two frontiers (objective vectors matched
    within ``rtol``, both directions)."""
    va = sorted({_vec(c) for c in a})
    vb = sorted({_vec(c) for c in b})
    if len(va) != len(vb):
        return False
    return all(all(abs(x - y) <= rtol * max(abs(x), abs(y), 1e-30)
                   for x, y in zip(p, q)) for p, q in zip(va, vb))


def scenario_frontier_exact(quick=True, models=None, batch_sizes=(1, 4),
                            replicas=None):
    """Frontier exactness: the ParetoLattice strategy must return the same
    objective-vector set as the exhaustive oracle — across 3G/4G/wired,
    operating points (measured batches × a replica budget), a must-use
    constraint, and overlapping restricted pipelines.  Mismatches
    accumulate in ``scenario_frontier_exact.failures`` so smoke mode turns
    them into a non-zero exit code."""
    print("\n# Frontier exactness — ParetoLattice vs exhaustive oracle")
    scenario_frontier_exact.failures = []
    rows = []
    models = models or ["MobileNetV2"]
    replicas = replicas if replicas is not None else \
        {"device": 2, "edge1": 2}
    queries = {
        "free": Query(batch_sizes=tuple(batch_sizes), replicas=replicas),
        "must": Query(batch_sizes=tuple(batch_sizes), replicas=replicas,
                      must_use=("device", "edge1", "cloud_gpu")),
        "pipes": Query(batch_sizes=tuple(batch_sizes), replicas=replicas,
                       pipelines=(("device", "edge1"),
                                  ("device", "edge1", "cloud_gpu"),
                                  ("device", "cloud_gpu"))),
    }
    for net in ("3g", "4g", "wired"):
        s = scenario_network._cache.setdefault(net, scission_for(net))
        for m in models:
            benchmark_cached(s, m, batch_sizes=batch_sizes)
            for qname, q in queries.items():
                exh = s.frontier(m, q, strategy="exhaustive")
                lat = s.frontier(m, q, strategy="lattice")
                auto = s.frontier(m, q)
                equal = _frontiers_match(exh.configs, lat.configs)
                # auto-dispatch must have picked the faster of the two
                # forced strategies on this (space, constraints) point
                forced = {"exhaustive": exh, "lattice": lat}
                fastest = min(forced, key=lambda k: forced[k].query_time_s)
                ok = "PASS" if equal else "FAIL"
                if not equal:
                    scenario_frontier_exact.failures.append(
                        f"{net}/{m}/{qname}")
                print(f"  [{net}] {m}/{qname}: front={len(exh.configs)} "
                      f"exh={exh.query_time_s * 1e3:.1f}ms "
                      f"lat={lat.query_time_s * 1e3:.1f}ms "
                      f"auto={auto.strategy}"
                      f"({auto.query_time_s * 1e3:.1f}ms, forced-best "
                      f"{fastest}) "
                      f"labels={lat.labels_kept}+{lat.labels_pruned} {ok}")
                rows.append((f"front_exact/{net}/{m}/{qname}",
                             lat.query_time_s * 1e6, len(lat.configs)))
                rows.append((f"front_exact_oracle/{net}/{m}/{qname}",
                             exh.query_time_s * 1e6, len(exh.configs)))
                rows.append((f"front_auto/{net}/{m}/{qname}",
                             auto.query_time_s * 1e6, auto.strategy))
                rows.append((f"front_labels/{net}/{m}/{qname}",
                             float(lat.labels_kept),
                             int(lat.labels_pruned)))
    return rows


scenario_frontier_exact.failures = []


def scenario_frontier_constrained(quick=True, models=None):
    """Binding path-dependent constraints (max_resource_time /
    min_blocks_on) folded into the lattice DP state: every lattice
    strategy must return exactly the exhaustive oracle's result set — no
    under-filled or empty results while a feasible config exists.  The
    caps are derived per (network, model) from the unconstrained winner
    (half its heaviest per-resource compute time), so the 'tmax' scenarios
    are binding by construction: the unconstrained winner itself is
    infeasible under them."""
    print("\n# Constraint exactness — binding path-dependent constraints")
    scenario_frontier_constrained.failures = []
    rows = []
    models = models or ["MobileNetV2"]
    for net in ("3g", "4g", "wired"):
        s = scenario_network._cache.setdefault(net, scission_for(net))
        for m in models:
            benchmark_cached(s, m)
            n_blocks = s._dbs[m].n_blocks
            base = s.query(m, Query(top_n=1)).best
            res_heavy, t_heavy = max(base.compute_s.items(),
                                     key=lambda kv: kv[1])
            floor = {"device": max(2, n_blocks // 3)}
            queries = {
                "tmax": Query(max_resource_time={res_heavy: t_heavy / 2}),
                "nmin": Query(min_blocks_on=floor),
                "both": Query(max_resource_time={res_heavy: t_heavy / 2},
                              min_blocks_on=floor),
            }
            for qname, q in queries.items():
                exh = s.frontier(m, q, strategy="exhaustive")
                lat = s.frontier(m, q, strategy="lattice")
                equal = _frontiers_match(exh.configs, lat.configs)
                underfill = bool(exh.configs) and not lat.configs
                ok = "PASS" if equal and not underfill else "FAIL"
                if ok == "FAIL":
                    scenario_frontier_constrained.failures.append(
                        f"{net}/{m}/{qname}")
                print(f"  [{net}] {m}/{qname}: front={len(exh.configs)} "
                      f"exh={exh.query_time_s * 1e3:.1f}ms "
                      f"lat={lat.query_time_s * 1e3:.1f}ms "
                      f"labels={lat.labels_kept}+{lat.labels_pruned} {ok}")
                rows.append((f"front_cons/{net}/{m}/{qname}",
                             lat.query_time_s * 1e6, len(lat.configs)))
                rows.append((f"front_cons_oracle/{net}/{m}/{qname}",
                             exh.query_time_s * 1e6, len(exh.configs)))
                rows.append((f"front_cons_labels/{net}/{m}/{qname}",
                             float(lat.labels_kept),
                             int(lat.labels_pruned)))
    return rows


scenario_frontier_constrained.failures = []

# fleet-sized frontier queries must stay interactive; the measured path is
# ~0.5 s on a 27-resource / 32-block fleet (~350k-config space), so 5 s is
# a generous regression tripwire rather than a tight bound
FLEET_FRONTIER_BUDGET_S = 5.0


def scenario_frontier_scale(quick=True, n_per_tier=9, n_blocks=32):
    """Frontier query-time scaling on a fleet-sized resource set (search
    space beyond EXHAUSTIVE_LIMIT, where only the lattice strategy is
    viable), with label-set statistics and the ε-dominance knob."""
    print("\n# Frontier scaling — fleet-sized space (lattice only)")
    scenario_frontier_scale.failures = []
    rows = []
    eng = fleet_engine(n_per_tier=n_per_tier, n_blocks=n_blocks)
    space = eng._search_space()
    n_res = len(eng.resources)
    print(f"  fleet: {n_res} resources x {eng.db.n_blocks} blocks, "
          f"search space {space} configs")
    rows.append(("front_scale/space", 0.0, space))
    import repro.core.query as query_mod
    assert space > query_mod.EXHAUSTIVE_LIMIT, \
        "fleet scenario must exceed the exhaustive limit"
    for eps in ((0.0, 0.05) if quick else (0.0, 0.01, 0.05)):
        res = eng.frontier(Query(frontier_epsilon=eps))
        ok = "PASS" if res.query_time_s < FLEET_FRONTIER_BUDGET_S else "FAIL"
        if ok == "FAIL":
            scenario_frontier_scale.failures.append(
                f"fleet/eps={eps}: {res.query_time_s:.2f}s "
                f"> {FLEET_FRONTIER_BUDGET_S}s")
        print(f"  [eps={eps}] {res.query_time_s * 1e3:.0f}ms "
              f"front={len(res.configs)} labels_kept={res.labels_kept} "
              f"labels_pruned={res.labels_pruned} ({res.strategy}) {ok}")
        rows.append((f"front_scale/eps{eps}", res.query_time_s * 1e6,
                     len(res.configs)))
        rows.append((f"front_scale_labels/eps{eps}",
                     float(res.labels_kept), int(res.labels_pruned)))
    return rows


scenario_frontier_scale.failures = []


def scenario_batched(quick=True, models=None, batch_sizes=(1, 4),
                     replicas=None):
    """Beyond-paper: batched + replicated operating points.  Benchmarks a
    per-batch profile, compares the best batch-1 single-replica throughput
    partition against the frontier's best (batch, replica) operating point,
    and validates the winner's prediction against the replica-aware
    pipeline simulation.

    A point FAILS when predicted vs simulated diverges by more than 25%
    (wall-clock batch profiles are noisier than the batch-1 path); the
    whole scenario additionally fails unless at least one (network, model)
    shows a batched/replicated point beating its batch-1 baseline.
    """
    print("\n# Batched/replicated operating points — frontier vs batch-1")
    scenario_batched.failures = []
    rows = []
    models = models or ["MobileNetV2"]
    replicas = replicas if replicas is not None else \
        {"device": 2, "edge1": 2}
    rep_desc = ",".join(f"{k}x{v}" for k, v in sorted(replicas.items()))
    gains = []
    for net in ("3g", "wired") if quick else ("3g", "4g", "wired"):
        s = scenario_network._cache.setdefault(net, scission_for(net))
        for m in models:
            benchmark_cached(s, m, batch_sizes=batch_sizes)
            base = s.query(m, Query(top_n=1, objective=THROUGHPUT)).best
            res = s.frontier(m, Query(batch_sizes=tuple(batch_sizes),
                                      replicas=replicas))
            top = max(res.configs, key=lambda c: c.throughput_rps)
            pred = top.throughput_rps
            t0 = time.perf_counter()
            sim = simulate_pipeline_throughput(top, n_requests=512)
            sim_us = (time.perf_counter() - t0) * 1e6
            err = abs(sim - pred) / pred if pred > 0 else 0.0
            gain = pred / base.throughput_rps if base.throughput_rps else 1.0
            gains.append(gain)
            ok = "PASS" if err < 0.25 else "FAIL"
            if ok == "FAIL":
                scenario_batched.failures.append(f"{net}/{m}")
            print(f"  [{net}] {m} (batches={list(batch_sizes)} "
                  f"budget={rep_desc}):")
            print(f"    batch-1 best : {base.describe()}")
            print(f"    frontier best: {top.describe()}")
            print(f"    pred={pred:8.2f}rps sim={sim:8.2f}rps "
                  f"err={err * 100:.2f}% gain={gain:.2f}x {ok}")
            rows.append((f"batched/{net}/{m}", res.query_time_s * 1e6,
                         round(pred, 3)))
            rows.append((f"batched_sim/{net}/{m}", sim_us, round(sim, 3)))
            rows.append((f"batched_gain/{net}/{m}", 0.0, round(gain, 3)))
    if gains and max(gains) <= 1.0:
        scenario_batched.failures.append(
            "no-gain: no batched/replicated point beat its batch-1 baseline")
    return rows


scenario_batched.failures = []


def _dag_graphs():
    """Genuinely branchy layer graphs for the DAG-general gate: an
    expert-sharded MoE layer (diamond with a residual direct edge) and a
    reduced enc-dec LM (encoder vs target-embedding branches joined at the
    decoder's cross-attention)."""
    import jax
    import jax.numpy as jnp

    from repro.models import build_model, get_config
    from repro.models import layers as L
    from repro.models.graph_adapter import encdec_to_graph, moe_to_graph
    from repro.models.moe import moe_spec

    p = L.init_tree(moe_spec(32, 64, 4), jax.random.PRNGKey(0), jnp.float32)
    moe = moe_to_graph(p, batch=1, seq_len=8, d_model=32, n_experts=4,
                       top_k=2, n_shards=2)
    cfg = get_config("whisper-medium").replace(
        name="encdec-smoke", n_layers=4, d_model=64, n_heads=4, n_kv_heads=4,
        head_dim=16, d_ff=128, vocab=256, encoder_layers=4, encoder_len=16,
        q_chunk=16, remat=False)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    encdec = encdec_to_graph(model, params, batch=1, seq_len=8, enc_splits=2)
    return [moe, encdec]


def _splits_parallel_region(dag, assignment) -> bool:
    """True when ``assignment`` places the blocks of some parallel region
    on more than one resource — the placement freedom chain fusing cannot
    express."""
    owner = {n: b.index for b in dag for n in b.node_ids}
    for region in dag.parallel_regions:
        blocks = {owner[n] for n in region}
        if len({assignment[b] for b in blocks}) > 1:
            return True
    return False


def scenario_dag(quick=True):
    """DAG-general partitioning gate: branchy graphs fused with
    ``fuse_block_dag`` over the paper networks.  Gates on (i) the SP-tree
    lattice returning exactly the DAG-aware exhaustive oracle's result —
    top-1 score per objective and frontier vector set, free and under
    constraints — and (ii) at least one optimal/frontier config splitting a
    parallel region across resources."""
    import numpy as np

    import repro.core.query as query_mod

    print("\n# DAG-general partitioning — branchy graphs, lattice vs oracle")
    scenario_dag.failures = []
    rows = []
    graphs = _dag_graphs()
    split_seen = []
    for net in ("3g", "4g", "wired"):
        s = scission_for(net)
        for g in graphs:
            s.benchmark(g, dag=True)
            dag = s._dags[g.name]
            spec = g.nodes[0].out_spec
            input_bytes = float(int(np.prod(spec.shape)) *
                                np.dtype(spec.dtype).itemsize)
            eng = s.engine(g.name, input_bytes)
            space = eng._search_space()
            queries = {
                "free": Query(top_n=1),
                "thpt": Query(top_n=1, objective=THROUGHPUT),
                "must": Query(top_n=1, must_use=("edge1", "edge2")),
                "tmax": Query(top_n=1,
                              max_resource_time={"device": 1e-4}),
            }
            for qname, q in queries.items():
                r_auto = eng.run(q)
                old = query_mod.EXHAUSTIVE_LIMIT
                try:
                    query_mod.EXHAUSTIVE_LIMIT = -1
                    r_sp = eng.run(q)
                finally:
                    query_mod.EXHAUSTIVE_LIMIT = old
                sc = q.objective.score
                equal = ([sc(c) for c in r_auto.configs]
                         == [sc(c) for c in r_sp.configs])
                if not equal:
                    scenario_dag.failures.append(
                        f"solve/{net}/{g.name}/{qname}")
                for cfg in r_auto.configs + r_sp.configs:
                    if _splits_parallel_region(dag, cfg.assignment):
                        split_seen.append(f"{net}/{g.name}/{qname}")
                rows.append((f"dag/{net}/{g.name}/{qname}",
                             r_auto.query_time_s * 1e6,
                             r_auto.strategy))
                rows.append((f"dag_sp/{net}/{g.name}/{qname}",
                             r_sp.query_time_s * 1e6,
                             round(sc(r_sp.best), 5) if r_sp.best else None))
            fe = eng.frontier(strategy="exhaustive")
            fl = eng.frontier(strategy="lattice")
            fequal = _frontiers_match(fe.configs, fl.configs)
            if not fequal:
                scenario_dag.failures.append(f"frontier/{net}/{g.name}")
            for cfg in fl.configs:
                if _splits_parallel_region(dag, cfg.assignment):
                    split_seen.append(f"{net}/{g.name}/frontier")
            ok = "PASS" if fequal else "FAIL"
            print(f"  [{net}] {g.name}: blocks={len(dag)} space={space} "
                  f"front={len(fe.configs)} "
                  f"exh={fe.query_time_s * 1e3:.1f}ms "
                  f"lat={fl.query_time_s * 1e3:.1f}ms {ok}")
            rows.append((f"dag_front/{net}/{g.name}",
                         fl.query_time_s * 1e6, len(fl.configs)))
            rows.append((f"dag_front_oracle/{net}/{g.name}",
                         fe.query_time_s * 1e6, len(fe.configs)))
    if not split_seen:
        scenario_dag.failures.append(
            "no-split: no optimal config placed a parallel region's "
            "branches on distinct resources")
    else:
        print(f"  parallel-region splits observed at "
              f"{len(set(split_seen))} query points, e.g. "
              f"{sorted(set(split_seen))[0]}")
    rows.append(("dag/split_points", 0.0, len(set(split_seen))))
    return rows


scenario_dag.failures = []


def scenario_replan(quick=True, reps=7):
    """Incremental elastic re-plans: ``QueryEngine.frontier_incremental``
    keeps each operating point's final label arrays and warm-starts the
    next re-plan from them.  Gates on (i) warm re-plans returning configs
    identical to cold solves in every scenario, and (ii) label reuse being
    demonstrable — warm re-solve < 50% of the cold solve time — both for a
    steady-state re-plan (unchanged membership) and for the loss of a
    link-budget-barred resource (its labels only enter the DP once
    activations fit the link budget, so the clean prefix is replayed and
    the DP re-runs only from the first affected block)."""
    import numpy as np

    from repro.core import Query as _Q

    print("\n# Incremental elastic re-plans — label reuse vs cold solves")
    scenario_replan.failures = []
    rows = []
    s = scenario_network._cache.setdefault("4g", scission_for("4g"))
    benchmark_cached(s, "MobileNetV2")
    eng = s.engine("MobileNetV2", 150e3)

    def _key(cfgs):
        return [(c.segments, c.batch_size, c.replicas) for c in cfgs]

    def _pair(eng2, q, states, label):
        cold = warm = float("inf")
        rc = rw = None
        for _ in range(reps):
            c, _ = eng2.frontier_incremental(q, None)
            cold = min(cold, c.solve_seconds)
            rc = c
            w, _ = eng2.frontier_incremental(q, states)
            warm = min(warm, w.solve_seconds)
            rw = w
        same = _key(rc.configs) == _key(rw.configs)
        ratio = warm / cold
        if not same:
            scenario_replan.failures.append(f"replan-mismatch/{label}")
        print(f"  {label:12s} cold={cold * 1e6:7.0f}us "
              f"warm={warm * 1e6:7.0f}us ratio={ratio:.3f} "
              f"{'PASS' if same else 'FAIL'}")
        return cold, warm, ratio

    # steady-state re-plan: membership unchanged, the kept labels replay
    # end to end (the controller's common case after any event settles)
    q = _Q()
    res, states = eng.frontier_incremental(q)
    cold, warm, ratio = _pair(eng, q, states, "steady")
    rows.append(("front_replan/cold", cold * 1e6, len(res.configs)))
    rows.append(("front_replan/steady", warm * 1e6, round(ratio, 3)))
    if ratio >= 0.5:
        scenario_replan.failures.append(
            f"replan-slow/steady ratio={ratio:.3f} (>= 0.5)")

    # membership loss of a link-barred resource: cloud_gpu only admits
    # hand-offs once activations fit the link budget, so most blocks never
    # saw a cloud_gpu label and their label arrays replay verbatim
    ob = np.asarray(eng.cost.out_bytes, dtype=float)
    lim = float(np.percentile(ob, 5))
    others = [r.name for r in s.resources if r.name != "cloud_gpu"]
    qb = _Q(max_link_bytes={(o, "cloud_gpu"): lim for o in others})
    _, states_b = eng.frontier_incremental(qb)
    s_drop = s.with_resources(
        [r for r in s.resources if r.name != "cloud_gpu"])
    eng_drop = s_drop.engine("MobileNetV2", 150e3)
    _, warm_d, ratio_d = _pair(eng_drop, qb, states_b, "drop-barred")
    rows.append(("front_replan/drop_barred", warm_d * 1e6,
                 round(ratio_d, 3)))
    if ratio_d >= 0.5:
        scenario_replan.failures.append(
            f"replan-slow/drop_barred ratio={ratio_d:.3f} (>= 0.5)")

    # resource join: the extend path generates only delta paths that visit
    # the newcomer; exactness is the gate (the delta spans most of this
    # small space, so no speedup is claimed)
    from repro.core import Resource as _R
    from repro.core.resources import EDGE_BOX_2 as _E2
    from repro.models import cnn_zoo as _zoo
    r_new = _R("edge3", "edge", _E2, speed_factor=2.0)
    s.benchmark_resource(_zoo.build("MobileNetV2"), r_new)
    s_join = s.with_resources([*s.resources, r_new])
    eng_join = s_join.engine("MobileNetV2", 150e3)
    _, warm_j, ratio_j = _pair(eng_join, q, states, "join")
    rows.append(("front_replan/join", warm_j * 1e6, round(ratio_j, 3)))
    return rows


scenario_replan.failures = []


def perf_gate(reps=7, threshold=1.5):
    """Exact-solver performance gate: on every smoke scenario the lattice
    (SP solve, SP frontier, chain frontier) must answer within
    ``threshold``x of the exhaustive oracle's pure solve time
    (min-of-``reps`` of ``QueryResult.solve_seconds``, both strategies
    warm — each keeps its natural caches after one cold priming call; the
    machine is too noisy for mean-of-reps to gate on).  Cold-vs-cold the
    vectorised lattices already beat enumeration from a few hundred
    configs (see EXHAUSTIVE_LIMIT), so steady-state re-query — the
    paper's <50 ms budget — is the regime the gate pins."""
    import numpy as np

    import repro.core.query as query_mod

    print(f"\n# Perf gate — lattice vs exhaustive oracle "
          f"(min of {reps}, fail > {threshold}x)")
    perf_gate.failures = []
    rows = []

    def _gate(name, t_lat, t_orc):
        ratio = t_lat / t_orc
        rows.append((f"gate/{name}", t_lat * 1e6, round(ratio, 3)))
        ok = ratio <= threshold
        if not ok:
            perf_gate.failures.append(f"{name} ratio={ratio:.2f}")
        print(f"  {name:34s} {t_lat * 1e6:7.0f}us vs {t_orc * 1e6:7.0f}us "
              f"= {ratio:5.2f}x {'PASS' if ok else 'FAIL'}")

    graphs = _dag_graphs()
    for net in ("3g", "4g", "wired"):
        s = scission_for(net)
        for g in graphs:
            s.benchmark(g, dag=True)
            spec = g.nodes[0].out_spec
            input_bytes = float(int(np.prod(spec.shape)) *
                                np.dtype(spec.dtype).itemsize)
            eng = s.engine(g.name, input_bytes)
            queries = {
                "free": Query(top_n=1),
                "thpt": Query(top_n=1, objective=THROUGHPUT),
                "must": Query(top_n=1, must_use=("edge1", "edge2")),
                "tmax": Query(top_n=1,
                              max_resource_time={"device": 1e-4}),
            }
            for qname, q in queries.items():
                sp = orc = float("inf")
                old = query_mod.EXHAUSTIVE_LIMIT
                try:
                    query_mod.EXHAUSTIVE_LIMIT = -1
                    eng.run(q)                      # prime lattice caches
                finally:
                    query_mod.EXHAUSTIVE_LIMIT = old
                eng.run(q)                          # prime oracle pool
                for _ in range(reps):
                    old = query_mod.EXHAUSTIVE_LIMIT
                    try:
                        query_mod.EXHAUSTIVE_LIMIT = -1
                        sp = min(sp, eng.run(q).solve_seconds)
                    finally:
                        query_mod.EXHAUSTIVE_LIMIT = old
                    orc = min(orc, eng.run(q).solve_seconds)
                _gate(f"dag_sp/{net}/{g.name}/{qname}", sp, orc)
            fl = fe = float("inf")
            eng.frontier(strategy="lattice")
            eng.frontier(strategy="exhaustive")
            for _ in range(reps):
                fl = min(fl, eng.frontier(
                    strategy="lattice").solve_seconds)
                fe = min(fe, eng.frontier(
                    strategy="exhaustive").solve_seconds)
            _gate(f"front_dag/{net}/{g.name}", fl, fe)
    for net in ("3g", "4g", "wired"):
        s = scission_for(net)
        benchmark_cached(s, "MobileNetV2")
        eng = s.engine("MobileNetV2", 150e3)
        fl = fe = float("inf")
        eng.frontier(strategy="lattice")
        eng.frontier(strategy="exhaustive")
        for _ in range(reps):
            fl = min(fl, eng.frontier(strategy="lattice").solve_seconds)
            fe = min(fe, eng.frontier(strategy="exhaustive").solve_seconds)
        _gate(f"front_chain/{net}/MobileNetV2", fl, fe)
    return rows


perf_gate.failures = []


def run(quick: bool = True):
    rows = []
    rows += scenario_network(quick)
    rows += scenario_input_size(quick)
    rows += scenario_constraints(quick)
    rows += scenario_pipelines(quick)
    rows += scenario_topn(quick)
    rows += scenario_throughput(quick)
    rows += scenario_frontier(quick)
    rows += scenario_batched(quick)
    rows += scenario_frontier_exact(quick)
    rows += scenario_frontier_constrained(quick)
    rows += scenario_frontier_scale(quick)
    rows += scenario_dag(quick)
    return rows


def smoke_batched():
    """CI pass for the batched/replicated path: one CNN, two batch sizes,
    a two-replica budget on the device and edge tiers, 3G + wired."""
    return scenario_batched(quick=True, models=["MobileNetV2"],
                            batch_sizes=(1, 4),
                            replicas={"device": 2, "edge1": 2})


def smoke_frontier():
    """CI pass for frontier exactness + scaling: gates on lattice-vs-
    exhaustive frontier vector-set equality (paper-network spaces across
    3G/4G/wired and operating points), on constraint exactness under
    binding path-dependent constraints (max_resource_time /
    min_blocks_on — no under-filled or empty lattice results while a
    feasible config exists), and on the fleet-sized frontier staying
    interactive, with label statistics in the JSON artifact."""
    rows = scenario_frontier_exact(quick=True, models=["MobileNetV2"],
                                   batch_sizes=(1, 4),
                                   replicas={"device": 2, "edge1": 2})
    rows += scenario_frontier_constrained(quick=True,
                                          models=["MobileNetV2"])
    rows += scenario_frontier_scale(quick=True)
    rows += scenario_replan(quick=True)
    return rows


def smoke_dag():
    """CI pass for DAG-general partitioning: branchy MoE / enc-dec graphs
    over 3G/4G/wired, gated on SP-lattice vs DAG-aware-oracle equality
    (top-1 per objective, full frontier) and on at least one optimal
    config splitting a parallel region across resources."""
    return scenario_dag(quick=True)


def smoke():
    """Minimal single-model pass for CI: one CNN, all three network
    conditions, exercising the latency, throughput and frontier query
    paths.  Returns JSON-serialisable rows."""
    rows = []
    rows += scenario_throughput(quick=True, models=["MobileNetV2"])
    rows += scenario_frontier(quick=True, models=["MobileNetV2"])
    s = scenario_network._cache.setdefault("wired", scission_for("wired"))
    benchmark_cached(s, "MobileNetV2")
    best, qt = _best(s, "MobileNetV2")
    rows.append(("smoke/latency/MobileNetV2", qt * 1e6,
                 round(best.latency_s, 4)))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="single-model CI pass (fastest)")
    ap.add_argument("--smoke-batched", action="store_true",
                    help="single-model CI pass over the batched/replicated "
                         "path (two batch sizes, replicated stages)")
    ap.add_argument("--smoke-frontier", action="store_true",
                    help="CI pass gated on lattice-vs-exhaustive frontier "
                         "equality plus fleet-sized query-time scaling")
    ap.add_argument("--smoke-dag", action="store_true",
                    help="CI pass for DAG-general partitioning: branchy "
                         "graphs, SP lattice vs DAG-aware oracle, "
                         "parallel-region splits")
    ap.add_argument("--perf-gate", action="store_true",
                    help="performance gate: every lattice/SP solve and "
                         "frontier must answer within 1.5x of the "
                         "exhaustive oracle on the smoke scenarios "
                         "(warm-vs-warm, min of 7 reps)")
    ap.add_argument("--full", action="store_true", help="all models")
    ap.add_argument("--out", default=None,
                    help="write rows as JSON to this path (smoke modes "
                         "default to results/bench_partitions_<mode>.json)")
    args = ap.parse_args()
    if args.smoke_batched:
        rows, mode = smoke_batched(), "smoke_batched"
    elif args.smoke_frontier:
        rows, mode = smoke_frontier(), "smoke_frontier"
    elif args.smoke_dag:
        rows, mode = smoke_dag(), "smoke_dag"
    elif args.smoke:
        rows, mode = smoke(), "smoke"
    elif args.perf_gate:
        rows, mode = perf_gate(), "perf_gate"
    else:
        rows, mode = run(quick=not args.full), None
    if args.out is None and mode is not None:
        args.out = f"results/bench_partitions_{mode}.json"
    print("\nname,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump([{"name": n, "us_per_call": us, "derived": d}
                       for n, us, d in rows], f, indent=2)
        print(f"wrote {args.out}")
    failures = (scenario_throughput.failures + scenario_batched.failures
                + scenario_frontier_exact.failures
                + scenario_frontier_constrained.failures
                + scenario_frontier_scale.failures
                + scenario_dag.failures
                + scenario_replan.failures + perf_gate.failures)
    if failures:
        print(f"FAILED validation (throughput / frontier exactness / "
              f"frontier scaling / DAG partitioning / incremental re-plan "
              f"/ perf gate): {', '.join(failures)}")
        raise SystemExit(1)


if __name__ == "__main__":
    main()
