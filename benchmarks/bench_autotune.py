"""Kernel block-size autotuning feeding the partition decision procedure.

Demonstrates the substrate autotuner end to end on a small transformer-ish
block graph built from the tunable kernel nodes:

1. sweep ``(block_q, block_k)`` / ``chunk`` candidates per (kernel, shape,
   resource) — CPU interpret mode, so absolute times are interpreter times,
   but the sweep/record/consume plumbing is identical on TPU;
2. benchmark the graph with the *tuned* kernels into a ``BenchmarkDB``
   (records carry ``tuned_params``);
3. run the Scission ``QueryEngine`` over that DB, i.e. partition decisions
   are made from tuned, not default, kernel timings.

Reports how many sweeps changed the default block size and the tuned
speedup per kernel.
"""

from __future__ import annotations

import zlib

import jax
import jax.numpy as jnp

from repro.core import (Link, NetworkModel, Query, QueryEngine, Resource,
                        TimingProvider, benchmark_model, linear_graph)
from repro.core.graph import LayerNode
from repro.core.resources import CLOUD_VM, EDGE_BOX_1
from repro.kernels import KernelAutotuner
from repro.kernels.ops import flash_attention_node, ssd_scan_node


def _mlp_node(name, d):
    seed = zlib.crc32(name.encode()) % 2**31
    w = jax.random.normal(jax.random.PRNGKey(seed), (d, d)) * 0.05
    return LayerNode(name=name, kind="dense",
                     apply=lambda x, w=w: jnp.tanh(x @ w),
                     flops=2.0 * d * d, param_bytes=4 * d * d)


def _graph(S, H, hd):
    # attention -> mlp -> ssd -> mlp: two tunable kernels, two cut points
    return linear_graph(
        "autotune-demo", jax.ShapeDtypeStruct((1, S, H, hd), jnp.float32),
        [flash_attention_node("attn", interpret=True),
         _mlp_node("mlp0", hd),
         ssd_scan_node("ssd", state_dim=16, interpret=True),
         _mlp_node("mlp1", hd)])


def run(quick: bool = True):
    S, H, hd = (192, 2, 32) if quick else (320, 4, 64)
    resources = [
        Resource("edge1", "edge", EDGE_BOX_1, speed_factor=2.0),
        Resource("cloud", "cloud", CLOUD_VM, speed_factor=1.0),
    ]
    candidates = {
        "flash_attention": [{"block_q": bq, "block_k": bk}
                            for bq in (64, 128) for bk in (64, 128)],
        "ssd_scan": [{"chunk": c} for c in (32, 64, 128)],
    }

    tuner = KernelAutotuner(candidates=candidates, runs=1 if quick else 2)
    g = _graph(S, H, hd)
    db = benchmark_model(g, resources, TimingProvider(tuner=tuner),
                         runs=2 if quick else 5)

    changed = [r for r in tuner.records.values() if r.changed_default]
    print("\n# Kernel autotune -> BenchmarkDB -> QueryEngine")
    for rec in tuner.records.values():
        mark = "*" if rec.changed_default else " "
        print(f" {mark} {rec.kernel:17s} @{rec.resource:6s} "
              f"default={rec.default_params} -> tuned={rec.params} "
              f"({rec.speedup_vs_default:.2f}x vs default)")
    print(f"  {len(changed)}/{len(tuner.records)} sweeps changed the "
          f"default block size")

    tuned_recs = sum(1 for rs in db.records.values()
                     for r in rs if r.tuned_params)
    net = NetworkModel(default=Link("wired", 0.005, 1e8))
    engine = QueryEngine(db, resources, net, source="edge1",
                         input_bytes=4.0 * S * H * hd)
    result = engine.run(Query(top_n=3))
    best = result.best
    print(f"  {tuned_recs} DB records carry tuned params; best partition: "
          f"{best.describe()} (query {result.query_time_s * 1e3:.1f}ms, "
          f"{result.strategy})")

    rows = [("autotune/sweeps_changed_default", float(len(changed)),
             f"{len(changed)}/{len(tuner.records)}"),
            ("autotune/db_records_tuned", float(tuned_recs), tuned_recs),
            ("autotune/best_latency", best.latency_s * 1e6,
             round(best.latency_s * 1e3, 3))]
    for rec in tuner.records.values():
        rows.append((f"autotune/{rec.kernel}@{rec.resource}",
                     rec.time_s * 1e6,
                     "->".join([str(rec.default_params), str(rec.params)])))
    return rows
