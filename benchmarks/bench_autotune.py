"""Kernel block-size autotuning feeding the partition decision procedure.

Demonstrates the substrate autotuner end to end on a small transformer-ish
block graph built from the tunable kernel nodes:

1. sweep ``(block_q, block_k)`` / ``chunk`` candidates per (kernel, shape,
   resource) — CPU interpret mode, so absolute times are interpreter times,
   but the sweep/record/consume plumbing is identical on TPU;
2. benchmark the graph with the *tuned* kernels into a ``BenchmarkDB``
   (records carry ``tuned_params``);
3. run the Scission ``QueryEngine`` over that DB, i.e. partition decisions
   are made from tuned, not default, kernel timings.

Reports how many sweeps changed the default block size and the tuned
speedup per kernel.

It also runs the **VMEM pruning gate** (repro.analysis.kernel_vmem): the
same candidate sweep is re-run for a resource whose ``vmem_bytes`` budget
statically rules out at least one candidate, and the gate asserts that

* >= 1 candidate is pruned *before timing* (no compile/measure cost), and
* the selected winner — and its measured time — is identical to the
  unpruned sweep's (the budget is set to the unpruned winners' maximum
  footprint, so pruning only removes losers).

It runs the analogous **tiling pruning gate** (repro.analysis.tiling): a
sweep that includes a sublane-misaligned candidate is re-run with
``tile_check`` enabled, and the gate asserts the misaligned candidate is
pruned *before timing* while the winner (params and measured time) stays
bit-identical to the unpruned sweep's.

``--verify-vmem`` cross-checks the static SCN202 VMEM footprint model
against the compiler's own memory accounting (``memory_analysis()`` /
``cost_analysis()``) per kernel at the default block sizes, reporting the
per-kernel deltas; in interpret mode (no Mosaic compilation — the CI
configuration) each kernel records a clean ``skipped`` reason instead.

``--out`` writes the gate reports (and the ``--verify-vmem`` table when
requested) as a JSON artifact (uploaded by the CI ``lint`` job).
"""

from __future__ import annotations

import argparse
import json
import os
import zlib

import jax
import jax.numpy as jnp

from repro.analysis.kernel_vmem import kernel_footprint
from repro.core import (Link, NetworkModel, Query, QueryEngine, Resource,
                        TimingProvider, benchmark_model, linear_graph)
from repro.core.graph import LayerNode, fuse_blocks
from repro.core.resources import CLOUD_VM, EDGE_BOX_1
from repro.kernels import KernelAutotuner
from repro.kernels.ops import flash_attention_node, ssd_scan_node


def _mlp_node(name, d):
    seed = zlib.crc32(name.encode()) % 2**31
    w = jax.random.normal(jax.random.PRNGKey(seed), (d, d)) * 0.05
    return LayerNode(name=name, kind="dense",
                     apply=lambda x, w=w: jnp.tanh(x @ w),
                     flops=2.0 * d * d, param_bytes=4 * d * d)


def _graph(S, H, hd):
    # attention -> mlp -> ssd -> mlp: two tunable kernels, two cut points
    return linear_graph(
        "autotune-demo", jax.ShapeDtypeStruct((1, S, H, hd), jnp.float32),
        [flash_attention_node("attn", interpret=True),
         _mlp_node("mlp0", hd),
         ssd_scan_node("ssd", state_dim=16, interpret=True),
         _mlp_node("mlp1", hd)])


def _candidates():
    return {
        "flash_attention": [{"block_q": bq, "block_k": bk}
                            for bq in (64, 128) for bk in (64, 128)],
        "ssd_scan": [{"chunk": c} for c in (32, 64, 128)],
    }


def vmem_gate(quick: bool = True) -> dict:
    """The VMEM pruning gate (see module docstring).

    One tuner serves both sweeps, so the constrained resource selects among
    the *cached* trial measurements — which is exactly why the winner's
    time must come out bit-identical, not merely close.  A wider SSD state
    (``state_dim=64``) makes the largest-chunk SSD candidate the biggest
    footprint in the sweep, guaranteeing the budget (= max footprint among
    the unpruned winners) prunes it.
    """
    S, H, hd = (192, 2, 32) if quick else (320, 4, 64)
    g = linear_graph(
        "autotune-vmem-gate",
        jax.ShapeDtypeStruct((1, S, H, hd), jnp.float32),
        [flash_attention_node("attn", interpret=True),
         _mlp_node("mlp0", hd),
         ssd_scan_node("ssd", state_dim=64, interpret=True),
         _mlp_node("mlp1", hd)])
    blocks = fuse_blocks(g)
    tuner = KernelAutotuner(candidates=_candidates(), runs=1)

    for blk in blocks:                      # unconstrained reference sweep
        tuner.tune_block(blk, resource="cloud")
    budget = 0.0
    for i, node in enumerate(g.nodes):
        if not node.kernel:
            continue
        rec = next(r for (k, _, res), r in tuner.records.items()
                   if k == node.kernel and res == "cloud")
        spec = g.nodes[g.preds[i][0]].out_spec
        fp = kernel_footprint(node.kernel, rec.params, [spec],
                              node.kernel_options)
        budget = max(budget, float(fp.vmem_bytes))

    tuner.vmem_limits["edge1"] = budget
    for blk in blocks:                      # constrained sweep, same tuner
        tuner.tune_block(blk, resource="edge1")

    report = {"budget_bytes": budget, "kernels": {}}
    for (kernel, shape_key, res), rec in sorted(tuner.records.items()):
        if res != "edge1":
            continue
        base = tuner.records[(kernel, shape_key, "cloud")]
        report["kernels"][kernel] = {
            "kept": len(rec.trials),
            "pruned": len(rec.pruned),
            "winner_params": rec.params,
            "winner_time_us": rec.time_s * 1e6,
            "winner_identical": (rec.params == base.params
                                 and rec.time_s == base.time_s),
        }
    report["total_pruned"] = sum(k["pruned"]
                                 for k in report["kernels"].values())
    report["all_winners_identical"] = all(k["winner_identical"]
                                          for k in report["kernels"].values())
    return report


def tiling_gate(quick: bool = True) -> dict:
    """The tile-alignment pruning gate (see module docstring).

    Follows the ``vmem_gate`` discipline: one tuner serves both sweeps, so
    the gated resource selects among *cached* trial measurements and the
    winner must come out bit-identical.  The sweep injects a
    ``block_k=100`` candidate (100 % 8 != 0: sublane-misaligned for f32);
    a deterministic ``measure`` hook prices each candidate at its *padded*
    tile area, so the misaligned candidate both loses the sweep and is
    exactly what ``tile_check`` statically removes.
    """
    from repro.kernels.substrate import round_up

    S, H, hd = (192, 2, 32) if quick else (320, 4, 64)
    candidates = {"flash_attention": [
        {"block_q": 64, "block_k": 64},
        {"block_q": 64, "block_k": 100},     # sublane-misaligned (f32)
        {"block_q": 128, "block_k": 128}]}
    misaligned_key = json.dumps({"block_q": 64, "block_k": 100},
                                sort_keys=True)

    def factory(params):
        def fn(x):
            return x
        fn.params = dict(params)
        return fn

    def measure(fn, args):
        p = fn.params
        return float(round_up(p["block_q"], 8) * round_up(p["block_k"], 8))

    x = jax.ShapeDtypeStruct((1, S, H, hd), jnp.float32)
    tuner = KernelAutotuner(candidates=candidates, measure=measure,
                            tile_check=False)
    base = tuner.tune("flash_attention", factory, (x,), resource="cloud")
    tuner.tile_check = True                 # gated sweep, same trial table
    gated = tuner.tune("flash_attention", factory, (x,), resource="edge1")

    return {
        "candidates": len(candidates["flash_attention"]),
        "measured_unpruned": len(base.trials),
        "tile_pruned": dict(gated.tile_pruned),
        "misaligned_measured_unpruned": misaligned_key in base.trials,
        "misaligned_in_gated_trials": misaligned_key in gated.trials,
        "winner_params": gated.params,
        "winner_identical": (gated.params == base.params
                             and gated.time_s == base.time_s),
    }


def verify_vmem(quick: bool = True) -> dict:
    """``--verify-vmem``: static SCN202 footprint vs compiled memory.

    For each kernel at its default block sizes, records the analyzer's
    static VMEM footprint and — when Mosaic compilation is available —
    the compiler's own memory accounting (``memory_analysis()`` with a
    ``cost_analysis()`` fallback) plus the delta.  In interpret mode each
    kernel records a ``skipped`` reason instead of failing.
    """
    from repro.kernels.ops import decode_attention_node
    from repro.kernels.substrate import (DEFAULT_PARAMS, compiled_costs,
                                         default_interpret)

    S, H, hd = (192, 2, 32) if quick else (320, 4, 64)
    interp = default_interpret()
    cases = [
        ("flash_attention",
         flash_attention_node("vv-attn"),
         jax.ShapeDtypeStruct((1, S, H, hd), jnp.float32)),
        ("decode_attention",
         decode_attention_node("vv-decode", cache_len=4 * S, kv_heads=H,
                               head_dim=hd),
         jax.ShapeDtypeStruct((1, H, hd), jnp.float32)),
        ("ssd_scan",
         ssd_scan_node("vv-ssd", state_dim=16),
         jax.ShapeDtypeStruct((1, S, H, hd), jnp.float32)),
    ]

    report = {"mode": "interpret" if interp else "compiled", "kernels": {}}
    for kernel, node, spec in cases:
        params = dict(DEFAULT_PARAMS[kernel])
        fp = kernel_footprint(kernel, params, [spec], node.kernel_options)
        entry: dict = {"params": params,
                       "static_bytes": float(fp.vmem_bytes)}
        if interp:
            entry["skipped"] = ("interpret mode: no compiled memory "
                                "analysis available")
        else:
            try:
                fn = node.kernel_factory(params)
                compiled = jax.jit(fn).lower(spec).compile()
                mem = None
                ma = getattr(compiled, "memory_analysis", None)
                if ma is not None:
                    m = ma()
                    parts = [getattr(m, f, None) for f in
                             ("temp_size_in_bytes", "output_size_in_bytes",
                              "argument_size_in_bytes")]
                    if any(p is not None for p in parts):
                        mem = float(sum(p for p in parts if p is not None))
                if mem is None:
                    mem = compiled_costs(compiled).get("bytes accessed")
                if mem is None:
                    entry["skipped"] = ("compiler exposed no memory "
                                       "accounting on this JAX version")
                else:
                    entry["compiled_bytes"] = float(mem)
                    entry["delta_bytes"] = float(mem) - float(fp.vmem_bytes)
            except Exception as e:   # keep the artifact, note the reason
                entry["skipped"] = f"{type(e).__name__}: {e}"
        report["kernels"][kernel] = entry
    return report


def run(quick: bool = True):
    S, H, hd = (192, 2, 32) if quick else (320, 4, 64)
    resources = [
        Resource("edge1", "edge", EDGE_BOX_1, speed_factor=2.0),
        Resource("cloud", "cloud", CLOUD_VM, speed_factor=1.0),
    ]
    candidates = _candidates()

    tuner = KernelAutotuner(candidates=candidates, runs=1 if quick else 2)
    g = _graph(S, H, hd)
    db = benchmark_model(g, resources, TimingProvider(tuner=tuner),
                         runs=2 if quick else 5)

    changed = [r for r in tuner.records.values() if r.changed_default]
    print("\n# Kernel autotune -> BenchmarkDB -> QueryEngine")
    for rec in tuner.records.values():
        mark = "*" if rec.changed_default else " "
        print(f" {mark} {rec.kernel:17s} @{rec.resource:6s} "
              f"default={rec.default_params} -> tuned={rec.params} "
              f"({rec.speedup_vs_default:.2f}x vs default)")
    print(f"  {len(changed)}/{len(tuner.records)} sweeps changed the "
          f"default block size")

    tuned_recs = sum(1 for rs in db.records.values()
                     for r in rs if r.tuned_params)
    net = NetworkModel(default=Link("wired", 0.005, 1e8))
    engine = QueryEngine(db, resources, net, source="edge1",
                         input_bytes=4.0 * S * H * hd)
    result = engine.run(Query(top_n=3))
    best = result.best
    print(f"  {tuned_recs} DB records carry tuned params; best partition: "
          f"{best.describe()} (query {result.query_time_s * 1e3:.1f}ms, "
          f"{result.strategy})")

    gate = vmem_gate(quick)
    print(f"  VMEM gate: budget {gate['budget_bytes'] / 2**20:.2f}MiB, "
          f"{gate['total_pruned']} candidate(s) statically pruned, "
          f"winners identical to unpruned sweep: "
          f"{gate['all_winners_identical']}")
    assert gate["total_pruned"] >= 1, \
        "VMEM gate: expected >= 1 statically pruned candidate"
    assert gate["all_winners_identical"], \
        "VMEM gate: pruning changed a winner (or its measured time)"

    tgate = tiling_gate(quick)
    print(f"  tiling gate: {len(tgate['tile_pruned'])} misaligned "
          f"candidate(s) statically pruned before timing, winner identical "
          f"to unpruned sweep: {tgate['winner_identical']}")
    assert len(tgate["tile_pruned"]) >= 1, \
        "tiling gate: expected >= 1 statically pruned misaligned candidate"
    assert not tgate["misaligned_in_gated_trials"], \
        "tiling gate: a misaligned candidate was still timed"
    assert tgate["winner_identical"], \
        "tiling gate: pruning changed the winner (or its measured time)"

    rows = [("autotune/sweeps_changed_default", float(len(changed)),
             f"{len(changed)}/{len(tuner.records)}"),
            ("autotune/db_records_tuned", float(tuned_recs), tuned_recs),
            ("autotune/best_latency", best.latency_s * 1e6,
             round(best.latency_s * 1e3, 3)),
            ("autotune/vmem_pruned", float(gate["total_pruned"]),
             f"budget={gate['budget_bytes']:.0f}B"),
            ("autotune/vmem_winner_identical",
             float(gate["all_winners_identical"]),
             gate["all_winners_identical"]),
            ("autotune/tile_pruned", float(len(tgate["tile_pruned"])),
             ";".join(sorted(tgate["tile_pruned"])) or "-"),
            ("autotune/tile_winner_identical",
             float(tgate["winner_identical"]), tgate["winner_identical"])]
    for rec in tuner.records.values():
        rows.append((f"autotune/{rec.kernel}@{rec.resource}",
                     rec.time_s * 1e6,
                     "->".join([str(rec.default_params), str(rec.params)])))
    run.last_gate = gate        # for --out (same idiom as bench_partitions)
    run.last_tiling_gate = tgate
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="quick dimensions (the CI configuration)")
    ap.add_argument("--full", action="store_true",
                    help="larger shapes / more runs")
    ap.add_argument("--out", default=None,
                    help="write the gate reports (kept/pruned per kernel) "
                         "as JSON")
    ap.add_argument("--verify-vmem", action="store_true",
                    help="cross-check the static VMEM footprint against "
                         "compiled memory accounting (skips cleanly in "
                         "interpret mode)")
    args = ap.parse_args()
    rows = run(quick=not args.full)
    report = dict(run.last_gate)
    report["tiling_gate"] = run.last_tiling_gate
    if args.verify_vmem:
        vv = verify_vmem(quick=not args.full)
        report["verify_vmem"] = vv
        print(f"  verify-vmem ({vv['mode']}):")
        for kernel, entry in sorted(vv["kernels"].items()):
            if "skipped" in entry:
                print(f"    {kernel}: static "
                      f"{entry['static_bytes'] / 2**20:.2f}MiB "
                      f"[skipped: {entry['skipped']}]")
            else:
                print(f"    {kernel}: static "
                      f"{entry['static_bytes'] / 2**20:.2f}MiB vs compiled "
                      f"{entry['compiled_bytes'] / 2**20:.2f}MiB "
                      f"(delta {entry['delta_bytes'] / 2**20:+.2f}MiB)")
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(report, f, indent=2, sort_keys=True)
        print(f"  wrote {args.out}")
    print("\nname,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
