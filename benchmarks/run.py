"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full]

Prints a human-readable report per table plus a machine-readable
``name,us_per_call,derived`` CSV at the end.
"""

from __future__ import annotations

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="all 18 CNNs / all scenarios (slower)")
    args = ap.parse_args()
    quick = not args.full

    from . import (bench_autotune, bench_elastic, bench_overhead,
                   bench_partitions, bench_query, bench_roofline, bench_zoo)

    rows = []
    rows += bench_zoo.run(quick)            # Table I
    rows += bench_overhead.run(quick)       # Table III
    rows += bench_partitions.run(quick)     # Figs 6-15 + Table IV
    rows += bench_query.run(quick)          # <50ms query claim
    rows += bench_elastic.run(quick)        # motivation (vi): re-planning
    rows += bench_roofline.run(quick)       # §Roofline (from dry-run)
    rows += bench_autotune.run(quick)       # kernel block-size autotuning

    print("\nname,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")

    if bench_partitions.scenario_throughput.failures:
        print("FAILED predicted-vs-simulated throughput validation: "
              + ", ".join(bench_partitions.scenario_throughput.failures))
        sys.exit(1)


if __name__ == "__main__":
    main()
