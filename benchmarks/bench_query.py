"""Query-engine latency (the paper's <50 ms claim, §II-B(vi)) — on the
paper-sized testbed and on a fleet-sized lattice, where the k-best
insertion strategy (``PartitionLattice._push``) dominates query time."""

from __future__ import annotations

import statistics
import time

from repro.core import Query
from repro.core.partition import PartitionLattice

from .common import benchmark_cached, fleet_engine, scission_for


class _SortPushLattice(PartitionLattice):
    """The pre-fix insertion strategy: append + full re-sort per relaxed
    edge (O(K log K) each) — kept here only to quantify the improvement of
    the bounded ``bisect.insort`` push on a fleet-sized lattice."""

    @staticmethod
    def _push(store: dict, key, entry, k: int) -> None:
        lst = store.setdefault(key, [])
        lst.append(entry)
        lst.sort(key=lambda e: e[0])
        del lst[k:]


def _time(fn, repeats=3):
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def run(quick: bool = True):
    s = scission_for("4g")
    benchmark_cached(s, "ResNet50")
    queries = [
        Query(top_n=3),
        Query(top_n=3, must_use=("device", "edge1", "cloud")),
        Query(top_n=3, exclude=("cloud", "cloud_gpu")),
        Query(top_n=3, max_link_bytes={("edge1", "cloud"): 1_000_000}),
        Query(top_n=3, max_resource_time={"device": 1.0}),
        Query(top_n=3, pin={5: "edge1"}),
    ]
    s.query("ResNet50")   # warm cache (paper: queries run on cached data)
    times = []
    for q in queries * (1 if quick else 5):
        t0 = time.perf_counter()
        s.query("ResNet50", q)
        times.append(time.perf_counter() - t0)
    worst = max(times)
    mean = statistics.fmean(times)
    print(f"\n# Query engine: mean={mean * 1e3:.2f}ms "
          f"worst={worst * 1e3:.2f}ms over {len(times)} queries "
          f"(paper budget: 50ms) {'PASS' if worst < 0.05 else 'FAIL'}")
    rows = [("query/mean", mean * 1e6, round(mean * 1e3, 3)),
            ("query/worst", worst * 1e6, round(worst * 1e3, 3))]

    # -- fleet-sized lattice: bounded-insort push vs legacy sort-per-insert -
    eng = fleet_engine(n_per_tier=6 if quick else 9,
                       n_blocks=24 if quick else 32)
    cost = eng.cost
    top_n = 8
    repeats = 2 if quick else 3
    t_insort = _time(lambda: PartitionLattice(cost).solve(top_n=top_n),
                     repeats)
    t_sort = _time(lambda: _SortPushLattice(cost).solve(top_n=top_n),
                   repeats)
    want = [c.latency_s for c in PartitionLattice(cost).solve(top_n=top_n)]
    got = [c.latency_s for c in _SortPushLattice(cost).solve(top_n=top_n)]
    assert want == got, "push strategies must agree on the k-best results"
    speedup = t_sort / t_insort if t_insort > 0 else float("inf")
    print(f"# Fleet lattice ({len(eng.resources)} resources x "
          f"{eng.db.n_blocks} blocks, top_n={top_n}): "
          f"insort-push={t_insort * 1e3:.0f}ms "
          f"sort-push={t_sort * 1e3:.0f}ms speedup={speedup:.2f}x")
    rows += [("query/fleet_insort_push", t_insort * 1e6,
              round(t_insort * 1e3, 1)),
             ("query/fleet_sort_push", t_sort * 1e6,
              round(t_sort * 1e3, 1)),
             ("query/fleet_push_speedup", 0.0, round(speedup, 2))]
    return rows
