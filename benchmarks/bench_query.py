"""Query-engine latency (the paper's <50 ms claim, §II-B(vi))."""

from __future__ import annotations

import statistics
import time

from repro.core import Query

from .common import benchmark_cached, scission_for


def run(quick: bool = True):
    s = scission_for("4g")
    benchmark_cached(s, "ResNet50")
    queries = [
        Query(top_n=3),
        Query(top_n=3, must_use=("device", "edge1", "cloud")),
        Query(top_n=3, exclude=("cloud", "cloud_gpu")),
        Query(top_n=3, max_link_bytes={("edge1", "cloud"): 1_000_000}),
        Query(top_n=3, max_resource_time={"device": 1.0}),
        Query(top_n=3, pin={5: "edge1"}),
    ]
    s.query("ResNet50")   # warm cache (paper: queries run on cached data)
    times = []
    for q in queries * (1 if quick else 5):
        t0 = time.perf_counter()
        s.query("ResNet50", q)
        times.append(time.perf_counter() - t0)
    worst = max(times)
    mean = statistics.fmean(times)
    print(f"\n# Query engine: mean={mean * 1e3:.2f}ms "
          f"worst={worst * 1e3:.2f}ms over {len(times)} queries "
          f"(paper budget: 50ms) {'PASS' if worst < 0.05 else 'FAIL'}")
    return [("query/mean", mean * 1e6, round(mean * 1e3, 3)),
            ("query/worst", worst * 1e6, round(worst * 1e3, 3))]
