"""Serving-plane benchmark: open-loop arrival traces through the request
router at a frontier-chosen operating point.

The end-to-end story the request plane exists for:

1. benchmark the model on the testbed (Steps 1-3, disk-cached),
2. ask :meth:`Scission.frontier` for the Pareto set and pick the
   highest-throughput operating point,
3. serve a seeded open-loop Poisson trace (offered at ~1.2x the point's
   predicted capacity, so the plane saturates) through the
   :class:`~repro.serving.router.Router`,
4. gate: steady-state measured **goodput** must land within 30% of the
   cost model's ``throughput_rps`` prediction for that point,
5. repeat under a bursty-diurnal trace with an SLO (admission control
   sheds the burst overflow at the front door),
6. re-plan live: an :class:`~repro.runtime.elastic.ElasticController`
   loses a resource mid-trace, its re-plan event swaps the router's
   operating point with zero dropped in-flight requests.

Run standalone in smoke mode for CI::

    PYTHONPATH=src python -m benchmarks.bench_serving --smoke \
        --out results/bench_serving_smoke.json
"""

from __future__ import annotations

import argparse
import json
import os

from repro.core import Query, THROUGHPUT
from repro.runtime.elastic import ElasticController
from repro.serving import (Router, bursty_diurnal_trace, empirical_rate,
                           poisson_trace)

from .common import benchmark_cached, scission_for

GOODPUT_TOLERANCE = 0.30          # measured vs predicted, saturated plane
MODEL = "MobileNetV2"
BATCHES = (1, 2, 4)
REPLICAS = {"edge1": 2, "edge2": 2, "cloud": 2, "cloud_gpu": 2}


def _frontier_point(scission, quick=True):
    """Highest-predicted-throughput point of the Pareto frontier over the
    measured batch sizes and a two-replica budget per offload tier."""
    q = Query(objective=THROUGHPUT, batch_sizes=BATCHES, replicas=REPLICAS)
    res = scission.frontier(MODEL, q, input_bytes=150e3)
    point = max(res.configs, key=lambda c: c.throughput_rps)
    return point, res


def scenario_poisson(point, quick=True):
    """Saturated Poisson trace; gates goodput against the prediction."""
    pred = point.throughput_rps
    # virtual-time horizon: the router simulates, so longer = tighter
    # steady state at negligible real cost
    horizon = 80.0 if quick else 400.0
    trace = poisson_trace(rate_rps=1.2 * pred, horizon_s=horizon, seed=0)
    router = Router(point, slo_s=None)
    rep = router.serve(trace)
    rel_err = abs(rep.goodput_rps - pred) / pred
    print(f"  poisson: offered={rep.offered_rps:.2f} rps  "
          f"predicted={pred:.2f} rps  goodput={rep.goodput_rps:.2f} rps  "
          f"rel_err={rel_err:.1%}  p50={rep.latency_p50_s * 1e3:.1f} ms  "
          f"p99={rep.latency_p99_s * 1e3:.1f} ms")
    if rel_err > GOODPUT_TOLERANCE:
        scenario_poisson.failures.append(
            f"poisson goodput {rep.goodput_rps:.2f} rps vs predicted "
            f"{pred:.2f} rps (rel err {rel_err:.1%} > "
            f"{GOODPUT_TOLERANCE:.0%})")
    if rep.arrivals != rep.completed + rep.shed:
        scenario_poisson.failures.append(
            f"poisson lost requests: {rep.arrivals} arrivals != "
            f"{rep.completed} completed + {rep.shed} shed")
    return {"predicted_rps": round(pred, 4), "rel_err": round(rel_err, 4),
            **rep.as_dict()}


scenario_poisson.failures = []


def scenario_bursty(point, quick=True):
    """Bursty-diurnal trace with an SLO: the diurnal peak oversubscribes
    the point, admission control sheds the overflow at the front door."""
    pred = point.throughput_rps
    horizon = 60.0 if quick else 240.0
    slo = max(20.0 * point.bottleneck_s, 2.0 * point.latency_s)
    trace = bursty_diurnal_trace(
        base_rps=0.5 * pred, peak_rps=2.0 * pred, horizon_s=horizon,
        period_s=horizon / 2, seed=1, burst_factor=1.5,
        burst_every_s=horizon / 4, burst_len_s=horizon / 20)
    router = Router(point, slo_s=slo)
    rep = router.serve(trace)
    print(f"  bursty: offered={rep.offered_rps:.2f} rps  "
          f"goodput={rep.goodput_rps:.2f} rps  shed={rep.shed} "
          f"({rep.shed_reasons})  slo={slo * 1e3:.0f} ms  "
          f"violations={rep.slo_violations}")
    if rep.arrivals != rep.completed + rep.shed:
        scenario_bursty.failures.append(
            f"bursty lost requests: {rep.arrivals} arrivals != "
            f"{rep.completed} completed + {rep.shed} shed")
    return {"predicted_rps": round(pred, 4), **rep.as_dict()}


scenario_bursty.failures = []


def scenario_replan(scission, quick=True):
    """Mid-trace re-plan: the controller loses a resource, the listener
    swaps the router's operating point live; nothing in flight drops."""
    ctl = ElasticController(
        scission, MODEL,
        query=Query(objective=THROUGHPUT, batch_sizes=BATCHES,
                    replicas=REPLICAS),
        track_frontier=True)
    point = ctl.current
    router = Router(point, slo_s=None)
    ctl.add_listener(router.on_plan)
    horizon = 40.0 if quick else 120.0
    trace = poisson_trace(rate_rps=1.1 * point.throughput_rps,
                          horizon_s=horizon, seed=2)
    half = horizon / 2
    lost = next(r for r in point.resources if r != "device")
    for a in trace:
        if lost is not None and a.t >= half:
            ctl.on_resource_lost(lost)       # -> router.on_plan -> swap
            lost = None
        router.offer(a)
    router.flush()
    rep = router.report()
    after = ctl.current
    print(f"  replan: lost a resource at t={half:.0f}s  swaps={rep.swaps}  "
          f"{point.throughput_rps:.2f} -> {after.throughput_rps:.2f} rps  "
          f"arrivals={rep.arrivals} completed={rep.completed} "
          f"shed={rep.shed}")
    if rep.swaps < 1:
        scenario_replan.failures.append(
            "replan produced no operating-point swap on the router")
    if rep.arrivals != rep.completed + rep.shed:
        scenario_replan.failures.append(
            f"replan lost requests: {rep.arrivals} arrivals != "
            f"{rep.completed} completed + {rep.shed} shed")
    return {"swaps": rep.swaps,
            "point_before_rps": round(point.throughput_rps, 4),
            "point_after_rps": round(after.throughput_rps, 4),
            **rep.as_dict()}


scenario_replan.failures = []


def smoke():
    """CI pass: frontier-pick one operating point, serve Poisson + bursty
    traces, re-plan mid-trace; gates goodput-vs-predicted and the
    no-lost-requests invariant."""
    s = scission_for("4g")
    benchmark_cached(s, MODEL, batch_sizes=BATCHES)
    point, res = _frontier_point(s)
    print(f"# frontier point ({MODEL}, 4g): batch={point.batch_size} "
          f"replicas={point.replicas} segments={len(point.segments)} "
          f"predicted={point.throughput_rps:.2f} rps "
          f"(frontier of {len(res.configs)} in {res.query_time_s:.3f}s)")
    out = {
        "model": MODEL, "network": "4g",
        "point": {
            "batch_size": point.batch_size,
            "replicas": list(point.replicas),
            "segments": [(seg.resource, seg.start, seg.end)
                         for seg in point.segments],
            "predicted_rps": round(point.throughput_rps, 4),
            "latency_s": round(point.latency_s, 6),
        },
        "frontier_size": len(res.configs),
        "poisson": scenario_poisson(point, quick=True),
        "bursty": scenario_bursty(point, quick=True),
        "replan": scenario_replan(s, quick=True),
    }
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="single-model CI pass with the goodput gate")
    ap.add_argument("--out", default=None,
                    help="write the serving report as JSON to this path")
    args = ap.parse_args()
    out = smoke()                 # smoke is currently the only mode
    if args.out is None:
        args.out = "results/bench_serving_smoke.json"
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
    print(f"wrote {args.out}")
    failures = (scenario_poisson.failures + scenario_bursty.failures
                + scenario_replan.failures)
    if failures:
        print(f"FAILED serving gates: {'; '.join(failures)}")
        raise SystemExit(1)


if __name__ == "__main__":
    main()
