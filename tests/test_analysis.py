"""scission-lint: the static-analysis layer (repro.analysis).

Covers the three analyzers (kernel VMEM / plan lint / graph IR), their
engine wiring (autotuner pruning, ``QueryResult.diagnostics``,
``GraphLintError``), the satellite fixes (failure maps, batch-clamp
surfacing, one-way links), and the acceptance property: whenever a
solve/frontier returns ``[]`` under generated constraints, the attached
diagnostics contain >= 1 error-severity code explaining the infeasibility
— and conversely, a non-empty result never carries an error (the linter
is *sound* on the generated constraint families).
"""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import (CODES, Diagnostic, ERROR, INFO, WARNING, dedupe,
                            errors, has_errors)
from repro.analysis.graph_lint import (GraphLintError, lint_db_against_graph,
                                       lint_graph)
from repro.analysis.kernel_vmem import (kernel_footprint, kernel_vmem_bytes,
                                        lint_candidates)
from repro.analysis.plan_lint import (explain_empty, feasible_exists,
                                      lint_plan)
from repro.core import (AnalyticProvider, Link, NetworkModel, Query,
                        QueryEngine, Resource, benchmark_model, fuse_blocks,
                        linear_graph)
from repro.core.bench import BenchmarkDB, BlockBenchmark
from repro.core.graph import LayerGraph, LayerNode
from repro.core.resources import CLOUD_VM, EDGE_BOX_1, RPI4
from repro.kernels import KernelAutotuner

from test_constraint_exact import _random_engine_and_query

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # degrade to the deterministic tests only
    HAVE_HYPOTHESIS = False


# ---------------------------------------------------------------------------
# the shared Diagnostic type
# ---------------------------------------------------------------------------

class TestDiagnostic:
    def test_severity_and_code_validation(self):
        with pytest.raises(ValueError, match="severity"):
            Diagnostic("SCN101", "fatal", "x")
        for bad in ("SCN1", "ABC101", "SCN1x1", "scn101"):
            with pytest.raises(ValueError, match="code"):
                Diagnostic(bad, ERROR, "x")

    def test_render_and_helpers(self):
        d = Diagnostic("SCN103", ERROR, "floor too high", subject="cloud",
                       hint="lower it")
        assert "SCN103" in d.render() and "[cloud]" in d.render() \
            and "lower it" in d.render()
        w = Diagnostic("SCN111", WARNING, "clamped")
        assert errors([d, w]) == [d]
        assert has_errors([w]) is False and has_errors([d, w]) is True
        assert dedupe([d, d, w]) == [d, w]

    def test_all_emitted_codes_are_documented(self):
        assert all(len(c) == 6 and c.startswith("SCN") for c in CODES)
        # one block per analyzer family
        assert {c[3] for c in CODES} == {"1", "2", "3", "4", "5"}


# ---------------------------------------------------------------------------
# kernel memory analyzer (SCN2xx)
# ---------------------------------------------------------------------------

class TestKernelVmem:
    def test_flash_footprint_hand_computed(self):
        # q (1, 192, 2, 32) f32, blocks (64, 64):
        #   q/k/v/o blocks are (1, 64, 1, 32) -> 8192 B each
        #   in  = 2 * 3 * 8192 = 49152 (double-buffered)
        #   out = 2 * 8192     = 16384
        #   scratch = 2*(64*4) + 64*32*4 = 8704
        q = np.zeros((1, 192, 2, 32), np.float32)
        fp = kernel_footprint("flash_attention",
                              {"block_q": 64, "block_k": 64}, [q])
        assert fp.in_bytes == 49152
        assert fp.out_bytes == 16384
        assert fp.scratch_bytes == 8704
        assert fp.vmem_bytes == 74240

    def test_flash_blocks_clamp_to_sequence(self):
        q = np.zeros((1, 32, 2, 16), np.float32)
        fp = kernel_footprint("flash_attention",
                              {"block_q": 256, "block_k": 256}, [q])
        assert fp.blocks["q"] == (1, 32, 1, 16)
        assert fp.blocks["k"] == (1, 32, 1, 16)

    def test_ssd_footprint_hand_computed(self):
        # x (1, 64, 1, 16) f32, chunk 32, N=8 (via options):
        #   x (1,32,1,16)=2048, log_a (1,32,1)=128, b=c=(1,32,1,8)=1024,
        #   y 2048, final (1,1,8,16)=512, scratch N*P*4=512
        x = np.zeros((1, 64, 1, 16), np.float32)
        fp = kernel_footprint("ssd_scan", {"chunk": 32}, [x],
                              options={"state_dim": 8})
        assert fp.in_bytes == 2 * (2048 + 128 + 1024 + 1024)
        assert fp.out_bytes == 2 * (2048 + 512)
        assert fp.scratch_bytes == 512
        assert fp.vmem_bytes == 14080

    def test_decode_needs_cache_length(self):
        q = np.zeros((1, 8, 64), np.float32)
        with pytest.raises(ValueError, match="cache"):
            kernel_footprint("decode_attention", {"block_k": 256}, [q])
        small = kernel_vmem_bytes("decode_attention", {"block_k": 128}, [q],
                                  options={"cache_len": 4096, "kv_heads": 8})
        large = kernel_vmem_bytes("decode_attention", {"block_k": 512}, [q],
                                  options={"cache_len": 4096, "kv_heads": 8})
        assert small < large

    def test_unknown_kernel_returns_none(self):
        assert kernel_footprint("nope", {}, []) is None

    def test_lint_candidates_split(self):
        q = np.zeros((1, 192, 2, 32), np.float32)
        cands = [{"block_q": 64, "block_k": 64},
                 {"block_q": 256, "block_k": 256}]
        small_fp = kernel_vmem_bytes("flash_attention", cands[0], [q])
        kept, pruned, diags = lint_candidates(
            "flash_attention", cands, [q], vmem_limit=small_fp)
        assert kept == [cands[0]]
        assert list(pruned) == [json.dumps(cands[1], sort_keys=True)]
        assert [d.code for d in diags] == ["SCN201"]
        assert diags[0].severity == INFO

    def test_lint_candidates_all_pruned_is_error(self):
        q = np.zeros((1, 192, 2, 32), np.float32)
        kept, pruned, diags = lint_candidates(
            "flash_attention", [{"block_q": 64, "block_k": 64}], [q],
            vmem_limit=16)
        assert kept == [] and len(pruned) == 1
        assert any(d.code == "SCN202" and d.is_error for d in diags)

    def test_lint_candidates_unlimited_and_unknown(self):
        cands = [{"block_q": 64, "block_k": 64}]
        kept, pruned, diags = lint_candidates(
            "flash_attention", cands, [], vmem_limit=None)
        assert kept == cands and not pruned and not diags
        kept, pruned, diags = lint_candidates("mystery", [{"p": 1}], [],
                                              vmem_limit=1)
        assert kept == [{"p": 1}]
        assert [d.code for d in diags] == ["SCN203"]


# ---------------------------------------------------------------------------
# autotuner integration: pruning before timing, failure maps
# ---------------------------------------------------------------------------

def _tagged_factory(params):
    fn = lambda x: x                                   # noqa: E731
    fn.params = dict(params)
    return fn


class TestAutotunerVmem:
    CANDS = {"ssd_scan": [{"chunk": c} for c in (32, 64, 128)]}
    ARGS = (np.zeros((1, 192, 1, 32), np.float32),)
    OPTS = {"state_dim": 64}

    def _tuner(self, measured, **kw):
        def measure(fn, args):
            measured.append(fn.params)
            return 1.0 / fn.params["chunk"]     # largest chunk wins
        return KernelAutotuner(candidates=self.CANDS, measure=measure, **kw)

    def test_pruned_candidates_are_never_measured(self):
        budget = kernel_vmem_bytes("ssd_scan", {"chunk": 64}, self.ARGS,
                                   options=self.OPTS)
        measured = []
        tuner = self._tuner(measured, vmem_limits={"edge": float(budget)})
        rec = tuner.tune("ssd_scan", _tagged_factory, self.ARGS,
                         resource="edge", options=self.OPTS)
        assert {p["chunk"] for p in measured} == {32, 64}
        assert rec.params == {"chunk": 64}      # fastest *admissible*
        assert list(rec.pruned) == [json.dumps({"chunk": 128})]
        assert rec.vmem_limit == float(budget)

    def test_constrained_winner_reuses_unconstrained_trials_exactly(self):
        budget = kernel_vmem_bytes("ssd_scan", {"chunk": 64}, self.ARGS,
                                   options=self.OPTS)
        measured = []
        tuner = self._tuner(measured)
        free = tuner.tune("ssd_scan", _tagged_factory, self.ARGS,
                          resource="cloud", options=self.OPTS)
        n_measured = len(measured)
        assert free.params == {"chunk": 128} and not free.pruned
        tuner.vmem_limits["edge"] = float(budget)
        tight = tuner.tune("ssd_scan", _tagged_factory, self.ARGS,
                           resource="edge", options=self.OPTS)
        # nothing re-timed: the admissible winner is selected from the
        # cached trial table, so its time is bit-identical to that sweep
        assert len(measured) == n_measured
        assert tight.params == {"chunk": 64}
        assert tight.time_s == free.trials[json.dumps({"chunk": 64})]

    def test_all_pruned_raises_with_footprints(self):
        tuner = self._tuner([], vmem_limits={"edge": 64.0})
        with pytest.raises(RuntimeError, match="VMEM budget"):
            tuner.tune("ssd_scan", _tagged_factory, self.ARGS,
                       resource="edge", options=self.OPTS)

    def test_every_candidate_failed_reports_per_candidate_errors(self):
        def measure(fn, args):
            raise ValueError(f"boom chunk={fn.params['chunk']}")
        tuner = KernelAutotuner(candidates=self.CANDS, measure=measure)
        with pytest.raises(RuntimeError) as ei:
            tuner.tune("ssd_scan", _tagged_factory, self.ARGS,
                       resource="host", options=self.OPTS)
        msg = str(ei.value)
        for chunk in (32, 64, 128):
            assert f"boom chunk={chunk}" in msg
        assert "ValueError" in msg

    def test_register_resources_adopts_vmem_budgets(self):
        tuner = KernelAutotuner(candidates=self.CANDS)
        tuner.register_resources([
            Resource("edge", "edge", EDGE_BOX_1, vmem_bytes=12345.0),
            Resource("cloud", "cloud", CLOUD_VM)])
        assert tuner.vmem_limits == {"edge": 12345.0}

    def test_tune_record_json_roundtrip_keeps_pruned(self):
        budget = kernel_vmem_bytes("ssd_scan", {"chunk": 64}, self.ARGS,
                                   options=self.OPTS)
        tuner = self._tuner([], vmem_limits={"edge": float(budget)})
        tuner.tune("ssd_scan", _tagged_factory, self.ARGS,
                   resource="edge", options=self.OPTS)
        back = KernelAutotuner.from_json(tuner.to_json())
        rec = next(iter(back.records.values()))
        assert rec.pruned and rec.vmem_limit == float(budget)


# ---------------------------------------------------------------------------
# plan linter (SCN1xx)
# ---------------------------------------------------------------------------

def _small_engine(n_blocks=4):
    """Deterministic 3-resource space with uniform dyadic times."""
    res = [Resource("device0", "device", RPI4),
           Resource("edge0", "edge", EDGE_BOX_1),
           Resource("cloud0", "cloud", CLOUD_VM)]
    db = BenchmarkDB(model="lint", n_blocks=n_blocks)
    for i, r in enumerate(res):
        t = [1 / (1 << (i + 2))] * n_blocks     # faster per tier
        db.records[r.name] = [
            BlockBenchmark(block=b, resource=r.name, mean_time_s=t[b],
                           std_time_s=0.0, output_bytes=1 << 10, runs=1)
            for b in range(n_blocks)]
    net = NetworkModel(default=Link("d", 1 / (1 << 10), float(1 << 20)))
    return QueryEngine(db, res, net, source="device0",
                       input_bytes=float(1 << 10))


def _codes(result):
    return {d.code for d in result.diagnostics}


class TestPlanLint:
    def test_feasible_query_is_clean(self):
        r = _small_engine().run(Query())
        assert r.configs and r.diagnostics == []

    def test_scn101_contradiction(self):
        r = _small_engine().run(Query(must_use=("cloud0",),
                                      exclude=("cloud0",)))
        assert not r.configs and "SCN101" in _codes(r)

    def test_scn102_unknown_demanded_vs_excluded(self):
        eng = _small_engine()
        r = eng.run(Query(must_use=("ghost",)))
        d = next(d for d in r.diagnostics if d.code == "SCN102")
        assert d.is_error and not r.configs
        # unknown names in exclude merely warn — the query still solves
        r2 = eng.run(Query(exclude=("ghost",)))
        d2 = next(d for d in r2.diagnostics if d.code == "SCN102")
        assert d2.severity == WARNING and r2.configs

    def test_scn103_floor_exceeds_blocks(self):
        r = _small_engine(4).run(Query(min_blocks_on={"cloud0": 5}))
        assert not r.configs and "SCN103" in _codes(r)

    def test_scn104_floors_cannot_fit(self):
        r = _small_engine(4).run(Query(min_blocks_on={"device0": 3,
                                                      "cloud0": 2}))
        assert not r.configs and "SCN104" in _codes(r)

    def test_scn105_cap_below_single_block(self):
        eng = _small_engine()
        # cloud0 block time is 1/16; demanded -> error
        r = eng.run(Query(must_use=("cloud0",),
                          max_resource_time={"cloud0": 1 / 32}))
        d = next(d for d in r.diagnostics if d.code == "SCN105")
        assert d.is_error and not r.configs
        # not demanded -> the resource is just unusable: warning
        r2 = eng.run(Query(max_resource_time={"cloud0": 1 / 32}))
        d2 = next(d for d in r2.diagnostics if d.code == "SCN105")
        assert d2.severity == WARNING and r2.configs

    def test_scn106_tier_collision_and_pin_order(self):
        eng = _small_engine()
        res = [Resource("device0", "device", RPI4),
               Resource("edge0", "edge", EDGE_BOX_1),
               Resource("edge1", "edge", EDGE_BOX_1)]
        diags = lint_plan(Query(must_use=("edge0",),
                                min_blocks_on={"edge1": 1}), res)
        assert any(d.code == "SCN106" and d.is_error for d in diags)
        # pins against the data-flow direction
        r = eng.run(Query(pin={0: "cloud0", 3: "device0"}))
        assert not r.configs and "SCN106" in _codes(r)

    def test_scn107_pinned_hop_without_explicit_link(self):
        eng = _small_engine()
        r = eng.run(Query(pin={1: "device0", 2: "cloud0"}))
        d = next(d for d in r.diagnostics if d.code == "SCN107")
        assert d.severity == WARNING      # advisory: default link prices it
        assert "device0" in d.subject and "cloud0" in d.subject

    def test_scn108_pipelines_admit_none(self):
        eng = _small_engine()
        r = eng.run(Query(pipelines=(("cloud0", "device0"),)))   # wrong order
        assert not r.configs and "SCN108" in _codes(r)
        r2 = eng.run(Query(must_use=("edge0",),
                           pipelines=(("device0", "cloud0"),)))
        assert not r2.configs and "SCN108" in _codes(r2)

    def test_scn110_one_way_link_against_flow(self):
        res = [Resource("device0", "device", RPI4),
               Resource("cloud0", "cloud", CLOUD_VM)]
        net = NetworkModel()
        # explicit link points cloud -> device; the planner-usable
        # device -> cloud direction silently falls back to the default
        net.connect("cloud0", "device0", Link("back", 0.01, 1e6),
                    symmetric=False)
        diags = lint_plan(Query(), res, net)
        d = next(d for d in diags if d.code == "SCN110")
        assert d.severity == WARNING and d.subject == "device0->cloud0"
        # a symmetric connect is clean
        net2 = NetworkModel().connect("device0", "cloud0",
                                      Link("ok", 0.01, 1e6))
        assert not [d for d in lint_plan(Query(), res, net2)
                    if d.code == "SCN110"]

    def test_scn112_nonpositive_top_n(self):
        r = _small_engine().run(Query(top_n=0))
        assert not r.configs and "SCN112" in _codes(r)
        # the frontier ignores top_n, so it must not flag it
        rf = _small_engine().frontier(Query(top_n=0))
        assert rf.configs and "SCN112" not in _codes(rf)

    def test_scn109_jointly_unsatisfiable_backstop(self):
        # every itemized check passes — the cap (0.3) is above device0's
        # single-block time (0.25) so SCN105 stays silent, and the floor
        # (2 of 4 blocks) fits on its own — but 2 blocks cost 0.5 > 0.3,
        # so the *combination* is unsatisfiable: only the exact sweep sees it
        eng = _small_engine(4)
        q = Query(min_blocks_on={"device0": 2},
                  max_resource_time={"device0": 0.3})
        r = eng.run(q)
        assert not r.configs
        assert _codes(r) == {"SCN109"}

    def test_feasible_exists_matches_solver(self):
        eng = _small_engine()
        q_ok = Query(must_use=("cloud0",))
        q_bad = Query(must_use=("cloud0",),
                      max_link_bytes={("device0", "cloud0"): 1.0,
                                      ("device0", "edge0"): 1.0,
                                      ("edge0", "cloud0"): 1.0})
        for q, want in ((q_ok, True), (q_bad, False)):
            cost = eng._cost_for(q)
            got = feasible_exists(cost, q.constraints())
            assert got is (bool(eng.run(q).configs)) is want

    def test_explain_empty_skips_when_prior_error_explains(self):
        eng = _small_engine()
        q = Query(min_blocks_on={"cloud0": 99})
        cost = eng._cost_for(q)
        prior = [Diagnostic("SCN103", ERROR, "floor")]
        assert explain_empty(q, q.constraints(), [cost], prior=prior) == []


# ---------------------------------------------------------------------------
# batch-clamp surfacing (SCN111)
# ---------------------------------------------------------------------------

class TestBatchClampDiagnostic:
    def _db(self):
        db = BenchmarkDB(model="clamp", n_blocks=2)
        db.records["edge0"] = [
            BlockBenchmark(block=b, resource="edge0", mean_time_s=0.01,
                           std_time_s=0.0, output_bytes=64, runs=1,
                           batch_profile={1: (0.01, 64), 4: (0.03, 256)})
            for b in range(2)]
        return db

    def test_out_of_range_batch_is_recorded_not_silent(self):
        db = self._db()
        t = db.time("edge0", 0, batch=16)        # above the measured range
        assert t == 0.03                         # still clamps (no change)
        diags = db.drain_diagnostics()
        assert [d.code for d in diags] == ["SCN111"]
        assert diags[0].severity == WARNING and "16" in diags[0].message
        assert db.drain_diagnostics() == []      # drained

    def test_repeated_clamps_dedupe_and_in_range_is_clean(self):
        db = self._db()
        db.time("edge0", 0, batch=16)
        db.time("edge0", 1, batch=16)            # same (resource, batch)
        assert len(db.drain_diagnostics()) == 1
        db.time("edge0", 0, batch=2)             # interpolated, in range
        db.time("edge0", 0, batch=1)
        assert db.drain_diagnostics() == []

    def test_pending_clamps_surface_on_query_result(self):
        eng = _small_engine()
        eng.db.records["edge0"][0].batch_profile = {1: (0.01, 64)}
        eng.db.time("edge0", 0, batch=8)         # out-of-range consumer
        r = eng.run(Query())
        assert any(d.code == "SCN111" and d.severity == WARNING
                   for d in r.diagnostics)


# ---------------------------------------------------------------------------
# graph IR checker (SCN3xx)
# ---------------------------------------------------------------------------

def _spec(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def _node(name, fn):
    return LayerNode(name=name, kind="dense", apply=fn)


class TestGraphLint:
    def test_empty_graph(self):
        g = LayerGraph("empty")
        assert [d.code for d in lint_graph(g)] == ["SCN301"]
        with pytest.raises(GraphLintError):
            fuse_blocks(g)

    def test_orphan_source_raises_named_diagnostic(self):
        g = LayerGraph("orphan")
        g.input(_spec(1, 8))
        g.add(_node("a", lambda x: x), preds=[0])
        g.add(_node("lost", lambda x: x), preds=[])    # orphan + extra sink
        with pytest.raises(ValueError) as ei:          # GraphLintError is one
            g.validate()
        assert isinstance(ei.value, GraphLintError)
        codes = {d.code for d in ei.value.diagnostics}
        assert "SCN304" in codes
        assert any(d.subject == "lost" for d in ei.value.diagnostics)

    def test_dangling_pred_after_mutation(self):
        g = LayerGraph("mut")
        g.input(_spec(1, 8))
        g.add(_node("a", lambda x: x), preds=[0])
        g.preds[1] = [7]                               # rewritten post-add
        diags = lint_graph(g)
        assert [d.code for d in diags] == ["SCN302"]
        assert "dangling" in diags[0].message

    def test_extra_sink(self):
        g = LayerGraph("sinks")
        g.input(_spec(1, 8))
        g.add(_node("a", lambda x: x), preds=[0])
        g.add(_node("b", lambda x: x), preds=[0])      # 'a' never consumed
        codes = [d.code for d in lint_graph(g)]
        assert codes == ["SCN303"]

    def test_missing_apply(self):
        g = LayerGraph("noapply")
        g.input(_spec(1, 8))
        g.add(LayerNode(name="hole", kind="dense", apply=None), preds=[0])
        assert any(d.code == "SCN305" for d in lint_graph(g))

    def test_shape_chain_mismatch_names_the_declaring_node(self):
        g = linear_graph("chain", _spec(1, 8),
                         [_node("a", lambda x: x * 2),
                          _node("b", lambda x: x + 1)])
        assert lint_graph(g, check_shapes=True) == []
        g.nodes[1].out_spec = _spec(1, 16)             # stale declaration
        diags = lint_graph(g, check_shapes=True)
        assert diags and all(d.code == "SCN306" for d in diags)
        assert diags[0].subject == "a"
        with pytest.raises(GraphLintError):
            g.validate(check_shapes=True)

    def test_untraced_graph_info(self):
        g = LayerGraph("untraced")
        g.input(_spec(1, 8))
        g.add(_node("a", lambda x: x), preds=[0])
        diags = lint_graph(g, check_shapes=True)
        assert [d.code for d in diags] == ["SCN308"]
        assert diags[0].severity == INFO

    def test_db_output_bytes_cross_check(self):
        g = linear_graph("xcheck", _spec(1, 8),
                         [_node("a", lambda x: x),
                          _node("b", lambda x: jnp.tanh(x))])
        blocks = fuse_blocks(g)
        res = [Resource("cloud0", "cloud", CLOUD_VM)]
        db = benchmark_model(g, res, AnalyticProvider(), runs=1,
                             blocks=blocks)
        assert lint_db_against_graph(db, blocks) == []
        db.records["cloud0"][0].output_bytes = 7       # tampered
        db.records["cloud0"][0].batch_profile[1] = (0.01, 7)
        diags = lint_db_against_graph(db, blocks)
        assert [d.code for d in diags] == ["SCN307"]


# ---------------------------------------------------------------------------
# acceptance property: empty result => error diagnostic (and soundness)
# ---------------------------------------------------------------------------

def _assert_empty_implies_error(seed):
    eng, query = _random_engine_and_query(seed)
    for result in (eng.run(query),
                   eng.frontier(query, strategy="exhaustive"),
                   eng.frontier(query, strategy="lattice")):
        rendered = [d.render() for d in result.diagnostics]
        if not result.configs:
            assert has_errors(result.diagnostics), \
                f"empty result carried no error diagnostic: {rendered}"
        else:
            # soundness: an error-severity finding must imply infeasibility
            assert not has_errors(result.diagnostics), \
                f"non-empty result carried an error: {rendered}"


@pytest.mark.parametrize("seed", range(40))
def test_empty_result_always_carries_error_diagnostic(seed):
    _assert_empty_implies_error(seed)


if HAVE_HYPOTHESIS:
    @given(st.integers(0, 10 ** 9))
    @settings(max_examples=30, deadline=None)
    def test_empty_result_error_diagnostic_property(seed):
        _assert_empty_implies_error(seed)
