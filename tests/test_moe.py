"""MoE: sort-based dispatch vs the one-hot GShard oracle, capacity
semantics, load-balance aux loss, gradients."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import layers as L
from repro.models.moe import _capacity, moe, moe_spec, pad_experts


def _setup(n_experts=12, top_k=2, d=32, d_ff=16, shared=0, key=0):
    spec = moe_spec(d, d_ff, n_experts, n_shared=1 if shared else 0,
                    d_shared=shared, pad_to=4)
    params = L.init_tree(spec, jax.random.PRNGKey(key), jnp.float32)
    return params


class TestEquivalence:
    @pytest.mark.parametrize("shape,group", [((2, 16), 16), ((4, 32), 64)])
    @pytest.mark.parametrize("top_k", [1, 2, 4])
    def test_sort_matches_onehot(self, shape, group, top_k):
        B, S = shape
        d = 32
        params = _setup(top_k=top_k)
        x = jax.random.normal(jax.random.PRNGKey(1), (B, S, d), jnp.float32)
        kw = dict(top_k=top_k, n_experts=12, activation="silu",
                  group_size=group)
        y1, a1 = moe(params, x, impl="onehot", **kw)
        y2, a2 = moe(params, x, impl="sort", **kw)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                                   rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(float(a1), float(a2), rtol=1e-5)

    def test_sort_matches_onehot_with_shared_expert(self):
        params = _setup(shared=24, key=3)
        x = jax.random.normal(jax.random.PRNGKey(2), (2, 16, 32))
        kw = dict(top_k=2, n_experts=12, group_size=16)
        y1, _ = moe(params, x, impl="onehot", **kw)
        y2, _ = moe(params, x, impl="sort", **kw)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                                   rtol=1e-4, atol=1e-5)

    def test_overflow_dropping_consistent(self):
        """With a tiny capacity factor both impls drop the same slots."""
        params = _setup(top_k=4, key=5)
        x = jax.random.normal(jax.random.PRNGKey(6), (2, 32, 32))
        kw = dict(top_k=4, n_experts=12, group_size=64,
                  capacity_factor=0.25)
        y1, _ = moe(params, x, impl="onehot", **kw)
        y2, _ = moe(params, x, impl="sort", **kw)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                                   rtol=1e-4, atol=1e-5)


class TestSemantics:
    def test_capacity_alignment(self):
        assert _capacity(512, 64, 4, 1.25) % 8 == 0
        assert _capacity(8, 64, 1, 1.0) == 8      # floor

    def test_padded_experts_never_routed(self):
        params = _setup(n_experts=12)   # padded to 12->12 (pad_to=4)
        # force pad: use 10 real of 12 padded
        x = jax.random.normal(jax.random.PRNGKey(7), (2, 16, 32))
        y, _ = moe(params, x, top_k=2, n_experts=10, group_size=16,
                   impl="sort")
        assert np.all(np.isfinite(np.asarray(y)))

    def test_pad_experts(self):
        assert pad_experts(60) == 64
        assert pad_experts(40) == 48
        assert pad_experts(16) == 16

    def test_gradients_flow_both_impls(self):
        params = _setup()
        x = jax.random.normal(jax.random.PRNGKey(8), (2, 16, 32))

        for impl in ("onehot", "sort"):
            def loss(p):
                y, aux = moe(p, x, top_k=2, n_experts=12, group_size=16,
                             impl=impl)
                return jnp.sum(y ** 2) + 0.01 * aux

            g = jax.grad(loss)(params)
            flat = jax.tree.leaves(g)
            assert all(np.all(np.isfinite(np.asarray(t, np.float32)))
                       for t in flat), impl
            total = sum(float(jnp.sum(jnp.abs(t.astype(jnp.float32))))
                        for t in flat)
            assert total > 0, impl

    def test_uniform_router_balanced_aux(self):
        """With a zero router (uniform probs) aux = E·Σ f_e·p̄_e = Σ f_e =
        top_k exactly — the balanced floor of the Switch aux loss."""
        params = _setup()
        params["router"] = jnp.zeros_like(params["router"])
        x = jax.random.normal(jax.random.PRNGKey(9), (2, 32, 32))
        _, aux = moe(params, x, top_k=2, n_experts=12, group_size=64,
                     impl="sort")
        assert float(aux) == pytest.approx(2.0, rel=1e-3)
