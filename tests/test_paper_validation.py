"""Paper-claim validation on a deterministic (analytic) testbed.

The wall-clock versions of these scenarios run in benchmarks/ (they depend
on host speed); here the same decision engine is driven by the analytic
provider so the paper's qualitative claims are asserted deterministically:

C1 (Figs 6-8)  — the optimum flips with network conditions;
C2 (Fig 9)     — the optimum is sensitive to input size;
C3 (Figs 10-11)— 'use the whole pipeline' changes the split;
C4 (Figs 12-14)— edge hardware changes the split;
C5 (Tab IV)    — top-N rankings are consistent and pipeline-restricted;
C6 (§III-B)    — querying cached benchmark data is <50 ms.
"""

import time

import jax
import jax.numpy as jnp
import pytest

from repro.core import (AnalyticProvider, Query, Resource, Scission,
                        paper_network, THREE_G, FOUR_G, WIRED)
from repro.core.resources import (CLOUD_VM, EDGE_BOX_1, EDGE_BOX_2, GTX_1070,
                                  RPI4)
from repro.models import cnn_zoo


def make_scission(link):
    res = [
        Resource("device", "device", RPI4),
        Resource("edge1", "edge", EDGE_BOX_1),
        Resource("edge2", "edge", EDGE_BOX_2),
        Resource("cloud", "cloud", CLOUD_VM),
        Resource("cloud_gpu", "cloud", GTX_1070),
    ]
    net = paper_network(link, edges=("edge1", "edge2"),
                        clouds=("cloud", "cloud_gpu"))
    return Scission(resources=res, network=net, source="device",
                    provider=AnalyticProvider(), runs=1)


@pytest.fixture(scope="module")
def graphs():
    return {n: cnn_zoo.build(n)
            for n in ("MobileNetV2", "ResNet50", "InceptionV3", "VGG16")}


@pytest.fixture(scope="module")
def scissions(graphs):
    out = {}
    for name, link in (("3g", THREE_G), ("4g", FOUR_G), ("wired", WIRED)):
        s = make_scission(link)
        for g in graphs.values():
            s.benchmark(g)
        out[name] = s
    return out


class TestC1NetworkFlip:
    def test_mobilenet_flips_device_to_cloud(self, scissions):
        best_3g = scissions["3g"].best("MobileNetV2")
        best_wired = scissions["wired"].best("MobileNetV2")
        # slow uplink -> stay on device; fast uplink -> offload everything
        assert best_3g.resources == ("device",)
        assert best_wired.resources[-1] in ("cloud", "cloud_gpu")

    def test_cloud_fraction_monotone_in_bandwidth(self, scissions):
        def cloud_blocks(cfg):
            return sum(s.end - s.start + 1 for s in cfg.segments
                       if s.resource.startswith("cloud"))

        per_net = [cloud_blocks(scissions[n].best("ResNet50"))
                   for n in ("3g", "4g", "wired")]
        assert per_net == sorted(per_net)


class TestC2InputSize:
    def test_larger_input_shifts_away_from_cloud(self, scissions):
        s = scissions["3g"]

        def offload_bytes(cfg):
            return cfg.transfer_bytes

        small = s.query("MobileNetV2", Query(top_n=1),
                        input_bytes=50e3).best
        huge = s.query("MobileNetV2", Query(top_n=1),
                       input_bytes=5e6).best
        # with a huge input the plan must not ship more data than before
        assert offload_bytes(huge) <= max(offload_bytes(small), 5e6)
        # and specifically: tiny input -> offloading attractive; huge input
        # over 3G -> device-native
        assert huge.resources == ("device",)


class TestC3Constraints:
    def test_full_pipeline_constraint_changes_split(self, scissions):
        s = scissions["4g"]
        free = s.best("ResNet50")
        forced = s.query(
            "ResNet50",
            Query(top_n=1, must_use=("device", "edge1", "cloud_gpu"),
                  exclude=("edge2", "cloud"))).best
        assert set(forced.resources) == {"device", "edge1", "cloud_gpu"}
        assert forced.latency_s >= free.latency_s


class TestC4EdgeHardware:
    def test_edge_choice_can_change_partition(self, scissions):
        s = scissions["wired"]
        q1 = Query(top_n=1, must_use=("edge1",), exclude=("edge2",))
        q2 = Query(top_n=1, must_use=("edge2",), exclude=("edge1",))
        b1 = s.query("InceptionV3", q1).best
        b2 = s.query("InceptionV3", q2).best
        # both are valid plans on their pipelines; latency reflects the
        # hardware difference (edge2 is the faster box in the paper)
        assert b1.latency_s != b2.latency_s


class TestC5TopN:
    def test_topn_pipeline_restriction(self, scissions):
        s = scissions["wired"]
        res = s.query("ResNet50",
                      Query(top_n=3, pipelines=(("edge1", "cloud_gpu"),)))
        assert 0 < len(res.configs) <= 3
        for cfg in res.configs:
            assert cfg.resources == ("edge1", "cloud_gpu")
        lats = [c.latency_s for c in res.configs]
        assert lats == sorted(lats)


class TestC6QueryBudget:
    def test_under_50ms_warm(self, scissions):
        s = scissions["4g"]
        s.query("VGG16")      # warm
        t0 = time.perf_counter()
        s.query("VGG16", Query(top_n=3, must_use=("edge1",)))
        assert time.perf_counter() - t0 < 0.05
