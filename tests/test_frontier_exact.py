"""Exact Pareto-frontier lattice (ParetoLattice) vs the exhaustive oracle,
plus the lattice/query/network regression fixes that shipped with it.

The hypothesis property fabricates benchmark DBs with *dyadic* times and
power-of-two bandwidths so every cost-model sum/max/division is exact in
float64 — vector-set comparisons between strategies can then use exact
equality, which is the acceptance bar: on every space where the exhaustive
oracle is tractable, the lattice frontier's objective-vector set equals the
exhaustive ``pareto_frontier``'s, with ε = 0, across batch sizes × replica
budgets and under must_use / exclude / pin / max_link_bytes constraints.
"""

import itertools

import numpy as np
import pytest

from repro.core import (BenchmarkDB, Constraints, CostModel, LATENCY, Link,
                        NetworkModel, ParetoLattice, Query, QueryEngine,
                        Resource, THROUGHPUT, dominates,
                        enumerate_partitions, objective_vector,
                        pareto_frontier, rank)
from repro.core.bench import BlockBenchmark
from repro.core.network import LOOPBACK
from repro.core.partition import BottleneckLattice, _nondominated_rows
from repro.core.resources import CLOUD_VM, EDGE_BOX_1, RPI4
import repro.core.query as query_mod

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # degrade to the deterministic tests only
    HAVE_HYPOTHESIS = False

DEVICE_MODELS = {"device": RPI4, "edge": EDGE_BOX_1, "cloud": CLOUD_VM}


_vec = objective_vector


def _make_db(model, n_blocks, resources, times, out_bytes, batches=(1,)):
    """Fabricate a BenchmarkDB directly (no jax tracing): ``times`` maps
    (resource, block, batch) -> seconds, ``out_bytes`` maps block -> bytes
    at batch 1 (scaled linearly for larger batches, like the real
    harness)."""
    db = BenchmarkDB(model=model, n_blocks=n_blocks)
    for r in resources:
        recs = []
        for b in range(n_blocks):
            profile = {bt: (times[(r.name, b, bt)], out_bytes[b] * bt)
                       for bt in batches}
            recs.append(BlockBenchmark(
                block=b, resource=r.name, mean_time_s=profile[1][0],
                std_time_s=0.0, output_bytes=out_bytes[b], runs=1,
                batch_profile=profile))
        db.records[r.name] = recs
    return db


def _grid_space(n_blocks=5, n_edge=2, n_cloud=1, batches=(1,)):
    """A small deterministic space with real trade-offs: dyadic times that
    differ per tier, a default link plus a couple of explicit ones."""
    res = [Resource("device0", "device", RPI4)]
    res += [Resource(f"edge{i}", "edge", EDGE_BOX_1) for i in range(n_edge)]
    res += [Resource(f"cloud{i}", "cloud", CLOUD_VM) for i in range(n_cloud)]
    times = {}
    for ri, r in enumerate(res):
        for b in range(n_blocks):
            for bt in batches:
                times[(r.name, b, bt)] = \
                    ((b + 2) * (ri + 1) % 7 + 1) * bt / (1 << 6)
    out_bytes = [((3 * b + 1) % 5 + 1) * (1 << 12) for b in range(n_blocks)]
    db = _make_db("grid", n_blocks, res, times, out_bytes, batches)
    net = NetworkModel(default=Link("d", 1 / (1 << 6), float(1 << 20)))
    net.connect("device0", "edge0", Link("a", 1 / (1 << 8), float(1 << 22)))
    net.connect("edge0", "cloud0", Link("b", 1 / (1 << 7), float(1 << 24)))
    eng = QueryEngine(db, res, net, source="device0", input_bytes=float(1 << 14))
    return eng


class TestParetoLatticeExact:
    """Lattice frontier == exhaustive frontier (vector-set equality)."""

    def test_unconstrained_matches_oracle(self):
        eng = _grid_space()
        cost = eng.cost
        got = {_vec(c) for c in ParetoLattice(cost).solve()}
        want = {_vec(c) for c in pareto_frontier(enumerate_partitions(cost))}
        assert got == want
        assert len(want) >= 2    # the space has a real trade-off surface

    @pytest.mark.parametrize("cons", [
        Constraints(must_use=("device0", "edge0", "cloud0")),
        Constraints(must_use=("edge1",)),
        Constraints(exclude=("edge0",)),
        Constraints(pin={2: "edge1"}),
        Constraints(max_link_bytes={("device0", "edge0"): float(1 << 13),
                                    ("device0", "cloud0"): float(1 << 13)}),
    ])
    def test_constrained_matches_oracle(self, cons):
        eng = _grid_space()
        cost = eng.cost
        got = {_vec(c) for c in ParetoLattice(cost, cons).solve()}
        want = {_vec(c) for c in pareto_frontier(
            [c for c in enumerate_partitions(cost)
             if eng._config_satisfies(c, cons, cost)])}
        assert got == want

    def test_engine_strategies_agree_across_operating_points(self):
        eng = _grid_space(batches=(1, 2))
        q = Query(replicas={"device0": 2, "edge0": 2})
        exh = eng.frontier(q, strategy="exhaustive")
        lat = eng.frontier(q, strategy="lattice")
        assert exh.strategy == "exhaustive" and lat.strategy == "lattice"
        assert {_vec(c) for c in lat.configs} == {_vec(c) for c in exh.configs}
        # the mix of batches on the frontier is preserved too
        assert {(c.batch_size, _vec(c)) for c in lat.configs} == \
            {(c.batch_size, _vec(c)) for c in exh.configs}
        # statistics surface only on the lattice strategy
        assert lat.labels_kept > 0
        assert exh.labels_kept == 0 and exh.labels_pruned == 0

    def test_engine_strategies_agree_on_overlapping_pipelines(self):
        eng = _grid_space()
        pipes = (("device0", "edge0"), ("device0", "edge0", "cloud0"),
                 ("device0", "cloud0"), ("edge0", "cloud0"))
        q = Query(pipelines=pipes)
        exh = eng.frontier(q, strategy="exhaustive")
        lat = eng.frontier(q, strategy="lattice")
        assert exh.configs, "restricted space must not be empty"
        assert {_vec(c) for c in lat.configs} == {_vec(c) for c in exh.configs}

    def test_unknown_strategy_rejected(self):
        eng = _grid_space()
        with pytest.raises(ValueError, match="strategy"):
            eng.frontier(Query(), strategy="bogus")

    def test_negative_epsilon_rejected(self):
        with pytest.raises(ValueError, match="frontier_epsilon"):
            Query(frontier_epsilon=-0.1)
        with pytest.raises(ValueError, match="epsilon"):
            ParetoLattice(_grid_space().cost, epsilon=-1e-3)

    def test_epsilon_bounds_labels_and_error(self):
        eng = _grid_space(n_blocks=6, n_edge=2, n_cloud=2)
        cost = eng.cost
        exact = ParetoLattice(cost)
        exact_front = exact.solve()
        eps = 0.25
        approx = ParetoLattice(cost, epsilon=eps)
        approx_front = approx.solve()
        assert approx.labels_kept <= exact.labels_kept
        assert 0 < len(approx_front) <= len(exact_front)
        # coverage: every exact-front point has an approximate point within
        # the compounded multiplicative bound in every objective
        bound = (1.0 + eps) ** cost.n_blocks
        for q in (_vec(c) for c in exact_front):
            assert any(all(p[i] <= bound * q[i] + 1e-12 for i in range(3))
                       for p in (_vec(c) for c in approx_front))
        # every approximate point is a genuine configuration of the space
        space = {_vec(c) for c in enumerate_partitions(cost)}
        assert {_vec(c) for c in approx_front} <= space

    def test_nondominated_rows_basic(self):
        pts = np.array([[1.0, 2.0], [2.0, 1.0], [2.0, 2.0], [1.0, 2.0],
                        [0.5, 3.0]])
        keep = _nondominated_rows(pts)
        # duplicates collapse to one representative; [2,2] is dominated
        assert [tuple(p) for p in pts[keep]] == \
            [(1.0, 2.0), (2.0, 1.0), (0.5, 3.0)]
        # ε-pruning keeps one representative of ε-close rows
        keep_eps = _nondominated_rows(np.array([[1.0, 1.0], [1.05, 1.05]]),
                                      eps=0.1)
        assert len(keep_eps) == 1


class TestSatelliteFixes:
    def test_pipelines_as_lists_not_silently_empty(self):
        """Regression: a pipe supplied as a list enumerated configs and then
        filtered every one of them out (raw-vs-normalized comparison)."""
        eng = _grid_space()
        want = eng.run(Query(top_n=3, pipelines=(("device0", "cloud0"),)))
        got = eng.run(Query(top_n=3, pipelines=[["device0", "cloud0"]]))
        assert got.configs, "list-shaped pipelines must not return []"
        assert [c.segments for c in got.configs] == \
            [c.segments for c in want.configs]
        # frontier path, both strategies
        for strategy in ("exhaustive", "lattice"):
            f_want = eng.frontier(Query(pipelines=(("device0", "cloud0"),)),
                                  strategy=strategy)
            f_got = eng.frontier(Query(pipelines=[["device0", "cloud0"]]),
                                 strategy=strategy)
            assert f_got.configs
            assert {_vec(c) for c in f_got.configs} == \
                {_vec(c) for c in f_want.configs}

    def test_pipelines_as_lists_on_lattice_run(self, monkeypatch):
        eng = _grid_space()
        want = eng.run(Query(top_n=3, pipelines=(("device0", "cloud0"),)))
        monkeypatch.setattr(query_mod, "EXHAUSTIVE_LIMIT", -1)
        lat_eng = _grid_space()
        got = lat_eng.run(Query(top_n=3, pipelines=[["device0", "cloud0"]]))
        assert got.strategy == "lattice" and got.configs
        # ties are common in the grid space, so compare objective values
        assert [c.latency_s for c in got.configs] == \
            [c.latency_s for c in want.configs]
        for c in got.configs:
            assert c.resources == ("device0", "cloud0")

    def test_bottleneck_tie_break_returns_min_latency(self):
        """Regression: reconstruction used to stop at ``top_n * 2`` configs
        *before* the (bottleneck, latency) tie-break sort, so when many
        paths tie on the bottleneck (input hop dominates) a lower-latency
        config could be cut and a strictly worse one returned."""
        res = [Resource("device0", "device", RPI4)]
        res += [Resource(f"edge{i}", "edge", EDGE_BOX_1) for i in range(4)]
        res += [Resource("cloud0", "cloud", CLOUD_VM)]
        n_blocks = 3
        times = {}
        for ri, r in enumerate(res):
            for b in range(n_blocks):
                # device so slow that no device-using config can tie; edges
                # get slower with their index; the cloud is fastest — so
                # the tied configs span a wide range of latencies and the
                # lowest-latency one (all-cloud) sorts *last* among the
                # finals' insertion order
                t = 6.0 if ri == 0 else float(8 - ri) / (1 << 6)
                times[(r.name, b, 1)] = t
        out_bytes = [1 << 8] * n_blocks
        db = _make_db("ties", n_blocks, res, times, out_bytes)
        # a slow access link + a large input make the input hop the shared
        # bottleneck of every off-device config
        net = NetworkModel(default=Link("slow", 1.0, float(1 << 16)))
        cost = CostModel(db=db, resources=res, network=net, source="device0",
                         input_bytes=float(1 << 18))
        configs = enumerate_partitions(cost)
        # the scenario is only meaningful if many configs tie on bottleneck
        best_b = min(c.bottleneck_s for c in configs)
        tied = [c for c in configs if c.bottleneck_s == best_b]
        assert len(tied) > 2, "scenario must produce > top_n*2 ties"
        oracle = min(tied, key=lambda c: c.latency_s)
        got = BottleneckLattice(cost).solve(top_n=1)[0]
        assert got.bottleneck_s == pytest.approx(best_b)
        assert got.latency_s == pytest.approx(oracle.latency_s)
        assert got.resources == ("cloud0",)

    @pytest.mark.parametrize("q", [
        Query(must_use=("nosuch",)),                       # unknown name
        Query(must_use=("edge0",), exclude=("edge0",)),    # self-excluded
    ])
    def test_unsatisfiable_must_use_consistent_across_strategies(self, q):
        """Regression: the lattices silently dropped must_use entries that
        were unknown or excluded, returning the *unconstrained* results
        where the exhaustive strategy correctly returns [] — on fleet-sized
        spaces (lattice default) a typoed must_use yielded a frontier that
        ignored the constraint."""
        eng = _grid_space()
        assert eng.run(q).configs == []
        for strategy in ("exhaustive", "lattice"):
            assert eng.frontier(q, strategy=strategy).configs == []
        cost, cons = eng.cost, q.constraints()
        from repro.core import BottleneckLattice, PartitionLattice
        assert ParetoLattice(cost, cons).solve() == []
        assert PartitionLattice(cost, cons).solve(top_n=3) == []
        assert BottleneckLattice(cost, cons).solve(top_n=3) == []

    def test_network_explicit_self_link_honored(self):
        staging = Link("staging", 1e-3, 1e9)
        net = NetworkModel().connect("host", "host", staging)
        assert net.link("host", "host") is staging
        assert net.comm_time("host", "host", 1e6) == \
            pytest.approx(1e-3 + 1e6 / 1e9)
        # implicit self-links stay free
        assert net.link("other", "other") is LOOPBACK
        assert net.comm_time("other", "other", 1e9) == 0.0


class TestElasticFrontierMode:
    def _scission(self, link):
        from repro.core import Scission, AnalyticProvider, linear_graph
        from repro.core.graph import LayerNode
        import jax, jax.numpy as jnp
        layers = [LayerNode(f"l{i}", "dense",
                            apply=lambda x: x * 1.0,
                            flops=float((i + 1) * 5e7)) for i in range(5)]
        g = linear_graph("toy-el", jax.ShapeDtypeStruct((1, 8), jnp.float32),
                         layers)
        res = [Resource("device", "device", RPI4, speed_factor=30.0),
               Resource("edge1", "edge", EDGE_BOX_1, speed_factor=3.0),
               Resource("cloud", "cloud", CLOUD_VM, speed_factor=1.0)]
        net = NetworkModel(default=link)
        s = Scission(resources=res, network=net, source="device",
                     provider=AnalyticProvider(), runs=1)
        s.benchmark(g)
        return s

    def test_track_frontier_reports_surface_movement(self):
        from repro.runtime.elastic import ElasticController, frontier_shift
        s = self._scission(Link("l", 0.01, 1e6))
        ctl = ElasticController(s, "toy-el", query=Query(top_n=1),
                                track_frontier=True)
        ev0 = ctl.history[0]
        assert ev0.frontier is not None and ev0.frontier_size >= 1
        assert ctl.last_frontier_shift() is None   # only one plan so far
        ev1 = ctl.on_network_change(NetworkModel(default=Link("f", 0.0, 1e12)))
        assert ev1.frontier is not None
        shift = ctl.last_frontier_shift()
        assert shift is not None
        assert shift["added"] or shift["removed"] or shift["kept"]
        # a near-free network shrinks the surface toward the all-cloud point
        assert shift == frontier_shift(ev0.frontier, ev1.frontier)
        assert set(shift) == {"added", "removed", "kept"}

    def test_frontier_mode_off_by_default(self):
        from repro.runtime.elastic import ElasticController
        s = self._scission(Link("l", 0.01, 1e6))
        ctl = ElasticController(s, "toy-el")
        assert ctl.history[0].frontier is None
        assert ctl.history[0].frontier_size == 0
        assert ctl.last_frontier_shift() is None


# ---------------------------------------------------------------------------
# randomized property: small spaces, exact vector-set equality.  One
# seed-driven generator serves both a deterministic parametrized sweep
# (always runs, executable in hypothesis-less containers) and a hypothesis
# amplifier that explores many more seeds when the package is available.
# ---------------------------------------------------------------------------

def _random_engine_and_query(seed):
    """A random small space with dyadic times and power-of-two bandwidths
    (so every cost-model sum/max/division is exact in float64), plus a
    random DP-exact constraint / replica budget / batch sweep."""
    rng = np.random.default_rng(seed)
    n_blocks = int(rng.integers(3, 7))
    batches = (1,) if rng.integers(2) else (1, 2)
    res = [Resource("device0", "device", RPI4)]
    res += [Resource(f"edge{i}", "edge", EDGE_BOX_1)
            for i in range(int(rng.integers(0, 3)))]
    res += [Resource(f"cloud{i}", "cloud", CLOUD_VM)
            for i in range(int(rng.integers(1, 3)))]
    names = [r.name for r in res]
    times = {}
    for r in names:
        for b in range(n_blocks):
            t1 = int(rng.integers(1, 1 << 10)) / (1 << 10)
            times[(r, b, 1)] = t1
            if 2 in batches:
                times[(r, b, 2)] = t1 + int(rng.integers(0, 1 << 10)) / (1 << 10)
    out_bytes = [int(rng.integers(1, 1 << 14)) for _ in range(n_blocks)]
    db = _make_db("rand", n_blocks, res, times, out_bytes, batches)

    def link(tag):
        return Link(tag, int(rng.integers(0, 1 << 6)) / (1 << 10),
                    float(1 << int(rng.integers(14, 23))))

    net = NetworkModel(default=link("d"))
    for a, b in itertools.permutations(names, 2):
        if rng.random() < 0.4:
            net.connect(a, b, link(f"{a}-{b}"), symmetric=False)
    eng = QueryEngine(db, res, net, source="device0",
                      input_bytes=float(rng.integers(1, 1 << 16)))
    # constraints: the DP-exact kinds from the acceptance criteria
    kind = ["none", "must_use", "exclude", "pin", "max_link"][
        int(rng.integers(5))]
    kw = {}
    if kind == "must_use":
        k = int(rng.integers(1, min(3, len(names)) + 1))
        kw["must_use"] = tuple(rng.choice(names, size=k, replace=False))
    elif kind == "exclude" and len(names) > 1:
        kw["exclude"] = (str(rng.choice(names[1:])),)
    elif kind == "pin":
        kw["pin"] = {int(rng.integers(n_blocks)): str(rng.choice(names))}
    elif kind == "max_link":
        a, b = rng.choice(names, size=2, replace=False)
        kw["max_link_bytes"] = {(str(a), str(b)):
                                float(rng.integers(1, 1 << 15))}
    if rng.integers(2):
        kw["replicas"] = {str(rng.choice(names)): 2}
    return eng, Query(batch_sizes=batches, **kw)


def _assert_lattice_equals_exhaustive(seed):
    """Acceptance property: on randomized small spaces (with and without
    constraints and replica budgets, across measured batch sizes) the
    lattice frontier's objective-vector set equals the exhaustive Pareto
    set exactly at ε = 0."""
    eng, query = _random_engine_and_query(seed)
    exh = eng.frontier(query, strategy="exhaustive")
    lat = eng.frontier(query, strategy="lattice")
    assert {_vec(c) for c in lat.configs} == {_vec(c) for c in exh.configs}
    # soundness of the oracle itself: nothing returned is dominated
    for c in exh.configs:
        assert not any(dominates(o, c) for o in exh.configs)


def _assert_epsilon_covers_exact(seed, eps=0.2):
    """With ε > 0 every exact-front point is within the compounded
    (1+ε)^B multiplicative bound of some returned point."""
    import dataclasses
    eng, query = _random_engine_and_query(seed)
    exact = eng.frontier(query, strategy="lattice")
    approx = eng.frontier(dataclasses.replace(query, frontier_epsilon=eps),
                          strategy="lattice")
    assert approx.labels_kept <= exact.labels_kept
    bound = (1.0 + eps) ** eng.db.n_blocks
    for q in (_vec(c) for c in exact.configs):
        assert any(all(p[i] <= bound * q[i] + 1e-12 for i in range(3))
                   for p in (_vec(c) for c in approx.configs))


@pytest.mark.parametrize("seed", range(20))
def test_lattice_frontier_equals_exhaustive_frontier(seed):
    _assert_lattice_equals_exhaustive(seed)


@pytest.mark.parametrize("seed", range(8))
def test_epsilon_frontier_covers_exact_front(seed):
    _assert_epsilon_covers_exact(seed)


if HAVE_HYPOTHESIS:
    @given(st.integers(0, 10 ** 9))
    @settings(max_examples=30, deadline=None)
    def test_lattice_frontier_property(seed):
        _assert_lattice_equals_exhaustive(seed)

    @given(st.integers(0, 10 ** 9))
    @settings(max_examples=10, deadline=None)
    def test_epsilon_frontier_property(seed):
        _assert_epsilon_covers_exact(seed)
