"""Per-architecture smoke tests: reduced config, one forward/train/decode
step on CPU, asserting output shapes and no NaNs (assignment requirement)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models import build_model, config_names, get_config

ARCHS = ["gemma2-9b", "starcoder2-15b", "gemma-7b", "granite-8b",
         "zamba2-2.7b", "xlstm-125m", "whisper-medium", "internvl2-76b",
         "qwen2-moe-a2.7b", "granite-moe-3b-a800m"]


def reduce_cfg(cfg: ModelConfig) -> ModelConfig:
    """Shrink every dimension while preserving the architectural family:
    same pattern/kinds, small widths, few layers, tiny vocab."""
    period = len(cfg.pattern)
    n_layers = (cfg.shared_attn_period * 2 if cfg.shared_attn_period
                else period * 2)
    kw = dict(
        n_layers=n_layers,
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 4) if cfg.n_kv_heads < cfg.n_heads
        else 4,
        head_dim=16,
        d_ff=0 if cfg.d_ff == 0 else 128,
        vocab=256,
        window=8 if cfg.window else None,
        moe_experts=8 if cfg.moe_experts else 0,
        moe_top_k=min(cfg.moe_top_k, 2) if cfg.moe_top_k else 0,
        moe_shared_dff=64 if cfg.moe_shared_dff else 0,
        moe_group_size=64,
        ssm_state=8,
        ssm_head_dim=8,
        ssm_chunk=8,
        encoder_layers=2 if cfg.is_encdec else 0,
        encoder_len=16 if cfg.is_encdec else cfg.encoder_len,
        n_img_tokens=4 if cfg.n_img_tokens else 0,
        q_chunk=16,
        loss_seq_chunk=None,
        query_pre_attn_scalar=(16.0 if cfg.query_pre_attn_scalar else None),
        remat=False,
    )
    return cfg.replace(**kw)


def make_batch(cfg, key, batch=2, seq=32):
    ks = jax.random.split(key, 3)
    tokens = jax.random.randint(ks[0], (batch, seq), 0, cfg.vocab)
    labels = jax.random.randint(ks[1], (batch, seq), 0, cfg.vocab)
    out = {"tokens": tokens, "labels": labels}
    if cfg.is_encdec:
        out["frames"] = jax.random.normal(
            ks[2], (batch, cfg.encoder_len, cfg.d_model)).astype(jnp.bfloat16)
    elif cfg.n_img_tokens:
        out["patch_embeds"] = jax.random.normal(
            ks[2], (batch, cfg.n_img_tokens, cfg.d_model)
        ).astype(jnp.bfloat16)
    return out


@pytest.mark.parametrize("arch", ARCHS)
class TestSmoke:
    def _setup(self, arch):
        cfg = reduce_cfg(get_config(arch))
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        return cfg, model, params

    def test_loss_finite(self, arch):
        cfg, model, params = self._setup(arch)
        batch = make_batch(cfg, jax.random.PRNGKey(1))
        loss, metrics = jax.jit(model.loss)(params, batch)
        assert loss.shape == ()
        assert np.isfinite(float(loss)), (arch, float(loss))
        # untrained loss should be near log(vocab)
        assert float(metrics["nll"]) < 3 * np.log(cfg.vocab)

    def test_train_step_updates_and_finite(self, arch):
        cfg, model, params = self._setup(arch)
        batch = make_batch(cfg, jax.random.PRNGKey(2))

        def loss_fn(p):
            return model.loss(p, batch)[0]

        loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
        assert np.isfinite(float(loss))
        flat = jax.tree.leaves(grads)
        assert all(np.all(np.isfinite(np.asarray(g, np.float32)))
                   for g in flat), arch
        # at least some gradient signal
        gnorm = sum(float(jnp.sum(jnp.square(g.astype(jnp.float32))))
                    for g in flat)
        assert gnorm > 0, arch

    def test_prefill_decode(self, arch):
        cfg, model, params = self._setup(arch)
        batch = make_batch(cfg, jax.random.PRNGKey(3), batch=2, seq=16)
        max_len = 32
        cache = model.init_cache(batch=2, max_len=max_len)
        kw = {}
        if cfg.is_encdec:
            kw["frames"] = batch["frames"]
        elif cfg.n_img_tokens:
            kw["patch_embeds"] = batch["patch_embeds"]
        logits, cache = jax.jit(model.prefill)(params, batch["tokens"],
                                               cache, **kw)
        assert logits.shape == (2, 1, cfg.vocab)
        assert np.all(np.isfinite(np.asarray(logits, np.float32))), arch

        prompt_len = 16 + (cfg.n_img_tokens or 0)
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        step = jax.jit(model.decode_step)
        logits2, cache = step(params, tok, cache, jnp.int32(prompt_len))
        assert logits2.shape == (2, 1, cfg.vocab)
        assert np.all(np.isfinite(np.asarray(logits2, np.float32))), arch


def test_all_assigned_archs_registered():
    names = config_names()
    for a in ARCHS:
        assert a in names, a


def test_full_configs_match_assignment():
    """The full (non-reduced) configs carry the exact assigned dimensions."""
    want = {
        "gemma2-9b": (42, 3584, 16, 8, 14336, 256000),
        "starcoder2-15b": (40, 6144, 48, 4, 24576, 49152),
        "gemma-7b": (28, 3072, 16, 16, 24576, 256000),
        "granite-8b": (36, 4096, 32, 8, 14336, 49152),
        "zamba2-2.7b": (54, 2560, 32, 32, 10240, 32000),
        "internvl2-76b": (80, 8192, 64, 8, 28672, 128256),
        "qwen2-moe-a2.7b": (24, 2048, 16, 16, 1408, 151936),
    }
    for name, (L, d, h, kv, ff, vocab) in want.items():
        c = get_config(name)
        assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
                c.vocab) == (L, d, h, kv, ff, vocab), name
    assert get_config("xlstm-125m").n_layers == 12
    assert get_config("whisper-medium").encoder_layers == 24
    assert get_config("granite-moe-3b-a800m").moe_experts == 40
    assert get_config("granite-moe-3b-a800m").moe_top_k == 8
