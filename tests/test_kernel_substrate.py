"""Kernel substrate layer: compat shim, cost normalizer, pad-and-mask
parity on uneven shapes, and the block-size autotuner."""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (AnalyticProvider, BenchmarkDB, Resource,
                        TimingProvider, benchmark_model, fuse_blocks,
                        linear_graph)
from repro.core.resources import CLOUD_VM
from repro.kernels import substrate
from repro.kernels.ops import (decode_attention, flash_attention,
                               flash_attention_node, ssd_scan, ssd_scan_node)
from repro.kernels.ref import (decode_attention_ref, flash_attention_ref,
                               ssd_ref)
from repro.kernels.substrate import (KernelAutotuner, TuneRecord,
                                     normalize_cost_analysis, pad_axis_to,
                                     resolve_compiler_params_cls, round_up,
                                     tpu_compiler_params)

TOL32 = dict(rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# compat shim
# ---------------------------------------------------------------------------

class TestCompilerParamsShim:
    def test_resolves_on_installed_jax(self):
        """Whatever the installed JAX calls it, the shim must find it."""
        from jax.experimental.pallas import tpu as pltpu
        cls = resolve_compiler_params_cls()
        assert cls is not None
        assert cls in (getattr(pltpu, "CompilerParams", None),
                       getattr(pltpu, "TPUCompilerParams", None))

    def test_constructs_with_dimension_semantics(self):
        params = tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary"))
        assert params is not None

    def test_unknown_kwargs_dropped(self):
        params = tpu_compiler_params(
            dimension_semantics=("parallel",),
            kwarg_from_a_future_jax_version=42)
        assert params is not None
        assert not hasattr(params, "kwarg_from_a_future_jax_version") or \
            getattr(params, "kwarg_from_a_future_jax_version", None) != 42


# ---------------------------------------------------------------------------
# cost-analysis normalizer
# ---------------------------------------------------------------------------

class TestNormalizeCostAnalysis:
    def test_dict_passthrough(self):
        got = normalize_cost_analysis({"flops": 10, "bytes accessed": 3.5})
        assert got == {"flops": 10.0, "bytes accessed": 3.5}

    def test_list_of_dicts_summed(self):
        got = normalize_cost_analysis([{"flops": 10.0, "bytes accessed": 4.0},
                                       {"flops": 5.0}])
        assert got == {"flops": 15.0, "bytes accessed": 4.0}

    def test_none_and_junk(self):
        assert normalize_cost_analysis(None) == {}
        assert normalize_cost_analysis("nope") == {}
        assert normalize_cost_analysis([{"flops": 1.0}, "junk"]) == \
            {"flops": 1.0}

    def test_real_compiled_artifact(self):
        lowered = jax.jit(lambda x: jnp.tanh(x @ x)).lower(
            jax.ShapeDtypeStruct((8, 8), jnp.float32))
        cost = normalize_cost_analysis(lowered.compile().cost_analysis())
        assert cost.get("flops", 0.0) >= 2 * 8 * 8 * 8


# ---------------------------------------------------------------------------
# pad helpers
# ---------------------------------------------------------------------------

class TestPadHelpers:
    def test_round_up(self):
        assert round_up(200, 128) == 256
        assert round_up(256, 128) == 256
        assert round_up(1, 128) == 128
        with pytest.raises(ValueError):
            round_up(5, 0)

    def test_pad_axis_to(self):
        x = jnp.ones((2, 5, 3))
        y = pad_axis_to(x, 1, 8)
        assert y.shape == (2, 8, 3)
        np.testing.assert_array_equal(np.asarray(y[:, 5:]), 0.0)
        assert pad_axis_to(x, 1, 5) is x
        with pytest.raises(ValueError):
            pad_axis_to(x, 1, 4)


# ---------------------------------------------------------------------------
# uneven-shape parity vs reference kernels (CPU interpret mode)
# ---------------------------------------------------------------------------

class TestUnevenShapeParity:
    @pytest.mark.parametrize("causal", [True, False])
    @pytest.mark.parametrize("Sq,Sk", [(200, 200), (384, 200), (130, 257)])
    def test_flash_uneven(self, Sq, Sk, causal):
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (1, Sq, 4, 64))
        k = jax.random.normal(ks[1], (1, Sk, 2, 64))
        v = jax.random.normal(ks[2], (1, Sk, 2, 64))
        got = flash_attention(q, k, v, causal=causal, block_q=128,
                              block_k=128, interpret=True)
        want = flash_attention_ref(q, k, v, causal=causal)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL32)

    def test_flash_uneven_window_softcap(self):
        ks = jax.random.split(jax.random.PRNGKey(1), 3)
        q = jax.random.normal(ks[0], (1, 300, 4, 64))
        k = jax.random.normal(ks[1], (1, 300, 2, 64))
        v = jax.random.normal(ks[2], (1, 300, 2, 64))
        got = flash_attention(q, k, v, causal=True, window=70, softcap=30.0,
                              block_q=128, block_k=128, interpret=True)
        want = flash_attention_ref(q, k, v, causal=True, window=70,
                                   softcap=30.0)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL32)

    def test_decode_uneven_cache(self):
        ks = jax.random.split(jax.random.PRNGKey(2), 4)
        B, Smax, H, Hk, hd = 2, 300, 4, 2, 64
        q = jax.random.normal(ks[0], (B, H, hd))
        k = jax.random.normal(ks[1], (B, Smax, Hk, hd))
        v = jax.random.normal(ks[2], (B, Smax, Hk, hd))
        lengths = jnp.array([300, 123], jnp.int32)
        got = decode_attention(q, k, v, lengths, block_k=256, interpret=True)
        want = decode_attention_ref(q, k, v, lengths)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL32)

    def test_decode_padding_never_leaks(self):
        """Values in the padded tail must not affect the output."""
        ks = jax.random.split(jax.random.PRNGKey(3), 3)
        B, Smax, H, hd = 1, 200, 2, 64
        q = jax.random.normal(ks[0], (B, H, hd))
        k = jax.random.normal(ks[1], (B, Smax, H, hd))
        v = jax.random.normal(ks[2], (B, Smax, H, hd))
        lengths = jnp.array([Smax], jnp.int32)
        got = decode_attention(q, k, v, lengths, block_k=256, interpret=True)
        want = decode_attention_ref(q, k, v, lengths)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL32)

    @pytest.mark.parametrize("S,chunk", [(200, 128), (130, 64), (257, 128)])
    def test_ssd_uneven(self, S, chunk):
        ks = jax.random.split(jax.random.PRNGKey(4), 4)
        B, H, P, N = 1, 2, 32, 16
        x = jax.random.normal(ks[0], (B, S, H, P))
        log_a = -jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
        b = jax.random.normal(ks[2], (B, S, H, N))
        c = jax.random.normal(ks[3], (B, S, H, N))
        y, fin = ssd_scan(x, log_a, b, c, chunk=chunk, interpret=True)
        y_ref, fin_ref = ssd_ref(x, log_a, b, c)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(np.asarray(fin), np.asarray(fin_ref),
                                   rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# autotuner
# ---------------------------------------------------------------------------

def _fake_measure(best_params):
    """Deterministic measurement: ``best_params`` wins, everything else is
    slower in proportion to its distance from it."""
    best_key = json.dumps(best_params, sort_keys=True)

    def measure(fn, args):
        params = getattr(fn, "_params", None)
        if params is None:
            return 1.0
        return 0.1 if json.dumps(params, sort_keys=True) == best_key else 1.0
    return measure


def _tagged_factory(params):
    def fn(x):
        return x
    fn._params = dict(params)
    return fn


class TestKernelAutotuner:
    def test_picks_winner_and_caches(self):
        tuner = KernelAutotuner(measure=_fake_measure({"block_q": 64,
                                                       "block_k": 64}))
        args = (jnp.zeros((1, 8)),)
        rec = tuner.tune("flash_attention", _tagged_factory, args,
                         resource="cloud")
        assert rec.params == {"block_q": 64, "block_k": 64}
        assert rec.changed_default        # default is (128, 128)
        assert rec.default_time_s > rec.time_s
        # cached: same key returns the same record object
        assert tuner.tune("flash_attention", _tagged_factory, args,
                          resource="cloud") is rec
        # different resource -> separate sweep
        rec2 = tuner.tune("flash_attention", _tagged_factory, args,
                          resource="device")
        assert rec2 is not rec

    def test_trials_shared_across_resources(self):
        """Per-resource records, but the (host wall-clock) trial table is
        measured once — not once per resource."""
        calls = []

        def counting_measure(fn, args):
            calls.append(fn._params)
            return 1.0

        tuner = KernelAutotuner(measure=counting_measure)
        args = (jnp.zeros((1, 4)),)
        tuner.tune("ssd_scan", _tagged_factory, args, resource="edge1")
        n = len(calls)
        assert n > 0
        tuner.tune("ssd_scan", _tagged_factory, args, resource="cloud")
        assert len(calls) == n      # second resource reused the trials

    def test_config_key_separates_same_shape_nodes(self):
        """Same input shapes, different kernel options -> separate sweeps."""
        tuner = KernelAutotuner(measure=lambda fn, args: 1.0)
        args = (jnp.zeros((1, 4)),)
        r1 = tuner.tune("ssd_scan", _tagged_factory, args,
                        config_key='{"causal": true}')
        r2 = tuner.tune("ssd_scan", _tagged_factory, args,
                        config_key='{"causal": false}')
        assert r1 is not r2
        assert r1.shape_key != r2.shape_key

    def test_failed_candidates_skipped(self):
        def factory(params):
            if params.get("chunk") != 64:
                raise ValueError("unsupported block shape")
            return _tagged_factory(params)

        tuner = KernelAutotuner(measure=lambda fn, args: 0.5)
        rec = tuner.tune("ssd_scan", factory, (jnp.zeros((1, 4)),))
        assert rec.params == {"chunk": 64}

    def test_json_roundtrip(self):
        tuner = KernelAutotuner(measure=_fake_measure({"chunk": 32}))
        tuner.tune("ssd_scan", _tagged_factory, (jnp.zeros((2, 2)),))
        back = KernelAutotuner.from_json(tuner.to_json())
        assert len(back.records) == 1
        rec = next(iter(back.records.values()))
        assert isinstance(rec, TuneRecord)
        assert rec.params == {"chunk": 32}

    def test_wall_clock_tune_real_kernel(self):
        """End-to-end wall-clock sweep of the real flash kernel (small shape,
        two candidates) — must pick *some* candidate and rewrite the node."""
        node = flash_attention_node(interpret=True)
        g = linear_graph("attn-toy",
                         jax.ShapeDtypeStruct((1, 96, 2, 32), jnp.float32),
                         [node])
        tuner = KernelAutotuner(
            candidates={"flash_attention": [{"block_q": 32, "block_k": 32},
                                            {"block_q": 96, "block_k": 96}]},
            runs=1)
        blocks = fuse_blocks(g)
        recs = tuner.tune_block(blocks[-1], resource="cloud")
        assert len(recs) == 1
        assert recs[0].params in ({"block_q": 32, "block_k": 32},
                                  {"block_q": 96, "block_k": 96},
                                  {"block_q": 128, "block_k": 128})
        assert node.kernel_params == recs[0].params


class TestTunedTimingsFlowIntoDB:
    def test_benchmark_records_carry_tuned_params(self):
        node = ssd_scan_node(state_dim=8, interpret=True)
        g = linear_graph("ssd-toy",
                         jax.ShapeDtypeStruct((1, 64, 1, 16), jnp.float32),
                         [node])
        res = [Resource("cloud", "cloud", CLOUD_VM, speed_factor=1.0)]
        tuner = KernelAutotuner(
            candidates={"ssd_scan": [{"chunk": 16}, {"chunk": 64}]}, runs=1)
        db = benchmark_model(g, res, TimingProvider(tuner=tuner), runs=1)
        recs = [r for r in db.records["cloud"] if r.tuned_params]
        assert recs, "no benchmark record carries tuned block sizes"
        tuned = next(iter(recs[0].tuned_params.values()))
        assert "chunk" in tuned
        # tuned params survive the DB's JSON round-trip (offline contract)
        db2 = BenchmarkDB.from_json(db.to_json())
        recs2 = [r for r in db2.records["cloud"] if r.tuned_params]
        assert recs2 and recs2[0].tuned_params == recs[0].tuned_params

    def test_untuned_provider_keeps_empty_params(self):
        node = ssd_scan_node(state_dim=8, interpret=True)
        g = linear_graph("ssd-toy2",
                         jax.ShapeDtypeStruct((1, 64, 1, 16), jnp.float32),
                         [node])
        res = [Resource("cloud", "cloud", CLOUD_VM, speed_factor=1.0)]
        db = benchmark_model(g, res, AnalyticProvider(), runs=1)
        assert all(not r.tuned_params for r in db.records["cloud"])
