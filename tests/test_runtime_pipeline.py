"""Pipeline executor + LM graph adapter + elastic controller integration."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (AnalyticProvider, Query, Resource, Scission,
                        paper_network, FOUR_G, fuse_blocks)
from repro.core.resources import CLOUD_VM, EDGE_BOX_1, RPI4
from repro.models import build_model, get_config, cnn_zoo
from repro.models.graph_adapter import lm_to_graph
from repro.runtime.elastic import ElasticController
from repro.runtime.pipeline import PipelineExecutor


def _scission():
    res = [Resource("device", "device", RPI4),
           Resource("edge1", "edge", EDGE_BOX_1),
           Resource("cloud", "cloud", CLOUD_VM)]
    net = paper_network(FOUR_G, edges=("edge1",), clouds=("cloud",))
    return Scission(resources=res, network=net, source="device",
                    provider=AnalyticProvider(), runs=1)


@pytest.fixture(scope="module")
def small_lm():
    cfg = get_config("granite-8b").replace(
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=128, remat=False, q_chunk=32, loss_seq_chunk=None)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


class TestGraphAdapter:
    def test_lm_graph_structure(self, small_lm):
        cfg, model, params = small_lm
        g = lm_to_graph(model, params, batch=2, seq_len=16)
        # input + embed + 3 groups + head
        assert g.n_layers == 2 + cfg.n_groups + 1
        blocks = fuse_blocks(g)
        assert len(blocks) >= cfg.n_groups

    def test_adapter_matches_model(self, small_lm):
        cfg, model, params = small_lm
        g = lm_to_graph(model, params, batch=2, seq_len=16)
        tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                    cfg.vocab)
        x = tokens
        for b in fuse_blocks(g):
            x = b.make_callable()(x)
        hidden, _ = model.forward(params, tokens)
        from repro.models import layers as L
        want = L.unembed(params["embed"], hidden[:, -1:])
        assert (np.argmax(np.asarray(x), -1)
                == np.argmax(np.asarray(want), -1)).all()


class TestPipelineExecutor:
    def test_executes_partition(self, small_lm):
        cfg, model, params = small_lm
        g = lm_to_graph(model, params, batch=2, seq_len=16)
        s = _scission()
        s.benchmark(g)
        best = s.query(g.name, Query(
            top_n=1, must_use=("device", "edge1", "cloud")),
            input_bytes=2 * 16 * 4).best
        assert len(best.segments) == 3
        ex = PipelineExecutor(g, best, s.network, source="device")
        tokens = jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0,
                                    cfg.vocab)
        out, timings = ex.run(tokens, collect_timing=True)
        assert out.shape == (2, 1, cfg.vocab)
        assert len(timings) == 3
        assert all(t.compute_s > 0 for t in timings)
        # comm is charged when crossing resources (stage 2 and 3)
        assert timings[1].comm_in_s > 0 and timings[2].comm_in_s > 0

    def test_cnn_pipeline(self):
        g = cnn_zoo.build("MobileNet")
        s = _scission()
        s.benchmark(g)
        best = s.best("MobileNet")
        ex = PipelineExecutor(g, best, s.network, source="device")
        x = jnp.zeros(g.input_spec.shape, g.input_spec.dtype)
        out, _ = ex.run(x)
        assert out.shape == (1, 1000)
        np.testing.assert_allclose(float(jnp.sum(out)), 1.0, rtol=1e-3)


class TestElastic:
    def test_drain_and_rejoin_replans(self):
        g = cnn_zoo.build("MobileNet")
        s = _scission()
        s.benchmark(g)
        ctl = ElasticController(s, "MobileNet", graph=g)
        first = ctl.current
        ev = ctl.on_resource_lost("cloud")
        assert "cloud" not in ev.config.resources
        new = Resource("cloud2", "cloud", CLOUD_VM)
        ev2 = ctl.on_resource_joined(new)
        assert ev2.config.latency_s <= ev.config.latency_s + 1e-9
        assert len(ctl.history) == 3

    def test_plan_survives_all_but_one(self):
        g = cnn_zoo.build("MobileNet")
        s = _scission()
        s.benchmark(g)
        ctl = ElasticController(s, "MobileNet", graph=g)
        ctl.on_resource_lost("cloud")
        ev = ctl.on_resource_lost("edge1")
        assert ev.config.resources == ("device",)
