"""Pipeline executor + LM graph adapter + elastic controller integration."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (AnalyticProvider, Query, Resource, Scission,
                        paper_network, FOUR_G, fuse_blocks)
from repro.core.resources import CLOUD_VM, EDGE_BOX_1, RPI4
from repro.models import build_model, get_config, cnn_zoo
from repro.models.graph_adapter import lm_to_graph
from repro.runtime.elastic import ElasticController
from repro.runtime.pipeline import PipelineExecutor


def _scission():
    res = [Resource("device", "device", RPI4),
           Resource("edge1", "edge", EDGE_BOX_1),
           Resource("cloud", "cloud", CLOUD_VM)]
    net = paper_network(FOUR_G, edges=("edge1",), clouds=("cloud",))
    return Scission(resources=res, network=net, source="device",
                    provider=AnalyticProvider(), runs=1)


@pytest.fixture(scope="module")
def small_lm():
    cfg = get_config("granite-8b").replace(
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=128, remat=False, q_chunk=32, loss_seq_chunk=None)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


class TestGraphAdapter:
    def test_lm_graph_structure(self, small_lm):
        cfg, model, params = small_lm
        g = lm_to_graph(model, params, batch=2, seq_len=16)
        # input + embed + 3 groups + head
        assert g.n_layers == 2 + cfg.n_groups + 1
        blocks = fuse_blocks(g)
        assert len(blocks) >= cfg.n_groups

    def test_adapter_matches_model(self, small_lm):
        cfg, model, params = small_lm
        g = lm_to_graph(model, params, batch=2, seq_len=16)
        tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                    cfg.vocab)
        x = tokens
        for b in fuse_blocks(g):
            x = b.make_callable()(x)
        hidden, _ = model.forward(params, tokens)
        from repro.models import layers as L
        want = L.unembed(params["embed"], hidden[:, -1:])
        assert (np.argmax(np.asarray(x), -1)
                == np.argmax(np.asarray(want), -1)).all()


class TestPipelineExecutor:
    def test_executes_partition(self, small_lm):
        cfg, model, params = small_lm
        g = lm_to_graph(model, params, batch=2, seq_len=16)
        s = _scission()
        s.benchmark(g)
        best = s.query(g.name, Query(
            top_n=1, must_use=("device", "edge1", "cloud")),
            input_bytes=2 * 16 * 4).best
        assert len(best.segments) == 3
        ex = PipelineExecutor(g, best, s.network, source="device")
        tokens = jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0,
                                    cfg.vocab)
        out, timings = ex.run(tokens, collect_timing=True)
        assert out.shape == (2, 1, cfg.vocab)
        assert len(timings) == 3
        assert all(t.compute_s > 0 for t in timings)
        # comm is charged when crossing resources (stage 2 and 3)
        assert timings[1].comm_in_s > 0 and timings[2].comm_in_s > 0

    def test_cnn_pipeline(self):
        g = cnn_zoo.build("MobileNet")
        s = _scission()
        s.benchmark(g)
        best = s.best("MobileNet")
        ex = PipelineExecutor(g, best, s.network, source="device")
        x = jnp.zeros(g.input_spec.shape, g.input_spec.dtype)
        out, _ = ex.run(x)
        assert out.shape == (1, 1000)
        np.testing.assert_allclose(float(jnp.sum(out)), 1.0, rtol=1e-3)


class TestElastic:
    def test_drain_and_rejoin_replans(self):
        g = cnn_zoo.build("MobileNet")
        s = _scission()
        s.benchmark(g)
        ctl = ElasticController(s, "MobileNet", graph=g)
        first = ctl.current
        ev = ctl.on_resource_lost("cloud")
        assert "cloud" not in ev.config.resources
        new = Resource("cloud2", "cloud", CLOUD_VM)
        ev2 = ctl.on_resource_joined(new)
        assert ev2.config.latency_s <= ev.config.latency_s + 1e-9
        assert len(ctl.history) == 3

    def test_plan_survives_all_but_one(self):
        g = cnn_zoo.build("MobileNet")
        s = _scission()
        s.benchmark(g)
        ctl = ElasticController(s, "MobileNet", graph=g)
        ctl.on_resource_lost("cloud")
        ev = ctl.on_resource_lost("edge1")
        assert ev.config.resources == ("device",)

    def test_join_without_graph_fails_fast(self):
        """Regression: joining an unbenchmarked resource with graph=None
        used to succeed and KeyError on the very next re-plan."""
        g = cnn_zoo.build("MobileNet")
        s = _scission()
        s.benchmark(g)
        ctl = ElasticController(s, "MobileNet", graph=None)
        new = Resource("edge9", "edge", EDGE_BOX_1)
        with pytest.raises(ValueError, match="edge9"):
            ctl.on_resource_joined(new)
        # the failed join must not corrupt the membership view
        assert all(r.name != "edge9" for r in ctl.scission.resources)
        ctl.on_resource_lost("edge1")          # re-planning still works

    def test_join_without_graph_ok_when_already_benchmarked(self):
        """A resource with existing records may join without a graph."""
        g = cnn_zoo.build("MobileNet")
        s_full = _scission()
        db = s_full.benchmark(g)
        res2 = [r for r in s_full.resources if r.name != "cloud"]
        s = Scission(resources=res2, network=s_full.network, source="device",
                     provider=AnalyticProvider(), runs=1)
        s.load(db)                   # full DB — cloud records included
        ctl = ElasticController(s, "MobileNet", graph=None)
        ev = ctl.on_resource_joined(Resource("cloud", "cloud", CLOUD_VM))
        assert "cloud" in {r.name for r in ctl.scission.resources}
        assert ev.config.latency_s > 0

    def test_with_resources_keeps_partial_db(self):
        """Regression: with_resources used to silently drop a model's whole
        DB when any new resource lacked records; now the partial DB is kept
        and querying names the unbenchmarked resource."""
        g = cnn_zoo.build("MobileNet")
        s = _scission()
        s.benchmark(g)
        newcomer = Resource("edge9", "edge", EDGE_BOX_1)
        s2 = s.with_resources([*s.resources, newcomer])
        assert "MobileNet" in s2._dbs          # partial DB survives
        with pytest.raises(ValueError, match="edge9.*MobileNet"):
            s2.query("MobileNet")
        # benchmarking the newcomer heals the engine
        s2.benchmark_resource(g, newcomer)
        assert s2.best("MobileNet").latency_s > 0

    def test_plan_events_record_both_metrics(self):
        from repro.core import THROUGHPUT
        g = cnn_zoo.build("MobileNet")
        s = _scission()
        s.benchmark(g)
        ctl = ElasticController(s, "MobileNet", graph=g,
                                query=Query(top_n=1, objective=THROUGHPUT))
        ev = ctl.on_resource_lost("edge1")
        for e in ctl.history:
            assert e.latency_s == pytest.approx(e.config.latency_s)
            assert e.throughput_rps == pytest.approx(
                e.config.throughput_rps)
        # throughput objective: the survivor plan maximises throughput
        assert ev.throughput_rps > 0
